"""Fault-tolerant checkpointing.

Atomic protocol: write ``step_N.npz.tmp`` + sha256 manifest, fsync, rename.
``restore_latest`` scans for the newest checkpoint whose manifest hash
verifies, so a preemption mid-write (torn .tmp) or a corrupted file falls
back to the previous valid step — this is what the kill-and-resume test
exercises. Checkpoints store *logical* (unsharded) arrays + the flat pytree
paths, so they are mesh-independent: a restore onto a different device
count / mesh shape re-shards on load (elastic restart).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    # tree_util spelling: jax.tree.flatten_with_path is absent on jax 0.4.x
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> Path:
        """state: any pytree of arrays. Returns final checkpoint path."""
        named = _flatten_with_paths(state)
        arrays = {f"a{i}": np.asarray(jax.device_get(x))
                  for i, (_, x) in enumerate(named)}
        paths = [p for p, _ in named]

        final = self.dir / f"step_{step:010d}.npz"
        tmp = final.with_suffix(".npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, __paths__=np.asarray(json.dumps(paths)), **arrays)
            f.flush()
            os.fsync(f.fileno())
        digest = _sha256(tmp)
        os.replace(tmp, final)                      # atomic publish
        manifest = final.with_suffix(".json")
        manifest_tmp = manifest.with_suffix(".json.tmp")
        manifest_tmp.write_text(json.dumps(
            dict(step=step, file=final.name, sha256=digest,
                 time=time.time())))
        os.replace(manifest_tmp, manifest)
        self._gc()
        return final

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".json").unlink(missing_ok=True)

    # --------------------------------------------------------------- restore
    def _candidates(self):
        steps = []
        for mf in self.dir.glob("step_*.json"):
            m = re.match(r"step_(\d+)\.json", mf.name)
            if m:
                steps.append((int(m.group(1)), mf))
        return sorted(steps, reverse=True)

    def latest_step(self) -> int | None:
        for step, mf in self._candidates():
            if self._verify(mf):
                return step
        return None

    def _verify(self, manifest: Path) -> bool:
        try:
            meta = json.loads(manifest.read_text())
            ckpt = self.dir / meta["file"]
            return ckpt.exists() and _sha256(ckpt) == meta["sha256"]
        except Exception:
            return False

    def restore(self, state_like, step: int | None = None):
        """Restore into the structure of ``state_like`` (shapes/tree must
        match; sharding/mesh may differ). Returns (state, step) or
        (None, None) when no valid checkpoint exists."""
        cands = self._candidates()
        if step is not None:
            cands = [(s, m) for s, m in cands if s == step]
        for s, mf in cands:
            if not self._verify(mf):
                continue  # torn/corrupt -> fall back to older
            meta = json.loads(mf.read_text())
            with np.load(self.dir / meta["file"], allow_pickle=False) as z:
                paths = json.loads(str(z["__paths__"]))
                arrays = [z[f"a{i}"] for i in range(len(paths))]
            leaves, treedef = jax.tree.flatten(state_like)
            assert len(leaves) == len(arrays), \
                f"checkpoint has {len(arrays)} leaves, state {len(leaves)}"
            out = []
            for ref, arr in zip(leaves, arrays):
                a = np.asarray(arr)
                assert tuple(ref.shape) == a.shape, (ref.shape, a.shape)
                sharding = getattr(ref, "sharding", None)
                if sharding is not None and hasattr(ref, "dtype"):
                    out.append(jax.device_put(a.astype(ref.dtype), sharding))
                else:
                    out.append(a.astype(ref.dtype))
            return jax.tree.unflatten(treedef, out), s
        return None, None
