"""Trainer: step execution + checkpoint/restart + elastic re-mesh.

Fault-tolerance model (DESIGN §5):
  * checkpoint every ``ckpt_every`` steps through the atomic manager;
  * on (re)start, ``run`` restores the newest *valid* checkpoint and replays
    the data stream from that step (pipelines are step-keyed, so the stream
    position is implied by the step counter — no separate data state);
  * ``remesh(new_mesh)`` re-resolves shardings for a different device count
    and re-jits — elastic scale-up/down after node loss; checkpoints are
    mesh-independent so a dead node only costs progress since the last save;
  * straggler mitigation is data re-balancing: batches are keyed by
    (step, host), so the host->slice assignment can be permuted without
    changing the global batch (exercised in tests by dropping a host).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import (Arch, make_step, param_builders,
                                step_arg_specs)
from repro.data.pipeline import make_batch
from repro.distributed.sharding import tree_shardings
from repro.optim.adamw import init_opt_state
from repro.train.checkpoint import CheckpointManager


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str | None = None
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, arch: Arch, shape_id: str, mesh=None,
                 cfg: TrainerConfig = TrainerConfig()):
        self.arch = arch
        self.shape = arch.shape(shape_id)
        assert self.shape.kind == "train", "Trainer drives train shapes"
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = (CheckpointManager(cfg.ckpt_dir)
                     if cfg.ckpt_dir else None)
        self.metrics_log: list[dict] = []
        self._build()

    # ------------------------------------------------------------------ build
    def _build(self):
        init_fn, _ = param_builders(self.arch, self.shape)
        params, _specs = init_fn(jax.random.PRNGKey(self.cfg.seed))
        opt_state = init_opt_state(params, self.arch.opt)
        step_fn = make_step(self.arch, self.shape)
        if self.mesh is not None and self.mesh.size > 1:
            args_shapes, args_specs = step_arg_specs(self.arch, self.shape)
            shardings = tree_shardings(args_shapes, args_specs, self.mesh)
            params = jax.device_put(params, shardings[0])
            opt_state = jax.device_put(opt_state, shardings[1])
            self._batch_sharding = shardings[2]
            self._jit = jax.jit(step_fn, in_shardings=shardings,
                                donate_argnums=(0, 1))
        else:
            self._batch_sharding = None
            self._jit = jax.jit(step_fn, donate_argnums=(0, 1))
        self.params, self.opt_state = params, opt_state
        self.step = 0

    # ------------------------------------------------------------- lifecycle
    def maybe_restore(self) -> int:
        if self.ckpt is None:
            return 0
        state, step = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state})
        if state is not None:
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = step
        return self.step

    def save(self):
        if self.ckpt is not None:
            self.ckpt.save(self.step,
                           {"params": self.params, "opt": self.opt_state})

    def remesh(self, new_mesh):
        """Elastic restart on a different mesh: host-gather state, re-resolve
        shardings, re-jit. State values are preserved exactly."""
        params = jax.device_get(self.params)
        opt = jax.device_get(self.opt_state)
        step = self.step
        self.mesh = new_mesh
        self._build()
        # overwrite freshly-initialised values with the carried-over state
        self.params = jax.tree.map(lambda ref, v: jax.device_put(
            np.asarray(v), ref.sharding), self.params, params)
        self.opt_state = jax.tree.map(lambda ref, v: jax.device_put(
            np.asarray(v), ref.sharding), self.opt_state, opt)
        self.step = step

    # ------------------------------------------------------------------- run
    def run_step(self):
        batch = make_batch(self.arch, self.shape, self.step,
                           seed=self.cfg.seed)
        if self._batch_sharding is not None:
            batch = jax.device_put(batch, self._batch_sharding)
        self.params, self.opt_state, metrics = self._jit(
            self.params, self.opt_state, batch)
        self.step += 1
        return metrics

    def run(self, steps: int | None = None):
        steps = steps or self.cfg.steps
        self.maybe_restore()
        t0 = time.time()
        while self.step < steps:
            metrics = self.run_step()
            if self.step % self.cfg.log_every == 0 or self.step == steps:
                m = {k: float(np.asarray(jax.device_get(v)))
                     for k, v in metrics.items()}
                m.update(step=self.step, wall=round(time.time() - t0, 3))
                self.metrics_log.append(m)
                print(f"step {self.step:5d} " + " ".join(
                    f"{k}={v:.5g}" for k, v in m.items() if k != "step"),
                    flush=True)
            if self.ckpt is not None and self.step % self.cfg.ckpt_every == 0:
                self.save()
        if self.ckpt is not None:
            self.save()
        return self.metrics_log
