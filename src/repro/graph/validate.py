"""Graph500 BFS-tree validator (spec section 4 of the Graph500 benchmark).

Host-side numpy; rules:
  1. parent[root] == root and depth[root] == 0;
  2. every reached vertex chains to the root through parent pointers with no
     cycles, and tree edges exist in the graph;
  3. tree-edge endpoints differ by exactly one BFS level;
  4. every graph edge between reached vertices spans <= 1 level;
  5. the reached set is closed under graph edges (=> it is exactly the
     connected component of the root).
"""
from __future__ import annotations

import numpy as np


class ValidationError(AssertionError):
    pass


# Largest n for which the dense key src*n+dst stays inside int64:
# max key is n*n - 1, so n <= floor(sqrt(2**63 - 1)). Beyond that the key
# multiplication wraps SILENTLY (numpy int64 overflow) and membership
# tests return garbage — fuzzed/synthetic graphs with huge sparse id
# spaces must take the per-row bisect path instead.
_DENSE_KEY_N_MAX = 3_037_000_499


def _edges_exist_dense_key(row_ptr, col_idx, u, v) -> np.ndarray:
    """CSR rows are sorted by neighbour id, so the global key src*n+dst is
    globally sorted -> one searchsorted answers all queries. Only valid
    for n <= _DENSE_KEY_N_MAX (key must fit int64)."""
    n = len(row_ptr) - 1
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(row_ptr))
    keys = src * n + col_idx.astype(np.int64)
    q = u.astype(np.int64) * n + v.astype(np.int64)
    pos = np.searchsorted(keys, q)
    pos = np.clip(pos, 0, len(keys) - 1)
    return keys[pos] == q


def _edges_exist_bisect(row_ptr, col_idx, u, v) -> np.ndarray:
    """Overflow-safe membership: vectorised lower_bound of v[i] within
    row u[i]'s sorted adjacency slice — no n-dependent key arithmetic."""
    m = len(col_idx)
    if m == 0:
        return np.zeros(len(u), dtype=bool)
    lo = row_ptr[u].astype(np.int64)
    end = row_ptr[u.astype(np.int64) + 1].astype(np.int64)
    hi = end.copy()
    v64 = v.astype(np.int64)
    while True:
        live = lo < hi
        if not live.any():
            break
        mid = (lo + hi) >> 1
        midv = col_idx[np.clip(mid, 0, max(m - 1, 0))].astype(np.int64)
        go_right = live & (midv < v64)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(live & ~go_right, mid, hi)
    return (lo < end) & (col_idx[np.clip(lo, 0, max(m - 1, 0))] == v64)


def _edges_exist(row_ptr, col_idx, u, v) -> np.ndarray:
    """Vectorised membership test: is v[i] in adj(u[i])?"""
    n = len(row_ptr) - 1
    if n <= _DENSE_KEY_N_MAX:
        return _edges_exist_dense_key(row_ptr, col_idx, u, v)
    return _edges_exist_bisect(row_ptr, col_idx, u, v)


def depths_from_parents(parent: np.ndarray, root: int,
                        max_depth: int = 64) -> np.ndarray:
    """Depth of every reached vertex via pointer doubling; raises on cycles
    or chains that do not reach the root within ``max_depth`` levels."""
    parent = np.asarray(parent)
    n = len(parent)
    reached = parent >= 0
    ptr = np.where(reached, parent, root).astype(np.int64)
    ptr[root] = root
    dist = np.where(reached, 1, 0).astype(np.int64)
    dist[root] = 0
    rounds = 0
    while True:
        live = reached & (ptr != root)
        if not live.any():
            break
        rounds += 1
        if (1 << rounds) > 4 * max_depth:
            raise ValidationError("rule 2: parent pointers do not reach root")
        dist = dist + np.where(live, dist[ptr], 0)
        ptr = np.where(live, ptr[ptr], ptr)
    return np.where(reached, dist, -1).astype(np.int64)


def validate_bfs_tree(row_ptr: np.ndarray, col_idx: np.ndarray,
                      parent: np.ndarray, root: int) -> dict:
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    parent = np.asarray(parent)
    n = len(row_ptr) - 1
    reached = parent >= 0

    if not reached[root] or parent[root] != root:
        raise ValidationError("rule 1: root not its own parent")

    depth = depths_from_parents(parent, root)

    tree_v = np.flatnonzero(reached & (np.arange(n) != root))
    if len(tree_v):
        tree_p = parent[tree_v]
        if not reached[tree_p].all():
            raise ValidationError("rule 2: parent of reached vertex unreached")
        if not _edges_exist(row_ptr, col_idx, tree_v, tree_p).all():
            raise ValidationError("rule 2: tree edge missing from graph")
        if not (depth[tree_v] == depth[tree_p] + 1).all():
            raise ValidationError("rule 3: tree edge does not span one level")

    src = np.repeat(np.arange(n), np.diff(row_ptr))
    dst = col_idx
    if (reached[src] & ~reached[dst]).any():
        raise ValidationError("rule 5: reached set not edge-closed")
    both = reached[src] & reached[dst]
    if both.any() and np.abs(depth[src[both]] - depth[dst[both]]).max() > 1:
        raise ValidationError("rule 4: graph edge spans >1 level")

    return {"n_reached": int(reached.sum()), "max_depth": int(depth.max())}
