"""Graph500 experimental harness (paper §6).

Runs the benchmark protocol: generate a Kronecker graph, pick 64 random
roots (degree>0, as the reference code does), run BFS per root with the
compiled executable, collect per-root wall time and TEPS, and report the
harmonic mean (the paper's headline number) plus min/max/mean.

``batched=True`` answers ALL roots — ``num_roots`` is no longer clamped to
64 — in ONE invocation of the pipelined MS-BFS engine
(``repro.core.msbfs.msbfs_pipelined``): roots beyond the ``lanes`` bit-lane
pool wait in the engine's pending queue and refill lanes the moment a
traversal finishes, so there is no per-64-batch barrier. Per-root wall time
is the shared sweep time, and ``aggregate_teps`` (total edges over total
wall time) is the number to compare against the serial loop; because
``times`` holds the single pipelined sweep time, the refill overlap is
priced in automatically — idle-lane time never inflates the denominator
the way summing per-batch sweep times would.

TEPS counts the *undirected* edges of the traversed component
(sum of degrees of reached vertices / 2), per the Graph500 spec.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSRGraph, to_numpy_adj
from repro.core.hybrid import bfs
from repro.core.msbfs import MAX_LANES, adaptive_lane_pool, msbfs_pipelined
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree

# serial mode name -> MS-BFS controller mode
_BATCHED_MODE = {"hybrid": "hybrid", "hybrid_nosimd": "hybrid",
                 "topdown": "topdown", "bottomup_simd": "bottomup",
                 "bottomup_nosimd": "bottomup"}


@dataclass
class Graph500Result:
    scale: int
    edgefactor: int
    mode: str
    batched: bool = False
    lanes: int = 0               # bit-lane pool size of the batched engine
    ndev: int = 1                # devices the batched engine was sharded over
    teps: list[float] = field(default_factory=list)
    times: list[float] = field(default_factory=list)
    traversed: list[int] = field(default_factory=list)

    @property
    def harmonic_mean_teps(self) -> float:
        t = np.asarray([x for x in self.teps if x > 0])
        return float(len(t) / np.sum(1.0 / t)) if len(t) else 0.0

    @property
    def aggregate_teps(self) -> float:
        """Total traversed edges over total wall time — the serving-throughput
        number; for batched runs ``times`` holds the single sweep time."""
        total_t = float(np.sum(self.times))
        return float(np.sum(self.traversed)) / total_t if total_t > 0 else 0.0

    def summary(self) -> dict:
        t = np.asarray(self.teps)
        return dict(scale=self.scale, edgefactor=self.edgefactor,
                    mode=self.mode, batched=self.batched, lanes=self.lanes,
                    ndev=self.ndev, nroots=len(self.traversed),
                    harmonic_mean_teps=self.harmonic_mean_teps,
                    aggregate_teps=self.aggregate_teps,
                    mean_teps=float(t.mean()) if len(t) else 0.0,
                    max_teps=float(t.max()) if len(t) else 0.0,
                    min_teps=float(t.min()) if len(t) else 0.0,
                    mean_time=float(np.mean(self.times)) if self.times else 0.0)


def run_graph500(scale: int, edgefactor: int, mode: str = "hybrid",
                 num_roots: int = 64, seed: int = 0, validate: bool = False,
                 alpha: float = 14.0, beta: float = 24.0, max_pos: int = 8,
                 probe_impl: str = "xla", warmup: bool = True,
                 skip_empty_fallback: bool = True, td_impl: str = "edge",
                 graph: CSRGraph | None = None,
                 batched: bool = False,
                 lanes: int | None = MAX_LANES,
                 ndev: int = 1, mesh=None) -> Graph500Result:
    g = graph if graph is not None else rmat_graph(scale, edgefactor, seed)
    roots = sample_roots(g, num_roots, seed=seed + 1)
    if batched:
        if td_impl != "edge" or not skip_empty_fallback:
            raise ValueError(
                "batched=True does not support td_impl/skip_empty_fallback "
                "(the MS-BFS sweep has its own step formulations)")
        return _run_batched(g, roots, scale, edgefactor, mode, alpha, beta,
                            max_pos, probe_impl, warmup, validate, lanes,
                            ndev, mesh)
    if ndev > 1 or mesh is not None:
        raise ValueError("ndev > 1 requires batched=True (the sharded "
                         "engine is the MS-BFS one)")
    res = Graph500Result(scale=scale, edgefactor=edgefactor, mode=mode)

    run = lambda r: bfs(g, r, mode, alpha, beta, max_pos, probe_impl,
                        skip_empty_fallback, td_impl)
    if warmup:
        jax.block_until_ready(run(int(roots[0])))  # compile once

    rp, ci = (to_numpy_adj(g) if validate else (None, None))
    for r in roots:
        t0 = time.perf_counter()
        out = run(int(r))
        jax.block_until_ready(out.parent)
        dt = time.perf_counter() - t0
        edges = int(out.edges_traversed) // 2
        res.times.append(dt)
        res.traversed.append(edges)
        res.teps.append(edges / dt if dt > 0 else 0.0)
        if validate:
            validate_bfs_tree(rp, ci, np.asarray(out.parent), int(r))
    return res


def _run_batched(g: CSRGraph, roots: np.ndarray, scale: int, edgefactor: int,
                 mode: str, alpha: float, beta: float, max_pos: int,
                 probe_impl: str, warmup: bool, validate: bool,
                 lanes: int | None, ndev: int = 1,
                 mesh=None) -> Graph500Result:
    """ALL roots in one pipelined MS-BFS engine invocation.

    Roots stream through a pool of ``lanes`` bit-lanes: a finished lane is
    refilled from the pending queue on the next layer, so R > lanes costs
    extra traversal layers but no batch barrier and no extra compilation.
    ``lanes=None`` (or 0) sizes the pool adaptively from the root count
    and the graph's degree stats (``adaptive_lane_pool``).

    ``ndev > 1`` (or an explicit ``mesh``) runs the SHARDED engine
    (``repro.core.dist_msbfs``): the graph is 1-D partitioned and each
    device traverses its row block, frontiers OR-merged per layer. Needs
    that many jax devices (CI forces host devices via XLA_FLAGS).

    The result's ``mode`` records the MS-BFS controller actually executed
    (there is no packed nosimd variant — comparing a serial ``*_nosimd``
    run against a batched one would cross the paper's SIMD axis silently).
    """
    msbfs_mode = _BATCHED_MODE[mode]
    if not lanes:
        lanes = adaptive_lane_pool(len(roots), g.n, g.m)
    batch = jnp.asarray(roots, dtype=jnp.int32)
    if ndev > 1 or mesh is not None:
        from repro.core.dist_msbfs import (dist_msbfs, host_mesh,
                                           partition_graph)
        if mesh is None:
            mesh = host_mesh(ndev)
        else:
            ndev = int(np.prod(mesh.devices.shape))
        dg = partition_graph(g, ndev)
        run = lambda: dist_msbfs(dg, batch, mesh, msbfs_mode, alpha, beta,
                                 max_pos, probe_impl, lanes=lanes)
    else:
        run = lambda: msbfs_pipelined(g, batch, msbfs_mode, alpha, beta,
                                      max_pos, probe_impl, lanes)
    res = Graph500Result(scale=scale, edgefactor=edgefactor,
                         mode=msbfs_mode, batched=True, lanes=lanes,
                         ndev=ndev)
    rp_ci = to_numpy_adj(g) if validate else None
    if warmup:
        jax.block_until_ready(run())  # compile once per (shape, R, lanes)
    t0 = time.perf_counter()
    out = run()
    jax.block_until_ready(out.parent)
    dt = time.perf_counter() - t0
    edges = np.asarray(out.edges_traversed) // 2
    res.times.append(dt)
    res.traversed.extend(int(e) for e in edges)
    # per-root TEPS against the shared sweep time (the engine answers every
    # query within the one pipelined sweep); aggregate_teps is the headline
    res.teps.extend(float(e) / dt if dt > 0 else 0.0 for e in edges)
    if validate:
        parent = np.asarray(out.parent)
        for r_i, root in enumerate(roots):
            validate_bfs_tree(rp_ci[0], rp_ci[1], parent[:, r_i], int(root))
    return res
