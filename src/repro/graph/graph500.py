"""Graph500 experimental harness (paper §6).

Runs the benchmark protocol: generate a Kronecker graph, pick 64 random
roots (degree>0, as the reference code does), run BFS per root with the
compiled executable, collect per-root wall time and TEPS, and report the
harmonic mean (the paper's headline number) plus min/max/mean.

TEPS counts the *undirected* edges of the traversed component
(sum of degrees of reached vertices / 2), per the Graph500 spec.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.csr import CSRGraph, to_numpy_adj
from repro.core.hybrid import bfs
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree


@dataclass
class Graph500Result:
    scale: int
    edgefactor: int
    mode: str
    teps: list[float] = field(default_factory=list)
    times: list[float] = field(default_factory=list)
    traversed: list[int] = field(default_factory=list)

    @property
    def harmonic_mean_teps(self) -> float:
        t = np.asarray([x for x in self.teps if x > 0])
        return float(len(t) / np.sum(1.0 / t)) if len(t) else 0.0

    def summary(self) -> dict:
        t = np.asarray(self.teps)
        return dict(scale=self.scale, edgefactor=self.edgefactor,
                    mode=self.mode, nroots=len(t),
                    harmonic_mean_teps=self.harmonic_mean_teps,
                    mean_teps=float(t.mean()) if len(t) else 0.0,
                    max_teps=float(t.max()) if len(t) else 0.0,
                    min_teps=float(t.min()) if len(t) else 0.0,
                    mean_time=float(np.mean(self.times)) if self.times else 0.0)


def run_graph500(scale: int, edgefactor: int, mode: str = "hybrid",
                 num_roots: int = 64, seed: int = 0, validate: bool = False,
                 alpha: float = 14.0, beta: float = 24.0, max_pos: int = 8,
                 probe_impl: str = "xla", warmup: bool = True,
                 skip_empty_fallback: bool = True, td_impl: str = "edge",
                 graph: CSRGraph | None = None) -> Graph500Result:
    g = graph if graph is not None else rmat_graph(scale, edgefactor, seed)
    roots = sample_roots(g, num_roots, seed=seed + 1)
    res = Graph500Result(scale=scale, edgefactor=edgefactor, mode=mode)

    run = lambda r: bfs(g, r, mode, alpha, beta, max_pos, probe_impl,
                        skip_empty_fallback, td_impl)
    if warmup:
        jax.block_until_ready(run(int(roots[0])))  # compile once

    rp, ci = (to_numpy_adj(g) if validate else (None, None))
    for r in roots:
        t0 = time.perf_counter()
        out = run(int(r))
        jax.block_until_ready(out.parent)
        dt = time.perf_counter() - t0
        edges = int(out.edges_traversed) // 2
        res.times.append(dt)
        res.traversed.append(edges)
        res.teps.append(edges / dt if dt > 0 else 0.0)
        if validate:
            validate_bfs_tree(rp, ci, np.asarray(out.parent), int(r))
    return res
