"""Fanout neighbour sampler — capped BFS frontier expansion.

Produces the ``minibatch_lg`` training subgraph: seed batch -> sample up to
``fanout[0]`` neighbours per seed (layer 1) -> ``fanout[1]`` per layer-1
node (layer 2). This *is* the paper's frontier expansion with a per-vertex
probe budget: sampling position ``r`` in a row is exactly the bottom-up
LoadAdj gather with a random ``pos`` instead of a sequential one, and the
visited-dedup option reuses the core bitmaps.

Fully jittable (static shapes; with-replacement sampling, masked rows for
isolated vertices — standard GraphSAGE semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitmap
from repro.core.csr import CSRGraph
from repro.models.gnn.common import GraphBatch


def _sample_layer(key, g: CSRGraph, frontier: jnp.ndarray, fanout: int):
    """frontier int32[F] -> (neigh int32[F, fanout], valid bool[F, fanout])."""
    deg = g.deg[frontier]
    starts = g.row_ptr[frontier]
    r = jax.random.randint(key, (frontier.shape[0], fanout), 0, 1 << 30)
    pos = r % jnp.maximum(deg, 1)[:, None]
    idx = jnp.clip(starts[:, None] + pos, 0, g.m - 1)
    neigh = g.col_idx[idx]                       # the LoadAdj gather
    valid = (deg > 0)[:, None] & jnp.ones((1, fanout), jnp.bool_)
    return neigh, valid


@partial(jax.jit, static_argnames=("fanout",))
def sample_subgraph(key, g: CSRGraph, seeds: jnp.ndarray,
                    fanout: tuple[int, ...] = (15, 10)):
    """Returns (nodes int32[N_sub], senders, receivers, edge_mask) where
    edges point sampled-neighbour -> requesting node (message direction),
    in *local subgraph coordinates*; node ids are original graph ids.

    Layout: [seeds | layer1 | layer2 | ...]; layer l node j's slot is
    deterministic, so shapes are static for any seed batch.
    """
    layers = [seeds]
    senders, receivers, masks = [], [], []
    offset = 0
    frontier = seeds
    for li, f in enumerate(fanout):
        key, sub = jax.random.split(key)
        neigh, valid = _sample_layer(sub, g, frontier, f)
        n_f = frontier.shape[0]
        next_offset = offset + n_f
        dst_local = jnp.repeat(jnp.arange(n_f, dtype=jnp.int32) + offset, f)
        src_local = jnp.arange(n_f * f, dtype=jnp.int32) + next_offset
        senders.append(src_local)
        receivers.append(dst_local)
        masks.append(valid.reshape(-1))
        layers.append(neigh.reshape(-1))
        frontier = neigh.reshape(-1)
        offset = next_offset
    nodes = jnp.concatenate(layers)
    return (nodes, jnp.concatenate(senders), jnp.concatenate(receivers),
            jnp.concatenate(masks))


def sampled_graph_batch(key, g: CSRGraph, seeds, feats, labels,
                        fanout=(15, 10), n_classes: int = 41) -> GraphBatch:
    """Assemble a GraphBatch for the GNN train step from a sampled subgraph;
    features/labels gathered from the full-graph arrays."""
    nodes, senders, receivers, edge_mask = sample_subgraph(
        key, g, seeds, tuple(fanout))
    return GraphBatch(
        senders=senders, receivers=receivers, edge_mask=edge_mask,
        feats=feats[nodes], pos=jnp.zeros((nodes.shape[0], 3), jnp.float32),
        labels=labels[nodes], node_mask=jnp.ones_like(nodes, jnp.bool_),
        graph_ids=jnp.zeros_like(nodes), n_graphs=1)


def khop_node_sets(g: CSRGraph, seeds, k: int, **engine_kwargs):
    """Exact k-hop candidate pools for neighbour sampling — the fast path
    through the packed MS-BFS engine (``repro.analytics.khop``).

    Where ``sample_subgraph`` draws a *bounded random* neighbourhood
    (fanout caps, with replacement), this returns each seed's *complete*
    depth<=k neighbourhood: all seeds share ONE lane sweep, and the
    candidate sets are the packed frontier words sliced at depth <= k.
    Use it to build unbiased candidate pools (then subsample host-side) or
    to measure fanout-sampling coverage against the exact neighbourhood.

    Returns ``(node_sets, khop_result)`` — ``node_sets[i]`` is the
    ascending int64 vertex-id array within ``k`` hops of ``seeds[i]``
    (seed included); ``khop_result`` keeps the packed words / counts /
    depths for packed consumers. ``engine_kwargs`` pass through to the
    analytics ``LaneEngine`` (``ndev=``, ``lanes=``, ...).
    """
    from repro.analytics.khop import khop_neighborhood
    res = khop_neighborhood(g, seeds, k, **engine_kwargs)
    sets = [res.members(i) for i in range(res.sources.size)]
    return sets, res


def dedup_count(nodes, n_total: int) -> jnp.ndarray:
    """Unique-vertex count via the core bitmap (instrumentation: measures
    sampling redundancy the way the BFS visited bitmap would)."""
    words = bitmap.set_bits(
        jnp.zeros((bitmap.num_words(n_total),), jnp.uint32), nodes)
    return bitmap.popcount_words(words)
