"""Graph500 Kronecker (R-MAT) graph generator.

Follows the Graph500 reference spec: ``n = 2**scale`` vertices,
``m = 2**scale * edgefactor`` undirected edges, initiator probabilities
A=0.57, B=0.19, C=0.19, D=0.05, followed by a random vertex relabelling and
edge-order shuffle (so vertex id carries no structural information).

Vectorised: all ``scale`` quadrant choices for all ``m`` edges are sampled in
one pass (numpy host-side — graph construction is part of the data pipeline,
not the measured BFS kernel, same as the Graph500 harness).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import (CSRGraph, WeightedCSRGraph, from_edges,
                            from_weighted_edges)

GRAPH500_ABCD = (0.57, 0.19, 0.19, 0.05)

# Graph500 SSSP-kernel convention: uniform edge weights in (0, 1]
WEIGHT_RANGE = (0.0, 1.0)


def rmat_edges(scale: int, edgefactor: int, seed: int = 0,
               abcd: tuple[float, float, float, float] = GRAPH500_ABCD,
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Sample directed R-MAT edges; returns (src, dst, n)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edgefactor
    a, b, c, d = abcd
    # Quadrant per (edge, bit): 0->(0,0) w.p. A, 1->(0,1) B, 2->(1,0) C, 3->(1,1) D
    u = rng.random((m, scale))
    q = np.zeros((m, scale), dtype=np.int8)
    q += (u >= a).astype(np.int8)
    q += (u >= a + b).astype(np.int8)
    q += (u >= a + b + c).astype(np.int8)
    src_bits = (q >= 2).astype(np.int64)
    dst_bits = (q & 1).astype(np.int64)
    weights = 1 << np.arange(scale - 1, -1, -1, dtype=np.int64)
    src = src_bits @ weights
    dst = dst_bits @ weights
    # Graph500: random relabelling + edge shuffle
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    order = rng.permutation(m)
    return src[order], dst[order], n


def rmat_graph(scale: int, edgefactor: int, seed: int = 0,
               abcd: tuple[float, float, float, float] = GRAPH500_ABCD,
               ) -> CSRGraph:
    """Generate a symmetrised CSR Graph500 graph."""
    src, dst, n = rmat_edges(scale, edgefactor, seed, abcd)
    return from_edges(src, dst, n, symmetrize=True, drop_self_loops=True)


def edge_weights(m: int, seed: int = 0,
                 weight_range: tuple[float, float] = WEIGHT_RANGE,
                 ) -> np.ndarray:
    """One uniform weight per directed input edge (the Graph500 SSSP
    kernel's weight model). Weights are drawn from a seed stream that is
    independent of the edge sampler's, so (scale, seed) still pins the
    unweighted topology exactly."""
    lo, hi = weight_range
    if not 0 <= lo <= hi:
        raise ValueError(f"need 0 <= lo <= hi, got weight_range "
                         f"({lo}, {hi})")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5557]))
    return rng.uniform(lo, hi, size=m)


def rmat_weighted_graph(scale: int, edgefactor: int, seed: int = 0,
                        abcd: tuple[float, float, float, float]
                        = GRAPH500_ABCD,
                        weight_range: tuple[float, float] = WEIGHT_RANGE,
                        ) -> WeightedCSRGraph:
    """``rmat_graph`` + per-edge weights generated alongside the Kronecker
    edges: same (scale, seed) topology, each undirected edge carrying one
    uniform weight both ways (``WeightedCSRGraph.csr`` is bit-identical to
    the ``rmat_graph`` CSR)."""
    src, dst, n = rmat_edges(scale, edgefactor, seed, abcd)
    w = edge_weights(len(src), seed, weight_range)
    return from_weighted_edges(src, dst, w, n, symmetrize=True,
                               drop_self_loops=True)


def uniform_random_weighted_graph(n: int, m: int, seed: int = 0,
                                  weight_range: tuple[float, float]
                                  = WEIGHT_RANGE) -> WeightedCSRGraph:
    """Weighted G(n, m) analog of ``uniform_random_graph`` — the SSSP
    property tests' graph model."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    w = edge_weights(m, seed, weight_range)
    return from_weighted_edges(src, dst, w, n, symmetrize=True,
                               drop_self_loops=True)


def uniform_random_graph(n: int, m: int, seed: int = 0) -> CSRGraph:
    """Erdős–Rényi-ish G(n, m) graph — used by property tests."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    return from_edges(src, dst, n, symmetrize=True, drop_self_loops=True)


def sample_roots(g: CSRGraph, num: int, seed: int = 1,
                 require_edges: bool = True) -> np.ndarray:
    """Graph500 root sampling: ``num`` distinct roots; roots with degree 0
    are excluded when ``require_edges`` (they'd traverse 0 edges)."""
    rng = np.random.default_rng(seed)
    deg = np.asarray(g.deg)
    candidates = np.flatnonzero(deg > 0) if require_edges else np.arange(g.n)
    num = min(num, len(candidates))
    return rng.choice(candidates, size=num, replace=False)
