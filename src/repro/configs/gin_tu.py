"""gin-tu [arXiv:1810.00826] — GIN, 5L sum-agg, learnable eps."""
from repro.configs.base import Arch, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.optim.adamw import OptConfig
from repro.models.gnn.gin import GINConfig

ARCH = register(Arch(
    arch_id="gin-tu", family="gnn",
    model_cfg=GINConfig(name="gin-tu", n_layers=5, d_hidden=64),
    shapes=gnn_shapes(), opt=OptConfig(moment_dtype="float32"),
    source="arXiv:1810.00826"))
