"""phi4-mini-3.8b [arXiv:2412.08905; hf] — dense 32L GQA transformer."""
from repro.configs.base import Arch, register
from repro.configs.lm_shapes import lm_shapes
from repro.models.transformer import LMConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="phi4-mini-3.8b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
        n_kv_heads=8, d_head=128, d_ff=8192, vocab=200064,
        rope_theta=10000.0, dtype="bfloat16", param_dtype="bfloat16",
        remat=True),
    shapes=lm_shapes(),
    opt=OptConfig(moment_dtype="float32"),
    microbatches=8,
    source="arXiv:2412.08905",
))
