"""Shared GNN shape contract (all four GNN archs).

Shapes (same contract for all four archs):
  full_graph_sm — cora-scale full-batch (n=2,708 e=10,556 d=1,433)
  minibatch_lg  — reddit-scale sampled training: the *step input* is the
                  sampled subgraph from batch_nodes=1,024 with fanout 15-10
                  (1,024 + 15,360 + 153,600 nodes; 168,960 edges; d=602);
                  the neighbour sampler (repro.graph.sampler) produces it
                  from the full 232,965-node / 114.6M-edge graph.
  ogb_products  — full-batch-large (n=2,449,029 e=61,859,140 d=100)
  molecule      — 128 packed molecular graphs (30 nodes / 64 edges each)
"""
from repro.configs.base import Shape

MINIBATCH_NODES = 1024 + 1024 * 15 + 1024 * 15 * 10     # 169,984
MINIBATCH_EDGES = 1024 * 15 + 1024 * 15 * 10            # 168,960


def gnn_shapes() -> tuple[Shape, ...]:
    return (
        Shape("full_graph_sm", "train",
              dims=dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                        n_classes=7)),
        Shape("minibatch_lg", "train",
              dims=dict(n_nodes=MINIBATCH_NODES, n_edges=MINIBATCH_EDGES,
                        d_feat=602, n_classes=41,
                        full_nodes=232965, full_edges=114615892,
                        batch_nodes=1024, fanout=(15, 10))),
        Shape("ogb_products", "train",
              dims=dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                        n_classes=47)),
        Shape("molecule", "train",
              dims=dict(n_nodes=30 * 128, n_edges=64 * 128, d_feat=64,
                        n_classes=16, n_graphs=128)),
    )


