"""mace [arXiv:2206.07697] — E(3)-equivariant, l_max=2, correlation 3."""
from repro.configs.base import Arch, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.optim.adamw import OptConfig
from repro.models.gnn.mace import MACEConfig

ARCH = register(Arch(
    arch_id="mace", family="gnn",
    model_cfg=MACEConfig(name="mace", n_layers=2, d_hidden=128, l_max=2,
                         correlation=3, n_rbf=8,
                         dtype="bfloat16", remat=False),
    shapes=gnn_shapes(), opt=OptConfig(moment_dtype="float32"),
    source="arXiv:2206.07697"))
