"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — 48L MoE, 128 experts top-8."""
from repro.configs.base import Arch, register
from repro.configs.lm_shapes import lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="qwen3-moe-30b-a3b",
    family="lm-moe",
    model_cfg=LMConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=0, vocab=151936,
        rope_theta=1000000.0, dtype="bfloat16", param_dtype="bfloat16",
        remat=True,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768)),
    shapes=lm_shapes(),
    opt=OptConfig(moment_dtype="float32"),
    microbatches=8,
    source="hf:Qwen/Qwen3-30B-A3B",
))
