"""gcn-cora [arXiv:1609.02907] — 2L GCN, sym-normalised SpMM."""
from repro.configs.base import Arch, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.optim.adamw import OptConfig
from repro.models.gnn.gcn import GCNConfig

ARCH = register(Arch(
    arch_id="gcn-cora", family="gnn",
    model_cfg=GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, norm="sym"),
    shapes=gnn_shapes(), opt=OptConfig(moment_dtype="float32"),
    source="arXiv:1609.02907"))
