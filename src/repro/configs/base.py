"""Architecture registry: config dataclasses, input-spec builders, step
builders. Every assigned architecture registers an ``Arch`` here; the
launcher, dry-run, trainer and tests all consume this one interface.

``input_specs(arch, shape)`` returns (pytree of ShapeDtypeStruct, logical
spec pytree) — weak-type-correct stand-ins, no device allocation. The dry-run
lowers ``make_step(arch, shape)`` against them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (OptConfig, adamw_update, clip_by_global_norm,
                               init_opt_state, opt_state_specs)

PAD_MULTIPLE = 8192   # node/edge padding so graph dims divide any mesh


@dataclass(frozen=True)
class Shape:
    shape_id: str
    kind: str                  # train | prefill | decode | serve | retrieval
    dims: dict
    skip_reason: str | None = None


@dataclass(frozen=True)
class Arch:
    arch_id: str
    family: str                # lm-dense | lm-moe | gnn | recsys
    model_cfg: Any
    shapes: tuple[Shape, ...]
    opt: OptConfig = OptConfig()
    source: str = ""
    # grad-accumulation microbatches for train shapes (activation memory
    # scales ~1/k; the scan also gives XLA a window to overlap the grad
    # reduce-scatter of microbatch i with compute of i+1)
    microbatches: int = 1

    def shape(self, shape_id: str) -> Shape:
        for s in self.shapes:
            if s.shape_id == shape_id:
                return s
        raise KeyError(f"{self.arch_id} has no shape {shape_id}")


REGISTRY: dict[str, Arch] = {}


def register(arch: Arch) -> Arch:
    REGISTRY[arch.arch_id] = arch
    return arch


def get_arch(arch_id: str) -> Arch:
    import repro.configs.all  # noqa: F401  (populates REGISTRY)
    return REGISTRY[arch_id]


def list_archs() -> list[str]:
    import repro.configs.all  # noqa: F401
    return sorted(REGISTRY)


def _pad(n: int, mult: int = PAD_MULTIPLE) -> int:
    return -(-n // mult) * mult


# ------------------------------------------------------------ param builders


def effective_cfg(arch: Arch, shape: Shape | None):
    """Per-shape config overrides (GNN input dims / task come from the
    shape; LM/recsys configs are shape-independent)."""
    cfg = arch.model_cfg
    if shape is None or arch.family != "gnn":
        return cfg
    import dataclasses
    over = {}
    if "d_feat" in shape.dims:
        over["d_feat"] = shape.dims["d_feat"]
    if "n_classes" in shape.dims and hasattr(cfg, "n_classes"):
        over["n_classes"] = shape.dims["n_classes"]
    if hasattr(cfg, "task"):
        over["task"] = "graph" if shape.dims.get("n_graphs", 1) > 1 else "node"
    return dataclasses.replace(cfg, **over)


def param_builders(arch: Arch, shape: Shape | None = None):
    """Returns (init_fn(key) -> (params, specs), loss_fn(params, batch))."""
    fam = arch.family
    cfg = effective_cfg(arch, shape)
    if fam in ("lm-dense", "lm-moe"):
        from repro.models.transformer import init_lm, lm_loss
        return (lambda k: init_lm(k, cfg)), (lambda p, b: lm_loss(p, b, cfg))
    if fam == "gnn":
        name = type(cfg).__name__
        if name == "GCNConfig":
            from repro.models.gnn.gcn import gcn_loss, init_gcn
            return (lambda k: init_gcn(k, cfg)), (lambda p, b: gcn_loss(p, b, cfg))
        if name == "GINConfig":
            from repro.models.gnn.gin import gin_loss, init_gin
            return (lambda k: init_gin(k, cfg)), (lambda p, b: gin_loss(p, b, cfg))
        if name == "EGNNConfig":
            from repro.models.gnn.egnn import egnn_loss, init_egnn
            return (lambda k: init_egnn(k, cfg)), (lambda p, b: egnn_loss(p, b, cfg))
        if name == "MACEConfig":
            from repro.models.gnn.mace import init_mace, mace_loss
            return (lambda k: init_mace(k, cfg)), (lambda p, b: mace_loss(p, b, cfg))
    if fam == "recsys":
        from repro.models.recsys.dien import dien_loss, init_dien
        return (lambda k: init_dien(k, cfg)), (lambda p, b: dien_loss(p, b, cfg))
    raise ValueError(fam)


def param_shapes(arch: Arch, shape: Shape | None = None):
    """(ShapeDtypeStruct tree, logical spec tree) — no allocation."""
    init_fn, _ = param_builders(arch, shape)
    box = {}

    def f(k):
        p, s = init_fn(k)
        box["s"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["s"]


# ------------------------------------------------------------- input builders


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _lm_inputs(arch: Arch, shape: Shape):
    cfg = arch.model_cfg
    d = shape.dims
    if shape.kind == "train":
        b, s = d["global_batch"], d["seq_len"]
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        specs = {"tokens": ("batch", None), "labels": ("batch", None)}
        return batch, specs
    if shape.kind == "prefill":
        b, s = d["global_batch"], d["seq_len"]
        return ({"tokens": _sds((b, s), jnp.int32)},
                {"tokens": ("batch", None)})
    if shape.kind == "decode":
        b, s = d["global_batch"], d["seq_len"]
        kv = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head)
        kv_spec = (None, "batch", "kv_seq", "kv_heads", None)
        batch = {"token": _sds((b, 1), jnp.int32),
                 "cache_k": _sds(kv, cfg.cache_dtype),
                 "cache_v": _sds(kv, cfg.cache_dtype),
                 "cache_len": _sds((), jnp.int32)}
        specs = {"token": ("batch", None), "cache_k": kv_spec,
                 "cache_v": kv_spec, "cache_len": None}
        return batch, specs
    raise ValueError(shape.kind)


def _gnn_inputs(arch: Arch, shape: Shape):
    d = shape.dims
    n = _pad(d["n_nodes"])
    e = _pad(d["n_edges"])
    f = d["d_feat"]
    g = d.get("n_graphs", 1)
    from repro.models.gnn.common import GraphBatch
    batch = GraphBatch(
        senders=_sds((e,), jnp.int32), receivers=_sds((e,), jnp.int32),
        edge_mask=_sds((e,), jnp.bool_), feats=_sds((n, f), jnp.float32),
        pos=_sds((n, 3), jnp.float32), labels=_sds((n,), jnp.int32),
        node_mask=_sds((n,), jnp.bool_), graph_ids=_sds((n,), jnp.int32),
        n_graphs=g)
    specs = GraphBatch(
        senders=("edges",), receivers=("edges",), edge_mask=("edges",),
        feats=("nodes", None), pos=("nodes", None), labels=("nodes",),
        node_mask=("nodes",), graph_ids=("nodes",), n_graphs=g)
    return batch, specs


def _recsys_inputs(arch: Arch, shape: Shape):
    cfg = arch.model_cfg
    d = shape.dims
    b = d["batch"]
    t = cfg.seq_len
    m = cfg.profile_bag
    base = {
        "target_item": _sds((b,), jnp.int32),
        "target_cat": _sds((b,), jnp.int32),
        "hist_items": _sds((b, t), jnp.int32),
        "hist_cats": _sds((b, t), jnp.int32),
        "hist_mask": _sds((b, t), jnp.bool_),
        "profile_ids": _sds((b, m), jnp.int32),
        "profile_mask": _sds((b, m), jnp.bool_),
    }
    specs = {k: ("batch",) + (None,) * (len(v.shape) - 1)
             for k, v in base.items()}
    if shape.kind == "train":
        base["labels"] = _sds((b,), jnp.float32)
        base["neg_items"] = _sds((b, t), jnp.int32)
        specs["labels"] = ("batch",)
        specs["neg_items"] = ("batch", None)
    if shape.kind == "retrieval":
        nc = d["n_candidates"]
        base["candidate_ids"] = _sds((nc,), jnp.int32)
        specs["candidate_ids"] = ("candidates",)
    return base, specs


def input_specs(arch: Arch, shape: Shape):
    if arch.family in ("lm-dense", "lm-moe"):
        return _lm_inputs(arch, shape)
    if arch.family == "gnn":
        return _gnn_inputs(arch, shape)
    if arch.family == "recsys":
        return _recsys_inputs(arch, shape)
    raise ValueError(arch.family)


# --------------------------------------------------------------- step makers


def make_step(arch: Arch, shape: Shape) -> Callable:
    """The function the dry-run lowers / the trainer executes.

    train:   step(params, opt_state, batch) -> (params, opt_state, metrics)
    prefill: step(params, batch) -> (logits, cache)
    decode:  step(params, batch) -> (logits, new_cache)
    serve:   step(params, batch) -> outputs
    """
    cfg = effective_cfg(arch, shape)
    _, loss_fn = param_builders(arch, shape)

    if shape.kind == "train":
        opt_cfg = arch.opt
        k = max(1, arch.microbatches)

        def _grads(params, batch):
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        def train_step(params, opt_state, batch):
            if k > 1:
                acc_dt = jnp.dtype(opt_cfg.accum_dtype)
                mb = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dt), params)

                def micro(acc, b):
                    (loss, metrics), g = _grads(params, b)
                    acc = jax.tree.map(
                        lambda a, gg: a + (gg / k).astype(acc_dt), acc, g)
                    return acc, loss

                grads, losses = jax.lax.scan(micro, zeros, mb)
                loss = losses.mean()
                metrics = {}
            else:
                (loss, metrics), grads = _grads(params, batch)
            grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            metrics = dict(metrics)
            metrics.update(loss=loss, grad_norm=gnorm)
            return params, opt_state, metrics
        return train_step

    if shape.kind == "prefill":
        from repro.models.transformer import lm_prefill

        def prefill_step(params, batch):
            return lm_prefill(params, batch["tokens"], cfg)
        return prefill_step

    if shape.kind == "decode":
        from repro.models.transformer import lm_decode_step

        def decode_step(params, batch):
            return lm_decode_step(params, batch["token"],
                                  (batch["cache_k"], batch["cache_v"]),
                                  batch["cache_len"], cfg)
        return decode_step

    if shape.kind == "serve":
        if arch.family == "recsys":
            from repro.models.recsys.dien import dien_forward

            def serve_step(params, batch):
                return jax.nn.sigmoid(dien_forward(params, batch, cfg))
            return serve_step

        def fwd_step(params, batch):   # GNN forward-only
            loss, metrics = loss_fn(params, batch)
            return metrics
        return fwd_step

    if shape.kind == "retrieval":
        from repro.models.recsys.dien import dien_retrieval

        def retrieval_step(params, batch):
            scores, top = dien_retrieval(params, batch, cfg)
            return top
        return retrieval_step

    raise ValueError(shape.kind)


def step_arg_specs(arch: Arch, shape: Shape):
    """((args shapes), (args logical specs)) matching make_step's signature."""
    batch, batch_specs = input_specs(arch, shape)
    if shape.kind == "train":
        p_shapes, p_specs = param_shapes(arch, shape)
        opt_shapes = jax.eval_shape(
            lambda: init_opt_state(p_shapes, arch.opt))
        o_specs = opt_state_specs(p_specs, arch.opt, p_shapes)
        return (p_shapes, opt_shapes, batch), (p_specs, o_specs, batch_specs)
    p_shapes, p_specs = param_shapes(arch, shape)
    return (p_shapes, batch), (p_specs, batch_specs)
