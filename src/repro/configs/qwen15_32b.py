"""qwen1.5-32b [hf:Qwen/Qwen1.5-*] — dense 64L, MHA (kv=40), QKV bias."""
from repro.configs.base import Arch, register
from repro.configs.lm_shapes import lm_shapes
from repro.models.transformer import LMConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="qwen1.5-32b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=40, d_head=128, d_ff=27392, vocab=152064,
        rope_theta=1000000.0, qkv_bias=True, dtype="bfloat16",
        param_dtype="bfloat16", remat=True,
        kv_cache_dtype="float8_e4m3fn", attn_seq_pin=False),
    shapes=lm_shapes(),
    opt=OptConfig(moment_dtype="float32"),
    microbatches=8,
    source="hf:Qwen/Qwen1.5-0.5B (scaled family config)",
))
