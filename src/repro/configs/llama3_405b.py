"""llama3-405b [arXiv:2407.21783] — dense 126L GQA, 128k vocab.

Optimizer is factored (Adafactor-style second moment, no first moment,
bf16 stats) so params+grads+opt fit 16 GiB/chip at 256-512 chips
(DESIGN.md §5); full Adam at 405B would need ~12.7 GiB/chip for moments
alone on a single pod.
"""
from repro.configs.base import Arch, register
from repro.configs.lm_shapes import lm_shapes
from repro.models.transformer import LMConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="llama3-405b",
    family="lm-dense",
    model_cfg=LMConfig(
        name="llama3-405b", n_layers=126, d_model=16384, n_heads=128,
        n_kv_heads=8, d_head=128, d_ff=53248, vocab=128256,
        rope_theta=500000.0, dtype="bfloat16", param_dtype="bfloat16",
        remat=True, seq_parallel_residual=True,
        kv_cache_dtype="float8_e4m3fn"),
    shapes=lm_shapes(),
    opt=OptConfig(b1=0.0, moment_dtype="bfloat16", factored=True,
                  accum_dtype="bfloat16"),
    microbatches=4,
    source="arXiv:2407.21783",
))
