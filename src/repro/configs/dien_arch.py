"""dien [arXiv:1809.03672] — sequential-behaviour CTR/recsys arch."""
from repro.configs.base import Arch, Shape, register
from repro.models.recsys.dien import DIENConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="dien", family="recsys",
    model_cfg=DIENConfig(
        name="dien", embed_dim=18, seq_len=100, gru_dim=108,
        mlp_dims=(200, 80), n_items=1_000_000, n_cats=1_000,
        n_profiles=100_000, use_aux_loss=True),
    shapes=(
        Shape("train_batch", "train", dims=dict(batch=65536)),
        Shape("serve_p99", "serve", dims=dict(batch=512)),
        Shape("serve_bulk", "serve", dims=dict(batch=262144)),
        Shape("retrieval_cand", "retrieval",
              dims=dict(batch=1, n_candidates=1_000_000)),
    ),
    opt=OptConfig(moment_dtype="float32"),
    microbatches=8,
    source="arXiv:1809.03672",
))
