"""The four LM-family input shapes shared by all five LM archs."""
from __future__ import annotations

from repro.configs.base import Shape

FULL_ATTN_SKIP = ("pure full-attention arch: 512k dense decode attention is "
                  "out of contract scope (sub-quadratic attention required); "
                  "see DESIGN.md §4")


def lm_shapes() -> tuple[Shape, ...]:
    return (
        Shape("train_4k", "train",
              dims=dict(seq_len=4096, global_batch=256)),
        Shape("prefill_32k", "prefill",
              dims=dict(seq_len=32768, global_batch=32)),
        Shape("decode_32k", "decode",
              dims=dict(seq_len=32768, global_batch=128)),
        Shape("long_500k", "decode",
              dims=dict(seq_len=524288, global_batch=1),
              skip_reason=FULL_ATTN_SKIP),
    )
