"""Import all architecture configs (populates the registry)."""
import repro.configs.phi4_mini_3_8b        # noqa: F401
import repro.configs.qwen15_32b            # noqa: F401
import repro.configs.llama3_405b           # noqa: F401
import repro.configs.granite_moe_1b_a400m  # noqa: F401
import repro.configs.qwen3_moe_30b_a3b     # noqa: F401
import repro.configs.gin_tu                # noqa: F401
import repro.configs.gcn_cora              # noqa: F401
import repro.configs.mace_arch             # noqa: F401
import repro.configs.egnn_arch             # noqa: F401
import repro.configs.dien_arch             # noqa: F401
