"""Reduced per-arch configs: same family/structure, small dims — used by the
CPU smoke tests and the runnable examples. The FULL configs are exercised
only through the dry-run (ShapeDtypeStruct, no allocation)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import Arch, Shape, get_arch
from repro.models.moe import MoEConfig


def _lm_reduced(arch: Arch) -> Arch:
    cfg = arch.model_cfg
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(8, cfg.moe.num_experts),
                        top_k=min(2, cfg.moe.top_k), d_ff_expert=32,
                        capacity_factor=2.0)
    small = dataclasses.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=(4 if cfg.n_kv_heads == cfg.n_heads else 2),
        d_head=16, d_ff=(0 if moe else 128), vocab=512, moe=moe,
        dtype="float32", param_dtype="float32", remat=True)
    shapes = (
        Shape("train_4k", "train", dims=dict(seq_len=64, global_batch=8)),
        Shape("prefill_32k", "prefill", dims=dict(seq_len=128,
                                                  global_batch=2)),
        Shape("decode_32k", "decode", dims=dict(seq_len=128, global_batch=4)),
    )
    return dataclasses.replace(arch, arch_id=arch.arch_id + "-reduced",
                               model_cfg=small, shapes=shapes,
                               opt=dataclasses.replace(arch.opt, lr=1e-3),
                               microbatches=2)


def _gnn_reduced(arch: Arch) -> Arch:
    cfg = arch.model_cfg
    over = dict(n_layers=2)
    if hasattr(cfg, "d_hidden"):
        over["d_hidden"] = 16
    small = dataclasses.replace(cfg, **over)
    shapes = (
        Shape("full_graph_sm", "train",
              dims=dict(n_nodes=120, n_edges=480, d_feat=16, n_classes=5)),
        Shape("molecule", "train",
              dims=dict(n_nodes=10 * 4, n_edges=24 * 4, d_feat=8,
                        n_classes=4, n_graphs=4)),
        Shape("minibatch_lg", "train",
              dims=dict(n_nodes=8 + 8 * 3 + 24 * 2, n_edges=8 * 3 + 24 * 2,
                        d_feat=12, n_classes=5, full_nodes=500,
                        full_edges=4000, batch_nodes=8, fanout=(3, 2))),
    )
    return dataclasses.replace(arch, arch_id=arch.arch_id + "-reduced",
                               model_cfg=small, shapes=shapes,
                               microbatches=1)


def _recsys_reduced(arch: Arch) -> Arch:
    cfg = arch.model_cfg
    small = dataclasses.replace(cfg, n_items=2000, n_cats=20, n_profiles=100,
                                seq_len=12, gru_dim=24, mlp_dims=(32, 16))
    shapes = (
        Shape("train_batch", "train", dims=dict(batch=16)),
        Shape("serve_p99", "serve", dims=dict(batch=8)),
        Shape("serve_bulk", "serve", dims=dict(batch=32)),
        Shape("retrieval_cand", "retrieval",
              dims=dict(batch=2, n_candidates=500)),
    )
    return dataclasses.replace(arch, arch_id=arch.arch_id + "-reduced",
                               model_cfg=small, shapes=shapes,
                               microbatches=2)


def reduce_arch(arch_id: str) -> Arch:
    arch = get_arch(arch_id)
    if arch.family in ("lm-dense", "lm-moe"):
        return _lm_reduced(arch)
    if arch.family == "gnn":
        return _gnn_reduced(arch)
    return _recsys_reduced(arch)
