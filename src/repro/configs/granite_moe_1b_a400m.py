"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base] —
24L MoE, 32 experts top-8, d_ff=512 per expert."""
from repro.configs.base import Arch, register
from repro.configs.lm_shapes import lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig
from repro.optim.adamw import OptConfig

ARCH = register(Arch(
    arch_id="granite-moe-1b-a400m",
    family="lm-moe",
    model_cfg=LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_head=64, d_ff=0, vocab=49155,
        rope_theta=10000.0, dtype="bfloat16", param_dtype="bfloat16",
        remat=True,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512)),
    shapes=lm_shapes(),
    opt=OptConfig(moment_dtype="float32"),
    microbatches=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
