"""egnn [arXiv:2102.09844] — E(n)-equivariant GNN, 4L."""
from repro.configs.base import Arch, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.optim.adamw import OptConfig
from repro.models.gnn.egnn import EGNNConfig

ARCH = register(Arch(
    arch_id="egnn", family="gnn",
    model_cfg=EGNNConfig(name="egnn", n_layers=4, d_hidden=64),
    shapes=gnn_shapes(), opt=OptConfig(moment_dtype="float32"),
    source="arXiv:2102.09844"))
