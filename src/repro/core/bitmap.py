"""Packed uint32 bitmap operations.

The paper (Listing 1) tests frontier membership via bitmap words:
``word = v >> 5; bit = v & 0x1F`` — we keep the identical layout so the
Pallas kernel is a line-for-line analog of ``LookingParents``.

All functions are jit-friendly (static shapes, no host sync).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32
_WORD_SHIFT = 5
_BIT_MASK = 0x1F


def num_words(n: int) -> int:
    """Number of uint32 words to hold ``n`` bits."""
    return (n + WORD_BITS - 1) // WORD_BITS


def pack(mask: jnp.ndarray) -> jnp.ndarray:
    """Pack a bool[n] mask into uint32[ceil(n/32)] words (LSB-first)."""
    n = mask.shape[0]
    nw = num_words(n)
    padded = jnp.zeros((nw * WORD_BITS,), dtype=jnp.uint32).at[:n].set(
        mask.astype(jnp.uint32))
    lanes = padded.reshape(nw, WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (lanes * weights).sum(axis=1, dtype=jnp.uint32)


def unpack(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """Unpack uint32 words into a bool[n] mask."""
    nw = words.shape[0]
    bits = (words[:, None] >> jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :]) & 1
    return bits.reshape(nw * WORD_BITS)[:n].astype(jnp.bool_)


def test(words: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Test bits at vertex ids ``idx`` (any shape). Out-of-range ids -> False.

    This is the vectorised form of the paper's
    ``(frontier->start[v >> 5] >> (v & 0x1F)) & 1``.
    """
    nbits = words.shape[0] * WORD_BITS
    idx_ = idx.astype(jnp.uint32)
    safe = jnp.clip(idx_, 0, jnp.uint32(nbits - 1))
    w = words[(safe >> _WORD_SHIFT).astype(jnp.int32)]
    bit = (w >> (safe & _BIT_MASK)) & jnp.uint32(1)
    in_range = idx_ < jnp.uint32(nbits)
    return (bit == 1) & in_range


def union(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bitwise OR of two word arrays."""
    return a | b


def popcount_words(words: jnp.ndarray) -> jnp.ndarray:
    """Total number of set bits (int32 scalar)."""
    x = words
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    per_word = (x * jnp.uint32(0x01010101)) >> 24
    return per_word.sum(dtype=jnp.uint32).astype(jnp.int32)


def set_bits(words: jnp.ndarray, idx: jnp.ndarray,
             valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Set bits for vertex ids ``idx`` where ``valid`` (scatter-OR).

    Implemented as unpack-free scatter: per-id one-hot word OR accumulated
    with ``.at[].max`` per bit is unsound for multiple bits per word, so we
    scatter into a bool view of only the touched range via segment ops.
    For simplicity/correctness we scatter to bool[n] then pack the delta.
    """
    nbits = words.shape[0] * WORD_BITS
    hit = jnp.zeros((nbits,), dtype=jnp.bool_)
    if valid is None:
        valid = jnp.ones(idx.shape, dtype=jnp.bool_)
    safe = jnp.clip(idx, 0, nbits - 1)
    hit = hit.at[safe].max(valid)
    return words | pack(hit[:nbits])
