"""Frontier-word exchange primitives shared by the distributed engines.

Every distributed traversal in this repo moves exactly one kind of state
between devices: packed lane words (``uint32``/``uint64`` bitmask columns,
``repro.core.packed``). This module is the ONE implementation of those
moves, so the 1-D engine (``repro.core.dist_msbfs``), the 2-D engine
(``repro.core.dist2d``) and any future partition share a wire format,
a compression rule, and a bytes-on-the-wire accounting:

* ``allreduce_or`` — bitwise-OR allreduce over mesh axes: the
  ``lax.psum`` analog for bitmasks (OR is associative+commutative but not
  a sum, so the collective is an all-gather + static OR-fold). This is
  the 1-D engine's whole exchange: each device ORs its placed row block
  into the replicated ``[n, W]`` frontier.

* ``gather_words`` — the transport both richer exchanges ride: all-gather
  per-device word slices along ONE mesh axis, optionally through the
  sparse (index, payload) codec of ``repro.distributed.compression``.
  The sparse/dense switch is taken PER COLLECTIVE GROUP (the devices
  being gathered agree via a pmax of their nonzero counts — a jit-safe
  ``lax.cond`` whose branches hold the group's own collectives), and the
  returned byte count follows the form actually shipped, so sparse
  layers cost bytes proportional to the frontier population, not the
  graph.

* ``exchange_expand`` / ``exchange_reduce_or`` — the two moves of the
  Buluc–Madduri 2-D decomposition: concatenate gathered slices into the
  expand-side frontier (allgather along grid rows), or OR-fold gathered
  partial products into the discovered set (reduce along grid columns).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.compression import (DENSE_THRESHOLD, _COUNT_BYTES,
                                           _IDX_BYTES, compress_values,
                                           compress_words, decompress_values,
                                           decompress_words, sparse_budget)

__all__ = [
    "allreduce_min", "allreduce_or", "exchange_expand",
    "exchange_expand_values", "exchange_reduce_min", "exchange_reduce_or",
    "gather_values", "gather_words", "sparse_budget",
]


def _or_fold(stacked: jnp.ndarray) -> jnp.ndarray:
    """OR-fold a gathered ``[ndev, ...]`` stack along its device dim."""
    out = stacked[0]
    for d in range(1, stacked.shape[0]):
        out = out | stacked[d]
    return out


def allreduce_or(words: jnp.ndarray, axes) -> jnp.ndarray:
    """Bitwise-OR allreduce across mesh axes — the ``lax.psum`` analog for
    packed lane words. Dense wire form; the 1-D engine's per-layer
    frontier exchange (partition-agnostic: for a contiguous 1-D partition
    it degenerates to an all-gather concatenation, but the OR form also
    serves overlapping placements)."""
    return _or_fold(jax.lax.all_gather(words, axes))


def _min_fold(stacked: jnp.ndarray) -> jnp.ndarray:
    """MIN-fold a gathered ``[ndev, ...]`` stack along its device dim."""
    out = stacked[0]
    for d in range(1, stacked.shape[0]):
        out = jnp.minimum(out, stacked[d])
    return out


def allreduce_min(vals: jnp.ndarray, axes) -> jnp.ndarray:
    """Elementwise-MIN allreduce across mesh axes — the tropical-semiring
    sibling of ``allreduce_or``. Float lane values fold under ``min``
    exactly as packed words fold under OR: ``inf`` is the identity, so
    settled/inactive lanes (which carry ``inf`` candidates) are a no-op in
    the fold. Dense wire form; float32 ``min`` is exactly associative and
    commutative absent NaN (the SSSP engines never produce one: weights
    are non-negative finite or ``inf`` and ``inf + finite = inf``), so the
    fold order cannot perturb bits."""
    return _min_fold(jax.lax.all_gather(vals, axes))


def gather_words(own: jnp.ndarray, axis, compress: bool = False,
                 threshold: float = DENSE_THRESHOLD):
    """All-gather a per-device word slice along ``axis``.

    Returns ``(stacked words[ndev, *own.shape], bytes int32)`` where
    ``bytes`` is the total payload the group shipped this call (summed
    over the group's devices, replicated within the group).

    ``compress=False`` ships the dense slice. ``compress=True`` runs the
    density switch: every device in the gather group compresses its slice
    into a ``sparse_budget(total, threshold)``-slot buffer, the group
    agrees on the max nonzero count (pmax along ``axis``), and if every
    slice fits the budget the group gathers (index, payload) buffers and
    decompresses — otherwise it falls back to the dense gather. One
    ``lax.cond`` per group: different groups (e.g. different grid columns)
    may take different branches, their collectives never cross.
    """
    itemsize = jnp.dtype(own.dtype).itemsize
    total = 1
    for s in own.shape:
        total *= s
    if not compress:
        stacked = jax.lax.all_gather(own, axis)
        ndev = stacked.shape[0]
        return stacked, jnp.int32(ndev * total * itemsize)

    budget = sparse_budget(total, threshold)
    idx, payload, count = compress_words(own, budget)
    count_max = jax.lax.pmax(count, axis)
    use_sparse = count_max <= budget
    # bytes follow the form the GROUP ships: all-sparse or all-dense
    sparse_bytes = jax.lax.psum(
        _COUNT_BYTES + count * (_IDX_BYTES + itemsize), axis)

    def do_sparse(args):
        idx, payload, _ = args
        g_idx = jax.lax.all_gather(idx, axis)          # [ndev, budget]
        g_pay = jax.lax.all_gather(payload, axis)
        slices = [decompress_words(g_idx[d], g_pay[d], total)
                  .reshape(own.shape) for d in range(g_idx.shape[0])]
        return jnp.stack(slices, axis=0)

    def do_dense(args):
        _, _, own = args
        return jax.lax.all_gather(own, axis)

    stacked = jax.lax.cond(use_sparse, do_sparse, do_dense,
                           (idx, payload, own))
    ndev = stacked.shape[0]
    nbytes = jnp.where(use_sparse, sparse_bytes,
                       ndev * total * itemsize).astype(jnp.int32)
    return stacked, nbytes


def gather_values(own: jnp.ndarray, axis, compress: bool = False,
                  threshold: float = DENSE_THRESHOLD):
    """All-gather a per-device float value slice along ``axis`` — the
    value-transport twin of ``gather_words`` for MIN-monoid exchanges.

    Returns ``(stacked vals[ndev, *own.shape], bytes int32)``. The dense
    form is population-blind (every entry ships every call); with
    ``compress=True`` the density switch runs on the FINITE-entry count —
    relaxation candidates are ``inf`` everywhere a relaxation did not fire
    this step, so sparse layers cost bytes proportional to the active
    frontier, not the graph. Same group-consensus rule as the word path:
    pmax of counts along ``axis``, one ``lax.cond`` per gather group.
    """
    itemsize = jnp.dtype(own.dtype).itemsize
    total = 1
    for s in own.shape:
        total *= s
    if not compress:
        stacked = jax.lax.all_gather(own, axis)
        ndev = stacked.shape[0]
        return stacked, jnp.int32(ndev * total * itemsize)

    budget = sparse_budget(total, threshold)
    idx, payload, count = compress_values(own, budget)
    count_max = jax.lax.pmax(count, axis)
    use_sparse = count_max <= budget
    sparse_bytes = jax.lax.psum(
        _COUNT_BYTES + count * (_IDX_BYTES + itemsize), axis)

    def do_sparse(args):
        idx, payload, _ = args
        g_idx = jax.lax.all_gather(idx, axis)          # [ndev, budget]
        g_pay = jax.lax.all_gather(payload, axis)
        slices = [decompress_values(g_idx[d], g_pay[d], total)
                  .reshape(own.shape) for d in range(g_idx.shape[0])]
        return jnp.stack(slices, axis=0)

    def do_dense(args):
        _, _, own = args
        return jax.lax.all_gather(own, axis)

    stacked = jax.lax.cond(use_sparse, do_sparse, do_dense,
                           (idx, payload, own))
    ndev = stacked.shape[0]
    nbytes = jnp.where(use_sparse, sparse_bytes,
                       ndev * total * itemsize).astype(jnp.int32)
    return stacked, nbytes


def exchange_expand(own: jnp.ndarray, axis, compress: bool = False,
                    threshold: float = DENSE_THRESHOLD):
    """Expand-side exchange of the 2-D decomposition: gather the frontier
    chunks owned by the devices along ``axis`` and concatenate them into
    the group's full frontier slice (chunks are stacked in axis order —
    the 2-D partition lays its column blocks out so this IS global
    order). Returns ``(words[ndev * rows, W], bytes)``."""
    stacked, nbytes = gather_words(own, axis, compress, threshold)
    return stacked.reshape((-1,) + own.shape[1:]), nbytes


def exchange_reduce_or(partial: jnp.ndarray, axis, compress: bool = False,
                       threshold: float = DENSE_THRESHOLD):
    """Reduce-side exchange of the 2-D decomposition: OR-fold the partial
    new-frontier products of the devices along ``axis`` into the complete
    discovered set (replicated within the group). Returns
    ``(words like partial, bytes)``."""
    stacked, nbytes = gather_words(partial, axis, compress, threshold)
    return _or_fold(stacked), nbytes


def exchange_expand_values(own: jnp.ndarray, axis, compress: bool = False,
                           threshold: float = DENSE_THRESHOLD):
    """Expand-side value exchange of the 2-D decomposition: gather the
    distance chunks owned by the devices along ``axis`` and concatenate
    them into the group's full value slice (chunks stack in axis order —
    the 2-D partition's column-local layout). Returns
    ``(vals[ndev * rows, L], bytes)``."""
    stacked, nbytes = gather_values(own, axis, compress, threshold)
    return stacked.reshape((-1,) + own.shape[1:]), nbytes


def exchange_reduce_min(partial: jnp.ndarray, axis, compress: bool = False,
                        threshold: float = DENSE_THRESHOLD):
    """Reduce-side value exchange: MIN-fold the partial relaxation
    candidates of the devices along ``axis`` into the complete candidate
    set (replicated within the group). Returns
    ``(vals like partial, bytes)``."""
    stacked, nbytes = gather_values(partial, axis, compress, threshold)
    return _min_fold(stacked), nbytes
