"""jax version-compatibility shims.

The distributed paths were written against the modern API surface
(``jax.shard_map``, ``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``);
the container may ship an older jax (0.4.x) where shard_map still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``) and there is no ambient-mesh setter. Everything here
resolves to the native API when present and otherwise emulates it, so
callers write the modern form only.
"""
from __future__ import annotations

import contextlib

import jax

_AMBIENT_MESH = None    # fallback ambient mesh for pre-set_mesh jax


def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` when available, else the experimental one.

    ``mesh=None`` resolves the ambient mesh installed by ``set_mesh``.
    ``check_vma`` maps onto the old spelling ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        mesh = _AMBIENT_MESH
        if mesh is None:
            raise ValueError("no mesh: pass mesh= or enter compat.set_mesh")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(mesh):`` — ambient mesh for shard_map/sharding."""
    global _AMBIENT_MESH
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    prev = _AMBIENT_MESH
    _AMBIENT_MESH = mesh
    try:
        with mesh:              # legacy physical-mesh context, for xmap-era
            yield mesh          # consumers; harmless otherwise
    finally:
        _AMBIENT_MESH = prev


def get_abstract_mesh():
    """The ambient mesh, or None when none is set (callers treat an empty
    mesh the same as None)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            return mesh
        return None
    return _AMBIENT_MESH
