"""Distributed hybrid BFS via shard_map — the multi-pod form of the paper.

1-D vertex partition over *all* mesh axes flattened (pod x data x model):
device d owns a contiguous vertex slice and the CSR rows of its vertices.
Per layer:

  bottom-up  — all_gather the packed frontier bitmap (n/32 uint32 words —
               the bitmap makes the exchange cheap, the same reason the
               paper packs bits), then probe *local* vertices; all writes
               are owner-local, no scatter traffic.
  top-down   — scan local rows of local frontier vertices, emit parent
               candidates over the full vertex range, min-reduce across
               devices (pmin), owners keep their slice. No visited-bitmap
               exchange is needed: owners discard candidates for already
               visited vertices locally.
  counters   — psum of local partials; the direction decision is computed
               redundantly on every device (replicated scalars).

Determinism matches the single-device path: min parent id wins everywhere,
so dist_bfs == hybrid.bfs == numpy oracle exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import bitmap, compat
from repro.core.csr import CSRGraph

MAX_LAYERS = 64


class DistBFSResult(NamedTuple):
    """Single-root distributed BFS result, sentinel conventions aligned
    with ``MSBFSResult``: dead/unreached vertices hold -1 in BOTH parent
    and depth, ``parent[root] == root`` and ``depth[root] == 0``. Arrays
    are trimmed to the original (pre-padding) vertex count."""
    parent: jnp.ndarray        # int32[n_orig], -1 unreached
    depth: jnp.ndarray         # int32[n_orig], -1 unreached
    num_layers: jnp.ndarray    # int32 scalar


@dataclass(frozen=True)
class DistGraph:
    """Host-partitioned CSR: stacked per-device blocks (leading dim = ndev)."""
    row_ptr: jnp.ndarray   # int32[ndev, n_loc+1] — local offsets into col_idx
    col_idx: jnp.ndarray   # int32[ndev, m_loc]   — global neighbour ids
    src_loc: jnp.ndarray   # int32[ndev, m_loc]   — local row of each edge
    deg: jnp.ndarray       # int32[ndev, n_loc]
    n: int                 # padded global vertex count (multiple of ndev*32)
    n_orig: int            # original vertex count
    m_loc: int             # uniform per-device edge-slab size (padded)


def partition_graph(g: CSRGraph, ndev: int) -> DistGraph:
    """Host-side 1-D partition with uniform padding across devices."""
    rp = np.asarray(g.row_ptr)
    ci = np.asarray(g.col_idx)
    n_orig = g.n
    block = -(-n_orig // (ndev * 32)) * 32          # n_loc multiple of 32
    n = block * ndev
    deg_full = np.zeros(n, np.int32)
    deg_full[:n_orig] = np.diff(rp)
    deg_l = deg_full.reshape(ndev, block)

    row_ptr_l = np.zeros((ndev, block + 1), np.int32)
    np.cumsum(deg_l, axis=1, out=row_ptr_l[:, 1:])

    slabs, srcs = [], []
    for d in range(ndev):
        lo_v, hi_v = d * block, min((d + 1) * block, n_orig)
        if lo_v < n_orig:
            slab = ci[rp[lo_v]:rp[hi_v]]
            src = np.repeat(np.arange(hi_v - lo_v, dtype=np.int32),
                            np.diff(rp[lo_v:hi_v + 1]))
        else:
            slab = src = np.zeros(0, np.int32)
        slabs.append(slab)
        srcs.append(src)
    m_loc = max(1, max(len(s) for s in slabs))
    col_l = np.full((ndev, m_loc), n, np.int32)      # sentinel pad (id = n)
    src_l = np.zeros((ndev, m_loc), np.int32)
    for d in range(ndev):
        col_l[d, :len(slabs[d])] = slabs[d]
        src_l[d, :len(srcs[d])] = srcs[d]
    # Padded edge slots: src_loc points at a vertex whose row is full, so
    # pos_e >= deg never activates them; col sentinel n fails bitmap tests.
    return DistGraph(row_ptr=jnp.asarray(row_ptr_l),
                     col_idx=jnp.asarray(col_l), src_loc=jnp.asarray(src_l),
                     deg=jnp.asarray(deg_l), n=n, n_orig=n_orig, m_loc=m_loc)


def _flat_axis_index(axes, sizes):
    # sizes come from the (static) mesh shape — jax.lax.axis_size does not
    # exist on jax 0.4.x
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * sizes[name] + jax.lax.axis_index(name)
    return idx


@partial(jax.jit,
         static_argnames=("mesh", "mode", "alpha", "beta", "max_pos",
                          "n", "n_loc", "m_loc", "n_orig", "probe_impl"))
def _dist_bfs_impl(row_ptr_s, col_s, srcloc_s, deg_s, root, *, mesh: Mesh,
                   mode: str, alpha: float, beta: float, max_pos: int,
                   n: int, n_loc: int, m_loc: int, n_orig: int,
                   probe_impl: str = "xla"):
    axes = tuple(mesh.axis_names)

    def body(row_ptr, col, src_loc, deg, root):
        row_ptr, col, src_loc, deg = (row_ptr[0], col[0], src_loc[0], deg[0])
        base = _flat_axis_index(axes, dict(mesh.shape)) * n_loc
        local_ids = base + jnp.arange(n_loc, dtype=jnp.int32)

        frontier = local_ids == root
        visited = frontier
        parent = jnp.where(frontier, root, -1).astype(jnp.int32)
        depth = jnp.where(frontier, 0, -1).astype(jnp.int32)
        starts = row_ptr[:-1]

        def cond_fn(state):
            return state[6] & (state[5] < MAX_LAYERS)

        def layer_fn(state):
            frontier, visited, parent, depth, topdown, layer, _ = state
            deg32 = deg.astype(jnp.int32)
            e_f = jax.lax.psum(jnp.sum(jnp.where(frontier, deg32, 0)), axes)
            v_f = jax.lax.psum(jnp.sum(frontier, dtype=jnp.int32), axes)
            e_u = jax.lax.psum(jnp.sum(jnp.where(visited, 0, deg32)), axes)
            if mode == "topdown":
                td = jnp.bool_(True)
            elif mode == "bottomup":
                td = jnp.bool_(False)
            else:
                go_bu = topdown & (e_f.astype(jnp.float32)
                                   > e_u.astype(jnp.float32) / alpha)
                go_td = (~topdown) & (v_f.astype(jnp.float32)
                                      < jnp.float32(n) / beta)
                td = jnp.where(go_bu, False, jnp.where(go_td, True, topdown))

            def run_td(args):
                frontier, visited, parent = args
                # col == n marks padded edge slots — exclude them, else a
                # frontier vertex at local row 0 scatters through the pad.
                act = frontier[src_loc] & (col < n)
                src_gid = (base + src_loc).astype(jnp.int32)
                cand = jnp.where(act, src_gid, n).astype(jnp.int32)
                full = jnp.full((n,), n, jnp.int32).at[
                    jnp.clip(col, 0, n - 1)].min(cand)
                full = jax.lax.pmin(full, axes)
                mine = jax.lax.dynamic_slice(full, (base,), (n_loc,))
                new = (mine < n) & ~visited
                parent = jnp.where(new, mine, parent)
                return new, visited | new, parent

            def run_bu(args):
                frontier, visited, parent = args
                fw_global = jax.lax.all_gather(bitmap.pack(frontier), axes,
                                               tiled=True)
                unv = ~visited
                if probe_impl == "pallas":
                    # the paper's probe as the Pallas kernel over the LOCAL
                    # edge slab (VMEM-resident per DESIGN §3.2)
                    from repro.kernels import (bottom_up_probe_pallas,
                                               interpret_default)
                    found_i, parent = bottom_up_probe_pallas(
                        starts, deg, unv, parent, col, fw_global,
                        max_pos=max_pos, interpret=interpret_default())
                    found = found_i != 0
                else:
                    found = jnp.zeros_like(unv)
                    for pos in range(max_pos):      # the paper's probe loop
                        live = unv & (~found) & (pos < deg)
                        vadj = col[jnp.clip(starts + pos, 0, m_loc - 1)]
                        hit = live & bitmap.test(fw_global, vadj)
                        parent = jnp.where(hit, vadj, parent)
                        found = found | hit
                # fallback: local edge-parallel scan beyond max_pos
                e = jnp.arange(m_loc, dtype=jnp.int32)
                pos_e = e - row_ptr[src_loc]
                rem = unv & (~found) & (deg > max_pos)
                act = rem[src_loc] & (pos_e >= max_pos) & bitmap.test(
                    fw_global, col)
                e_min = jnp.full((n_loc,), m_loc, jnp.int32).at[src_loc].min(
                    jnp.where(act, e, m_loc))
                hit2 = e_min < m_loc
                parent = jnp.where(
                    hit2, col[jnp.clip(e_min, 0, m_loc - 1)], parent)
                new = (found | hit2) & unv
                return new, visited | new, parent

            frontier, visited, parent = jax.lax.cond(
                td, run_td, run_bu, (frontier, visited, parent))
            depth = jnp.where(frontier, layer + 1, depth)
            nonempty = jax.lax.psum(jnp.sum(frontier, dtype=jnp.int32),
                                    axes) > 0
            return frontier, visited, parent, depth, td, layer + 1, nonempty

        state = (frontier, visited, parent, depth,
                 jnp.bool_(mode != "bottomup"), jnp.int32(0),
                 jnp.bool_(True))
        state = jax.lax.while_loop(cond_fn, layer_fn, state)
        parent, depth, layers = state[2], state[3], state[5]
        parent_full = jax.lax.all_gather(parent, axes, tiled=True)
        depth_full = jax.lax.all_gather(depth, axes, tiled=True)
        return parent_full, depth_full, layers

    spec_dev = P(axes)   # leading dim sharded over all mesh axes jointly
    # out_specs=P(): outputs are replicated (all_gather / psum products);
    # the static VMA check can't see through the while_loop, so disable it.
    parent_full, depth_full, layers = compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, spec_dev, P()),
        out_specs=(P(), P(), P()), check_vma=False,
    )(row_ptr_s, col_s, srcloc_s, deg_s, root)
    return parent_full[:n_orig], depth_full[:n_orig], layers


def dist_bfs(dg: DistGraph, root, mesh: Mesh, mode: str = "hybrid",
             alpha: float = 14.0, beta: float = 24.0, max_pos: int = 8,
             probe_impl: str = "xla") -> DistBFSResult:
    """Run distributed BFS; returns ``DistBFSResult(parent, depth,
    num_layers)`` with the serial/MS engines' -1 dead-vertex sentinel."""
    ndev = int(np.prod(mesh.devices.shape))
    parent, depth, layers = _dist_bfs_impl(
        dg.row_ptr, dg.col_idx, dg.src_loc, dg.deg, jnp.int32(root),
        mesh=mesh, mode=mode, alpha=alpha, beta=beta, max_pos=max_pos,
        n=dg.n, n_loc=dg.n // ndev, m_loc=dg.m_loc, n_orig=dg.n_orig,
        probe_impl=probe_impl)
    return DistBFSResult(parent=parent, depth=depth, num_layers=layers)
