"""Bit-packed multi-source BFS (MS-BFS) — batched traversal subsystem.

The paper vectorises ONE frontier across SIMD lanes; this module lifts the
same insight one level up (Then et al., "The More the Merrier"; SlimSell):
independent BFS traversals run concurrently by packing per-root state into
uint32 *lane words* — bit ``r & 31`` of word ``r >> 5`` at row ``v`` means
"root r's traversal has reached v".

Two engines share the packed step formulations:

* ``msbfs`` — one batch of R <= ``MAX_LANES`` roots, a single
  ``lax.while_loop`` sweep (PR 1).
* the *pipelined* engine (``msbfs_pipelined`` and the
  ``msbfs_engine_*`` stepping API) — arbitrary root counts streamed
  through a fixed pool of ``lanes`` bit-lanes. Roots live in a pending
  queue; the moment a lane's traversal finishes (frontier empty or the
  MAX_TRACE cap), its per-root results are flushed to the output slot and
  the lane is *immediately refilled* from the queue — no barrier between
  word-batches, so deep lanes never stall shallow ones. ``W`` (lane words
  per vertex) derives from the active lane pool, not a hard-coded
  ``MAX_LANES // 32``. New roots may be enqueued mid-sweep
  (``msbfs_engine_enqueue``) — the serving entry point
  ``repro.launch.serve_bfs`` drives exactly that loop.

State layout (all static shapes, jit-friendly):
  frontier : uint32[n, W]   W = ceil(num_roots / 32) lane words per vertex
  visited  : uint32[n, W]
  depth    : int32[n, R]    per-lane depth, -1 unreached

Both traversal directions become pure bitwise word ops:
  * top-down   — every edge lane contributes ``frontier[col] & td_sel``;
    per-row OR via a segmented associative scan (CSR rows are contiguous,
    so segment-OR is an ``lax.associative_scan`` with a segment-start flag).
  * bottom-up  — the paper's MAX_POS probe, word-packed: each vertex
    gathers the lane words of its first MAX_POS neighbours and ORs them
    (``repro.kernels.msbfs_probe`` is the Pallas analog); rows with
    deg > MAX_POS and unserved lanes fall back to the segmented scan,
    lax.cond-skipped when the probe retired everything.

Direction is chosen *per lane* each layer with the same alpha/beta rule as
the scalar controller (``repro.core.hybrid.switch_direction``): lanes in
top-down mode are selected by ``td_sel`` words, bottom-up lanes by
``bu_sel``, and the two partial frontiers are OR-merged.

Parent selection: parents are derived once at the end from the depth
arrays (min-id neighbour one level up), so they are *valid* Graph500
parents; serial ``bfs`` picks the min frontier-neighbour per layer, which
coincides for the min-parent rule — tests assert exact parent equality on
top of validator-level equivalence.

The packed step formulations themselves (lane packing, the segmented-OR
scan, the word-packed probe, per-lane direction dispatch) live in
``repro.core.packed`` — ONE implementation shared with the sharded engine
``repro.core.dist_msbfs`` (re-exported here for compatibility).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSRGraph
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT, MAX_TRACE
from repro.core.packed import (LANE_WORD_BITS, MODES, adaptive_lane_pool,
                               depth_slice_words, dispatch_packed_step,
                               lane_counters, num_lane_words, pack_lanes,
                               queue_claims, segment_or,
                               select_direction, unpack_lanes, word_dtype)

__all__ = [
    "LANE_WORD_BITS", "LayerReadout", "MAX_LANES", "MODES", "MSBFSResult",
    "adaptive_lane_pool", "depth_slice_words", "msbfs",
    "msbfs_engine_drain", "msbfs_engine_enqueue", "msbfs_engine_idle",
    "msbfs_engine_init", "msbfs_engine_readout", "msbfs_engine_result",
    "msbfs_engine_retire", "msbfs_engine_step", "msbfs_engine_stream",
    "msbfs_pipelined", "num_lane_words", "pack_lanes", "segment_or",
    "unpack_lanes",
]

MAX_LANES = 64          # two uint32 words of roots per batch


class MSBFSResult(NamedTuple):
    parent: jnp.ndarray          # int32[n, R], -1 unreached, parent[root_r, r]=root_r
    depth: jnp.ndarray           # int32[n, R], -1 unreached
    num_layers: jnp.ndarray      # int32[R] — layers until lane r's frontier emptied
    edges_traversed: jnp.ndarray  # int32[R] — 2x undirected component edges per lane
    trace_dir: jnp.ndarray       # int32[MAX_TRACE, R]: 0 TD, 1 BU, -1 lane idle
    trace_vf: jnp.ndarray        # int32[MAX_TRACE, R]
    trace_ef: jnp.ndarray        # int32[MAX_TRACE, R]
    trace_eu: jnp.ndarray        # int32[MAX_TRACE, R]

    def reached_words(self, max_depth=None, min_depth=0) -> jnp.ndarray:
        """Packed lane words over the depth band [min_depth, max_depth] —
        the engines' own bit layout, recovered from the result. With the
        defaults this is each lane's full reached set; ``max_depth=k``
        slices the k-hop neighbourhood (``repro.analytics.khop`` rides
        this), ``min_depth=max_depth=d`` reconstructs the layer-d
        frontier."""
        if max_depth is None:
            max_depth = jnp.iinfo(jnp.int32).max
        return depth_slice_words(self.depth, max_depth, min_depth)


class _State(NamedTuple):
    frontier: jnp.ndarray        # uint32[n, W]
    visited: jnp.ndarray         # uint32[n, W]
    depth: jnp.ndarray           # int32[n, R]
    topdown: jnp.ndarray         # bool[R]
    layer: jnp.ndarray           # int32 scalar
    trace_dir: jnp.ndarray
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray


def _derive_parents(g: CSRGraph, depth: jnp.ndarray, roots: jnp.ndarray,
                    lane_chunk: int = 16) -> jnp.ndarray:
    """parent[v, r] = min-id neighbour of v one level up in lane r.

    Chunked over lanes to bound the [m, chunk] candidate buffer. The min-id
    rule matches the serial steps' deterministic scatter-min parent choice.
    """
    n, m = g.n, g.m
    num_roots = roots.shape[0]
    if num_roots == 0:
        return jnp.zeros((n, 0), jnp.int32)
    src, col = g.src_idx, g.col_idx
    outs = []
    for lo in range(0, num_roots, lane_chunk):
        d = depth[:, lo:lo + lane_chunk]                    # int32[n, c]
        ok = (d[col] >= 0) & (d[col] + 1 == d[src])         # [m, c]
        cand = jnp.where(ok, col[:, None], n).astype(jnp.int32)
        best = jnp.full((n, d.shape[1]), n, jnp.int32).at[src].min(cand)
        outs.append(jnp.where(best < n, best, -1))
    parent = jnp.concatenate(outs, axis=1)
    lane = jnp.arange(num_roots)
    return parent.at[roots, lane].set(roots.astype(jnp.int32))


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def msbfs(g: CSRGraph, roots: jnp.ndarray, mode: str = "hybrid",
          alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
          max_pos: int = 8, probe_impl: str = "xla") -> MSBFSResult:
    """Run up to MAX_LANES BFS traversals concurrently, one bit-lane each.

    Args:
      roots: int[R] root vertex per lane, R <= 64. Compiles once per
        (graph shape, R, mode) — the Graph500 batched harness answers all
        64 roots with a single executable sweep.
      mode: "hybrid" (per-lane alpha/beta switching), "topdown", "bottomup".
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    n = g.n
    roots = roots.astype(jnp.int32)
    num_roots = roots.shape[0]
    if num_roots > MAX_LANES:
        raise ValueError(f"at most {MAX_LANES} roots per batch, "
                         f"got {num_roots} — use msbfs_pipelined for "
                         f"arbitrary root counts")
    w = num_lane_words(num_roots)
    root_onehot = roots[None, :] == jnp.arange(n, dtype=jnp.int32)[:, None]
    frontier0 = pack_lanes(root_onehot)                      # uint32[n, W]
    lane_mask = pack_lanes(jnp.ones((num_roots,), jnp.bool_))  # uint32[W]

    def cond_fn(s: _State):
        return jnp.any(s.frontier != 0) & (s.layer < MAX_TRACE)

    def body_fn(s: _State):
        frontier_b = unpack_lanes(s.frontier, num_roots)
        visited_b = unpack_lanes(s.visited, num_roots)
        e_f, v_f, e_u = lane_counters(g, frontier_b, visited_b)
        topdown = select_direction(mode, s.topdown, e_f, v_f, e_u, n,
                                   alpha, beta, num_roots)

        # dead lanes (empty frontier) leave BOTH selectors: the switch rule
        # flips them to TD (v_f = 0 < n/beta), which would otherwise keep
        # td_sel nonzero forever and defeat the cond-skip in the dispatch
        live = v_f > 0
        td_sel = pack_lanes(topdown & live) & lane_mask      # uint32[W]
        bu_sel = pack_lanes(~topdown & live) & lane_mask
        new = dispatch_packed_step(g, s.frontier, s.visited, td_sel,
                                   bu_sel, mode, max_pos, probe_impl)

        depth2 = jnp.where(unpack_lanes(new, num_roots), s.layer + 1, s.depth)
        i = s.layer
        # dead lanes record nothing (-1 dir, zero counters) — the rows a
        # finished lane never ran must read identically to the serial
        # trace and to the pipelined engine, which retires the lane
        return _State(
            frontier=new, visited=s.visited | new, depth=depth2,
            topdown=topdown, layer=i + 1,
            trace_dir=s.trace_dir.at[i].set(
                jnp.where(live, jnp.where(topdown, 0, 1),
                          -1).astype(jnp.int32)),
            trace_vf=s.trace_vf.at[i].set(jnp.where(live, v_f, 0)),
            trace_ef=s.trace_ef.at[i].set(jnp.where(live, e_f, 0)),
            trace_eu=s.trace_eu.at[i].set(jnp.where(live, e_u, 0)),
        )

    init = _State(
        frontier=frontier0, visited=frontier0,
        depth=jnp.where(root_onehot, 0, -1).astype(jnp.int32),
        topdown=jnp.full((num_roots,), mode != "bottomup"),
        layer=jnp.int32(0),
        trace_dir=jnp.full((MAX_TRACE, num_roots), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
    )
    s = jax.lax.while_loop(cond_fn, body_fn, init)

    visited_b = unpack_lanes(s.visited, num_roots)
    deg = g.deg.astype(jnp.int32)[:, None]
    edges = jnp.sum(jnp.where(visited_b, deg, 0), axis=0,
                    dtype=jnp.int32)
    # a cap-terminated lane ran exactly MAX_TRACE layers (the serial
    # controller's loop bound and the pipelined engine's flush agree)
    num_layers = jnp.minimum(jnp.max(s.depth, axis=0) + 1, MAX_TRACE)
    parent = _derive_parents(g, s.depth, roots)
    return MSBFSResult(parent=parent, depth=s.depth, num_layers=num_layers,
                       edges_traversed=edges, trace_dir=s.trace_dir,
                       trace_vf=s.trace_vf, trace_eu=s.trace_eu,
                       trace_ef=s.trace_ef)


# ---------------------------------------------------------------------------
# Pipelined engine: arbitrary root counts through a fixed bit-lane pool.
#
# State invariants (maintained by _refill / the step body):
#   * lane_qidx[l] < capacity  <=>  lane l is serving queue slot lane_qidx[l];
#     idle lanes hold lane_qidx == capacity and have all-zero frontier /
#     visited bits and an all -1 depth column.
#   * queue[:queued] holds enqueued roots; queue slots [next_root, queued)
#     are pending. Every claimed slot is served by exactly one lane until
#     its traversal finishes, then flushed to out_* column lane_qidx[l].
#   * out_layers[q] > 0  <=>  query q has been answered (flushed).
# Output arrays carry one trailing *trash* column (index == capacity) that
# absorbs the per-layer scatter of non-finished lanes, keeping the flush a
# single static-shape write.
# ---------------------------------------------------------------------------


class PipelineState(NamedTuple):
    frontier: jnp.ndarray        # uint32[n, W]  packed lane frontiers
    visited: jnp.ndarray         # uint32[n, W]
    depth: jnp.ndarray           # int32[n, L]   active-lane depths (-1 unreached)
    lane_layer: jnp.ndarray      # int32[L]      steps run for the lane's root
    lane_qidx: jnp.ndarray       # int32[L]      queue slot served; capacity = idle
    topdown: jnp.ndarray         # bool[L]
    queue: jnp.ndarray           # int32[capacity] enqueued root ids
    queued: jnp.ndarray          # int32 scalar  number of roots enqueued
    next_root: jnp.ndarray       # int32 scalar  next queue slot to claim
    sweep_layers: jnp.ndarray    # int32 scalar  total engine steps run
    out_depth: jnp.ndarray       # int32[n, capacity+1]
    out_edges: jnp.ndarray       # int32[capacity+1]
    out_layers: jnp.ndarray      # int32[capacity+1]  0 = unanswered
    trace_dir: jnp.ndarray       # int32[MAX_TRACE, capacity+1]
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray

    @property
    def num_lanes(self) -> int:
        return self.lane_qidx.shape[0]

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def msbfs_engine_init(g: CSRGraph, capacity: int,
                      lanes: int = MAX_LANES) -> PipelineState:
    """Fresh engine: all lanes idle, empty root queue of ``capacity`` slots.

    ``lanes`` is the concurrency (bit-lane pool size); ``W`` lane words per
    vertex derive from it. A capacity larger than ``lanes`` is the whole
    point: excess roots wait in the queue and stream into lanes as they
    free up.
    """
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    n = g.n
    w = num_lane_words(lanes)
    cap = capacity
    return PipelineState(
        frontier=jnp.zeros((n, w), word_dtype()),
        visited=jnp.zeros((n, w), word_dtype()),
        depth=jnp.full((n, lanes), -1, jnp.int32),
        lane_layer=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        topdown=jnp.ones((lanes,), jnp.bool_),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_layers=jnp.int32(0),
        out_depth=jnp.full((n, cap + 1), -1, jnp.int32),
        out_edges=jnp.zeros((cap + 1,), jnp.int32),
        out_layers=jnp.zeros((cap + 1,), jnp.int32),
        trace_dir=jnp.full((MAX_TRACE, cap + 1), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
    )


def msbfs_engine_enqueue(state: PipelineState,
                         roots: jnp.ndarray) -> PipelineState:
    """Append roots to the pending queue (host helper, mid-sweep safe).

    The roots land in idle lanes on the next ``msbfs_engine_step`` — the
    streaming-root path: a sweep in flight keeps absorbing new queries.
    """
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    k = roots.shape[0]
    queued = int(state.queued)
    if queued + k > state.capacity:
        raise ValueError(
            f"queue overflow: {queued} queued + {k} new > capacity "
            f"{state.capacity}")
    queue = jax.lax.dynamic_update_slice(state.queue, roots,
                                         (state.queued,))
    return state._replace(queue=queue, queued=state.queued + jnp.int32(k))


def msbfs_engine_idle(state: PipelineState) -> bool:
    """True when no lane is active and no enqueued root is pending."""
    return (int(state.next_root) >= int(state.queued)
            and not bool(jnp.any(state.lane_qidx < state.capacity)))


def _refill(g: CSRGraph, s: PipelineState, topdown_init: bool) -> PipelineState:
    """Claim pending queue slots for idle lanes and seat their roots.

    The O(n * lanes) seat-building is lax.cond-skipped in the steady state
    (no idle lane or no pending root — e.g. the whole deep tail of a
    sweep), the same pattern as the TD/BU dispatch."""
    n = g.n
    cap = s.capacity

    def do_refill(s: PipelineState) -> PipelineState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        fresh = pack_lanes(onehot)                            # uint32[n, W]
        return s._replace(
            frontier=s.frontier | fresh,
            visited=s.visited | fresh,
            depth=jnp.where(claim[None, :],
                            jnp.where(onehot, 0, -1), s.depth),
            lane_layer=jnp.where(claim, 0, s.lane_layer),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            topdown=jnp.where(claim, topdown_init, s.topdown),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= cap) & (s.next_root < s.queued)
    return jax.lax.cond(needed, do_refill, lambda s: s, s)


def _pipeline_body(g: CSRGraph, s: PipelineState, mode: str, alpha: float,
                   beta: float, max_pos: int,
                   probe_impl: str) -> PipelineState:
    """One engine step: refill idle lanes, advance one layer, flush finished
    lanes to their output slots."""
    n = g.n
    lanes = s.lane_qidx.shape[0]
    cap = s.queue.shape[0]
    s = _refill(g, s, mode != "bottomup")

    active = s.lane_qidx < cap
    frontier_b = unpack_lanes(s.frontier, lanes)
    visited_b = unpack_lanes(s.visited, lanes)
    e_f, v_f, e_u = lane_counters(g, frontier_b, visited_b)
    topdown = select_direction(mode, s.topdown, e_f, v_f, e_u, n,
                               alpha, beta, lanes)

    live = active & (v_f > 0)
    td_sel = pack_lanes(topdown & live)                       # uint32[W]
    bu_sel = pack_lanes(~topdown & live)

    # per-root trace rows are indexed by the lane's OWN layer counter and
    # its queue slot, so a root's trace replays its serial run regardless
    # of which lane served it or when it was claimed
    tr_row = jnp.clip(s.lane_layer, 0, MAX_TRACE - 1)
    tr_col = jnp.where(active, s.lane_qidx, cap)
    # int32 up front: under x64 a weak-int64 scatter value into the
    # int32 trace will become an error in future jax
    dir_vals = jnp.where(live, jnp.where(topdown, 0, 1),
                         -1).astype(jnp.int32)
    trace_dir = s.trace_dir.at[tr_row, tr_col].set(dir_vals)
    trace_vf = s.trace_vf.at[tr_row, tr_col].set(v_f)
    trace_ef = s.trace_ef.at[tr_row, tr_col].set(e_f)
    trace_eu = s.trace_eu.at[tr_row, tr_col].set(e_u)

    new = dispatch_packed_step(g, s.frontier, s.visited, td_sel, bu_sel,
                               mode, max_pos, probe_impl)

    new_b = unpack_lanes(new, lanes)
    visited2 = s.visited | new
    visited2_b = visited_b | new_b
    lane_layer2 = s.lane_layer + active.astype(jnp.int32)
    depth2 = jnp.where(new_b, lane_layer2[None, :], s.depth)

    # finish = frontier drained OR per-lane layer cap (mirrors the serial
    # while-loop bound, and guarantees the drain loop terminates)
    finished = active & (~new_b.any(axis=0) | (lane_layer2 >= MAX_TRACE))

    deg = g.deg.astype(jnp.int32)[:, None]
    edges_l = jnp.sum(jnp.where(visited2_b, deg, 0), axis=0,
                      dtype=jnp.int32)
    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_depth = s.out_depth.at[:, fcol].set(depth2)
    out_edges = s.out_edges.at[fcol].set(edges_l)
    out_layers = s.out_layers.at[fcol].set(lane_layer2)

    # retire finished lanes: zero their packed bits so _refill can seat a
    # fresh root into the slot on the very next step
    clear = pack_lanes(finished)                              # uint32[W]
    return s._replace(
        frontier=new & ~clear,
        visited=visited2 & ~clear,
        depth=jnp.where(finished[None, :], -1, depth2),
        lane_layer=jnp.where(finished, 0, lane_layer2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        topdown=topdown,
        sweep_layers=s.sweep_layers + 1,
        out_depth=out_depth, out_edges=out_edges, out_layers=out_layers,
        trace_dir=trace_dir, trace_vf=trace_vf, trace_ef=trace_ef,
        trace_eu=trace_eu,
    )


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def msbfs_engine_step(g: CSRGraph, state: PipelineState, mode: str = "hybrid",
                      alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
                      max_pos: int = 8,
                      probe_impl: str = "xla") -> PipelineState:
    """Advance the pipelined engine by one traversal layer (streaming API).

    Compiles once per (graph shape, lanes, capacity, mode); the serving loop
    interleaves ``msbfs_engine_enqueue`` calls between steps to feed idle
    lanes mid-sweep.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    return _pipeline_body(g, state, mode, alpha, beta, max_pos, probe_impl)


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def _drain(g: CSRGraph, state: PipelineState, mode: str, alpha: float,
           beta: float, max_pos: int, probe_impl: str) -> PipelineState:
    cap = state.queue.shape[0]

    def cond_fn(s: PipelineState):
        return (s.next_root < s.queued) | jnp.any(s.lane_qidx < cap)

    def body_fn(s: PipelineState):
        return _pipeline_body(g, s, mode, alpha, beta, max_pos, probe_impl)

    return jax.lax.while_loop(cond_fn, body_fn, state)


def msbfs_engine_drain(g: CSRGraph, state: PipelineState,
                       mode: str = "hybrid", alpha: float = ALPHA_DEFAULT,
                       beta: float = BETA_DEFAULT, max_pos: int = 8,
                       probe_impl: str = "xla") -> PipelineState:
    """Step the engine until every enqueued root has been answered."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    return _drain(g, state, mode, alpha, beta, max_pos, probe_impl)


def msbfs_engine_result(g: CSRGraph, state: PipelineState,
                        derive_parents: bool = True) -> MSBFSResult:
    """Assemble an ``MSBFSResult`` over the answered queue slots.

    Columns of unanswered slots (``out_layers == 0``) hold init values
    (-1 depths); callers normally drain first. ``derive_parents=False``
    skips the O(m)-per-lane-chunk parent scatter and returns a
    zero-width ``parent`` — the depth-only contract the analytics
    workloads consume.
    """
    r = int(state.queued)
    depth = state.out_depth[:, :r]
    roots = state.queue[:r]
    parent = (_derive_parents(g, depth, roots) if derive_parents
              else jnp.zeros((g.n, 0), jnp.int32))
    return MSBFSResult(
        parent=parent, depth=depth, num_layers=state.out_layers[:r],
        edges_traversed=state.out_edges[:r],
        trace_dir=state.trace_dir[:, :r], trace_vf=state.trace_vf[:, :r],
        trace_ef=state.trace_ef[:, :r], trace_eu=state.trace_eu[:, :r])


# ---------------------------------------------------------------------------
# Mid-sweep read-out: the per-layer streaming surface.
#
# BFS depth finality: once a lane has run t layers, every depth value
# <= t in its column is FINAL (level-synchronous traversal never revisits
# a vertex). So a depth-k query (khop band, reach hit) is answerable the
# moment its lane's layer counter passes k — layers before the lane would
# naturally flush. ``LayerReadout`` is that surface; ``msbfs_engine_retire``
# is the matching unlock: flush an answered lane's partial column to its
# output slot NOW and hand the lane back to the pool.
# ---------------------------------------------------------------------------


class LayerReadout(NamedTuple):
    """Host-side snapshot of the engine's per-lane depth surface after a
    step — everything a streaming consumer needs to answer depth-bounded
    queries mid-sweep (``repro.serving`` drives this each layer)."""
    layer: int                   # total engine steps run (sweep clock)
    capacity: int                # queue capacity (lane_qidx == capacity = idle)
    lane_qidx: np.ndarray        # int32[L] queue slot served per lane
    lane_layer: np.ndarray       # int32[L] layers run for the lane's root
    depth: np.ndarray            # int32[n, L] live per-lane depths
    out_depth: np.ndarray        # int32[n, capacity+1] flushed columns
    out_layers: np.ndarray       # int32[capacity+1]  0 = unanswered

    def active(self) -> np.ndarray:
        """bool[L] — lane currently serving a queue slot."""
        return self.lane_qidx < self.capacity

    def band_final(self, k: int) -> np.ndarray:
        """bool[L] — active lane whose ``depth <= k`` band is final (it
        has run at least ``k`` layers; depths are never rewritten)."""
        return self.active() & (self.lane_layer >= k)

    def lane_of_slot(self, q: int) -> int:
        """Lane currently serving queue slot ``q`` (-1 if none)."""
        hit = np.flatnonzero(self.lane_qidx == q)
        return int(hit[0]) if hit.size else -1

    def slot_depth(self, q: int) -> np.ndarray | None:
        """Depth column for queue slot ``q``: the flushed output column
        once answered, the live lane column while in flight, None before
        the root is seated."""
        if self.out_layers[q] > 0:
            return self.out_depth[:, q]
        lane = self.lane_of_slot(q)
        return self.depth[:, lane] if lane >= 0 else None

    def slice_words(self, max_depth: int, min_depth: int = 0) -> np.ndarray:
        """``packed.depth_slice_words`` over the LIVE lane depths — the
        engines' own packed bit layout, mid-sweep."""
        return np.asarray(depth_slice_words(self.depth, max_depth,
                                            min_depth))


def msbfs_engine_readout(state: PipelineState) -> LayerReadout:
    """Snapshot the streaming read-out surface of the host engine."""
    return LayerReadout(
        layer=int(state.sweep_layers), capacity=state.capacity,
        lane_qidx=np.asarray(state.lane_qidx),
        lane_layer=np.asarray(state.lane_layer),
        depth=np.asarray(state.depth),
        out_depth=np.asarray(state.out_depth),
        out_layers=np.asarray(state.out_layers))


def msbfs_engine_stream(g: CSRGraph, state: PipelineState,
                        mode: str = "hybrid", alpha: float = ALPHA_DEFAULT,
                        beta: float = BETA_DEFAULT, max_pos: int = 8,
                        probe_impl: str = "xla"):
    """Iterate the engine to idleness, yielding ``(state, LayerReadout)``
    after every layer — the streaming-callback form of
    ``msbfs_engine_drain``. The caller may enqueue new roots or retire
    answered lanes between yields; the loop re-checks idleness against
    the state it yielded, so keep stepping the LAST yielded state."""
    while not msbfs_engine_idle(state):
        state = msbfs_engine_step(g, state, mode, alpha, beta, max_pos,
                                  probe_impl)
        yield state, msbfs_engine_readout(state)


@jax.jit
def _retire(g: CSRGraph, state: PipelineState,
            lane_mask: jnp.ndarray) -> PipelineState:
    cap = state.capacity
    mask = lane_mask & (state.lane_qidx < cap)
    visited_b = unpack_lanes(state.visited, state.num_lanes)
    deg = g.deg.astype(jnp.int32)[:, None]
    edges_l = jnp.sum(jnp.where(visited_b, deg, 0), axis=0, dtype=jnp.int32)
    # the flush pattern of _pipeline_body: masked lanes write their queue
    # slot, everyone else the trailing trash column
    fcol = jnp.where(mask, state.lane_qidx, cap)
    out_depth = state.out_depth.at[:, fcol].set(state.depth)
    out_edges = state.out_edges.at[fcol].set(edges_l)
    # out_layers > 0 is the answered flag; a lane retired before its
    # first step (k = 0 band) still counts one layer
    out_layers = state.out_layers.at[fcol].set(
        jnp.maximum(state.lane_layer, 1))
    clear = pack_lanes(mask)
    return state._replace(
        frontier=state.frontier & ~clear,
        visited=state.visited & ~clear,
        depth=jnp.where(mask, -1, state.depth),
        lane_layer=jnp.where(mask, 0, state.lane_layer),
        lane_qidx=jnp.where(mask, cap, state.lane_qidx),
        out_depth=out_depth, out_edges=out_edges, out_layers=out_layers)


def msbfs_engine_retire(g: CSRGraph, state: PipelineState,
                        lane_mask) -> PipelineState:
    """Retire the masked ACTIVE lanes early: flush their depth columns to
    their output slots as-is and free the lanes for the pending queue.

    The streaming unlock behind depth-k serving: once ``LayerReadout
    .band_final(k)`` says a khop/reach lane's band is final, the answer
    no longer needs the lane — retiring it mid-sweep returns its bit
    lane to the pool layers before the traversal would drain. A retired
    slot's output column is PARTIAL past the retirement layer (exactly
    the band the caller declared final); ``out_layers`` records the
    layers actually run. Idle lanes in the mask are ignored."""
    lane_mask = jnp.asarray(lane_mask, jnp.bool_).reshape(-1)
    if lane_mask.shape[0] != state.num_lanes:
        raise ValueError(
            f"lane_mask has {lane_mask.shape[0]} lanes, engine has "
            f"{state.num_lanes}")
    return _retire(g, state, lane_mask)


def msbfs_pipelined(g: CSRGraph, roots: jnp.ndarray, mode: str = "hybrid",
                    alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
                    max_pos: int = 8, probe_impl: str = "xla",
                    lanes: int = MAX_LANES, derive_parents: bool = True,
                    recorder=None) -> MSBFSResult:
    """Answer an arbitrary number of roots in ONE pipelined engine sweep.

    Splits R > ``lanes`` roots across bit-lane word-batches WITHOUT batch
    barriers: each finished lane refills from the pending-root queue on the
    next layer, so the sweep's critical path is set by total traversal
    work, not by the deepest root of each 64-root batch. With R <= lanes
    the lane pool shrinks to ``ceil32(R)`` lanes and this reduces to the
    single-batch ``msbfs`` sweep (same packed steps, same results).

    ``recorder`` (a ``repro.obs.SweepRecorder``) switches the fused drain
    for a host step-loop that records a ``LayerRecord`` per layer — the
    step and the drain share ``_pipeline_body``, so results and traces
    are bit-identical either way; with ``recorder=None`` (the default)
    nothing from ``repro.obs`` is imported or executed.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one root")
    # W derives from the ACTIVE batch: small R never pays for idle words
    lanes = max(1, min(lanes, LANE_WORD_BITS * num_lane_words(num_roots)))
    state = msbfs_engine_init(g, capacity=num_roots, lanes=lanes)
    state = msbfs_engine_enqueue(state, roots)
    if recorder is None:
        state = msbfs_engine_drain(g, state, mode, alpha, beta, max_pos,
                                   probe_impl)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: msbfs_engine_step(g, s, mode, alpha, beta, max_pos,
                                        probe_impl),
            msbfs_engine_idle, kind="bfs")
    return msbfs_engine_result(g, state, derive_parents=derive_parents)
