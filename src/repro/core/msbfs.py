"""Bit-packed multi-source BFS (MS-BFS) — batched traversal subsystem.

The paper vectorises ONE frontier across SIMD lanes; this module lifts the
same insight one level up (Then et al., "The More the Merrier"; SlimSell):
up to ``MAX_LANES`` (64) independent BFS traversals run concurrently by
packing per-root state into uint32 *lane words* — bit ``r & 31`` of word
``r >> 5`` at row ``v`` means "root r's traversal has reached v".

State layout (all static shapes, jit-friendly):
  frontier : uint32[n, W]   W = ceil(num_roots / 32) lane words per vertex
  visited  : uint32[n, W]
  depth    : int32[n, R]    per-lane depth, -1 unreached

Both traversal directions become pure bitwise word ops:
  * top-down   — every edge lane contributes ``frontier[col] & td_sel``;
    per-row OR via a segmented associative scan (CSR rows are contiguous,
    so segment-OR is an ``lax.associative_scan`` with a segment-start flag).
  * bottom-up  — the paper's MAX_POS probe, word-packed: each vertex
    gathers the lane words of its first MAX_POS neighbours and ORs them
    (``repro.kernels.msbfs_probe`` is the Pallas analog); rows with
    deg > MAX_POS and unserved lanes fall back to the segmented scan,
    lax.cond-skipped when the probe retired everything.

Direction is chosen *per lane* each layer with the same alpha/beta rule as
the scalar controller (``repro.core.hybrid.switch_direction``): lanes in
top-down mode are selected by ``td_sel`` words, bottom-up lanes by
``bu_sel``, and the two partial frontiers are OR-merged.

Parent selection: parents are derived once at the end from the depth
arrays (min-id neighbour one level up), so they are *valid* Graph500
parents; serial ``bfs`` picks the min frontier-neighbour per layer, which
coincides for the min-parent rule — tests assert exact parent equality on
top of validator-level equivalence.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.csr import CSRGraph
from repro.core.hybrid import (ALPHA_DEFAULT, BETA_DEFAULT, MAX_TRACE,
                               switch_direction)

MAX_LANES = 64          # two uint32 words of roots per batch
LANE_WORD_BITS = 32

MODES = ("hybrid", "topdown", "bottomup")


class MSBFSResult(NamedTuple):
    parent: jnp.ndarray          # int32[n, R], -1 unreached, parent[root_r, r]=root_r
    depth: jnp.ndarray           # int32[n, R], -1 unreached
    num_layers: jnp.ndarray      # int32[R] — layers until lane r's frontier emptied
    edges_traversed: jnp.ndarray  # int32[R] — 2x undirected component edges per lane
    trace_dir: jnp.ndarray       # int32[MAX_TRACE, R]: 0 TD, 1 BU, -1 lane idle
    trace_vf: jnp.ndarray        # int32[MAX_TRACE, R]
    trace_ef: jnp.ndarray        # int32[MAX_TRACE, R]
    trace_eu: jnp.ndarray        # int32[MAX_TRACE, R]


class _State(NamedTuple):
    frontier: jnp.ndarray        # uint32[n, W]
    visited: jnp.ndarray         # uint32[n, W]
    depth: jnp.ndarray           # int32[n, R]
    topdown: jnp.ndarray         # bool[R]
    layer: jnp.ndarray           # int32 scalar
    trace_dir: jnp.ndarray
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray


def num_lane_words(num_roots: int) -> int:
    return (num_roots + LANE_WORD_BITS - 1) // LANE_WORD_BITS


def pack_lanes(mask: jnp.ndarray) -> jnp.ndarray:
    """Pack bool[..., R] lane masks into uint32[..., W] words (LSB-first)."""
    r = mask.shape[-1]
    w = num_lane_words(r)
    pad = w * LANE_WORD_BITS - r
    if pad:
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), mask.dtype)], axis=-1)
    lanes = mask.reshape(mask.shape[:-1] + (w, LANE_WORD_BITS))
    weights = jnp.uint32(1) << jnp.arange(LANE_WORD_BITS, dtype=jnp.uint32)
    return (lanes.astype(jnp.uint32) * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_lanes(words: jnp.ndarray, num_roots: int) -> jnp.ndarray:
    """Unpack uint32[..., W] lane words into bool[..., R]."""
    shifts = jnp.arange(LANE_WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (-1,))
    return flat[..., :num_roots].astype(jnp.bool_)


def segment_or(vals: jnp.ndarray, row_ptr: jnp.ndarray) -> jnp.ndarray:
    """Per-CSR-row bitwise OR of uint32[m, W] edge-lane words -> uint32[n, W].

    CSR rows are contiguous runs of edge slots, so the row-OR is a textbook
    segmented scan: an inclusive ``lax.associative_scan`` over
    (word, segment-start-flag) pairs, read out at each row's last slot.
    Empty rows produce 0.
    """
    m = vals.shape[0]
    # row starts equal to m (trailing empty rows) must not flag slot m-1
    flags = jnp.zeros((m,), jnp.bool_).at[row_ptr[:-1]].set(True, mode="drop")

    def comb(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb[..., None], vb, va | vb), fa | fb

    scanned, _ = jax.lax.associative_scan(comb, (vals, flags))
    deg = row_ptr[1:] - row_ptr[:-1]
    last = jnp.clip(row_ptr[1:] - 1, 0, m - 1)
    return jnp.where((deg > 0)[:, None], scanned[last], jnp.uint32(0))


def _probe_xla(g: CSRGraph, frontier: jnp.ndarray, need: jnp.ndarray,
               max_pos: int) -> jnp.ndarray:
    """Word-packed MAX_POS probe, XLA formulation (static unroll).

    For each vertex, OR the lane words of its first ``max_pos`` neighbours,
    retiring the gather once every needed lane has found a parent. The
    result must be masked with ``need`` by the caller.
    """
    m = g.m
    starts = g.row_ptr[:-1]
    deg = g.deg
    acc = jnp.zeros_like(need)
    for pos in range(max_pos):
        live = ((need & ~acc) != 0).any(axis=-1) & (pos < deg)
        vadj = g.col_idx[jnp.clip(starts + pos, 0, m - 1)]
        acc = acc | jnp.where(live[:, None], frontier[vadj], jnp.uint32(0))
    return acc


def _bottomup_packed_step(g: CSRGraph, frontier: jnp.ndarray,
                          visited: jnp.ndarray, bu_sel: jnp.ndarray,
                          max_pos: int, probe_impl: str) -> jnp.ndarray:
    """Packed bottom-up: probe + lax.cond-skipped segmented-scan fallback.
    Returns new frontier bits for bottom-up lanes (already & ~visited)."""
    need = (~visited) & bu_sel
    if probe_impl == "pallas":
        from repro.kernels.msbfs_probe import ops as probe_ops
        acc = probe_ops.msbfs_probe(g.row_ptr, g.col_idx, frontier, need,
                                    max_pos=max_pos)
    else:
        acc = _probe_xla(g, frontier, need, max_pos)
    found = acc & need

    residue = ((need & ~found) != 0).any(axis=-1) & (g.deg > max_pos)

    def run_fallback(found):
        pos_e = jnp.arange(g.m, dtype=jnp.int32) - g.row_ptr[g.src_idx]
        act = residue[g.src_idx] & (pos_e >= max_pos)
        contrib = jnp.where(act[:, None], frontier[g.col_idx], jnp.uint32(0))
        return found | (segment_or(contrib, g.row_ptr) & need)

    return jax.lax.cond(jnp.any(residue), run_fallback, lambda f: f, found)


def _topdown_packed_step(g: CSRGraph, frontier: jnp.ndarray,
                         visited: jnp.ndarray,
                         td_sel: jnp.ndarray) -> jnp.ndarray:
    """Packed top-down: every edge lane forwards its col-side frontier words
    (masked to top-down lanes); per-row segmented OR gathers them. On the
    symmetrised Graph500 graphs this is exactly the TD expansion — the row
    owner collects from neighbours whose frontier bit is set."""
    contrib = frontier[g.col_idx] & td_sel
    return segment_or(contrib, g.row_ptr) & ~visited


def _lane_counters(g: CSRGraph, frontier_b: jnp.ndarray,
                   visited_b: jnp.ndarray):
    """Per-lane (e_f, v_f, e_u) from unpacked bool[n, R] state."""
    deg = g.deg.astype(jnp.int32)[:, None]
    e_f = jnp.sum(jnp.where(frontier_b, deg, 0), axis=0)
    v_f = jnp.sum(frontier_b, axis=0, dtype=jnp.int32)
    e_u = jnp.sum(jnp.where(visited_b, 0, deg), axis=0)
    return e_f, v_f, e_u


def _derive_parents(g: CSRGraph, depth: jnp.ndarray, roots: jnp.ndarray,
                    lane_chunk: int = 16) -> jnp.ndarray:
    """parent[v, r] = min-id neighbour of v one level up in lane r.

    Chunked over lanes to bound the [m, chunk] candidate buffer. The min-id
    rule matches the serial steps' deterministic scatter-min parent choice.
    """
    n, m = g.n, g.m
    num_roots = roots.shape[0]
    src, col = g.src_idx, g.col_idx
    outs = []
    for lo in range(0, num_roots, lane_chunk):
        d = depth[:, lo:lo + lane_chunk]                    # int32[n, c]
        ok = (d[col] >= 0) & (d[col] + 1 == d[src])         # [m, c]
        cand = jnp.where(ok, col[:, None], n).astype(jnp.int32)
        best = jnp.full((n, d.shape[1]), n, jnp.int32).at[src].min(cand)
        outs.append(jnp.where(best < n, best, -1))
    parent = jnp.concatenate(outs, axis=1)
    lane = jnp.arange(num_roots)
    return parent.at[roots, lane].set(roots.astype(jnp.int32))


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def msbfs(g: CSRGraph, roots: jnp.ndarray, mode: str = "hybrid",
          alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
          max_pos: int = 8, probe_impl: str = "xla") -> MSBFSResult:
    """Run up to MAX_LANES BFS traversals concurrently, one bit-lane each.

    Args:
      roots: int[R] root vertex per lane, R <= 64. Compiles once per
        (graph shape, R, mode) — the Graph500 batched harness answers all
        64 roots with a single executable sweep.
      mode: "hybrid" (per-lane alpha/beta switching), "topdown", "bottomup".
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    n = g.n
    roots = roots.astype(jnp.int32)
    num_roots = roots.shape[0]
    if num_roots > MAX_LANES:
        raise ValueError(f"at most {MAX_LANES} roots per batch, "
                         f"got {num_roots}")
    w = num_lane_words(num_roots)
    lane_ids = jnp.arange(num_roots, dtype=jnp.int32)
    root_onehot = roots[None, :] == jnp.arange(n, dtype=jnp.int32)[:, None]
    frontier0 = pack_lanes(root_onehot)                      # uint32[n, W]
    lane_mask = pack_lanes(jnp.ones((num_roots,), jnp.bool_))  # uint32[W]

    def cond_fn(s: _State):
        return jnp.any(s.frontier != 0) & (s.layer < MAX_TRACE)

    def body_fn(s: _State):
        frontier_b = unpack_lanes(s.frontier, num_roots)
        visited_b = unpack_lanes(s.visited, num_roots)
        e_f, v_f, e_u = _lane_counters(g, frontier_b, visited_b)
        if mode == "topdown":
            topdown = jnp.ones((num_roots,), jnp.bool_)
        elif mode == "bottomup":
            topdown = jnp.zeros((num_roots,), jnp.bool_)
        else:
            topdown = switch_direction(s.topdown, e_f, v_f, e_u, n,
                                       alpha, beta)

        # dead lanes (empty frontier) leave BOTH selectors: the switch rule
        # flips them to TD (v_f = 0 < n/beta), which would otherwise keep
        # td_sel nonzero forever and defeat the cond-skip below
        live = v_f > 0
        td_sel = pack_lanes(topdown & live) & lane_mask      # uint32[W]
        bu_sel = pack_lanes(~topdown & live) & lane_mask
        if mode == "topdown":
            new = _topdown_packed_step(g, s.frontier, s.visited, td_sel)
        elif mode == "bottomup":
            new = _bottomup_packed_step(g, s.frontier, s.visited, bu_sel,
                                        max_pos, probe_impl)
        else:
            # middle layers usually have EVERY lane on one side — cond-skip
            # the other direction's O(m)/O(n*max_pos) work (the packed
            # analog of the serial controller's lax.cond)
            zero = jnp.zeros_like(s.frontier)
            new_td = jax.lax.cond(
                jnp.any(td_sel != 0),
                lambda: _topdown_packed_step(g, s.frontier, s.visited,
                                             td_sel),
                lambda: zero)
            new_bu = jax.lax.cond(
                jnp.any(bu_sel != 0),
                lambda: _bottomup_packed_step(g, s.frontier, s.visited,
                                              bu_sel, max_pos, probe_impl),
                lambda: zero)
            new = new_td | new_bu

        depth2 = jnp.where(unpack_lanes(new, num_roots), s.layer + 1, s.depth)
        i = s.layer
        lane_live = v_f > 0
        return _State(
            frontier=new, visited=s.visited | new, depth=depth2,
            topdown=topdown, layer=i + 1,
            trace_dir=s.trace_dir.at[i].set(
                jnp.where(lane_live, jnp.where(topdown, 0, 1), -1)),
            trace_vf=s.trace_vf.at[i].set(v_f),
            trace_ef=s.trace_ef.at[i].set(e_f),
            trace_eu=s.trace_eu.at[i].set(e_u),
        )

    init = _State(
        frontier=frontier0, visited=frontier0,
        depth=jnp.where(root_onehot, 0, -1).astype(jnp.int32),
        topdown=jnp.full((num_roots,), mode != "bottomup"),
        layer=jnp.int32(0),
        trace_dir=jnp.full((MAX_TRACE, num_roots), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE, num_roots), jnp.int32),
    )
    s = jax.lax.while_loop(cond_fn, body_fn, init)

    visited_b = unpack_lanes(s.visited, num_roots)
    deg = g.deg.astype(jnp.int32)[:, None]
    edges = jnp.sum(jnp.where(visited_b, deg, 0), axis=0)
    num_layers = jnp.max(s.depth, axis=0) + 1
    parent = _derive_parents(g, s.depth, roots)
    return MSBFSResult(parent=parent, depth=s.depth, num_layers=num_layers,
                       edges_traversed=edges, trace_dir=s.trace_dir,
                       trace_vf=s.trace_vf, trace_ef=s.trace_ef,
                       trace_eu=s.trace_eu)
