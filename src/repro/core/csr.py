"""CSR graph container and builders.

Layout (all static shapes, jit-friendly):
  row_ptr : int32[n+1]   start offset of each vertex's adjacency slice
  col_idx : int32[m]     neighbour ids, sorted within each row
  src_idx : int32[m]     CSR row expansion (owner of edge slot e) — enables
                         the edge-parallel top-down / fallback formulations

``col_idx`` entries are always valid vertex ids (no padding inside rows);
edge-parallel code masks by frontier/visited state instead.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class CSRGraph(NamedTuple):
    row_ptr: jnp.ndarray  # int32[n+1]
    col_idx: jnp.ndarray  # int32[m]
    src_idx: jnp.ndarray  # int32[m]

    @property
    def n(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def m(self) -> int:
        return self.col_idx.shape[0]

    @property
    def deg(self) -> jnp.ndarray:
        return self.row_ptr[1:] - self.row_ptr[:-1]


def from_edges(src: np.ndarray, dst: np.ndarray, n: int,
               symmetrize: bool = True, drop_self_loops: bool = True,
               dedup: bool = False) -> CSRGraph:
    """Build a CSR graph from a directed edge list (host-side, numpy).

    Graph500 graphs are undirected: ``symmetrize`` adds the reverse edges.
    """
    if len(src) * (2 if symmetrize else 1) >= 2 ** 31:
        # row_ptr/col_idx are int32 and every BFS counter (edges_traversed,
        # trace_ef/eu) sums degrees in int32 — refuse graphs that would
        # silently overflow rather than produce wrong TEPS. Checked before
        # any copy/symmetrization so absurd inputs fail fast; conservative
        # w.r.t. self-loop/dup removal.
        raise ValueError(
            f"edge count {len(src)} overflows the int32 CSR/counter layout")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    if drop_self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    key = src * n + dst
    order = np.argsort(key, kind="stable")
    src, dst = src[order], dst[order]
    if dedup and len(src):
        keep = np.ones(len(src), dtype=bool)
        keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst = src[keep], dst[keep]
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRGraph(
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(dst, dtype=jnp.int32),
        src_idx=jnp.asarray(src, dtype=jnp.int32),
    )


def to_numpy_adj(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """Host copies of (row_ptr, col_idx) for oracle/validator use."""
    return np.asarray(g.row_ptr), np.asarray(g.col_idx)


def relabel(g: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """Relabel vertices: new id of old vertex v is ``perm[v]``.

    Used for BFS locality reordering (beyond-paper optimisation): vertices
    visited consecutively get consecutive ids, improving gather locality of
    both the probe kernel and GNN SpMM.
    """
    row_ptr, col_idx = to_numpy_adj(g)
    n = g.n
    perm = np.asarray(perm)
    src = perm[np.asarray(g.src_idx)]
    dst = perm[col_idx]
    return from_edges(src, dst, n, symmetrize=False, drop_self_loops=False)


def ell_pad(g: CSRGraph, k_max: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Restructure CSR rows into an ELL slab: int32[n, k_max] neighbour ids
    (padded with n) + bool[n, k_max] validity. The paper's core insight —
    restructure irregular data into a vector-friendly layout — applied to
    message passing. Rows longer than k_max are truncated (caller handles
    the residue via the edge-parallel path, mirroring MAX_POS + fallback).
    """
    n, rp, ci = g.n, g.row_ptr, g.col_idx
    pos = jnp.arange(k_max, dtype=jnp.int32)[None, :]
    starts = rp[:-1][:, None]
    valid = pos < g.deg[:, None]
    idx = jnp.clip(starts + pos, 0, g.m - 1)
    neigh = jnp.where(valid, ci[idx], n)
    return neigh.astype(jnp.int32), valid
