"""CSR graph container and builders.

Layout (all static shapes, jit-friendly):
  row_ptr : int32[n+1]   start offset of each vertex's adjacency slice
  col_idx : int32[m]     neighbour ids, sorted within each row
  src_idx : int32[m]     CSR row expansion (owner of edge slot e) — enables
                         the edge-parallel top-down / fallback formulations

``col_idx`` entries are always valid vertex ids (no padding inside rows);
edge-parallel code masks by frontier/visited state instead.

``WeightedCSRGraph`` extends the layout with one float32 weight per edge
slot (``weights[e]`` belongs to edge ``src_idx[e] -> col_idx[e]``) — the
substrate of the semiring traversal subsystem (``repro.traversal``):
boolean traversal ignores the weights, tropical (min-plus) traversal
relaxes over them. Symmetrized edges carry the SAME weight both ways, so
undirected shortest paths match an undirected Dijkstra oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class CSRGraph(NamedTuple):
    row_ptr: jnp.ndarray  # int32[n+1]
    col_idx: jnp.ndarray  # int32[m]
    src_idx: jnp.ndarray  # int32[m]

    @property
    def n(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def m(self) -> int:
        return self.col_idx.shape[0]

    @property
    def deg(self) -> jnp.ndarray:
        return self.row_ptr[1:] - self.row_ptr[:-1]


class WeightedCSRGraph(NamedTuple):
    row_ptr: jnp.ndarray  # int32[n+1]
    col_idx: jnp.ndarray  # int32[m]
    src_idx: jnp.ndarray  # int32[m]
    weights: jnp.ndarray  # float32[m] — weight of edge src_idx[e]->col_idx[e]

    @property
    def n(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def m(self) -> int:
        return self.col_idx.shape[0]

    @property
    def deg(self) -> jnp.ndarray:
        return self.row_ptr[1:] - self.row_ptr[:-1]

    @property
    def csr(self) -> CSRGraph:
        """The unweighted view — every boolean-semiring consumer (the
        MS-BFS engines, the analytics sweeps) takes this; the weights ride
        alongside for the tropical/numeric semirings only."""
        return CSRGraph(row_ptr=self.row_ptr, col_idx=self.col_idx,
                        src_idx=self.src_idx)


def _build_csr(src: np.ndarray, dst: np.ndarray, w, n: int,
               symmetrize: bool, drop_self_loops: bool, dedup: bool):
    """Shared sort/symmetrize/dedup pipeline; ``w`` is None (unweighted)
    or float64[len(src)] weights carried through every permutation."""
    if len(src) * (2 if symmetrize else 1) >= 2 ** 31:
        # row_ptr/col_idx are int32 and every BFS counter (edges_traversed,
        # trace_ef/eu) sums degrees in int32 — refuse graphs that would
        # silently overflow rather than produce wrong TEPS. Checked before
        # any copy/symmetrization so absurd inputs fail fast; conservative
        # w.r.t. self-loop/dup removal.
        raise ValueError(
            f"edge count {len(src)} overflows the int32 CSR/counter layout")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if w is not None:
        w = np.asarray(w, dtype=np.float64)
        if w.shape != src.shape:
            raise ValueError(f"weights shape {w.shape} != edge count "
                             f"{src.shape}")
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if w is not None:
            w = np.concatenate([w, w])   # reverse edge keeps the SAME weight
    if drop_self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
    key = src * n + dst
    if w is None:
        order = np.argsort(key, kind="stable")
    else:
        # secondary sort by weight: dedup's keep-first rule then keeps the
        # MINIMUM-weight parallel edge — the one shortest paths would use
        order = np.lexsort((w, key))
    src, dst = src[order], dst[order]
    if w is not None:
        w = w[order]
    if dedup and len(src):
        keep = np.ones(len(src), dtype=bool)
        keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst = src[keep], dst[keep]
        if w is not None:
            w = w[keep]
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return row_ptr, dst, src, w


def from_edges(src: np.ndarray, dst: np.ndarray, n: int,
               symmetrize: bool = True, drop_self_loops: bool = True,
               dedup: bool = False) -> CSRGraph:
    """Build a CSR graph from a directed edge list (host-side, numpy).

    Graph500 graphs are undirected: ``symmetrize`` adds the reverse edges.
    """
    row_ptr, dst, src, _ = _build_csr(src, dst, None, n, symmetrize,
                                      drop_self_loops, dedup)
    return CSRGraph(
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(dst, dtype=jnp.int32),
        src_idx=jnp.asarray(src, dtype=jnp.int32),
    )


def from_weighted_edges(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                        n: int, symmetrize: bool = True,
                        drop_self_loops: bool = True,
                        dedup: bool = False) -> WeightedCSRGraph:
    """``from_edges`` with one non-negative weight per directed input edge.

    ``symmetrize`` gives the reverse edge the same weight (undirected
    semantics); ``dedup`` keeps the minimum-weight copy of parallel edges
    (the only one shortest paths can use). Negative weights are rejected —
    the delta-stepping engine (and Dijkstra) require w >= 0.
    """
    w = np.asarray(w, dtype=np.float64)
    # finiteness AND sign checked explicitly: a `min() < 0` guard lets
    # NaN through (fails both orderings), `>= 0` alone lets +inf through
    # (which turns default_delta into inf and silently degrades the
    # bucketed engine to pure Bellman-Ford) — both must raise here
    ok = np.isfinite(w) & (w >= 0)
    if len(w) and not ok.all():
        bad = w[~ok][0]
        raise ValueError(
            f"invalid edge weight {bad} — tropical traversal "
            f"(delta-stepping / Dijkstra) requires finite non-negative "
            f"weights")
    row_ptr, dst, src, w = _build_csr(src, dst, w, n, symmetrize,
                                      drop_self_loops, dedup)
    return WeightedCSRGraph(
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(dst, dtype=jnp.int32),
        src_idx=jnp.asarray(src, dtype=jnp.int32),
        weights=jnp.asarray(w, dtype=jnp.float32),
    )


def to_numpy_adj(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """Host copies of (row_ptr, col_idx) for oracle/validator use."""
    return np.asarray(g.row_ptr), np.asarray(g.col_idx)


def relabel(g: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """Relabel vertices: new id of old vertex v is ``perm[v]``.

    Used for BFS locality reordering (beyond-paper optimisation): vertices
    visited consecutively get consecutive ids, improving gather locality of
    both the probe kernel and GNN SpMM.
    """
    row_ptr, col_idx = to_numpy_adj(g)
    n = g.n
    perm = np.asarray(perm)
    src = perm[np.asarray(g.src_idx)]
    dst = perm[col_idx]
    return from_edges(src, dst, n, symmetrize=False, drop_self_loops=False)


def ell_pad(g: CSRGraph, k_max: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Restructure CSR rows into an ELL slab: int32[n, k_max] neighbour ids
    (padded with n) + bool[n, k_max] validity. The paper's core insight —
    restructure irregular data into a vector-friendly layout — applied to
    message passing. Rows longer than k_max are truncated (caller handles
    the residue via the edge-parallel path, mirroring MAX_POS + fallback).
    """
    n, rp, ci = g.n, g.row_ptr, g.col_idx
    pos = jnp.arange(k_max, dtype=jnp.int32)[None, :]
    starts = rp[:-1][:, None]
    valid = pos < g.deg[:, None]
    idx = jnp.clip(starts + pos, 0, g.m - 1)
    neigh = jnp.where(valid, ci[idx], n)
    return neigh.astype(jnp.int32), valid
