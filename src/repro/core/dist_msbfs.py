"""Distributed multi-source BFS: the bit-lane engine sharded across devices.

Fuses the two scaling axes grown so far:

* PR 1-2's packed MS-BFS — R concurrent traversals as uint32 lane words,
  pipelined through a fixed bit-lane pool with a pending-root queue;
* ``dist_bfs``'s 1-D vertex partition — device d owns a contiguous row
  block of the CSR and all writes to it.

This is the frontier-exchange structure of Buluc & Madduri (arXiv
1104.4518) applied to the vectorisable packed representation (SlimSell):
each device runs the SAME packed step formulations as the single-host
engine (``repro.core.packed`` — the segmented-OR scan and the MAX_POS
word probe are one shared implementation, not a copy) over its local CSR
block against the full replicated ``uint32[n, W]`` frontier, producing
new-frontier words for its own rows only. The per-layer exchange is a
bitwise-OR allreduce of the placed row blocks (``allreduce_or`` — the
``lax.psum`` analog for bitmasks; for this 1-D contiguous partition it
degenerates to an all-gather concatenation, but the OR form is
partition-agnostic and ready for 2-D edge partitions).

Engine control state (root queue, lane<->queue-slot binding, per-lane
alpha/beta direction flags) is replicated: every device runs the refill
and flush logic on identical values, with the direction decision computed
from ``psum``-merged global counters, so the distributed engine's
lane/queue evolution — and therefore every per-root result and trace —
is bit-identical to the single-host pipelined engine (asserted by
``tests/test_dist_msbfs.py`` at ndev ∈ {1, 2, 4}).

Per-device state layout (``shard_map`` view; leading dim = ndev stacked):
  frontier  : uint32[n, W]            replicated, n padded to ndev*32
  visited   : uint32[ndev, n_loc, W]  device-local rows
  depth     : int32[ndev, n_loc, L]
  out_depth : int32[ndev, n_loc, cap+1]
  everything else (queue, selectors, counters, traces): replicated.

The switch rule uses ``n_orig`` (not the padded ``n``): padded vertices
have degree 0 and never traverse, so with the original vertex count in
the beta threshold every lane's TD/BU trace replays its serial run.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.csr import CSRGraph
from repro.core.dist_bfs import DistGraph, _flat_axis_index, partition_graph
from repro.core.exchange import allreduce_or
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT, MAX_TRACE
from repro.core.msbfs import (LayerReadout, MAX_LANES, MSBFSResult,
                              msbfs_engine_enqueue, msbfs_engine_idle)
from repro.core.packed import (LANE_WORD_BITS, MODES, adaptive_lane_pool,
                               dispatch_packed_step, lane_counters,
                               num_lane_words, pack_lanes, queue_claims,
                               select_direction, unpack_lanes, word_dtype)

__all__ = [
    "DistGraph", "DistPipelineState", "allreduce_or", "dist_msbfs",
    "dist_msbfs_engine_drain", "dist_msbfs_engine_enqueue",
    "dist_msbfs_engine_idle", "dist_msbfs_engine_init",
    "dist_msbfs_engine_readout", "dist_msbfs_engine_result",
    "dist_msbfs_engine_retire", "dist_msbfs_engine_step", "host_mesh",
    "partition_graph",
]


class DistPipelineState(NamedTuple):
    """Pipelined-engine state, partitioned. Mirrors ``msbfs.PipelineState``
    field-for-field (so the host-side enqueue/idle helpers are shared);
    row-indexed arrays carry a leading stacked device dim instead."""
    frontier: jnp.ndarray        # uint32[n, W] — full, replicated
    visited: jnp.ndarray         # uint32[ndev, n_loc, W]
    depth: jnp.ndarray           # int32[ndev, n_loc, L]
    lane_layer: jnp.ndarray      # int32[L]
    lane_qidx: jnp.ndarray       # int32[L]   queue slot served; capacity = idle
    topdown: jnp.ndarray         # bool[L]
    queue: jnp.ndarray           # int32[capacity]
    queued: jnp.ndarray          # int32 scalar
    next_root: jnp.ndarray       # int32 scalar
    sweep_layers: jnp.ndarray    # int32 scalar
    out_depth: jnp.ndarray       # int32[ndev, n_loc, capacity+1]
    out_edges: jnp.ndarray       # int32[capacity+1]
    out_layers: jnp.ndarray      # int32[capacity+1]  0 = unanswered
    trace_dir: jnp.ndarray       # int32[MAX_TRACE, capacity+1]
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray

    @property
    def num_lanes(self) -> int:
        return self.lane_qidx.shape[0]

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def _state_specs(axes) -> DistPipelineState:
    dev = P(axes)
    rep = P()
    return DistPipelineState(
        frontier=rep, visited=dev, depth=dev, lane_layer=rep, lane_qidx=rep,
        topdown=rep, queue=rep, queued=rep, next_root=rep, sweep_layers=rep,
        out_depth=dev, out_edges=rep, out_layers=rep, trace_dir=rep,
        trace_vf=rep, trace_ef=rep, trace_eu=rep)


def _check_partition(dg: DistGraph, mesh: Mesh) -> int:
    ndev = int(np.prod(mesh.devices.shape))
    if dg.row_ptr.shape[0] != ndev:
        raise ValueError(
            f"DistGraph partitioned for {dg.row_ptr.shape[0]} devices but "
            f"mesh has {ndev} — repartition with partition_graph(g, {ndev})")
    return ndev


def dist_msbfs_engine_init(dg: DistGraph, mesh: Mesh, capacity: int,
                           lanes: int = MAX_LANES) -> DistPipelineState:
    """Fresh sharded engine: all lanes idle, empty root queue."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    ndev = _check_partition(dg, mesh)
    n_loc = dg.n // ndev
    w = num_lane_words(lanes)
    cap = capacity
    return DistPipelineState(
        frontier=jnp.zeros((dg.n, w), word_dtype()),
        visited=jnp.zeros((ndev, n_loc, w), word_dtype()),
        depth=jnp.full((ndev, n_loc, lanes), -1, jnp.int32),
        lane_layer=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        topdown=jnp.ones((lanes,), jnp.bool_),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_layers=jnp.int32(0),
        out_depth=jnp.full((ndev, n_loc, cap + 1), -1, jnp.int32),
        out_edges=jnp.zeros((cap + 1,), jnp.int32),
        out_layers=jnp.zeros((cap + 1,), jnp.int32),
        trace_dir=jnp.full((MAX_TRACE, cap + 1), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
    )


def dist_msbfs_engine_enqueue(state: DistPipelineState,
                              roots) -> DistPipelineState:
    """Append roots to the (replicated) pending queue — the host helper is
    the single-host one: queue state is replicated, so enqueue is identical
    on every device."""
    return msbfs_engine_enqueue(state, roots)


def dist_msbfs_engine_idle(state: DistPipelineState) -> bool:
    """True when no lane is active and no enqueued root is pending."""
    return msbfs_engine_idle(state)


def _dist_pipeline_body(g_loc: CSRGraph, base, s: DistPipelineState,
                        mode: str, alpha: float, beta: float, max_pos: int,
                        probe_impl: str, n: int, n_loc: int, n_orig: int,
                        axes) -> DistPipelineState:
    """One engine step, per-device view: refill idle lanes (replicated),
    advance one layer on the local row block, exchange frontiers, flush
    finished lanes. Mirrors ``msbfs._pipeline_body`` exactly — the only
    distributed moves are two ``psum`` counter merges and one
    ``allreduce_or`` frontier exchange."""
    lanes = s.lane_qidx.shape[0]
    cap = s.queue.shape[0]
    w = s.frontier.shape[1]
    # dynamic_slice wants all start indices in ONE dtype; a bare 0 would
    # weak-type to int64 under x64 (the u64 lane-word rung) and clash
    # with the int32 device base
    col0 = jnp.zeros((), jnp.asarray(base).dtype)

    # --- refill: replicated claim logic, row-local seat writes -----------
    def do_refill(s: DistPipelineState) -> DistPipelineState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        fresh = pack_lanes(onehot)                            # uint32[n, W]
        onehot_loc = jax.lax.dynamic_slice(onehot, (base, col0), (n_loc, lanes))
        fresh_loc = jax.lax.dynamic_slice(fresh, (base, col0), (n_loc, w))
        return s._replace(
            frontier=s.frontier | fresh,
            visited=s.visited | fresh_loc,
            depth=jnp.where(claim[None, :],
                            jnp.where(onehot_loc, 0, -1), s.depth),
            lane_layer=jnp.where(claim, 0, s.lane_layer),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            topdown=jnp.where(claim, mode != "bottomup", s.topdown),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= cap) & (s.next_root < s.queued)
    s = jax.lax.cond(needed, do_refill, lambda s: s, s)

    # --- per-lane direction from psum-merged global counters -------------
    active = s.lane_qidx < cap
    frontier_loc = jax.lax.dynamic_slice(s.frontier, (base, col0), (n_loc, w))
    frontier_b = unpack_lanes(frontier_loc, lanes)
    visited_b = unpack_lanes(s.visited, lanes)
    pe_f, pv_f, pe_u = lane_counters(g_loc, frontier_b, visited_b)
    e_f = jax.lax.psum(pe_f, axes)
    v_f = jax.lax.psum(pv_f, axes)
    e_u = jax.lax.psum(pe_u, axes)
    topdown = select_direction(mode, s.topdown, e_f, v_f, e_u, n_orig,
                               alpha, beta, lanes)

    live = active & (v_f > 0)
    td_sel = pack_lanes(topdown & live)                       # uint32[W]
    bu_sel = pack_lanes(~topdown & live)

    tr_row = jnp.clip(s.lane_layer, 0, MAX_TRACE - 1)
    tr_col = jnp.where(active, s.lane_qidx, cap)
    # int32 up front: under x64 a weak-int64 scatter value into the
    # int32 trace will become an error in future jax
    dir_vals = jnp.where(live, jnp.where(topdown, 0, 1),
                         -1).astype(jnp.int32)
    trace_dir = s.trace_dir.at[tr_row, tr_col].set(dir_vals)
    trace_vf = s.trace_vf.at[tr_row, tr_col].set(v_f)
    trace_ef = s.trace_ef.at[tr_row, tr_col].set(e_f)
    trace_eu = s.trace_eu.at[tr_row, tr_col].set(e_u)

    # --- the SHARED packed step over the local block ---------------------
    new_loc = dispatch_packed_step(g_loc, s.frontier, s.visited, td_sel,
                                   bu_sel, mode, max_pos, probe_impl)

    # --- frontier exchange: place local rows, OR-merge across devices ----
    placed = jax.lax.dynamic_update_slice(
        jnp.zeros((n, w), new_loc.dtype), new_loc, (base, col0))
    new_full = allreduce_or(placed, axes)

    new_loc_b = unpack_lanes(new_loc, lanes)
    visited2 = s.visited | new_loc
    visited2_b = visited_b | new_loc_b
    lane_layer2 = s.lane_layer + active.astype(jnp.int32)
    depth2 = jnp.where(new_loc_b, lane_layer2[None, :], s.depth)

    # finish = GLOBAL frontier drained OR per-lane layer cap
    new_any = unpack_lanes(new_full, lanes).any(axis=0)
    finished = active & (~new_any | (lane_layer2 >= MAX_TRACE))

    deg = g_loc.deg.astype(jnp.int32)[:, None]
    edges_l = jax.lax.psum(
        jnp.sum(jnp.where(visited2_b, deg, 0), axis=0,
                dtype=jnp.int32), axes)
    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_depth = s.out_depth.at[:, fcol].set(depth2)
    out_edges = s.out_edges.at[fcol].set(edges_l)
    out_layers = s.out_layers.at[fcol].set(lane_layer2)

    clear = pack_lanes(finished)                              # uint32[W]
    return s._replace(
        frontier=new_full & ~clear,
        visited=visited2 & ~clear,
        depth=jnp.where(finished[None, :], -1, depth2),
        lane_layer=jnp.where(finished, 0, lane_layer2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        topdown=topdown,
        sweep_layers=s.sweep_layers + 1,
        out_depth=out_depth, out_edges=out_edges, out_layers=out_layers,
        trace_dir=trace_dir, trace_vf=trace_vf, trace_ef=trace_ef,
        trace_eu=trace_eu,
    )


@partial(jax.jit, static_argnames=("mesh", "mode", "alpha", "beta",
                                   "max_pos", "probe_impl", "n", "n_loc",
                                   "n_orig", "drain"))
def _dist_engine_run(row_ptr_s, col_s, srcloc_s, deg_s,
                     state: DistPipelineState, *, mesh: Mesh, mode: str,
                     alpha: float, beta: float, max_pos: int,
                     probe_impl: str, n: int, n_loc: int, n_orig: int,
                     drain: bool) -> DistPipelineState:
    axes = tuple(mesh.axis_names)
    cap = state.queue.shape[0]

    def body(row_ptr, col, src_loc, deg, s: DistPipelineState):
        # strip the stacked device dim from the sharded leaves
        g_loc = CSRGraph(row_ptr=row_ptr[0], col_idx=col[0],
                         src_idx=src_loc[0])
        del deg   # g_loc.deg (row_ptr diffs) == the stored per-device deg
        base = _flat_axis_index(axes, dict(mesh.shape)) * n_loc
        s = s._replace(visited=s.visited[0], depth=s.depth[0],
                       out_depth=s.out_depth[0])

        step = partial(_dist_pipeline_body, g_loc, base, mode=mode,
                       alpha=alpha, beta=beta, max_pos=max_pos,
                       probe_impl=probe_impl, n=n, n_loc=n_loc,
                       n_orig=n_orig, axes=axes)
        if drain:
            s = jax.lax.while_loop(
                lambda s: (s.next_root < s.queued)
                | jnp.any(s.lane_qidx < cap),
                lambda s: step(s), s)
        else:
            s = step(s)
        return s._replace(visited=s.visited[None], depth=s.depth[None],
                          out_depth=s.out_depth[None])

    spec_dev = P(axes)
    specs = _state_specs(axes)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, spec_dev, specs),
        out_specs=specs, check_vma=False,
    )(row_ptr_s, col_s, srcloc_s, deg_s, state)


def dist_msbfs_engine_step(dg: DistGraph, state: DistPipelineState,
                           mesh: Mesh, mode: str = "hybrid",
                           alpha: float = ALPHA_DEFAULT,
                           beta: float = BETA_DEFAULT, max_pos: int = 8,
                           probe_impl: str = "xla") -> DistPipelineState:
    """Advance the sharded engine by one traversal layer (streaming API).

    Compiles once per (graph shapes, lanes, capacity, mode); the serving
    loop interleaves ``dist_msbfs_engine_enqueue`` between steps exactly
    like the single-host engine."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    ndev = _check_partition(dg, mesh)
    return _dist_engine_run(
        dg.row_ptr, dg.col_idx, dg.src_loc, dg.deg, state, mesh=mesh,
        mode=mode, alpha=alpha, beta=beta, max_pos=max_pos,
        probe_impl=probe_impl, n=dg.n, n_loc=dg.n // ndev,
        n_orig=dg.n_orig, drain=False)


def dist_msbfs_engine_drain(dg: DistGraph, state: DistPipelineState,
                            mesh: Mesh, mode: str = "hybrid",
                            alpha: float = ALPHA_DEFAULT,
                            beta: float = BETA_DEFAULT, max_pos: int = 8,
                            probe_impl: str = "xla") -> DistPipelineState:
    """Step the sharded engine until every enqueued root is answered."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    ndev = _check_partition(dg, mesh)
    return _dist_engine_run(
        dg.row_ptr, dg.col_idx, dg.src_loc, dg.deg, state, mesh=mesh,
        mode=mode, alpha=alpha, beta=beta, max_pos=max_pos,
        probe_impl=probe_impl, n=dg.n, n_loc=dg.n // ndev,
        n_orig=dg.n_orig, drain=True)


@partial(jax.jit, static_argnames=("mesh", "n", "n_loc", "num_roots",
                                   "lane_chunk"))
def _derive_parents_dist(row_ptr_s, col_s, srcloc_s, depth_full, roots, *,
                         mesh: Mesh, n: int, n_loc: int, num_roots: int,
                         lane_chunk: int = 16):
    """Distributed analog of ``msbfs._derive_parents``: each device scans
    its local edge slab for the min-id neighbour one level up, then the
    row blocks are gathered. Same deterministic min-id rule, chunked over
    lanes to bound the [m_loc, chunk] candidate buffer."""
    axes = tuple(mesh.axis_names)

    def body(row_ptr, col, src_loc, depth_full, roots):
        row_ptr, col, src_loc = row_ptr[0], col[0], src_loc[0]
        base = _flat_axis_index(axes, dict(mesh.shape)) * n_loc
        depth_loc = jax.lax.dynamic_slice(
            depth_full, (base, jnp.zeros((), jnp.asarray(base).dtype)),
            (n_loc, num_roots))
        colc = jnp.clip(col, 0, n - 1)
        valid = (col < n)[:, None]       # pad slots carry the sentinel n
        outs = []
        for lo in range(0, num_roots, lane_chunk):
            d_full = depth_full[:, lo:lo + lane_chunk]
            d_loc = depth_loc[:, lo:lo + lane_chunk]
            ok = valid & (d_full[colc] >= 0) & (d_full[colc] + 1
                                                == d_loc[src_loc])
            cand = jnp.where(ok, col[:, None], n).astype(jnp.int32)
            best = jnp.full((n_loc, d_loc.shape[1]), n,
                            jnp.int32).at[src_loc].min(cand)
            outs.append(jnp.where(best < n, best, -1))
        parent_loc = jnp.concatenate(outs, axis=1)
        # seat roots owned by this device; rows outside the block are
        # pushed past n_loc so mode="drop" discards them (a bare
        # ``roots - base`` would WRAP for negative rows)
        lane = jnp.arange(num_roots, dtype=jnp.int32)
        own = (roots >= base) & (roots < base + n_loc)
        lrow = jnp.where(own, roots - base, n_loc)
        parent_loc = parent_loc.at[lrow, lane].set(
            roots.astype(jnp.int32), mode="drop")
        return jax.lax.all_gather(parent_loc, axes, tiled=True)

    spec_dev = P(axes)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, P(), P()),
        out_specs=P(), check_vma=False,
    )(row_ptr_s, col_s, srcloc_s, depth_full, roots)


def dist_msbfs_engine_result(dg: DistGraph, state: DistPipelineState,
                             mesh: Mesh, trim: bool = True,
                             derive_parents: bool = True) -> MSBFSResult:
    """Assemble an ``MSBFSResult`` over the answered queue slots.

    Depths come from the flushed per-device row blocks; parents are
    derived distributed (min-id neighbour one level up, the MSBFSResult
    convention: -1 for unreached/dead vertices, ``parent[root_r, r] ==
    root_r``) unless ``derive_parents=False`` (zero-width ``parent``, the
    analytics depth-only contract). With ``trim`` the arrays are cut back
    to the original (pre-padding) vertex count."""
    ndev = _check_partition(dg, mesh)
    r = int(state.queued)
    cap = state.capacity
    depth = jnp.reshape(state.out_depth, (dg.n, cap + 1))[:, :r]
    roots = state.queue[:r]
    if r and derive_parents:
        parent = _derive_parents_dist(
            dg.row_ptr, dg.col_idx, dg.src_loc, depth,
            roots.astype(jnp.int32), mesh=mesh, n=dg.n,
            n_loc=dg.n // ndev, num_roots=r)
    else:
        parent = jnp.zeros((dg.n, 0), jnp.int32)
    lim = dg.n_orig if trim else dg.n
    return MSBFSResult(
        parent=parent[:lim], depth=depth[:lim],
        num_layers=state.out_layers[:r],
        edges_traversed=state.out_edges[:r],
        trace_dir=state.trace_dir[:, :r], trace_vf=state.trace_vf[:, :r],
        trace_ef=state.trace_ef[:, :r], trace_eu=state.trace_eu[:, :r])


def dist_msbfs_engine_readout(dg: DistGraph,
                              state: DistPipelineState) -> LayerReadout:
    """Snapshot the streaming read-out surface of the sharded engine —
    the SAME ``LayerReadout`` as the host engine, with the per-device row
    blocks reassembled into global vertex order and trimmed to the
    original vertex count, so streaming consumers are partition-blind
    (control state is replicated; the depth surfaces are bit-identical
    to the host engine's at every layer)."""
    cap = state.capacity
    lanes = state.num_lanes
    depth = np.reshape(np.asarray(state.depth), (dg.n, lanes))
    out_depth = np.reshape(np.asarray(state.out_depth), (dg.n, cap + 1))
    return LayerReadout(
        layer=int(state.sweep_layers), capacity=cap,
        lane_qidx=np.asarray(state.lane_qidx),
        lane_layer=np.asarray(state.lane_layer),
        depth=depth[:dg.n_orig], out_depth=out_depth[:dg.n_orig],
        out_layers=np.asarray(state.out_layers))


@jax.jit
def _retire_dist(deg_s, state: DistPipelineState,
                 lane_mask: jnp.ndarray) -> DistPipelineState:
    cap = state.capacity
    mask = lane_mask & (state.lane_qidx < cap)
    visited_b = unpack_lanes(state.visited, state.num_lanes)
    deg = deg_s.astype(jnp.int32)[..., None]              # [ndev, n_loc, 1]
    edges_l = jnp.sum(jnp.where(visited_b, deg, 0), axis=(0, 1),
                      dtype=jnp.int32)
    fcol = jnp.where(mask, state.lane_qidx, cap)
    out_depth = state.out_depth.at[:, :, fcol].set(state.depth)
    out_edges = state.out_edges.at[fcol].set(edges_l)
    out_layers = state.out_layers.at[fcol].set(
        jnp.maximum(state.lane_layer, 1))
    clear = pack_lanes(mask)
    return state._replace(
        frontier=state.frontier & ~clear,
        visited=state.visited & ~clear,
        depth=jnp.where(mask, -1, state.depth),
        lane_layer=jnp.where(mask, 0, state.lane_layer),
        lane_qidx=jnp.where(mask, cap, state.lane_qidx),
        out_depth=out_depth, out_edges=out_edges, out_layers=out_layers)


def dist_msbfs_engine_retire(dg: DistGraph, state: DistPipelineState,
                             lane_mask) -> DistPipelineState:
    """Retire the masked ACTIVE lanes early (sharded mirror of
    ``msbfs_engine_retire``): flush their depth columns to the per-device
    output blocks and free the lanes. Control state is replicated, so the
    host-level mask applies identically on every device; like the
    enqueue helper this runs outside ``shard_map`` — the next step's jit
    re-shards the touched leaves."""
    lane_mask = jnp.asarray(lane_mask, jnp.bool_).reshape(-1)
    if lane_mask.shape[0] != state.num_lanes:
        raise ValueError(
            f"lane_mask has {lane_mask.shape[0]} lanes, engine has "
            f"{state.num_lanes}")
    return _retire_dist(dg.deg, state, lane_mask)


def host_mesh(ndev: int) -> Mesh:
    """1-D mesh over the first ``ndev`` local devices (shared by the
    graph500 harness and the serving loop)."""
    devs = jax.devices()
    if len(devs) < ndev:
        raise ValueError(
            f"ndev={ndev} but only {len(devs)} jax devices — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev} "
            f"before the first jax import")
    return Mesh(np.asarray(devs[:ndev]), ("data",))


def dist_msbfs(dg: DistGraph, roots, mesh: Mesh, mode: str = "hybrid",
               alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
               max_pos: int = 8, probe_impl: str = "xla",
               lanes: int | None = None, derive_parents: bool = True,
               recorder=None) -> MSBFSResult:
    """Answer an arbitrary number of roots with ONE sharded engine sweep.

    ``lanes=None`` (or 0) sizes the bit-lane pool adaptively from the pending
    root count and the graph's degree stats (``packed.adaptive_lane_pool``
    — the ROADMAP rung); pass an int to pin the pool width. Every lane's
    depths/parents match serial ``bfs()`` exactly and pass the Graph500
    spec-4 validator; results are trimmed to the original vertex count.

    ``recorder`` (a ``repro.obs.SweepRecorder``) records a ``LayerRecord``
    per layer by stepping the engine instead of the fused drain — step
    and drain share the sharded body, so results and traces are
    bit-identical; None (the default) touches nothing in ``repro.obs``.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one root")
    if not lanes:                  # None or 0: the documented adaptive knob
        m_total = int(np.asarray(dg.deg, dtype=np.int64).sum())
        lanes = adaptive_lane_pool(num_roots, dg.n_orig, m_total)
    # W derives from the ACTIVE batch: small R never pays for idle words
    lanes = max(1, min(lanes, LANE_WORD_BITS * num_lane_words(num_roots)))
    state = dist_msbfs_engine_init(dg, mesh, capacity=num_roots, lanes=lanes)
    state = dist_msbfs_engine_enqueue(state, roots)
    if recorder is None:
        state = dist_msbfs_engine_drain(dg, state, mesh, mode, alpha, beta,
                                        max_pos, probe_impl)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: dist_msbfs_engine_step(dg, s, mesh, mode, alpha,
                                             beta, max_pos, probe_impl),
            dist_msbfs_engine_idle, kind="bfs")
    return dist_msbfs_engine_result(dg, state, mesh,
                                    derive_parents=derive_parents)
