"""Reference BFS oracles (host-side numpy) — independent implementations the
JAX/Pallas paths are validated against.

Two oracles:
  * ``bfs_reference`` — level-synchronous numpy BFS with the same
    deterministic min-parent rule as the JAX steps: exact array equality is
    asserted in tests.
  * ``bfs_queue`` — classic deque BFS; used for *depth* ground truth only
    (its parent choice is queue-order dependent, like the paper's
    non-deterministic trees).
"""
from __future__ import annotations

from collections import deque

import numpy as np


def bfs_reference(row_ptr: np.ndarray, col_idx: np.ndarray, root: int):
    """Level-synchronous BFS; parent[v] = min-id frontier neighbour of v.

    Returns (parent, depth) int32 arrays (-1 for unreached; parent[root]=root).
    """
    n = len(row_ptr) - 1
    src = np.repeat(np.arange(n), np.diff(row_ptr))
    dst = np.asarray(col_idx)
    parent = np.full(n, -1, np.int32)
    depth = np.full(n, -1, np.int32)
    parent[root] = root
    depth[root] = 0
    frontier = np.zeros(n, bool)
    visited = np.zeros(n, bool)
    frontier[root] = visited[root] = True
    layer = 0
    while frontier.any():
        active = frontier[src] & ~visited[dst]
        cand = np.full(n, n, np.int64)
        np.minimum.at(cand, dst[active], src[active])
        new = (cand < n) & ~visited
        parent[new] = cand[new]
        depth[new] = layer + 1
        visited |= new
        frontier = new
        layer += 1
    return parent, depth


def bfs_queue(row_ptr: np.ndarray, col_idx: np.ndarray, root: int):
    """Deque BFS for independent depth ground truth."""
    n = len(row_ptr) - 1
    depth = np.full(n, -1, np.int32)
    depth[root] = 0
    q = deque([root])
    while q:
        u = q.popleft()
        for v in col_idx[row_ptr[u]:row_ptr[u + 1]]:
            if depth[v] < 0:
                depth[v] = depth[u] + 1
                q.append(v)
    return depth
