"""Shared packed bit-lane primitives for multi-source BFS.

ONE implementation of the lane-word machinery serves both MS-BFS engines:

* the single-host engines in ``repro.core.msbfs`` (single-batch sweep and
  the pipelined root-queue engine), and
* the sharded engine in ``repro.core.dist_msbfs`` (lane words traversing a
  1-D partitioned graph, Buluc & Madduri frontier exchange applied to the
  packed representation).

The key property that makes sharing possible: every step function takes
the graph as a ``CSRGraph`` *view* and only assumes

  - ``row_ptr``/``src_idx`` index LOCAL rows (the rows this caller owns),
  - ``col_idx`` holds GLOBAL neighbour ids (indices into ``frontier``),
  - ``frontier`` covers the full global vertex range,
  - ``visited``/``need`` cover the local rows only.

On a single host "local" and "global" coincide and these are exactly the
PR-1/PR-2 formulations; under ``shard_map`` each device passes its CSR
block and the replicated full-width frontier, and the SAME code computes
that device's slice of the next frontier. Rows padded with the sentinel
column id ``frontier.shape[0]`` (the distributed edge-slab pad) are
neutralised by the ``pos < deg`` probe guard, the ``pos_e < deg`` fallback
guard, and the segmented scan's read-out points all sitting before the pad
region.

``segment_or`` is the segmented-OR associative scan named by ROADMAP as
the piece to share with the distributed partition.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.csr import CSRGraph
from repro.core.hybrid import switch_direction

# the single knob of the ROADMAP uint64-lane rung: settable per process
# via the LANE_WORD_BITS env var (the CI uint64 tier-1 leg runs the whole
# engine stack under LANE_WORD_BITS=64 + JAX_ENABLE_X64=1), or swapped at
# runtime by tests (tests/test_msbfs.py lane_word_bits context manager)
LANE_WORD_BITS = int(os.environ.get("LANE_WORD_BITS", "32"))
if LANE_WORD_BITS not in (32, 64):
    raise ValueError(
        f"LANE_WORD_BITS must be 32 or 64, got {LANE_WORD_BITS}")

MODES = ("hybrid", "topdown", "bottomup")


def word_dtype():
    """Lane-word dtype for the current ``LANE_WORD_BITS``. Everything
    downstream derives the dtype from here. 64-bit words hard-require jax
    x64: without it jnp silently materializes uint64 as uint32 and lanes
    32-63 of every word would vanish without an error — fail loudly,
    naming the fix."""
    if LANE_WORD_BITS == 64:
        if not jax.config.jax_enable_x64:
            raise RuntimeError(
                'LANE_WORD_BITS=64 requires jax x64 — run with '
                'jax.config.update("jax_enable_x64", True) (or set '
                'JAX_ENABLE_X64=1) before any jax call; without it '
                'uint64 lane words silently downcast to uint32 and '
                'lanes 32-63 of every word are lost')
        return jnp.uint64
    return jnp.uint32


def num_lane_words(num_roots: int) -> int:
    return (num_roots + LANE_WORD_BITS - 1) // LANE_WORD_BITS


def pack_lanes(mask: jnp.ndarray) -> jnp.ndarray:
    """Pack bool[..., R] lane masks into uint[..., W] words (LSB-first)."""
    r = mask.shape[-1]
    w = num_lane_words(r)
    dt = word_dtype()
    pad = w * LANE_WORD_BITS - r
    if pad:
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), mask.dtype)], axis=-1)
    lanes = mask.reshape(mask.shape[:-1] + (w, LANE_WORD_BITS))
    weights = jnp.asarray(1, dt) << jnp.arange(LANE_WORD_BITS, dtype=dt)
    return (lanes.astype(dt) * weights).sum(axis=-1, dtype=dt)


def unpack_lanes(words: jnp.ndarray, num_roots: int) -> jnp.ndarray:
    """Unpack uint[..., W] lane words into bool[..., R]."""
    dt = words.dtype
    shifts = jnp.arange(LANE_WORD_BITS, dtype=dt)
    bits = (words[..., None] >> shifts) & jnp.asarray(1, dt)
    flat = bits.reshape(words.shape[:-1] + (-1,))
    return flat[..., :num_roots].astype(jnp.bool_)


def depth_slice_words(depth: jnp.ndarray, max_depth,
                      min_depth=0) -> jnp.ndarray:
    """Re-pack per-lane depths into frontier-style lane words, sliced to
    the band ``min_depth <= depth <= max_depth``.

    ``depth`` is the engines' int32[n, R] output (-1 unreached); the result
    is uint[n, W] in the SAME bit layout the engines traverse with —
    bit ``r % LANE_WORD_BITS`` of word ``r // LANE_WORD_BITS``. This is the
    k-hop / reachability read-out surface: ``max_depth=k`` yields the
    packed k-hop neighbourhood of every lane root at once, and
    ``min_depth=max_depth=d`` reconstructs the layer-``d`` frontier.
    """
    return pack_lanes((depth >= min_depth) & (depth <= max_depth))


def segment_or(vals: jnp.ndarray, row_ptr: jnp.ndarray) -> jnp.ndarray:
    """Per-CSR-row bitwise OR of uint32[m, W] edge-lane words -> uint32[n, W].

    CSR rows are contiguous runs of edge slots, so the row-OR is a textbook
    segmented scan: an inclusive ``lax.associative_scan`` over
    (word, segment-start-flag) pairs, read out at each row's last slot.
    Empty rows produce 0. Slots past ``row_ptr[-1]`` (distributed edge-slab
    padding) only extend the last segment beyond every read-out point, so
    their values never reach an output row.
    """
    m = vals.shape[0]
    # row starts equal to m (trailing empty rows) must not flag slot m-1
    flags = jnp.zeros((m,), jnp.bool_).at[row_ptr[:-1]].set(True, mode="drop")

    def comb(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb[..., None], vb, va | vb), fa | fb

    scanned, _ = jax.lax.associative_scan(comb, (vals, flags))
    deg = row_ptr[1:] - row_ptr[:-1]
    last = jnp.clip(row_ptr[1:] - 1, 0, m - 1)
    return jnp.where((deg > 0)[:, None], scanned[last],
                     jnp.zeros((), vals.dtype))


def probe_xla(g: CSRGraph, frontier: jnp.ndarray, need: jnp.ndarray,
              max_pos: int) -> jnp.ndarray:
    """Word-packed MAX_POS probe, XLA formulation (static unroll).

    For each local vertex, OR the lane words of its first ``max_pos``
    neighbours, retiring the gather once every needed lane has found a
    parent. ``pos < deg`` keeps the gather inside real adjacency (pad
    slots are never read). The result must be masked with ``need`` by the
    caller.
    """
    m = g.m
    starts = g.row_ptr[:-1]
    deg = g.deg
    acc = jnp.zeros_like(need)
    for pos in range(max_pos):
        live = ((need & ~acc) != 0).any(axis=-1) & (pos < deg)
        vadj = g.col_idx[jnp.clip(starts + pos, 0, m - 1)]
        acc = acc | jnp.where(live[:, None], frontier[vadj],
                              jnp.zeros((), frontier.dtype))
    return acc


def bottomup_packed_step(g: CSRGraph, frontier: jnp.ndarray,
                         visited: jnp.ndarray, bu_sel: jnp.ndarray,
                         max_pos: int, probe_impl: str) -> jnp.ndarray:
    """Packed bottom-up: probe + lax.cond-skipped segmented-scan fallback.
    Returns new frontier bits for bottom-up lanes (already & ~visited)."""
    need = (~visited) & bu_sel
    if probe_impl == "pallas":
        from repro.kernels import msbfs_probe
        acc = msbfs_probe(g.row_ptr, g.col_idx, frontier, need,
                          max_pos=max_pos)
    else:
        acc = probe_xla(g, frontier, need, max_pos)
    found = acc & need

    residue = ((need & ~found) != 0).any(axis=-1) & (g.deg > max_pos)

    def run_fallback(found):
        pos_e = jnp.arange(g.m, dtype=jnp.int32) - g.row_ptr[g.src_idx]
        # pos_e < deg keeps pad slots (distributed slab tail) inert: their
        # src row is already full, so they never contribute
        act = (residue[g.src_idx] & (pos_e >= max_pos)
               & (pos_e < g.deg[g.src_idx]))
        contrib = jnp.where(act[:, None], frontier[g.col_idx],
                            jnp.zeros((), frontier.dtype))
        return found | (segment_or(contrib, g.row_ptr) & need)

    return jax.lax.cond(jnp.any(residue), run_fallback, lambda f: f, found)


def topdown_packed_step(g: CSRGraph, frontier: jnp.ndarray,
                        visited: jnp.ndarray,
                        td_sel: jnp.ndarray) -> jnp.ndarray:
    """Packed top-down: every edge lane forwards its col-side frontier words
    (masked to top-down lanes); per-row segmented OR gathers them. On the
    symmetrised Graph500 graphs this is exactly the TD expansion — the row
    owner collects from neighbours whose frontier bit is set."""
    contrib = frontier[jnp.clip(g.col_idx, 0, frontier.shape[0] - 1)] & td_sel
    return segment_or(contrib, g.row_ptr) & ~visited


def lane_counters(g: CSRGraph, frontier_b: jnp.ndarray,
                  visited_b: jnp.ndarray):
    """Per-lane (e_f, v_f, e_u) from unpacked bool[n, R] state. Under
    sharding these are per-device partials the caller psums."""
    deg = g.deg.astype(jnp.int32)[:, None]
    # int32 accumulators even under x64 (the u64 lane-word rung): the
    # trace buffers are int32 and m < 2**31 is enforced at build time
    e_f = jnp.sum(jnp.where(frontier_b, deg, 0), axis=0, dtype=jnp.int32)
    v_f = jnp.sum(frontier_b, axis=0, dtype=jnp.int32)
    e_u = jnp.sum(jnp.where(visited_b, 0, deg), axis=0, dtype=jnp.int32)
    return e_f, v_f, e_u


def select_direction(mode: str, topdown_prev: jnp.ndarray, e_f, v_f, e_u,
                     n: int, alpha: float, beta: float,
                     lanes: int) -> jnp.ndarray:
    """Per-lane TD/BU decision for one layer — shared by all engines.
    ``n`` is the switch-rule vertex count (the ORIGINAL graph size: the
    distributed engine passes ``n_orig`` so padded vertices never skew the
    beta threshold and traces replay the serial controller exactly)."""
    if mode == "topdown":
        return jnp.ones((lanes,), jnp.bool_)
    if mode == "bottomup":
        return jnp.zeros((lanes,), jnp.bool_)
    return switch_direction(topdown_prev, e_f, v_f, e_u, n, alpha, beta)


def dispatch_packed_step(g: CSRGraph, frontier: jnp.ndarray,
                         visited: jnp.ndarray, td_sel: jnp.ndarray,
                         bu_sel: jnp.ndarray, mode: str, max_pos: int,
                         probe_impl: str) -> jnp.ndarray:
    """Run the packed TD/BU step(s) for one layer under the lane selectors
    — shared by the single-batch sweep, the pipelined engine, and the
    per-device body of the distributed engine (all three must advance
    frontiers bit-for-bit identically)."""
    if mode == "topdown":
        return topdown_packed_step(g, frontier, visited, td_sel)
    if mode == "bottomup":
        return bottomup_packed_step(g, frontier, visited, bu_sel,
                                    max_pos, probe_impl)
    # middle layers usually have EVERY lane on one side — cond-skip the
    # other direction's O(m)/O(n*max_pos) work (the packed analog of the
    # serial controller's lax.cond)
    zero = jnp.zeros_like(visited)
    new_td = jax.lax.cond(
        jnp.any(td_sel != 0),
        lambda: topdown_packed_step(g, frontier, visited, td_sel),
        lambda: zero)
    new_bu = jax.lax.cond(
        jnp.any(bu_sel != 0),
        lambda: bottomup_packed_step(g, frontier, visited, bu_sel,
                                     max_pos, probe_impl),
        lambda: zero)
    return new_td | new_bu


def queue_claims(lane_qidx: jnp.ndarray, next_root: jnp.ndarray,
                 queued: jnp.ndarray, queue: jnp.ndarray):
    """Pending-queue claim rule of the pipelined engines: idle lanes (those
    with ``lane_qidx >= capacity``) claim consecutive pending queue slots
    in lane order. Returns ``(claim bool[L], cand int32[L], root int32[L])``
    — the slot index and root id are only meaningful where ``claim``.

    ONE implementation shared by the single-host and the sharded engine:
    their lane/queue evolution must stay bit-identical, so the claim rule
    lives here and only the seat writes are engine-specific.
    """
    cap = queue.shape[0]
    idle = lane_qidx >= cap
    rank = jnp.cumsum(idle.astype(jnp.int32)) - 1
    cand = next_root + rank
    claim = idle & (cand < queued)
    root = queue[jnp.clip(cand, 0, cap - 1)]
    return claim, cand, root


def adaptive_lane_pool(pending: int, n: int, m: int, max_lanes: int = 256,
                       state_budget_bytes: int = 64 << 20) -> int:
    """Pick the bit-lane pool width from queue depth + graph degree stats.

    The ROADMAP "adaptive lane-pool sizing" rung. Rules, in order:

    * never wider than the pending root count, rounded up to a full
      32-bit lane word (a partial word costs the same as a full one);
    * average degree tiers the width: sparse graphs run deep, layer-bound
      sweeps where refill opportunities are frequent and extra lane words
      amortise over many layers, so they earn wide pools; dense graphs
      saturate the segmented scan within a few layers, so extra words only
      inflate every gather — the pool stays near the 64-lane default;
    * capped so the packed state (frontier + visited ``uint32[n, W]`` plus
      ``int32 depth[n, lanes]``) stays inside ``state_budget_bytes``.

    Returns a positive multiple of 32 (one full lane word minimum); the
    engines clamp it down to ``ceil32(pending)`` themselves.
    """
    if n < 1:
        raise ValueError(f"need a non-empty graph, got n={n}")
    pending = max(int(pending), 1)
    avg_deg = m / n
    if avg_deg >= 16.0:
        tier_cap = 64
    elif avg_deg >= 4.0:
        tier_cap = 128
    else:
        tier_cap = max_lanes
    # bytes per lane: frontier + visited cost n/8 B each, depth costs 4n B
    per_lane = 4.25 * n
    budget_cap = max(int(state_budget_bytes / per_lane), 1)
    want = max(1, min(pending, tier_cap, budget_cap, max_lanes))
    return LANE_WORD_BITS * num_lane_words(want)
