"""2-D partitioned multi-source BFS: the bit-lane engine on a pr x pc grid.

The Buluc-Madduri (arXiv 1104.4518) 2-D decomposition applied to the
packed lane-word representation. Where the 1-D engine
(``repro.core.dist_msbfs``) replicates the full ``[n, W]`` frontier on
every device and OR-allreduces whole row blocks each layer, the 2-D
engine partitions the adjacency matrix over a ``pr x pc`` device grid and
never materialises replicated global frontier state:

* vertices are cut into ``G = pr * pc`` equal chunks (each padded to a
  multiple of 32); grid device ``(i, j)`` owns chunk ``g = i*pc + j``;
* *row block* ``i`` = chunks ``[i*pc, (i+1)*pc)`` — a CONTIGUOUS global
  row range of ``n_loc_r = pc * chunk`` vertices (so results assemble by
  concatenation, exactly like the 1-D engine);
* *column block* ``j`` = chunks ``{i*pc + j}`` — strided, one chunk per
  grid row, so each column's expand gathers exactly one chunk from each
  of its ``pr`` devices;
* device ``(i, j)`` stores the CSR rows of row block ``i`` RESTRICTED to
  destinations in column block ``j`` (``partition_graph_2d``), with
  column ids rewritten to column-block-local positions.

Per layer, per device ``(i, j)``:

  expand     — all-gather the ``chunk x W`` frontier chunks along the
               "row" axis (``exchange_expand``): the devices of grid
               column ``j`` assemble ``x_j``, column block ``j``'s
               frontier slice, in grid-row order = column-local order.
  local step — the SAME packed formulations as every other engine
               (``repro.core.packed``: segmented-OR top-down, MAX_POS
               word probe + scan fallback bottom-up) over the local
               ``(i, j)`` block against ``x_j``, producing PARTIAL
               new-frontier words for row block ``i`` (this block's
               edges only).
  fold       — OR-reduce the partials along the "col" axis
               (``exchange_reduce_or``): grid row ``i`` assembles the
               complete new frontier of row block ``i``, replicated
               along "col" — which is exactly the state the next
               layer's expand slices its chunk from.

Both exchanges ride ``repro.core.exchange.gather_words`` and therefore
the sparse frontier-word codec (``repro.distributed.compression``): with
``compress=True`` each gather group ships (index, payload) pairs whenever
every member's slice is sparse enough, so bytes on the wire per layer
track the FRONTIER POPULATION, not the graph — the engine accumulates the
actual per-step byte totals (``exch_bytes`` / ``exch_log``) and the star
benchmark (``benchmarks/dist2d_teps.py``) reports them.

Bit-identity with the host and 1-D engines (asserted across the whole
grid/width/wire-format matrix by ``tests/test_dist2d.py``): the packed
step computes, for every local row, the OR of its slab neighbours'
frontier words masked by ``need`` — probe retirement only fires once a
plane's needed bits are all served, and the scan fallback covers every
position past MAX_POS, so the partial is EXACTLY (partial row OR) & need
regardless of retirement granularity. Partial-row ORs over the grid
columns compose to the full row OR, the direction decision uses
psum-merged global counters, and all control state is replicated — so
depths, parents, layer counts, and per-layer traces replay the
single-host pipelined engine bit-for-bit.

Per-device state layout (``shard_map`` view):
  frontier  : word[pr, n_loc_r, W]  row block, REPLICATED along "col"
  visited   : word[pr, n_loc_r, W]            (P("row") in the mesh)
  depth     : int32[pr, n_loc_r, L]
  out_depth : int32[pr, n_loc_r, cap+1]
  graph     : stacked [G, ...] blocks, P(("row", "col"))
  everything else (queue, selectors, counters, traces): replicated.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.csr import CSRGraph
from repro.core.exchange import exchange_expand, exchange_reduce_or
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT, MAX_TRACE
from repro.core.msbfs import (MAX_LANES, MSBFSResult, msbfs_engine_enqueue,
                              msbfs_engine_idle)
from repro.core.packed import (LANE_WORD_BITS, MODES, adaptive_lane_pool,
                               dispatch_packed_step, lane_counters,
                               num_lane_words, pack_lanes, queue_claims,
                               select_direction, unpack_lanes, word_dtype)

__all__ = [
    "DistGraph2D", "Dist2DPipelineState", "dist2d_msbfs",
    "dist2d_msbfs_engine_drain", "dist2d_msbfs_engine_enqueue",
    "dist2d_msbfs_engine_idle", "dist2d_msbfs_engine_init",
    "dist2d_msbfs_engine_result", "dist2d_msbfs_engine_step", "mesh2d",
    "partition_graph_2d",
]


@dataclass(frozen=True)
class DistGraph2D:
    """Host-partitioned 2-D CSR: stacked per-device blocks, leading dim
    ``G = pr * pc`` in grid-row-major order (device ``(i, j)`` = slab
    ``i*pc + j``, matching a ``P(("row", "col"))`` placement)."""
    row_ptr: jnp.ndarray   # int32[G, n_loc_r+1] — offsets into the slab
    col_loc: jnp.ndarray   # int32[G, m_loc] — column-block-LOCAL dest ids
    col_gid: jnp.ndarray   # int32[G, m_loc] — global dest ids (parents)
    src_loc: jnp.ndarray   # int32[G, m_loc] — row-block-local source row
    deg: jnp.ndarray       # int32[G, n_loc_r] — PARTIAL (block) degrees
    n: int                 # padded global vertex count (G * chunk)
    n_orig: int            # original vertex count
    pr: int                # grid rows
    pc: int                # grid columns
    chunk: int             # rows per chunk (multiple of 32)
    m_loc: int             # uniform per-device edge-slab size (padded)

    @property
    def n_loc_r(self) -> int:
        """Rows per row block (= pc * chunk)."""
        return self.pc * self.chunk

    @property
    def n_x(self) -> int:
        """Rows per column-block frontier slice (= pr * chunk)."""
        return self.pr * self.chunk


def partition_graph_2d(g: CSRGraph, pr: int, pc: int) -> DistGraph2D:
    """Host-side 2-D partition: split ``g`` into ``pr x pc`` adjacency
    blocks with uniform padding.

    Row blocks are contiguous global row ranges; inside block ``(i, j)``
    each row keeps only its edges whose destination chunk ``v // chunk``
    lies in column block ``j`` (chunk index ``% pc == j``), in original
    adjacency order. ``col_loc`` rewrites destinations to their position
    inside the column block's gathered frontier slice
    (``grid_row * chunk + v % chunk``); ``col_gid`` keeps the global id
    for parent derivation. Padded edge slots carry sentinel column ids
    (``n_x`` local / ``n`` global) and live past every row's read-out
    point, so the packed steps never consume them."""
    if pr < 1 or pc < 1:
        raise ValueError(f"grid dims must be >= 1, got {pr}x{pc}")
    rp = np.asarray(g.row_ptr)
    ci = np.asarray(g.col_idx)
    n_orig = g.n
    ndev = pr * pc
    chunk = -(-n_orig // (ndev * 32)) * 32       # chunk multiple of 32
    n = chunk * ndev
    n_loc_r = pc * chunk
    n_x = pr * chunk

    slabs_loc, slabs_gid, srcs, degs = [], [], [], []
    for i in range(pr):
        lo_v, hi_v = i * n_loc_r, min((i + 1) * n_loc_r, n_orig)
        if lo_v < n_orig:
            dst = ci[rp[lo_v]:rp[hi_v]]
            src = np.repeat(np.arange(hi_v - lo_v, dtype=np.int32),
                            np.diff(rp[lo_v:hi_v + 1]))
        else:
            dst = src = np.zeros(0, np.int32)
        dst_chunk = dst // chunk
        for j in range(pc):
            sel = dst_chunk % pc == j
            d, s = dst[sel], src[sel]
            # column-local id: grid row of the dest chunk, then offset
            loc = (dst_chunk[sel] // pc) * chunk + d % chunk
            slabs_loc.append(loc.astype(np.int32))
            slabs_gid.append(d.astype(np.int32))
            srcs.append(s)
            degs.append(np.bincount(s, minlength=n_loc_r).astype(np.int32))

    m_loc = max(1, max(len(s) for s in srcs))
    col_loc = np.full((ndev, m_loc), n_x, np.int32)   # sentinel pads
    col_gid = np.full((ndev, m_loc), n, np.int32)
    src_l = np.zeros((ndev, m_loc), np.int32)
    deg_l = np.stack(degs)
    row_ptr_l = np.zeros((ndev, n_loc_r + 1), np.int32)
    np.cumsum(deg_l, axis=1, out=row_ptr_l[:, 1:])
    for d in range(ndev):
        k = len(srcs[d])
        col_loc[d, :k] = slabs_loc[d]
        col_gid[d, :k] = slabs_gid[d]
        src_l[d, :k] = srcs[d]
    return DistGraph2D(
        row_ptr=jnp.asarray(row_ptr_l), col_loc=jnp.asarray(col_loc),
        col_gid=jnp.asarray(col_gid), src_loc=jnp.asarray(src_l),
        deg=jnp.asarray(deg_l), n=n, n_orig=n_orig, pr=pr, pc=pc,
        chunk=chunk, m_loc=m_loc)


class Dist2DPipelineState(NamedTuple):
    """Pipelined-engine state on the 2-D grid. Mirrors
    ``dist_msbfs.DistPipelineState`` field-for-field (the host enqueue /
    idle helpers are shared) with two differences: the frontier is a
    row-block slice like ``visited`` (NO replicated ``[n, W]`` state —
    the tentpole), and the exchange-byte meters ride along."""
    frontier: jnp.ndarray        # word[pr, n_loc_r, W] — row block
    visited: jnp.ndarray         # word[pr, n_loc_r, W]
    depth: jnp.ndarray           # int32[pr, n_loc_r, L]
    lane_layer: jnp.ndarray      # int32[L]
    lane_qidx: jnp.ndarray       # int32[L]  queue slot served; cap = idle
    topdown: jnp.ndarray         # bool[L]
    queue: jnp.ndarray           # int32[capacity]
    queued: jnp.ndarray          # int32 scalar
    next_root: jnp.ndarray       # int32 scalar
    sweep_layers: jnp.ndarray    # int32 scalar
    out_depth: jnp.ndarray       # int32[pr, n_loc_r, capacity+1]
    out_edges: jnp.ndarray       # int32[capacity+1]
    out_layers: jnp.ndarray      # int32[capacity+1]  0 = unanswered
    trace_dir: jnp.ndarray       # int32[MAX_TRACE, capacity+1]
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray
    exch_bytes: jnp.ndarray      # int32 scalar — mesh-total wire bytes
    exch_log: jnp.ndarray        # int32[MAX_TRACE] — bytes per sweep step

    @property
    def num_lanes(self) -> int:
        return self.lane_qidx.shape[0]

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def _state_specs_2d() -> Dist2DPipelineState:
    row = P("row")
    rep = P()
    return Dist2DPipelineState(
        frontier=row, visited=row, depth=row, lane_layer=rep, lane_qidx=rep,
        topdown=rep, queue=rep, queued=rep, next_root=rep, sweep_layers=rep,
        out_depth=row, out_edges=rep, out_layers=rep, trace_dir=rep,
        trace_vf=rep, trace_ef=rep, trace_eu=rep, exch_bytes=rep,
        exch_log=rep)


def _check_partition_2d(dg: DistGraph2D, mesh: Mesh) -> None:
    shape = dict(mesh.shape)
    if tuple(mesh.axis_names) != ("row", "col"):
        raise ValueError(
            f'2-D engine needs a ("row", "col") mesh — got axes '
            f"{tuple(mesh.axis_names)}; build one with mesh2d(pr, pc)")
    if (shape["row"], shape["col"]) != (dg.pr, dg.pc):
        raise ValueError(
            f"DistGraph2D partitioned for a {dg.pr}x{dg.pc} grid but mesh "
            f"is {shape['row']}x{shape['col']} — repartition with "
            f"partition_graph_2d(g, {shape['row']}, {shape['col']})")


def mesh2d(pr: int, pc: int) -> Mesh:
    """``pr x pc`` grid mesh over the first ``pr*pc`` local devices."""
    devs = jax.devices()
    if len(devs) < pr * pc:
        raise ValueError(
            f"grid {pr}x{pc} needs {pr * pc} devices but only {len(devs)} "
            f"jax devices — set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={pr * pc} before the first jax import")
    return Mesh(np.asarray(devs[:pr * pc]).reshape(pr, pc), ("row", "col"))


def dist2d_msbfs_engine_init(dg: DistGraph2D, mesh: Mesh, capacity: int,
                             lanes: int = MAX_LANES) -> Dist2DPipelineState:
    """Fresh 2-D engine: all lanes idle, empty root queue, byte meters 0."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    _check_partition_2d(dg, mesh)
    n_loc_r = dg.n_loc_r
    w = num_lane_words(lanes)
    cap = capacity
    return Dist2DPipelineState(
        frontier=jnp.zeros((dg.pr, n_loc_r, w), word_dtype()),
        visited=jnp.zeros((dg.pr, n_loc_r, w), word_dtype()),
        depth=jnp.full((dg.pr, n_loc_r, lanes), -1, jnp.int32),
        lane_layer=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        topdown=jnp.ones((lanes,), jnp.bool_),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_layers=jnp.int32(0),
        out_depth=jnp.full((dg.pr, n_loc_r, cap + 1), -1, jnp.int32),
        out_edges=jnp.zeros((cap + 1,), jnp.int32),
        out_layers=jnp.zeros((cap + 1,), jnp.int32),
        trace_dir=jnp.full((MAX_TRACE, cap + 1), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE, cap + 1), jnp.int32),
        exch_bytes=jnp.int32(0),
        exch_log=jnp.zeros((MAX_TRACE,), jnp.int32),
    )


def dist2d_msbfs_engine_enqueue(state: Dist2DPipelineState,
                                roots) -> Dist2DPipelineState:
    """Append roots to the (replicated) pending queue."""
    return msbfs_engine_enqueue(state, roots)


def dist2d_msbfs_engine_idle(state: Dist2DPipelineState) -> bool:
    """True when no lane is active and no enqueued root is pending."""
    return msbfs_engine_idle(state)


def _dist2d_pipeline_body(g_loc: CSRGraph, base_r, chunk_base,
                          s: Dist2DPipelineState, mode: str, alpha: float,
                          beta: float, max_pos: int, probe_impl: str,
                          n: int, n_loc_r: int, chunk: int, n_orig: int,
                          compress: bool) -> Dist2DPipelineState:
    """One engine step, per-device view: refill idle lanes (replicated),
    expand the column frontier along "row", advance one layer on the
    local adjacency block, OR-fold the partials along "col", flush
    finished lanes. Mirrors ``dist_msbfs._dist_pipeline_body`` with the
    allreduce-OR exchange replaced by the two 2-D moves."""
    lanes = s.lane_qidx.shape[0]
    cap = s.queue.shape[0]
    w = s.frontier.shape[1]
    # one dtype for every dynamic_slice start (a bare 0 weak-types to
    # int64 under x64 — the u64 lane-word rung — and clashes with int32)
    col0 = jnp.zeros((), jnp.asarray(base_r).dtype)

    # --- refill: replicated claim logic, row-block seat writes -----------
    def do_refill(s: Dist2DPipelineState) -> Dist2DPipelineState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        fresh = pack_lanes(onehot)                            # word[n, W]
        onehot_loc = jax.lax.dynamic_slice(onehot, (base_r, col0),
                                           (n_loc_r, lanes))
        fresh_loc = jax.lax.dynamic_slice(fresh, (base_r, col0), (n_loc_r, w))
        return s._replace(
            frontier=s.frontier | fresh_loc,
            visited=s.visited | fresh_loc,
            depth=jnp.where(claim[None, :],
                            jnp.where(onehot_loc, 0, -1), s.depth),
            lane_layer=jnp.where(claim, 0, s.lane_layer),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            topdown=jnp.where(claim, mode != "bottomup", s.topdown),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= cap) & (s.next_root < s.queued)
    s = jax.lax.cond(needed, do_refill, lambda s: s, s)

    # --- per-lane direction from psum-merged global counters -------------
    # block degrees are PARTIAL (this column block's edges only), so the
    # edge counters merge over BOTH grid axes — at fixed i the j-sum
    # rebuilds the rows' global degrees, the i-sum totals the blocks —
    # while the vertex counter merges over "row" alone (row-block state
    # is replicated along "col"; both axes would count it pc times)
    active = s.lane_qidx < cap
    frontier_b = unpack_lanes(s.frontier, lanes)
    visited_b = unpack_lanes(s.visited, lanes)
    pe_f, pv_f, pe_u = lane_counters(g_loc, frontier_b, visited_b)
    e_f = jax.lax.psum(pe_f, ("row", "col"))
    v_f = jax.lax.psum(pv_f, "row")
    e_u = jax.lax.psum(pe_u, ("row", "col"))
    topdown = select_direction(mode, s.topdown, e_f, v_f, e_u, n_orig,
                               alpha, beta, lanes)

    live = active & (v_f > 0)
    td_sel = pack_lanes(topdown & live)                       # word[W]
    bu_sel = pack_lanes(~topdown & live)

    tr_row = jnp.clip(s.lane_layer, 0, MAX_TRACE - 1)
    tr_col = jnp.where(active, s.lane_qidx, cap)
    dir_vals = jnp.where(live, jnp.where(topdown, 0, 1),
                         -1).astype(jnp.int32)
    trace_dir = s.trace_dir.at[tr_row, tr_col].set(dir_vals)
    trace_vf = s.trace_vf.at[tr_row, tr_col].set(v_f)
    trace_ef = s.trace_ef.at[tr_row, tr_col].set(e_f)
    trace_eu = s.trace_eu.at[tr_row, tr_col].set(e_u)

    # --- expand: assemble this column block's frontier slice x_j ---------
    f_own = jax.lax.dynamic_slice(s.frontier, (chunk_base, col0), (chunk, w))
    x_j, bytes_expand = exchange_expand(f_own, "row", compress)

    # --- the SHARED packed step over the local adjacency block -----------
    new_partial = dispatch_packed_step(g_loc, x_j, s.visited, td_sel,
                                       bu_sel, mode, max_pos, probe_impl)

    # --- fold: complete the row block's new frontier along "col" ---------
    new_row, bytes_fold = exchange_reduce_or(new_partial, "col", compress)

    new_row_b = unpack_lanes(new_row, lanes)
    visited2 = s.visited | new_row
    visited2_b = visited_b | new_row_b
    lane_layer2 = s.lane_layer + active.astype(jnp.int32)
    depth2 = jnp.where(new_row_b, lane_layer2[None, :], s.depth)

    # finish = GLOBAL frontier drained OR per-lane layer cap
    v_next = jax.lax.psum(
        jnp.sum(new_row_b, axis=0, dtype=jnp.int32), "row")
    finished = active & ((v_next == 0) | (lane_layer2 >= MAX_TRACE))

    deg = g_loc.deg.astype(jnp.int32)[:, None]
    edges_l = jax.lax.psum(
        jnp.sum(jnp.where(visited2_b, deg, 0), axis=0,
                dtype=jnp.int32), ("row", "col"))
    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_depth = s.out_depth.at[:, fcol].set(depth2)
    out_edges = s.out_edges.at[fcol].set(edges_l)
    out_layers = s.out_layers.at[fcol].set(lane_layer2)

    # mesh-total wire bytes this step: each "row" gather group (a grid
    # column) reports its expand total, each "col" group (a grid row) its
    # fold total — summing each along the OTHER axis covers the mesh once
    step_bytes = (jax.lax.psum(bytes_expand, "col")
                  + jax.lax.psum(bytes_fold, "row")).astype(jnp.int32)
    log_row = jnp.clip(s.sweep_layers, 0, MAX_TRACE - 1)
    exch_log = s.exch_log.at[log_row].add(step_bytes)

    clear = pack_lanes(finished)                              # word[W]
    return s._replace(
        frontier=new_row & ~clear,
        visited=visited2 & ~clear,
        depth=jnp.where(finished[None, :], -1, depth2),
        lane_layer=jnp.where(finished, 0, lane_layer2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        topdown=topdown,
        sweep_layers=s.sweep_layers + 1,
        out_depth=out_depth, out_edges=out_edges, out_layers=out_layers,
        trace_dir=trace_dir, trace_vf=trace_vf, trace_ef=trace_ef,
        trace_eu=trace_eu,
        exch_bytes=s.exch_bytes + step_bytes,
        exch_log=exch_log,
    )


@partial(jax.jit, static_argnames=("mesh", "mode", "alpha", "beta",
                                   "max_pos", "probe_impl", "n", "n_loc_r",
                                   "chunk", "n_orig", "compress", "drain"))
def _dist2d_engine_run(row_ptr_s, colloc_s, srcloc_s,
                       state: Dist2DPipelineState, *, mesh: Mesh, mode: str,
                       alpha: float, beta: float, max_pos: int,
                       probe_impl: str, n: int, n_loc_r: int, chunk: int,
                       n_orig: int, compress: bool,
                       drain: bool) -> Dist2DPipelineState:
    cap = state.queue.shape[0]

    def body(row_ptr, col_loc, src_loc, s: Dist2DPipelineState):
        # strip the stacked device dims from the sharded leaves
        g_loc = CSRGraph(row_ptr=row_ptr[0], col_idx=col_loc[0],
                         src_idx=src_loc[0])
        i = jax.lax.axis_index("row")
        j = jax.lax.axis_index("col")
        base_r = (i * n_loc_r).astype(jnp.int32)     # row block start
        chunk_base = (j * chunk).astype(jnp.int32)   # own chunk, in-block
        s = s._replace(frontier=s.frontier[0], visited=s.visited[0],
                       depth=s.depth[0], out_depth=s.out_depth[0])

        step = partial(_dist2d_pipeline_body, g_loc, base_r, chunk_base,
                       mode=mode, alpha=alpha, beta=beta, max_pos=max_pos,
                       probe_impl=probe_impl, n=n, n_loc_r=n_loc_r,
                       chunk=chunk, n_orig=n_orig, compress=compress)
        if drain:
            s = jax.lax.while_loop(
                lambda s: (s.next_root < s.queued)
                | jnp.any(s.lane_qidx < cap),
                lambda s: step(s), s)
        else:
            s = step(s)
        return s._replace(frontier=s.frontier[None], visited=s.visited[None],
                          depth=s.depth[None], out_depth=s.out_depth[None])

    spec_dev = P(("row", "col"))
    specs = _state_specs_2d()
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, specs),
        out_specs=specs, check_vma=False,
    )(row_ptr_s, colloc_s, srcloc_s, state)


def dist2d_msbfs_engine_step(dg: DistGraph2D, state: Dist2DPipelineState,
                             mesh: Mesh, mode: str = "hybrid",
                             alpha: float = ALPHA_DEFAULT,
                             beta: float = BETA_DEFAULT, max_pos: int = 8,
                             probe_impl: str = "xla",
                             compress: bool = False) -> Dist2DPipelineState:
    """Advance the 2-D engine by one traversal layer (streaming API)."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    _check_partition_2d(dg, mesh)
    return _dist2d_engine_run(
        dg.row_ptr, dg.col_loc, dg.src_loc, state, mesh=mesh, mode=mode,
        alpha=alpha, beta=beta, max_pos=max_pos, probe_impl=probe_impl,
        n=dg.n, n_loc_r=dg.n_loc_r, chunk=dg.chunk, n_orig=dg.n_orig,
        compress=compress, drain=False)


def dist2d_msbfs_engine_drain(dg: DistGraph2D, state: Dist2DPipelineState,
                              mesh: Mesh, mode: str = "hybrid",
                              alpha: float = ALPHA_DEFAULT,
                              beta: float = BETA_DEFAULT, max_pos: int = 8,
                              probe_impl: str = "xla",
                              compress: bool = False) -> Dist2DPipelineState:
    """Step the 2-D engine until every enqueued root is answered."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    _check_partition_2d(dg, mesh)
    return _dist2d_engine_run(
        dg.row_ptr, dg.col_loc, dg.src_loc, state, mesh=mesh, mode=mode,
        alpha=alpha, beta=beta, max_pos=max_pos, probe_impl=probe_impl,
        n=dg.n, n_loc_r=dg.n_loc_r, chunk=dg.chunk, n_orig=dg.n_orig,
        compress=compress, drain=True)


@partial(jax.jit, static_argnames=("mesh", "n", "n_loc_r", "num_roots",
                                   "lane_chunk"))
def _derive_parents_2d(row_ptr_s, colgid_s, srcloc_s, depth_full, roots, *,
                       mesh: Mesh, n: int, n_loc_r: int, num_roots: int,
                       lane_chunk: int = 16):
    """2-D analog of ``dist_msbfs._derive_parents_dist``: each device
    scans its adjacency block for the min-id neighbour one level up
    (GLOBAL ids via ``col_gid``), grid rows pmin their column partials,
    then the row blocks are gathered. The min-id winner over a row's full
    adjacency is the min over its column-block partials, so parents match
    the host derivation exactly."""
    def body(row_ptr, col, src_loc, depth_full, roots):
        row_ptr, col, src_loc = row_ptr[0], col[0], src_loc[0]
        base_r = (jax.lax.axis_index("row") * n_loc_r).astype(jnp.int32)
        depth_loc = jax.lax.dynamic_slice(
            depth_full, (base_r, jnp.zeros((), base_r.dtype)),
            (n_loc_r, num_roots))
        colc = jnp.clip(col, 0, n - 1)
        valid = (col < n)[:, None]       # pad slots carry the sentinel n
        outs = []
        for lo in range(0, num_roots, lane_chunk):
            d_full = depth_full[:, lo:lo + lane_chunk]
            d_loc = depth_loc[:, lo:lo + lane_chunk]
            ok = valid & (d_full[colc] >= 0) & (d_full[colc] + 1
                                                == d_loc[src_loc])
            cand = jnp.where(ok, col[:, None], n).astype(jnp.int32)
            best = jnp.full((n_loc_r, d_loc.shape[1]), n,
                            jnp.int32).at[src_loc].min(cand)
            outs.append(best)
        parent_loc = jax.lax.pmin(jnp.concatenate(outs, axis=1), "col")
        parent_loc = jnp.where(parent_loc < n, parent_loc, -1)
        # seat roots owned by this row block; rows outside are pushed past
        # n_loc_r so mode="drop" discards them
        lane = jnp.arange(num_roots, dtype=jnp.int32)
        own = (roots >= base_r) & (roots < base_r + n_loc_r)
        lrow = jnp.where(own, roots - base_r, n_loc_r)
        parent_loc = parent_loc.at[lrow, lane].set(
            roots.astype(jnp.int32), mode="drop")
        return jax.lax.all_gather(parent_loc, "row", tiled=True)

    spec_dev = P(("row", "col"))
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, P(), P()),
        out_specs=P(), check_vma=False,
    )(row_ptr_s, colgid_s, srcloc_s, depth_full, roots)


def dist2d_msbfs_engine_result(dg: DistGraph2D, state: Dist2DPipelineState,
                               mesh: Mesh, trim: bool = True,
                               derive_parents: bool = True) -> MSBFSResult:
    """Assemble an ``MSBFSResult`` over the answered queue slots (row
    blocks are contiguous, so the stacked ``out_depth`` reshapes straight
    into global row order). Same conventions as the other engines."""
    _check_partition_2d(dg, mesh)
    r = int(state.queued)
    cap = state.capacity
    depth = jnp.reshape(state.out_depth, (dg.n, cap + 1))[:, :r]
    roots = state.queue[:r]
    if r and derive_parents:
        parent = _derive_parents_2d(
            dg.row_ptr, dg.col_gid, dg.src_loc, depth,
            roots.astype(jnp.int32), mesh=mesh, n=dg.n,
            n_loc_r=dg.n_loc_r, num_roots=r)
    else:
        parent = jnp.zeros((dg.n, 0), jnp.int32)
    lim = dg.n_orig if trim else dg.n
    return MSBFSResult(
        parent=parent[:lim], depth=depth[:lim],
        num_layers=state.out_layers[:r],
        edges_traversed=state.out_edges[:r],
        trace_dir=state.trace_dir[:, :r], trace_vf=state.trace_vf[:, :r],
        trace_ef=state.trace_ef[:, :r], trace_eu=state.trace_eu[:, :r])


def dist2d_msbfs(dg: DistGraph2D, roots, mesh: Mesh, mode: str = "hybrid",
                 alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
                 max_pos: int = 8, probe_impl: str = "xla",
                 lanes: int | None = None, compress: bool = False,
                 derive_parents: bool = True, recorder=None) -> MSBFSResult:
    """Answer an arbitrary number of roots with ONE 2-D engine sweep.

    ``compress=True`` ships both per-layer exchanges through the sparse
    frontier-word codec whenever the gather group is below the density
    threshold (wire bytes then track the frontier population — results
    are bit-identical either way). ``lanes=None`` sizes the pool
    adaptively, as in the other engines. ``recorder`` (a ``repro.obs
    .SweepRecorder``) steps layer-by-layer recording a ``LayerRecord``
    each — including this engine's per-layer ``exch_bytes`` delta —
    bit-identical to the fused drain; None touches nothing in obs."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one root")
    if not lanes:
        m_total = int(np.asarray(dg.deg, dtype=np.int64).sum())
        lanes = adaptive_lane_pool(num_roots, dg.n_orig, m_total)
    lanes = max(1, min(lanes, LANE_WORD_BITS * num_lane_words(num_roots)))
    state = dist2d_msbfs_engine_init(dg, mesh, capacity=num_roots,
                                     lanes=lanes)
    state = dist2d_msbfs_engine_enqueue(state, roots)
    if recorder is None:
        state = dist2d_msbfs_engine_drain(dg, state, mesh, mode, alpha,
                                          beta, max_pos, probe_impl,
                                          compress)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: dist2d_msbfs_engine_step(dg, s, mesh, mode, alpha,
                                               beta, max_pos, probe_impl,
                                               compress),
            dist2d_msbfs_engine_idle, kind="bfs",
            exch_format="compressed" if compress else "dense")
    return dist2d_msbfs_engine_result(dg, state, mesh,
                                      derive_parents=derive_parents)
