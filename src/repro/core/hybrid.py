"""Direction-optimizing (hybrid) BFS controller — paper Algorithm 3.

A ``lax.while_loop`` over layers. Each iteration:
  1. compute the heuristic counters: e_f (edges to check from the frontier),
     v_f (frontier vertex count), e_u (edges from unvisited vertices);
  2. apply the switching rule  — TD→BU when ``e_f > e_u / alpha``,
     BU→TD when ``v_f < n / beta``  (Beamer et al.; the paper's f/g
     functions are "architecture specific" — alpha/beta are config);
  3. ``lax.cond`` into the chosen step;
  4. record the per-layer trace (Table 2 analog).

Modes: hybrid | topdown | bottomup_simd | bottomup_nosimd | hybrid_nosimd
(hybrid with the non-SIMD bottom-up — the paper's blue line in Fig. 3).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bottomup import (MAX_POS_DEFAULT, bottomup_nosimd_step,
                                 bottomup_simd_step)
from repro.core.csr import CSRGraph
from repro.core.csr import ell_pad
from repro.core.topdown import topdown_ell_step, topdown_step

MAX_TRACE = 64  # fixed trace buffer (Graph500 R-MAT diameters are ~6-10)

ALPHA_DEFAULT = 14.0
BETA_DEFAULT = 24.0


class BFSResult(NamedTuple):
    # All counters are int32: values are bounded by m (the directed edge
    # count), and ``from_edges`` rejects graphs with m >= 2**31 — for
    # Graph500 edgefactor 16 that is headroom up to scale ~26 (scale 20 is
    # m ~ 2**25.1, far below the limit).
    parent: jnp.ndarray        # int32[n], -1 unreached, parent[root]=root
    depth: jnp.ndarray         # int32[n], -1 unreached
    num_layers: jnp.ndarray    # int32 scalar
    edges_traversed: jnp.ndarray  # int32 scalar — 2x undirected component edges
    trace_dir: jnp.ndarray     # int32[MAX_TRACE]: 0 TD, 1 BU, -1 unused
    trace_vf: jnp.ndarray      # int32[MAX_TRACE]
    trace_ef: jnp.ndarray      # int32[MAX_TRACE]
    trace_eu: jnp.ndarray      # int32[MAX_TRACE]


class _State(NamedTuple):
    frontier: jnp.ndarray
    visited: jnp.ndarray
    parent: jnp.ndarray
    depth: jnp.ndarray
    topdown: jnp.ndarray       # bool scalar
    layer: jnp.ndarray         # int32 scalar
    trace_dir: jnp.ndarray
    trace_vf: jnp.ndarray
    trace_ef: jnp.ndarray
    trace_eu: jnp.ndarray


def _counters(g: CSRGraph, frontier, visited):
    deg = g.deg.astype(jnp.int32)
    e_f = jnp.sum(jnp.where(frontier, deg, 0))
    v_f = jnp.sum(frontier, dtype=jnp.int32)
    e_u = jnp.sum(jnp.where(visited, 0, deg))
    return e_f, v_f, e_u


def switch_direction(topdown, e_f, v_f, e_u, n: int,
                     alpha: float = ALPHA_DEFAULT,
                     beta: float = BETA_DEFAULT):
    """Paper Algorithm 3 switching rule (Beamer et al.), one layer.

    TD->BU when ``e_f > e_u / alpha``; BU->TD when ``v_f < n / beta``;
    otherwise keep the current direction. All arguments may be scalars or
    arrays (the MS-BFS controller applies the rule per packed lane).
    Returns the new ``topdown`` flag(s).
    """
    go_bu = topdown & (jnp.asarray(e_f, jnp.float32)
                       > jnp.asarray(e_u, jnp.float32) / alpha)
    go_td = (~topdown) & (jnp.asarray(v_f, jnp.float32)
                          < jnp.float32(n) / beta)
    return jnp.where(go_bu, False, jnp.where(go_td, True, topdown))


@partial(jax.jit, static_argnums=(2, 3, 4, 5, 6, 7, 8))
def bfs(g: CSRGraph, root, mode: str = "hybrid",
        alpha: float = ALPHA_DEFAULT, beta: float = BETA_DEFAULT,
        max_pos: int = MAX_POS_DEFAULT, probe_impl: str = "xla",
        skip_empty_fallback: bool = True, td_impl: str = "edge") -> BFSResult:
    """Run a full BFS from ``root``. Compiles once per graph shape; the
    Graph500 harness reuses the compiled executable across the 64 roots."""
    n = g.n
    frontier = jnp.zeros((n,), jnp.bool_).at[root].set(True)
    visited = frontier
    parent = jnp.full((n,), -1, jnp.int32).at[root].set(root)
    depth = jnp.full((n,), -1, jnp.int32).at[root].set(0)
    # beyond-paper ELL top-down: bounded adjacency slabs, built once per graph
    ell = ell_pad(g, 16) if td_impl == "ell" else None

    def cond_fn(s: _State):
        return jnp.any(s.frontier) & (s.layer < MAX_TRACE)

    def body_fn(s: _State):
        e_f, v_f, e_u = _counters(g, s.frontier, s.visited)
        if mode == "topdown":
            topdown = jnp.bool_(True)
        elif mode in ("bottomup_simd", "bottomup_nosimd"):
            topdown = jnp.bool_(False)
        else:  # hybrid / hybrid_nosimd — paper Algorithm 3
            topdown = switch_direction(s.topdown, e_f, v_f, e_u, n,
                                       alpha, beta)

        def run_td(args):
            f, v, p = args
            if td_impl == "ell":
                return topdown_ell_step(g, ell, f, v, p, k_max=16)
            return topdown_step(g, f, v, p)

        def run_bu(args):
            f, v, p = args
            if mode in ("bottomup_nosimd", "hybrid_nosimd"):
                return bottomup_nosimd_step(g, f, v, p)
            return bottomup_simd_step(
                g, f, v, p, max_pos=max_pos, probe_impl=probe_impl,
                skip_empty_fallback=skip_empty_fallback)

        new_frontier, visited2, parent2 = jax.lax.cond(
            topdown, run_td, run_bu, (s.frontier, s.visited, s.parent))
        depth2 = jnp.where(new_frontier, s.layer + 1, s.depth)
        i = s.layer
        return _State(
            frontier=new_frontier, visited=visited2, parent=parent2,
            depth=depth2, topdown=topdown, layer=i + 1,
            trace_dir=s.trace_dir.at[i].set(jnp.where(topdown, 0, 1)),
            trace_vf=s.trace_vf.at[i].set(v_f),
            trace_ef=s.trace_ef.at[i].set(e_f),
            trace_eu=s.trace_eu.at[i].set(e_u),
        )

    init = _State(
        frontier=frontier, visited=visited, parent=parent, depth=depth,
        topdown=jnp.bool_(mode != "bottomup_simd" and mode != "bottomup_nosimd"),
        layer=jnp.int32(0),
        trace_dir=jnp.full((MAX_TRACE,), -1, jnp.int32),
        trace_vf=jnp.zeros((MAX_TRACE,), jnp.int32),
        trace_ef=jnp.zeros((MAX_TRACE,), jnp.int32),
        trace_eu=jnp.zeros((MAX_TRACE,), jnp.int32),
    )
    s = jax.lax.while_loop(cond_fn, body_fn, init)
    edges = jnp.sum(jnp.where(s.visited, g.deg.astype(jnp.int32), 0))
    return BFSResult(parent=s.parent, depth=s.depth, num_layers=s.layer,
                     edges_traversed=edges, trace_dir=s.trace_dir,
                     trace_vf=s.trace_vf, trace_ef=s.trace_ef,
                     trace_eu=s.trace_eu)
