"""Bottom-up BFS steps: the paper's vectorised probe (BU-SIMD) and the
non-SIMD baseline (Algorithm 2).

BU-SIMD (paper §5.1, Algorithms 4-5):
  * probe phase — for pos in [0, MAX_POS): every unvisited vertex gathers its
    pos-th neighbour and tests the frontier *bitmap* (word = v>>5, bit = v&31,
    Listing 1). Lanes that find a parent are retired from later rounds.
  * fallback phase — vertices with deg > MAX_POS that found nothing fall back
    to the full adjacency scan. On KNC this is a scalar loop; here it is the
    masked edge-parallel scan, and — beyond the paper — it is *skipped
    entirely* (lax.cond) when the probe retired everything, which restores
    the work savings that the scalar early-exit gave the paper.

Parent selection is deterministic: col_idx is sorted within each row, so
"first hit in adjacency order" == "min frontier-neighbour id" — identical to
the top-down scatter-min rule (DESIGN §3.3).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import bitmap
from repro.core.csr import CSRGraph

MAX_POS_DEFAULT = 8  # paper §5.2, Table 3


def _fallback_scan(g: CSRGraph, frontier_words, remaining, parent, min_pos: int):
    """Edge-parallel bottom-up scan over adjacency positions >= min_pos for
    vertices in ``remaining``. First hit = min edge index (= min neighbour id
    within the row). Returns (found2, parent)."""
    n, m = g.n, g.m
    e = jnp.arange(m, dtype=jnp.int32)
    pos_e = e - g.row_ptr[g.src_idx]
    act = remaining[g.src_idx] & (pos_e >= min_pos) & bitmap.test(
        frontier_words, g.col_idx)
    e_cand = jnp.where(act, e, m)
    e_min = jnp.full((n,), m, dtype=jnp.int32).at[g.src_idx].min(e_cand)
    hit = e_min < m
    par_new = g.col_idx[jnp.clip(e_min, 0, m - 1)]
    parent = jnp.where(hit, par_new, parent)
    return hit, parent


def bottomup_nosimd_step(g: CSRGraph, frontier: jnp.ndarray,
                         visited: jnp.ndarray, parent: jnp.ndarray):
    """Algorithm 2 baseline: full adjacency scan for every unvisited vertex
    (no probe phase, no bitmap-retirement)."""
    frontier_words = bitmap.pack(frontier)
    remaining = ~visited
    found, parent = _fallback_scan(g, frontier_words, remaining, parent, 0)
    new = found & remaining
    return new, visited | new, parent


def _probe_xla(g: CSRGraph, frontier_words, unvisited, parent, max_pos: int):
    """The MAX_POS probe loop, XLA formulation (static unroll)."""
    m = g.m
    starts = g.row_ptr[:-1]
    deg = g.deg
    found = jnp.zeros_like(unvisited)
    for pos in range(max_pos):
        live = unvisited & ~found & (pos < deg)
        vadj = g.col_idx[jnp.clip(starts + pos, 0, m - 1)]
        hit = live & bitmap.test(frontier_words, vadj)
        parent = jnp.where(hit, vadj, parent)
        found = found | hit
    return found, parent


def bottomup_simd_step(g: CSRGraph, frontier: jnp.ndarray,
                       visited: jnp.ndarray, parent: jnp.ndarray,
                       max_pos: int = MAX_POS_DEFAULT,
                       probe_impl: str = "xla",
                       skip_empty_fallback: bool = True):
    """The paper's vectorised bottom-up (probe + conditional fallback).

    ``skip_empty_fallback=False`` ablates the beyond-paper lax.cond that
    skips the fallback scan when the probe retired everything.
    """
    frontier_words = bitmap.pack(frontier)
    unvisited = ~visited
    if probe_impl == "pallas":
        from repro.kernels import bottom_up_probe
        found, parent = bottom_up_probe(
            g.row_ptr, g.col_idx, frontier_words, unvisited, parent, max_pos)
    else:
        found, parent = _probe_xla(g, frontier_words, unvisited, parent, max_pos)

    remaining = unvisited & ~found & (g.deg > max_pos)

    def run_fallback(args):
        rem, par = args
        hit2, par = _fallback_scan(g, frontier_words, rem, par, max_pos)
        return hit2, par

    if skip_empty_fallback:
        def skip_fallback(args):
            rem, par = args
            return jnp.zeros_like(rem), par

        found2, parent = jax.lax.cond(jnp.any(remaining), run_fallback,
                                      skip_fallback, (remaining, parent))
    else:
        found2, parent = run_fallback((remaining, parent))
    new = (found | found2) & unvisited
    return new, visited | new, parent


def bottomup_probe_stats(g: CSRGraph, frontier: jnp.ndarray,
                         visited: jnp.ndarray, max_pos: int):
    """Instrumentation for the Table-3 analog: per-layer counts of
    (unvisited, retired-by-probe, residue needing fallback, probe lanes)."""
    frontier_words = bitmap.pack(frontier)
    unvisited = ~visited
    parent = jnp.full((g.n,), -1, dtype=jnp.int32)
    found, _ = _probe_xla(g, frontier_words, unvisited, parent, max_pos)
    residue = unvisited & ~found & (g.deg > max_pos)
    return dict(
        unvisited=jnp.sum(unvisited, dtype=jnp.int32),
        retired=jnp.sum(found, dtype=jnp.int32),
        residue=jnp.sum(residue, dtype=jnp.int32),
        probe_lanes=jnp.sum(unvisited, dtype=jnp.int32) * max_pos,
    )
