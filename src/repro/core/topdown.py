"""Edge-parallel top-down BFS step (the TD-SIMD analog).

The paper's top-down vectorisation [Paredes et al., CF'16] processes
adjacency lists in 16-lane chunks. On a flat-vector machine the natural
equivalent is the fully edge-parallel formulation: every edge slot is one
lane; lanes whose source is in the frontier are active. Parent selection is
deterministic (min frontier-neighbour id via scatter-min), which makes
top-down, bottom-up and the oracle produce *identical* trees (DESIGN §3.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import CSRGraph


def topdown_step(g: CSRGraph, frontier: jnp.ndarray, visited: jnp.ndarray,
                 parent: jnp.ndarray):
    """One top-down layer.

    Args:
      frontier: bool[n] — current layer.
      visited:  bool[n] — includes the frontier.
      parent:   int32[n].
    Returns (new_frontier, visited, parent).
    """
    n = g.n
    active = frontier[g.src_idx] & ~visited[g.col_idx]
    cand = jnp.where(active, g.src_idx, n).astype(jnp.int32)
    best = jnp.full((n,), n, dtype=jnp.int32).at[g.col_idx].min(cand)
    new = (best < n) & ~visited
    parent = jnp.where(new, best, parent)
    return new, visited | new, parent


def topdown_active_lanes(g: CSRGraph, frontier: jnp.ndarray) -> jnp.ndarray:
    """e_f — number of edge lanes that are active this layer (the paper's
    'edges to check in the frontier' counter)."""
    return jnp.sum(jnp.where(frontier, g.deg, 0), dtype=jnp.int32)


def topdown_ell_step(g: CSRGraph, ell, frontier: jnp.ndarray,
                     visited: jnp.ndarray, parent: jnp.ndarray,
                     k_max: int = 16):
    """Beyond-paper: the bounded-probe insight applied to TOP-DOWN.

    Instead of activating all m edge lanes, scan only the first ``k_max``
    adjacency slots of every vertex (ELL slab, precomputed once per graph)
    masked by frontier membership — O(n*k_max) lanes — and fall back to the
    masked edge-parallel scan *only* for frontier vertices with
    deg > k_max (lax.cond-skipped when there are none). For Graph500
    edgefactors 16-64, n*k_max << m.

    ``ell`` = (neigh int32[n, k_max], valid bool[n, k_max]) from
    ``repro.core.csr.ell_pad``.
    """
    n = g.n
    neigh, valid = ell
    act = valid & frontier[:, None]                       # [n, k_max]
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                           neigh.shape)
    cand = jnp.where(act, src, n).astype(jnp.int32)
    best = jnp.full((n,), n, dtype=jnp.int32).at[
        jnp.clip(neigh, 0, n - 1).reshape(-1)].min(cand.reshape(-1))

    need_residue = jnp.any(frontier & (g.deg > k_max))

    def residue(best):
        e = jnp.arange(g.m, dtype=jnp.int32)
        pos_e = e - g.row_ptr[g.src_idx]
        act_e = frontier[g.src_idx] & (pos_e >= k_max)
        cand_e = jnp.where(act_e, g.src_idx, n).astype(jnp.int32)
        return best.at[g.col_idx].min(cand_e)

    best = jax.lax.cond(need_residue, residue, lambda b: b, best)
    new = (best < n) & ~visited
    parent = jnp.where(new, best, parent)
    return new, visited | new, parent
