"""Distributed delta-stepping SSSP: the tropical lane engine sharded.

The weighted sibling of ``dist_msbfs``/``dist2d``: float lane values fold
under ``min`` across partitions exactly as packed words fold under OR
(Buluc-Madduri's decomposition and SlimSell's semiring-BFS formulation
generalized past the boolean algebra), so both partition shapes reuse the
shared exchange layer (``repro.core.exchange``) through its MIN-monoid
surface — ``allreduce_min`` / ``gather_values`` / ``exchange_reduce_min``
— and the same density-switched sparse wire format: a relaxation
candidate is ``inf`` everywhere a relaxation did not fire this step, so
compressed layers cost bytes proportional to the ACTIVE frontier, not the
graph.

**1-D engine** (``dist_sssp_*``): device d owns a contiguous row block of
the weighted CSR (``partition_weighted_graph`` — the ``dist_bfs``
partition plus an inf-padded weight slab). Lane distances, the ``relaxed``
request flags, and all bucket control are REPLICATED; per step each device
runs the host engine's masked ``tropical_relax`` phases over its local
block against the full replicated values, places its row-block candidates
onto an inf background, and the per-step exchange is one
``exchange_reduce_min`` over the mesh (the ``allreduce_or`` analog, with
optional value compression + byte metering). Bucket control replays the
host engine from collectively-merged counters: per-block light-pending
counts ``psum`` to the global request-set population, per-block unsettled
minima ``pmin`` to the global bucket advance — int32 sums and float32
mins are exact, so every control decision (and therefore every distance,
step count, truncation flag, and bucket/phase trace) is bit-identical to
single-host ``sssp_pipelined``.

**2-D engine** (``dist2d_sssp_*``): the ``pr x pc`` grid of ``dist2d``
with no replicated ``[n, L]`` value state. Device ``(i, j)`` holds row
block ``i``'s distances (replicated along "col") and the weighted
adjacency block ``(i, j)``. Per step: slice the own chunk, all-gather it
along "row" (``exchange_expand_values``) into the column block's value
slice, run the masked relax phases over the local block, MIN-fold the
row-block partials along "col" (``exchange_reduce_min``). The two phases
of a lane are mutually exclusive, so ONE masked source array ships per
step — each device recovers the light/heavy operands from the replicated
per-lane phase flags after the gather, keeping the wire as sparse as the
union of both request sets. Partial row minima over column blocks compose
exactly to the full row minimum, so the grid replays the host engine
bit-for-bit too (``tests/test_dist_sssp.py`` pins the whole matrix).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.csr import CSRGraph, WeightedCSRGraph
from repro.core.dist2d import (DistGraph2D, _check_partition_2d, mesh2d,
                               partition_graph_2d)
from repro.core.dist_bfs import _flat_axis_index, partition_graph
from repro.core.dist_msbfs import host_mesh
from repro.core.exchange import (allreduce_min, exchange_expand_values,
                                 exchange_reduce_min)
from repro.core.packed import queue_claims
from repro.traversal.semiring import INF, tropical_relax
from repro.traversal.sssp import (DEFAULT_LANES, MAX_SSSP_STEPS,
                                  MAX_SSSP_TRACE, SSSPResult, _check_delta,
                                  _delta_lanes, sssp_engine_enqueue,
                                  sssp_engine_idle)

__all__ = [
    "DistSSSPState", "DistWeightedGraph", "DistWeightedGraph2D",
    "allreduce_min", "default_delta_dist", "dist2d_sssp",
    "dist2d_sssp_engine_drain", "dist2d_sssp_engine_enqueue",
    "dist2d_sssp_engine_idle", "dist2d_sssp_engine_init",
    "dist2d_sssp_engine_result", "dist2d_sssp_engine_step", "dist_sssp",
    "dist_sssp_engine_drain", "dist_sssp_engine_enqueue",
    "dist_sssp_engine_idle", "dist_sssp_engine_init",
    "dist_sssp_engine_result", "dist_sssp_engine_step", "host_mesh",
    "mesh2d", "partition_weighted_graph", "partition_weighted_graph_2d",
]


# ---------------------------------------------------------------------------
# Weighted partitions: the unweighted structure + an inf-padded weight slab.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DistWeightedGraph:
    """1-D ``DistGraph`` plus the matching per-device weight slabs. Edge
    slab d is row block d's edges in ORIGINAL adjacency order, so the
    weight slab is the same contiguous cut of ``wg.weights``; pad slots
    carry ``inf`` (the min-plus annihilator — a consumed pad could only
    produce an inf candidate, which the fold ignores)."""
    row_ptr: jnp.ndarray   # int32[ndev, n_loc+1]
    col_idx: jnp.ndarray   # int32[ndev, m_loc] — global neighbour ids
    src_loc: jnp.ndarray   # int32[ndev, m_loc]
    deg: jnp.ndarray       # int32[ndev, n_loc]
    weights: jnp.ndarray   # float32[ndev, m_loc] — inf pads
    n: int                 # padded global vertex count
    n_orig: int            # original vertex count
    m_loc: int             # uniform per-device edge-slab size


def partition_weighted_graph(wg: WeightedCSRGraph,
                             ndev: int) -> DistWeightedGraph:
    """1-D partition of a weighted CSR: ``dist_bfs.partition_graph`` on
    the structure, plus the per-block weight slabs it implies."""
    dg = partition_graph(wg.csr, ndev)
    rp = np.asarray(wg.row_ptr)
    w = np.asarray(wg.weights)
    block = dg.n // ndev
    w_l = np.full((ndev, dg.m_loc), np.inf, np.float32)
    for d in range(ndev):
        lo_v, hi_v = d * block, min((d + 1) * block, wg.n)
        if lo_v < wg.n:
            slab = w[rp[lo_v]:rp[hi_v]]
            w_l[d, :len(slab)] = slab
    return DistWeightedGraph(
        row_ptr=dg.row_ptr, col_idx=dg.col_idx, src_loc=dg.src_loc,
        deg=dg.deg, weights=jnp.asarray(w_l), n=dg.n, n_orig=dg.n_orig,
        m_loc=dg.m_loc)


@dataclass(frozen=True)
class DistWeightedGraph2D:
    """2-D ``DistGraph2D`` plus per-block weight slabs (inf pads). The
    structure partition is ``dist2d.partition_graph_2d`` verbatim; the
    weights replay the same per-block edge selection."""
    g2: DistGraph2D
    weights: jnp.ndarray   # float32[G, m_loc] — inf pads

    @property
    def n(self) -> int:
        return self.g2.n

    @property
    def n_orig(self) -> int:
        return self.g2.n_orig


def partition_weighted_graph_2d(wg: WeightedCSRGraph, pr: int,
                                pc: int) -> DistWeightedGraph2D:
    """2-D partition of a weighted CSR: structure from
    ``partition_graph_2d``, weight slabs by replaying its per-block edge
    selection (same row-block cut, same per-column-block destination
    filter, same order)."""
    g2 = partition_graph_2d(wg.csr, pr, pc)
    rp = np.asarray(wg.row_ptr)
    ci = np.asarray(wg.col_idx)
    w = np.asarray(wg.weights)
    chunk, n_loc_r = g2.chunk, g2.n_loc_r
    w_l = np.full((pr * pc, g2.m_loc), np.inf, np.float32)
    rp_check = np.asarray(g2.row_ptr)
    for i in range(pr):
        lo_v, hi_v = i * n_loc_r, min((i + 1) * n_loc_r, wg.n)
        if lo_v < wg.n:
            dst = ci[rp[lo_v]:rp[hi_v]]
            wrow = w[rp[lo_v]:rp[hi_v]]
        else:
            dst = np.zeros(0, np.int32)
            wrow = np.zeros(0, np.float32)
        dst_chunk = dst // chunk
        for j in range(pc):
            sel = dst_chunk % pc == j
            d = i * pc + j
            k = int(sel.sum())
            if k != int(rp_check[d, -1]):
                raise AssertionError(
                    f"weight slab {d} selected {k} edges but the structure "
                    f"partition holds {int(rp_check[d, -1])}")
            w_l[d, :k] = wrow[sel]
    return DistWeightedGraph2D(g2=g2, weights=jnp.asarray(w_l))


def default_delta_dist(dwg) -> float:
    """``sssp.default_delta`` recomputed from a partitioned weighted graph
    — same max-weight / average-degree rule over the REAL edges (pads are
    inf and excluded), bit-identical to the host value so a distributed
    run with ``delta=None`` replays the host engine exactly."""
    w = np.asarray(dwg.weights)
    fin = np.isfinite(w)
    m = int(fin.sum())
    if m == 0:
        return 1.0
    w_max = float(w[fin].max())
    avg_deg = m / max(dwg.n_orig, 1)
    delta = w_max / max(avg_deg, 1.0)
    return delta if delta > 0 else 1.0


# ---------------------------------------------------------------------------
# Shared engine state (both partition shapes).
# ---------------------------------------------------------------------------

class DistSSSPState(NamedTuple):
    """Sharded-engine state. Mirrors ``sssp.SSSPState`` field-for-field
    (so the host enqueue/idle helpers are shared) plus the exchange byte
    meters. On the 1-D partition EVERY field is replicated (the graph is
    what's sharded — value state stays replicated like the 1-D MS-BFS
    frontier); on the 2-D grid the row-indexed arrays are row-block
    slices with a leading stacked device dim."""
    dist: jnp.ndarray          # float32[..., L]  lane distances
    relaxed: jnp.ndarray       # bool[..., L]     light request flags
    lane_bucket: jnp.ndarray   # int32[L]
    lane_steps: jnp.ndarray    # int32[L]
    lane_qidx: jnp.ndarray     # int32[L]   queue slot served; cap = idle
    queue: jnp.ndarray         # int32[capacity]
    queued: jnp.ndarray        # int32 scalar
    next_root: jnp.ndarray     # int32 scalar
    sweep_steps: jnp.ndarray   # int32 scalar
    out_dist: jnp.ndarray      # float32[..., capacity+1]
    out_steps: jnp.ndarray     # int32[capacity+1]  0 = unanswered
    out_truncated: jnp.ndarray  # bool[capacity+1]
    trace_bucket: jnp.ndarray  # int32[MAX_SSSP_TRACE, capacity+1]
    trace_phase: jnp.ndarray   # int32[MAX_SSSP_TRACE, capacity+1]
    exch_bytes: jnp.ndarray    # int32 scalar — mesh-total wire bytes
    exch_log: jnp.ndarray      # int32[MAX_SSSP_TRACE] — bytes per step

    @property
    def num_lanes(self) -> int:
        return self.lane_qidx.shape[0]

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def _masked_relax_groups(g_loc: CSRGraph, w_loc: jnp.ndarray, vals_from,
                         delta, lanes: int, iterating, settling,
                         max_pos: int, relax_impl: str) -> jnp.ndarray:
    """The host engine's per-delta-group light/heavy relax pair over a
    LOCAL adjacency block: ``vals_from(phase_sel)`` supplies the masked
    [nf, L] source values for a per-lane selector (inf outside it), the
    block's candidates min-fold across groups. Same group structure as
    ``sssp._sssp_body``, so scalar deltas run the exact single-width
    relaxations."""
    n_loc = g_loc.n
    cand = jnp.full((n_loc, lanes), jnp.inf, jnp.float32)
    widths = (sorted(set(delta)) if isinstance(delta, tuple)
              else [float(delta)])
    lane_widths = (delta if isinstance(delta, tuple)
                   else (float(delta),) * lanes)

    def relax_phase(vals, phase_w):
        def run(vals):
            return tropical_relax(g_loc, phase_w, vals, max_pos, relax_impl)
        return jax.lax.cond(
            jnp.any(jnp.isfinite(vals)), run,
            lambda vals: jnp.full((n_loc, lanes), jnp.inf, jnp.float32),
            vals)

    for dv in widths:
        gsel = jnp.asarray([lw == dv for lw in lane_widths], jnp.bool_)
        dv32 = jnp.float32(dv)
        light_w = jnp.where(w_loc <= dv32, w_loc, INF)
        heavy_w = jnp.where(w_loc > dv32, w_loc, INF)
        cand = jnp.minimum(
            cand, relax_phase(vals_from(iterating & gsel), light_w))
        cand = jnp.minimum(
            cand, relax_phase(vals_from(settling & gsel), heavy_w))
    return cand


def _bucket_control(s: DistSSSPState, d32, min_unsettled, iterating,
                    max_steps: int):
    """Replicated post-relax control shared by both engines: request-flag
    update is the caller's (it needs the local ``changed``); this covers
    bucket advance, the step/truncation bookkeeping, and the trace writes
    — exactly ``sssp._sssp_body``'s tail, computed from globally-merged
    ``min_unsettled``."""
    cap = s.capacity
    active = s.lane_qidx < cap
    settling = active & ~iterating
    exhausted = settling & ~jnp.isfinite(min_unsettled)
    next_bucket = jnp.where(
        settling & jnp.isfinite(min_unsettled),
        jnp.maximum(jnp.floor(min_unsettled / d32).astype(jnp.int32),
                    s.lane_bucket + 1),
        s.lane_bucket)
    lane_steps2 = s.lane_steps + active.astype(jnp.int32)
    capped = active & (lane_steps2 >= max_steps) & ~exhausted
    finished = exhausted | capped

    tr_row = jnp.clip(s.lane_steps, 0, MAX_SSSP_TRACE - 1)
    tr_col = jnp.where(active, s.lane_qidx, cap)
    trace_bucket = s.trace_bucket.at[tr_row, tr_col].set(
        jnp.where(active, s.lane_bucket, -1))
    trace_phase = s.trace_phase.at[tr_row, tr_col].set(
        jnp.where(active, jnp.where(iterating, 0, 1), -1).astype(jnp.int32))
    return (next_bucket, lane_steps2, capped, finished, trace_bucket,
            trace_phase)


# ---------------------------------------------------------------------------
# 1-D engine: replicated values, sharded graph, allreduce-MIN exchange.
# ---------------------------------------------------------------------------

def _state_specs_1d() -> DistSSSPState:
    rep = P()
    return DistSSSPState(*([rep] * len(DistSSSPState._fields)))


def _check_partition_1d(dwg: DistWeightedGraph, mesh: Mesh) -> int:
    ndev = int(np.prod(mesh.devices.shape))
    if dwg.row_ptr.shape[0] != ndev:
        raise ValueError(
            f"DistWeightedGraph partitioned for {dwg.row_ptr.shape[0]} "
            f"devices but mesh has {ndev} — repartition with "
            f"partition_weighted_graph(wg, {ndev})")
    return ndev


def dist_sssp_engine_init(dwg: DistWeightedGraph, mesh: Mesh, capacity: int,
                          lanes: int = DEFAULT_LANES) -> DistSSSPState:
    """Fresh sharded SSSP engine: all lanes idle, empty source queue,
    byte meters zero."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    _check_partition_1d(dwg, mesh)
    n = dwg.n
    cap = capacity
    return DistSSSPState(
        dist=jnp.full((n, lanes), jnp.inf, jnp.float32),
        relaxed=jnp.zeros((n, lanes), jnp.bool_),
        lane_bucket=jnp.zeros((lanes,), jnp.int32),
        lane_steps=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_steps=jnp.int32(0),
        out_dist=jnp.full((n, cap + 1), jnp.inf, jnp.float32),
        out_steps=jnp.zeros((cap + 1,), jnp.int32),
        out_truncated=jnp.zeros((cap + 1,), jnp.bool_),
        trace_bucket=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
        trace_phase=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
        exch_bytes=jnp.int32(0),
        exch_log=jnp.zeros((MAX_SSSP_TRACE,), jnp.int32),
    )


def dist_sssp_engine_enqueue(state: DistSSSPState, roots) -> DistSSSPState:
    """Append sources to the (replicated) pending queue — the host helper
    verbatim, as in the MS-BFS engines."""
    return sssp_engine_enqueue(state, roots)


def dist_sssp_engine_idle(state: DistSSSPState) -> bool:
    """True when no lane is active and no enqueued source is pending."""
    return sssp_engine_idle(state)


def _queue_refill(s: DistSSSPState, n: int):
    """Replicated refill — ``sssp._refill`` on the engine's own state
    width (both engines' control state is replicated, so the claim logic
    is the host one verbatim)."""
    def do_refill(s: DistSSSPState) -> DistSSSPState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        return s._replace(
            dist=jnp.where(claim[None, :],
                           jnp.where(onehot, jnp.float32(0), INF), s.dist),
            relaxed=jnp.where(claim[None, :], False, s.relaxed),
            lane_bucket=jnp.where(claim, 0, s.lane_bucket),
            lane_steps=jnp.where(claim, 0, s.lane_steps),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= s.capacity) & (s.next_root < s.queued)
    return jax.lax.cond(needed, do_refill, lambda s: s, s)


def _dist_sssp_body(gw_loc, base, s: DistSSSPState, delta, max_pos: int,
                    relax_impl: str, max_steps: int, n: int, n_loc: int,
                    axes, compress: bool) -> DistSSSPState:
    """One engine step, per-device view: refill idle lanes (replicated),
    run the masked relax phases over the local row block, MIN-exchange
    the placed candidates, advance buckets from psum/pmin-merged
    counters, flush finished lanes."""
    g_loc, w_loc = gw_loc
    cap = s.capacity
    lanes = s.num_lanes
    col0 = jnp.zeros((), jnp.asarray(base).dtype)
    s = _queue_refill(s, n)

    d32 = _delta_lanes(delta, lanes)                          # [L]
    active = s.lane_qidx < cap
    b_hi = (s.lane_bucket.astype(jnp.float32) + 1) * d32      # [L]
    in_bucket = active[None, :] & (s.dist < b_hi[None, :])    # [n, L]
    light_pending = in_bucket & ~s.relaxed

    # request-set population via psum of per-block counts: each device
    # counts its OWN rows, the int32 sum is exact, so the phase decision
    # replays the host's global any() bit-for-bit
    lp_loc = jax.lax.dynamic_slice(light_pending, (base, col0),
                                   (n_loc, lanes))
    req_count = jax.lax.psum(
        jnp.sum(lp_loc, axis=0, dtype=jnp.int32), axes)       # [L]
    iterating = req_count > 0
    settling = active & ~iterating

    def vals_from(phase_sel):
        # light lanes mask by the request set, settling lanes by bucket
        # membership — phase_sel already carries the lane split
        mask = jnp.where(iterating[None, :], light_pending, in_bucket)
        return jnp.where(mask & phase_sel[None, :], s.dist, INF)

    cand_loc = _masked_relax_groups(g_loc, w_loc, vals_from, delta, lanes,
                                    iterating, settling, max_pos,
                                    relax_impl)               # [n_loc, L]

    # --- candidate exchange: place the row block, MIN-fold the mesh -----
    placed = jax.lax.dynamic_update_slice(
        jnp.full((n, lanes), jnp.inf, jnp.float32), cand_loc, (base, col0))
    cand_full, step_bytes = exchange_reduce_min(placed, axes, compress)

    new_dist = jnp.minimum(s.dist, cand_full)
    changed = new_dist < s.dist
    relaxed2 = (s.relaxed | (light_pending & iterating[None, :])) & ~changed

    # bucket advance from pmin-merged per-block unsettled minima (float32
    # min is exactly associative: same bits as the host's global min)
    unsettled = jnp.where(new_dist >= b_hi[None, :], new_dist, INF)
    uns_loc = jax.lax.dynamic_slice(unsettled, (base, col0), (n_loc, lanes))
    min_unsettled = jax.lax.pmin(jnp.min(uns_loc, axis=0), axes)  # [L]

    (next_bucket, lane_steps2, capped, finished, trace_bucket,
     trace_phase) = _bucket_control(s, d32, min_unsettled, iterating,
                                    max_steps)

    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_dist = s.out_dist.at[:, fcol].set(new_dist)
    out_steps = s.out_steps.at[fcol].set(lane_steps2)
    out_truncated = s.out_truncated.at[fcol].set(capped)

    log_row = jnp.clip(s.sweep_steps, 0, MAX_SSSP_TRACE - 1)
    return s._replace(
        dist=jnp.where(finished[None, :], INF, new_dist),
        relaxed=relaxed2 & ~finished[None, :],
        lane_bucket=jnp.where(finished, 0, next_bucket),
        lane_steps=jnp.where(finished, 0, lane_steps2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        sweep_steps=s.sweep_steps + 1,
        out_dist=out_dist, out_steps=out_steps,
        out_truncated=out_truncated,
        trace_bucket=trace_bucket, trace_phase=trace_phase,
        exch_bytes=s.exch_bytes + step_bytes,
        exch_log=s.exch_log.at[log_row].add(step_bytes),
    )


@partial(jax.jit, static_argnames=("mesh", "delta", "max_pos", "relax_impl",
                                   "max_steps", "n", "n_loc", "compress",
                                   "drain"))
def _dist_sssp_run(row_ptr_s, col_s, srcloc_s, w_s, state: DistSSSPState, *,
                   mesh: Mesh, delta, max_pos: int, relax_impl: str,
                   max_steps: int, n: int, n_loc: int, compress: bool,
                   drain: bool) -> DistSSSPState:
    axes = tuple(mesh.axis_names)
    cap = state.queue.shape[0]

    def body(row_ptr, col, src_loc, w, s: DistSSSPState):
        g_loc = CSRGraph(row_ptr=row_ptr[0], col_idx=col[0],
                         src_idx=src_loc[0])
        base = _flat_axis_index(axes, dict(mesh.shape)) * n_loc
        step = partial(_dist_sssp_body, (g_loc, w[0]), base, delta=delta,
                       max_pos=max_pos, relax_impl=relax_impl,
                       max_steps=max_steps, n=n, n_loc=n_loc, axes=axes,
                       compress=compress)
        if drain:
            s = jax.lax.while_loop(
                lambda s: (s.next_root < s.queued)
                | jnp.any(s.lane_qidx < cap),
                lambda s: step(s), s)
        else:
            s = step(s)
        return s

    spec_dev = P(axes)
    specs = _state_specs_1d()
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, spec_dev, specs),
        out_specs=specs, check_vma=False,
    )(row_ptr_s, col_s, srcloc_s, w_s, state)


def dist_sssp_engine_step(dwg: DistWeightedGraph, state: DistSSSPState,
                          mesh: Mesh, delta, max_pos: int = 8,
                          relax_impl: str = "xla",
                          max_steps: int = MAX_SSSP_STEPS,
                          compress: bool = False) -> DistSSSPState:
    """Advance the sharded SSSP engine by one phase step (streaming API).
    ``delta`` is a scalar or per-lane tuple, static like the host's."""
    _check_delta(delta)
    ndev = _check_partition_1d(dwg, mesh)
    return _dist_sssp_run(
        dwg.row_ptr, dwg.col_idx, dwg.src_loc, dwg.weights, state,
        mesh=mesh, delta=delta, max_pos=max_pos, relax_impl=relax_impl,
        max_steps=max_steps, n=dwg.n, n_loc=dwg.n // ndev,
        compress=compress, drain=False)


def dist_sssp_engine_drain(dwg: DistWeightedGraph, state: DistSSSPState,
                           mesh: Mesh, delta, max_pos: int = 8,
                           relax_impl: str = "xla",
                           max_steps: int = MAX_SSSP_STEPS,
                           compress: bool = False) -> DistSSSPState:
    """Step the sharded engine until every enqueued source is answered."""
    _check_delta(delta)
    ndev = _check_partition_1d(dwg, mesh)
    return _dist_sssp_run(
        dwg.row_ptr, dwg.col_idx, dwg.src_loc, dwg.weights, state,
        mesh=mesh, delta=delta, max_pos=max_pos, relax_impl=relax_impl,
        max_steps=max_steps, n=dwg.n, n_loc=dwg.n // ndev,
        compress=compress, drain=True)


def dist_sssp_engine_result(dwg: DistWeightedGraph,
                            state: DistSSSPState) -> SSSPResult:
    """Assemble an ``SSSPResult`` over the answered queue slots, trimmed
    to the original (pre-padding) vertex count."""
    r = int(state.queued)
    return SSSPResult(sources=state.queue[:r],
                      dist=state.out_dist[:dwg.n_orig, :r],
                      steps=state.out_steps[:r],
                      truncated=state.out_truncated[:r],
                      trace_bucket=state.trace_bucket[:, :r],
                      trace_phase=state.trace_phase[:, :r])


def dist_sssp(dwg: DistWeightedGraph, roots, mesh: Mesh, delta=None,
              lanes: int = DEFAULT_LANES, max_pos: int = 8,
              relax_impl: str = "xla", max_steps: int = MAX_SSSP_STEPS,
              compress: bool = False, recorder=None) -> SSSPResult:
    """Answer an arbitrary number of SSSP sources with ONE sharded sweep.
    ``delta=None`` picks the host's ``default_delta`` value (recomputed
    from the partition, bit-identical); distances/steps/truncation/traces
    replay ``sssp_pipelined`` exactly on every partition shape.
    ``recorder`` (a ``repro.obs.SweepRecorder``) steps the engine
    recording a ``LayerRecord`` (incl. the per-step ``exch_bytes`` delta)
    each phase — bit-identical to the drain; None touches nothing."""
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one source")
    if delta is None:
        delta = default_delta_dist(dwg)
    lanes = max(1, min(lanes, num_roots))
    delta = delta if isinstance(delta, tuple) else float(delta)
    state = dist_sssp_engine_init(dwg, mesh, capacity=num_roots, lanes=lanes)
    state = dist_sssp_engine_enqueue(state, roots)
    if recorder is None:
        state = dist_sssp_engine_drain(dwg, state, mesh, delta, max_pos,
                                       relax_impl, max_steps, compress)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: dist_sssp_engine_step(dwg, s, mesh, delta, max_pos,
                                            relax_impl, max_steps,
                                            compress),
            dist_sssp_engine_idle, kind="sssp",
            exch_format="compressed" if compress else "dense")
    return dist_sssp_engine_result(dwg, state)


# ---------------------------------------------------------------------------
# 2-D engine: row-block values, expand/fold grid exchanges, MIN monoid.
# ---------------------------------------------------------------------------

def _state_specs_2d() -> DistSSSPState:
    row = P("row")
    rep = P()
    return DistSSSPState(
        dist=row, relaxed=row, lane_bucket=rep, lane_steps=rep,
        lane_qidx=rep, queue=rep, queued=rep, next_root=rep,
        sweep_steps=rep, out_dist=row, out_steps=rep, out_truncated=rep,
        trace_bucket=rep, trace_phase=rep, exch_bytes=rep, exch_log=rep)


def dist2d_sssp_engine_init(dwg2: DistWeightedGraph2D, mesh: Mesh,
                            capacity: int,
                            lanes: int = DEFAULT_LANES) -> DistSSSPState:
    """Fresh 2-D SSSP engine: row-block value state, byte meters zero."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    g2 = dwg2.g2
    _check_partition_2d(g2, mesh)
    n_loc_r = g2.n_loc_r
    cap = capacity
    return DistSSSPState(
        dist=jnp.full((g2.pr, n_loc_r, lanes), jnp.inf, jnp.float32),
        relaxed=jnp.zeros((g2.pr, n_loc_r, lanes), jnp.bool_),
        lane_bucket=jnp.zeros((lanes,), jnp.int32),
        lane_steps=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_steps=jnp.int32(0),
        out_dist=jnp.full((g2.pr, n_loc_r, cap + 1), jnp.inf, jnp.float32),
        out_steps=jnp.zeros((cap + 1,), jnp.int32),
        out_truncated=jnp.zeros((cap + 1,), jnp.bool_),
        trace_bucket=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
        trace_phase=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
        exch_bytes=jnp.int32(0),
        exch_log=jnp.zeros((MAX_SSSP_TRACE,), jnp.int32),
    )


def dist2d_sssp_engine_enqueue(state: DistSSSPState,
                               roots) -> DistSSSPState:
    """Append sources to the (replicated) pending queue."""
    return sssp_engine_enqueue(state, roots)


def dist2d_sssp_engine_idle(state: DistSSSPState) -> bool:
    """True when no lane is active and no enqueued source is pending."""
    return sssp_engine_idle(state)


def _dist2d_sssp_body(gw_loc, base_r, chunk_base, s: DistSSSPState, delta,
                      max_pos: int, relax_impl: str, max_steps: int, n: int,
                      n_loc_r: int, chunk: int,
                      compress: bool) -> DistSSSPState:
    """One engine step, per-device view on the grid: refill (replicated
    control, row-block seat writes), expand the own chunk's masked values
    along "row", relax over the local weighted block, MIN-fold the
    partials along "col", advance buckets from globally-merged counters,
    flush finished lanes."""
    g_loc, w_loc = gw_loc
    cap = s.capacity
    lanes = s.num_lanes
    col0 = jnp.zeros((), jnp.asarray(base_r).dtype)

    # --- refill: replicated claim logic, row-block seat writes ----------
    def do_refill(s: DistSSSPState) -> DistSSSPState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        onehot_loc = jax.lax.dynamic_slice(onehot, (base_r, col0),
                                           (n_loc_r, lanes))
        return s._replace(
            dist=jnp.where(claim[None, :],
                           jnp.where(onehot_loc, jnp.float32(0), INF),
                           s.dist),
            relaxed=jnp.where(claim[None, :], False, s.relaxed),
            lane_bucket=jnp.where(claim, 0, s.lane_bucket),
            lane_steps=jnp.where(claim, 0, s.lane_steps),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= cap) & (s.next_root < s.queued)
    s = jax.lax.cond(needed, do_refill, lambda s: s, s)

    d32 = _delta_lanes(delta, lanes)                          # [L]
    active = s.lane_qidx < cap
    b_hi = (s.lane_bucket.astype(jnp.float32) + 1) * d32      # [L]
    in_bucket = active[None, :] & (s.dist < b_hi[None, :])    # [n_loc_r, L]
    light_pending = in_bucket & ~s.relaxed

    # phase decision from psum'd per-row-block request counts ("row" only:
    # row-block state is replicated along "col" — both axes would count
    # it pc times)
    req_count = jax.lax.psum(
        jnp.sum(light_pending, axis=0, dtype=jnp.int32), "row")
    iterating = req_count > 0
    settling = active & ~iterating

    # ONE masked source array per step: a lane is in exactly one phase,
    # so the union mask ships once and each device recovers the per-phase
    # operands from the replicated lane flags after the gather — the wire
    # stays as sparse as the union of the request sets
    masked_src = jnp.where(
        jnp.where(iterating[None, :], light_pending, in_bucket),
        s.dist, INF)

    # --- expand: assemble this column block's value slice x_j -----------
    f_own = jax.lax.dynamic_slice(masked_src, (chunk_base, col0),
                                  (chunk, lanes))
    x_j, bytes_expand = exchange_expand_values(f_own, "row", compress)

    def vals_from(phase_sel):
        return jnp.where(phase_sel[None, :], x_j, INF)

    partial_cand = _masked_relax_groups(g_loc, w_loc, vals_from, delta,
                                        lanes, iterating, settling,
                                        max_pos, relax_impl)  # [n_loc_r, L]

    # --- fold: complete the row block's candidates along "col" ----------
    cand, bytes_fold = exchange_reduce_min(partial_cand, "col", compress)

    new_dist = jnp.minimum(s.dist, cand)
    changed = new_dist < s.dist
    relaxed2 = (s.relaxed | (light_pending & iterating[None, :])) & ~changed

    unsettled = jnp.where(new_dist >= b_hi[None, :], new_dist, INF)
    min_unsettled = jax.lax.pmin(jnp.min(unsettled, axis=0), "row")  # [L]

    (next_bucket, lane_steps2, capped, finished, trace_bucket,
     trace_phase) = _bucket_control(s, d32, min_unsettled, iterating,
                                    max_steps)

    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_dist = s.out_dist.at[:, fcol].set(new_dist)
    out_steps = s.out_steps.at[fcol].set(lane_steps2)
    out_truncated = s.out_truncated.at[fcol].set(capped)

    # mesh-total wire bytes this step: each "row" gather group (a grid
    # column) reports its expand total, each "col" group (a grid row) its
    # fold total — summing each along the OTHER axis covers the mesh once
    step_bytes = (jax.lax.psum(bytes_expand, "col")
                  + jax.lax.psum(bytes_fold, "row")).astype(jnp.int32)
    log_row = jnp.clip(s.sweep_steps, 0, MAX_SSSP_TRACE - 1)

    return s._replace(
        dist=jnp.where(finished[None, :], INF, new_dist),
        relaxed=relaxed2 & ~finished[None, :],
        lane_bucket=jnp.where(finished, 0, next_bucket),
        lane_steps=jnp.where(finished, 0, lane_steps2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        sweep_steps=s.sweep_steps + 1,
        out_dist=out_dist, out_steps=out_steps,
        out_truncated=out_truncated,
        trace_bucket=trace_bucket, trace_phase=trace_phase,
        exch_bytes=s.exch_bytes + step_bytes,
        exch_log=s.exch_log.at[log_row].add(step_bytes),
    )


@partial(jax.jit, static_argnames=("mesh", "delta", "max_pos", "relax_impl",
                                   "max_steps", "n", "n_loc_r", "chunk",
                                   "compress", "drain"))
def _dist2d_sssp_run(row_ptr_s, colloc_s, srcloc_s, w_s,
                     state: DistSSSPState, *, mesh: Mesh, delta,
                     max_pos: int, relax_impl: str, max_steps: int, n: int,
                     n_loc_r: int, chunk: int, compress: bool,
                     drain: bool) -> DistSSSPState:
    cap = state.queue.shape[0]

    def body(row_ptr, col_loc, src_loc, w, s: DistSSSPState):
        g_loc = CSRGraph(row_ptr=row_ptr[0], col_idx=col_loc[0],
                         src_idx=src_loc[0])
        i = jax.lax.axis_index("row")
        j = jax.lax.axis_index("col")
        base_r = (i * n_loc_r).astype(jnp.int32)     # row block start
        chunk_base = (j * chunk).astype(jnp.int32)   # own chunk, in-block
        s = s._replace(dist=s.dist[0], relaxed=s.relaxed[0],
                       out_dist=s.out_dist[0])

        step = partial(_dist2d_sssp_body, (g_loc, w[0]), base_r, chunk_base,
                       delta=delta, max_pos=max_pos, relax_impl=relax_impl,
                       max_steps=max_steps, n=n, n_loc_r=n_loc_r,
                       chunk=chunk, compress=compress)
        if drain:
            s = jax.lax.while_loop(
                lambda s: (s.next_root < s.queued)
                | jnp.any(s.lane_qidx < cap),
                lambda s: step(s), s)
        else:
            s = step(s)
        return s._replace(dist=s.dist[None], relaxed=s.relaxed[None],
                          out_dist=s.out_dist[None])

    spec_dev = P(("row", "col"))
    specs = _state_specs_2d()
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(spec_dev, spec_dev, spec_dev, spec_dev, specs),
        out_specs=specs, check_vma=False,
    )(row_ptr_s, colloc_s, srcloc_s, w_s, state)


def dist2d_sssp_engine_step(dwg2: DistWeightedGraph2D, state: DistSSSPState,
                            mesh: Mesh, delta, max_pos: int = 8,
                            relax_impl: str = "xla",
                            max_steps: int = MAX_SSSP_STEPS,
                            compress: bool = False) -> DistSSSPState:
    """Advance the 2-D SSSP engine by one phase step (streaming API)."""
    _check_delta(delta)
    g2 = dwg2.g2
    _check_partition_2d(g2, mesh)
    return _dist2d_sssp_run(
        g2.row_ptr, g2.col_loc, g2.src_loc, dwg2.weights, state, mesh=mesh,
        delta=delta, max_pos=max_pos, relax_impl=relax_impl,
        max_steps=max_steps, n=g2.n, n_loc_r=g2.n_loc_r, chunk=g2.chunk,
        compress=compress, drain=False)


def dist2d_sssp_engine_drain(dwg2: DistWeightedGraph2D, state: DistSSSPState,
                             mesh: Mesh, delta, max_pos: int = 8,
                             relax_impl: str = "xla",
                             max_steps: int = MAX_SSSP_STEPS,
                             compress: bool = False) -> DistSSSPState:
    """Step the 2-D engine until every enqueued source is answered."""
    _check_delta(delta)
    g2 = dwg2.g2
    _check_partition_2d(g2, mesh)
    return _dist2d_sssp_run(
        g2.row_ptr, g2.col_loc, g2.src_loc, dwg2.weights, state, mesh=mesh,
        delta=delta, max_pos=max_pos, relax_impl=relax_impl,
        max_steps=max_steps, n=g2.n, n_loc_r=g2.n_loc_r, chunk=g2.chunk,
        compress=compress, drain=True)


def dist2d_sssp_engine_result(dwg2: DistWeightedGraph2D,
                              state: DistSSSPState) -> SSSPResult:
    """Assemble an ``SSSPResult`` (row blocks are contiguous, so the
    stacked ``out_dist`` reshapes straight into global row order), trimmed
    to the original vertex count."""
    g2 = dwg2.g2
    r = int(state.queued)
    cap = state.capacity
    dist = jnp.reshape(state.out_dist, (g2.n, cap + 1))[:g2.n_orig, :r]
    return SSSPResult(sources=state.queue[:r],
                      dist=dist,
                      steps=state.out_steps[:r],
                      truncated=state.out_truncated[:r],
                      trace_bucket=state.trace_bucket[:, :r],
                      trace_phase=state.trace_phase[:, :r])


def dist2d_sssp(dwg2: DistWeightedGraph2D, roots, mesh: Mesh, delta=None,
                lanes: int = DEFAULT_LANES, max_pos: int = 8,
                relax_impl: str = "xla", max_steps: int = MAX_SSSP_STEPS,
                compress: bool = False, recorder=None) -> SSSPResult:
    """Answer an arbitrary number of SSSP sources with ONE 2-D grid sweep.
    ``compress=True`` ships both per-step value exchanges through the
    sparse (index, payload) codec whenever the gather group is below the
    density threshold — results are bit-identical either way.
    ``recorder`` records a ``LayerRecord`` per phase step as in the
    other engines (None, the default, touches nothing in obs)."""
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one source")
    if delta is None:
        delta = default_delta_dist(dwg2)
    lanes = max(1, min(lanes, num_roots))
    delta = delta if isinstance(delta, tuple) else float(delta)
    state = dist2d_sssp_engine_init(dwg2, mesh, capacity=num_roots,
                                    lanes=lanes)
    state = dist2d_sssp_engine_enqueue(state, roots)
    if recorder is None:
        state = dist2d_sssp_engine_drain(dwg2, state, mesh, delta, max_pos,
                                         relax_impl, max_steps, compress)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: dist2d_sssp_engine_step(dwg2, s, mesh, delta,
                                              max_pos, relax_impl,
                                              max_steps, compress),
            dist2d_sssp_engine_idle, kind="sssp",
            exch_format="compressed" if compress else "dense")
    return dist2d_sssp_engine_result(dwg2, state)
