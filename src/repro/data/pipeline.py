"""Synthetic-but-deterministic data pipelines for all three families.

Every pipeline is seeded, host-shardable (each host materialises only its
slice given (host_id, n_hosts)), and resumable: ``state`` is a step counter,
so restoring a checkpoint restores the exact data stream position —
required for deterministic restart-after-failure tests.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Arch, Shape, effective_cfg
from repro.models.gnn.common import GraphBatch, synthetic_graph_batch


@dataclass
class PipelineState:
    step: int = 0


def _fold(seed: int, *vals: int) -> np.random.Generator:
    return np.random.default_rng(np.uint64(abs(hash((seed,) + vals))
                                           % (1 << 63)))


def lm_batch(arch: Arch, shape: Shape, step: int, seed: int = 0,
             host_id: int = 0, n_hosts: int = 1):
    d = shape.dims
    b, s = d["global_batch"] // n_hosts, d["seq_len"]
    rng = _fold(seed, step, host_id)
    toks = rng.integers(0, arch.model_cfg.vocab, size=(b, s), dtype=np.int32)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def gnn_batch(arch: Arch, shape: Shape, step: int, seed: int = 0) -> GraphBatch:
    d = shape.dims
    cfg = effective_cfg(arch, shape)
    key = jax.random.PRNGKey(seed + 7919 * step)
    return synthetic_graph_batch(
        key, d["n_nodes"], d["n_edges"], d["d_feat"],
        n_classes=d.get("n_classes", 16), n_graphs=d.get("n_graphs", 1))


def recsys_batch(arch: Arch, shape: Shape, step: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
    cfg = arch.model_cfg
    b = shape.dims["batch"] // n_hosts
    t, m = cfg.seq_len, cfg.profile_bag
    rng = _fold(seed, step, host_id)
    batch = {
        "target_item": rng.integers(0, cfg.n_items, b, dtype=np.int32),
        "target_cat": rng.integers(0, cfg.n_cats, b, dtype=np.int32),
        "hist_items": rng.integers(0, cfg.n_items, (b, t), dtype=np.int32),
        "hist_cats": rng.integers(0, cfg.n_cats, (b, t), dtype=np.int32),
        "hist_mask": rng.random((b, t)) < 0.9,
        "profile_ids": rng.integers(0, cfg.n_profiles, (b, m), dtype=np.int32),
        "profile_mask": np.ones((b, m), bool),
    }
    if shape.kind == "train":
        batch["labels"] = rng.random(b).astype(np.float32) < 0.5
        batch["neg_items"] = rng.integers(0, cfg.n_items, (b, t),
                                          dtype=np.int32)
    if shape.kind == "retrieval":
        batch["candidate_ids"] = np.arange(shape.dims["n_candidates"],
                                           dtype=np.int32)
    out = {k: jnp.asarray(v) for k, v in batch.items()}
    if "labels" in out:
        out["labels"] = out["labels"].astype(jnp.float32)
    return out


def make_batch(arch: Arch, shape: Shape, step: int, seed: int = 0,
               host_id: int = 0, n_hosts: int = 1):
    if arch.family in ("lm-dense", "lm-moe"):
        return lm_batch(arch, shape, step, seed, host_id, n_hosts)
    if arch.family == "gnn":
        return gnn_batch(arch, shape, step, seed)
    return recsys_batch(arch, shape, step, seed, host_id, n_hosts)
