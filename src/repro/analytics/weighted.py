"""Weighted analytics workloads on the tropical (SSSP) lane engine.

The unweighted workloads read per-lane BFS *depths*; these read per-lane
shortest-path *distances* from the delta-stepping engine
(``repro.traversal.sssp``) through the same ``LaneEngine`` facade:

* ``sssp_distances`` — batched single-source shortest paths: one dense
  tropical lane per source, sources beyond the lane pool streamed
  through the pending queue;
* ``weighted_closeness_centrality`` — Wasserman–Faust closeness over
  weighted distances, exact chunked all-sources or the sampled
  Eppstein–Wang style estimator — the SAME accumulation/estimator code
  as the unweighted version (``closeness_from_dists``), so sampling all
  vertices again reduces exactly to the exact numbers.

Engines must be built from a ``WeightedCSRGraph``; the boolean workloads
keep working on the same engine (weights ignored).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.analytics.closeness import (ClosenessResult,
                                       closeness_from_dists,
                                       select_sources)
from repro.analytics.engine import as_engine, pad_roots
from repro.analytics.meta import QueryMeta

__all__ = ["SSSPDistancesResult", "sssp_distances",
           "weighted_closeness_centrality"]


@dataclass(frozen=True)
class SSSPDistancesResult:
    sources: np.ndarray          # int32[S]
    dist: np.ndarray             # float32[n, S], inf unreached
    delta: float | tuple         # bucket width(s) the sweep ran with
    steps: np.ndarray            # int32[S] engine steps per source lane
    truncated_lanes: np.ndarray  # bool[S] — lane hit the step cap: its
    #                              column is a partial relaxation
    meta: QueryMeta = field(default_factory=QueryMeta)

    @property
    def truncated(self) -> np.ndarray:
        """Deprecated spelling of ``truncated_lanes`` (the common
        ``meta.truncated`` flag is now the any-lane summary)."""
        warnings.warn(
            "SSSPDistancesResult.truncated is deprecated — use "
            ".truncated_lanes (per-lane) or .meta.truncated (any lane)",
            DeprecationWarning, stacklevel=2)
        return self.truncated_lanes

    def reached(self) -> np.ndarray:
        """bool[n, S] — vertices with a finite distance per source."""
        return np.isfinite(self.dist)

    def distances_to(self, targets) -> np.ndarray:
        """float64[S, T] pairwise source->target distances (inf
        unreachable) — the weighted analog of ``khop.reachability``."""
        targets = np.asarray(targets, np.int64).reshape(-1)
        return np.asarray(self.dist, np.float64)[targets].T


def _resolve_delta(eng, delta) -> float | tuple | None:
    """Pin ``delta=None`` to the graph default ONCE per workload call —
    the engine would otherwise recompute it (a host copy of all m
    weights) inside every chunk sweep, and the recorded metadata would
    not name the width actually used. ``delta="adaptive"`` runs the
    weight-histogram rule (``traversal.sssp.adaptive_delta``): on bimodal
    weights it widens the bucket past the light/heavy gap — fewer settle
    steps, identical distances (any positive width is exact at fixpoint).
    A scalar or per-lane tuple passes through unchanged."""
    if not eng.weighted:
        return delta              # unweighted: let sssp_sweep raise
    if delta is None:
        from repro.traversal.sssp import default_delta
        return float(default_delta(eng.wg))
    if isinstance(delta, str):
        if delta != "adaptive":
            raise ValueError(
                f"delta must be None, 'adaptive', a scalar, or a "
                f"per-lane tuple — got {delta!r}")
        from repro.traversal.sssp import adaptive_delta
        return float(adaptive_delta(eng.wg))
    return delta


def sssp_distances(g_or_engine, sources, delta=None,
                   **engine_kwargs) -> SSSPDistancesResult:
    """Shortest-path distances from each source, one pipelined
    delta-stepping sweep — on whatever partition the engine was built
    with (host, 1-D mesh, or 2-D grid; distances are bit-identical).
    ``delta=None`` picks the engine default
    (``traversal.sssp.default_delta``); ``delta="adaptive"`` the
    weight-histogram width; a per-lane tuple hands each lane its own."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    delta = _resolve_delta(eng, delta)
    sources = np.asarray(sources, np.int32).reshape(-1)
    res = eng.sssp_sweep(sources, delta=delta)
    steps = np.asarray(res.steps)
    truncated_lanes = np.asarray(res.truncated)
    return SSSPDistancesResult(
        sources=sources, dist=np.asarray(res.dist),
        delta=delta if isinstance(delta, tuple) else float(delta),
        steps=steps, truncated_lanes=truncated_lanes,
        meta=QueryMeta(kind="sssp", layers=int(steps.max()),
                       truncated=bool(truncated_lanes.any()),
                       lanes=eng.sssp_lanes_for(sources.size),
                       ndev=eng.ndev,
                       extra=dict(grid=eng.grid, compress=eng.compress,
                                  delta=delta)))


def weighted_closeness_centrality(g_or_engine,
                                  sources: int | str | None = "auto",
                                  seed: int = 0, chunk: int = 64,
                                  delta=None,
                                  **engine_kwargs) -> ClosenessResult:
    """Weighted closeness centrality of every vertex — the unweighted
    estimator with SSSP distances standing in for BFS depths.

    ``sources`` follows the same rule: ``None`` forces exact
    all-sources, an int samples that many, ``"auto"`` dispatches on n.
    ``chunk`` bounds sources per engine sweep (dense float lanes — the
    default is narrower than the packed-lane chunk).
    """
    eng = as_engine(g_or_engine, **engine_kwargs)
    delta = _resolve_delta(eng, delta)
    n = eng.n
    src, method = select_sources(n, sources, seed)
    chunk = max(1, min(chunk, src.size))

    dist_cols = np.empty((n, src.size), np.float32)
    sweeps = 0
    steps = 0
    truncated = 0
    for lo in range(0, src.size, chunk):
        real = min(chunk, src.size - lo)
        res = eng.sssp_sweep(pad_roots(src[lo:lo + chunk], chunk),
                             delta=delta)
        dist_cols[:, lo:lo + real] = np.asarray(res.dist)[:, :real]
        truncated += int(np.asarray(res.truncated)[:real].sum())
        steps += int(np.asarray(res.steps).max())
        sweeps += 1
    closeness = closeness_from_dists(dist_cols, n)
    return ClosenessResult(
        closeness=closeness, method=method, num_sources=int(src.size),
        seed=None if method == "exact" else seed,
        meta=QueryMeta(kind="weighted_closeness", layers=steps,
                       truncated=truncated > 0,
                       lanes=eng.sssp_lanes_for(chunk), sweeps=sweeps,
                       ndev=eng.ndev,
                       extra=dict(chunk=chunk, weighted=True, delta=delta,
                                  truncated_lanes=truncated)))
