"""Connected components as a lane-BFS forest.

On an undirected graph, one BFS lane reaches *exactly* its root's
component — so components fall out of the MS-BFS engine for free: seed a
batch of roots drawn from the still-unlabelled vertices, sweep, label
every vertex reached by a lane, repeat. Each sweep retires between one
component (all roots collide in one) and ``batch`` of them, so the sweep
count lands in ``[ceil(num_components / batch), num_components]`` — the
classic MS-BFS payoff of answering many traversals per sweep, with the
floor attained when every root hits a distinct component.

Labelling is canonical: roots are always the *smallest* unlabelled vertex
ids, so every component ends up labelled with its minimum vertex id
(within a batch, two roots landing in the same component merge to the
smaller root — the component-merging rule). That makes results directly
comparable to any reference labelling after the same canonicalisation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analytics.engine import as_engine, pad_roots
from repro.analytics.meta import QueryMeta

__all__ = ["ComponentsResult", "connected_components"]


@dataclass(frozen=True)
class ComponentsResult:
    labels: np.ndarray           # int64[n] — component id = min vertex id in it
    num_components: int
    component_ids: np.ndarray    # int64[C] sorted unique labels
    sizes: np.ndarray            # int64[C] vertices per component, aligned
    sweeps: int                  # engine sweeps run
    roots_used: int              # total BFS lanes consumed
    meta: QueryMeta = field(default_factory=QueryMeta)

    @property
    def largest(self) -> tuple[int, int]:
        """(component id, size) of the largest component."""
        i = int(np.argmax(self.sizes))
        return int(self.component_ids[i]), int(self.sizes[i])


def connected_components(g_or_engine, batch: int = 64,
                         **engine_kwargs) -> ComponentsResult:
    """Label every vertex with its connected component via lane-BFS sweeps.

    ``batch`` roots are seeded per sweep (padded by repeating the first
    pending root so every sweep reuses ONE compiled engine executable).
    Accepts a ``CSRGraph`` plus engine kwargs (``ndev=``, ``lanes=``, ...)
    or a prebuilt ``LaneEngine``.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    eng = as_engine(g_or_engine, **engine_kwargs)
    n = eng.n
    labels = np.full(n, -1, np.int64)
    sweeps = 0
    roots_used = 0
    layers = 0
    while True:
        unlabelled = np.flatnonzero(labels < 0)
        if unlabelled.size == 0:
            break
        real = min(batch, unlabelled.size)
        roots = pad_roots(unlabelled[:real], batch)
        res = eng.sweep(roots)
        depth = np.asarray(res.depth)                  # int32[n, batch]
        reached = depth >= 0
        # roots ascend, so the FIRST lane reaching v carries the minimum
        # root id — the in-batch merge rule
        first = np.argmax(reached, axis=1)
        hit = reached.any(axis=1) & (labels < 0)
        labels[hit] = roots[first[hit]]
        layers += int(np.asarray(res.num_layers).max())
        sweeps += 1
        roots_used += real
    ids, sizes = np.unique(labels, return_counts=True)
    return ComponentsResult(
        labels=labels, num_components=int(ids.size),
        component_ids=ids.astype(np.int64), sizes=sizes.astype(np.int64),
        sweeps=sweeps, roots_used=roots_used,
        meta=QueryMeta(kind="components", layers=layers,
                       lanes=eng.lanes_for(batch), sweeps=sweeps,
                       ndev=eng.ndev, extra=dict(batch=batch)))
