"""K-hop neighbourhoods and reachability from packed frontier words.

A k-hop query is a depth-sliced BFS read-out: run the lane engine from the
query sources, then slice the per-lane depths at ``depth <= k``. The
result keeps the engines' OWN bit layout (``packed.depth_slice_words`` —
uint lane words, bit ``r % LANE_WORD_BITS`` of word ``r // LANE_WORD_BITS``)
so downstream packed consumers (set intersections across queries, the GNN
sampler's candidate pools) operate on words, not n-vectors; per-lane
membership unpacks on demand.

``graph/sampler.py`` exposes this as ``khop_node_sets`` — exact
neighbourhood candidate pools for GNN sampling riding the same fast path
as BFS serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analytics.engine import as_engine
from repro.core.packed import unpack_lanes

__all__ = ["KHopResult", "khop_neighborhood", "reachability"]


@dataclass(frozen=True)
class KHopResult:
    sources: np.ndarray          # int32[S]
    k: int
    words: np.ndarray            # uint[n, W] — packed membership, lane s = source s
    counts: np.ndarray           # int64[S] — |k-hop neighbourhood| incl. source
    depth: np.ndarray            # int32[n, S] — full BFS depths (-1 unreached)
    meta: dict = field(default_factory=dict)

    def members(self, lane: int) -> np.ndarray:
        """Vertex ids within k hops of ``sources[lane]`` (ascending)."""
        d = self.depth[:, lane]
        return np.flatnonzero((d >= 0) & (d <= self.k))

    def member_mask(self) -> np.ndarray:
        """bool[n, S] unpacked membership (one column per source)."""
        return np.asarray(unpack_lanes(self.words, self.sources.size))


def khop_neighborhood(g_or_engine, sources, k: int,
                      **engine_kwargs) -> KHopResult:
    """All vertices within ``k`` hops of each source, one engine sweep.

    Sources share the sweep as bit lanes; the packed ``words`` output is
    ``MSBFSResult.reached_words(k)`` — the depth-sliced frontier surface
    the core engines expose.
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    eng = as_engine(g_or_engine, **engine_kwargs)
    sources = np.asarray(sources, np.int32).reshape(-1)
    res = eng.sweep(sources)
    depth = np.asarray(res.depth)
    words = np.asarray(res.reached_words(k))
    counts = ((depth >= 0) & (depth <= k)).sum(axis=0).astype(np.int64)
    return KHopResult(sources=sources, k=int(k), words=words, counts=counts,
                      depth=depth, meta=dict(ndev=eng.ndev))


def reachability(g_or_engine, sources, targets=None,
                 **engine_kwargs) -> np.ndarray:
    """Pairwise hop distances ``int64[S, T]`` between source and target
    batches (-1 unreachable) — one sweep from the sources, gathered at the
    target rows. ``targets=None`` uses the sources (all-pairs among
    them)."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    sources = np.asarray(sources, np.int32).reshape(-1)
    targets = sources if targets is None else np.asarray(
        targets, np.int32).reshape(-1)
    res = eng.sweep(sources)
    return np.asarray(res.depth)[targets].T.astype(np.int64)
