"""Traversal read-out workloads: BFS depths, k-hop bands, reachability.

A k-hop query is a depth-sliced BFS read-out: run the lane engine from the
query sources, then slice the per-lane depths at ``depth <= k``. The
result keeps the engines' OWN bit layout (``packed.depth_slice_words`` —
uint lane words, bit ``r % LANE_WORD_BITS`` of word ``r // LANE_WORD_BITS``)
so downstream packed consumers (set intersections across queries, the GNN
sampler's candidate pools) operate on words, not n-vectors; per-lane
membership unpacks on demand.

``bfs_depths`` / ``reach_hops`` are the plain-traversal siblings behind
``BFSQuery`` / ``ReachQuery``: full per-source depth columns and pairwise
hop distances. They exist so the serving path (``repro.serving``) and the
offline ``run_query`` dispatch share ONE handler per tag — the streaming
service answers the same ``KHopResult``/``ReachResult``/``BFSResult``
values, mid-sweep, from the identical depth band.

``graph/sampler.py`` exposes the k-hop band as ``khop_node_sets`` — exact
neighbourhood candidate pools for GNN sampling riding the same fast path
as BFS serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analytics.engine import as_engine
from repro.analytics.meta import QueryMeta
from repro.core.packed import unpack_lanes

__all__ = ["BFSResult", "KHopResult", "ReachResult", "bfs_depths",
           "khop_neighborhood", "reach_hops", "reachability"]


@dataclass(frozen=True)
class KHopResult:
    sources: np.ndarray          # int32[S]
    k: int
    words: np.ndarray            # uint[n, W] — packed membership, lane s = source s
    counts: np.ndarray           # int64[S] — |k-hop neighbourhood| incl. source
    depth: np.ndarray            # int32[n, S] — BFS depths (-1 unreached);
    #                              a streamed answer only guarantees the
    #                              depth <= k band (meta.extra["depth_partial"])
    meta: QueryMeta = field(default_factory=QueryMeta)

    def members(self, lane: int) -> np.ndarray:
        """Vertex ids within k hops of ``sources[lane]`` (ascending)."""
        d = self.depth[:, lane]
        return np.flatnonzero((d >= 0) & (d <= self.k))

    def member_mask(self) -> np.ndarray:
        """bool[n, S] unpacked membership (one column per source)."""
        return np.asarray(unpack_lanes(self.words, self.sources.size))


@dataclass(frozen=True)
class BFSResult:
    """Full traversal read-out per source: depth columns + reach counts."""
    sources: np.ndarray          # int32[S]
    depth: np.ndarray            # int32[n, S] — BFS depths, -1 unreached
    num_layers: np.ndarray       # int64[S] — layers until the frontier emptied
    reached: np.ndarray          # int64[S] — vertices reached incl. source
    meta: QueryMeta = field(default_factory=QueryMeta)


@dataclass(frozen=True)
class ReachResult:
    """Pairwise source->target hop distances (-1 unreachable)."""
    sources: np.ndarray          # int32[S]
    targets: np.ndarray          # int32[T]
    hops: np.ndarray             # int64[S, T]
    meta: QueryMeta = field(default_factory=QueryMeta)

    def reachable(self) -> np.ndarray:
        """bool[S, T] — target reachable from source."""
        return self.hops >= 0


def khop_result_from_depth(sources: np.ndarray, k: int, depth: np.ndarray,
                           meta: QueryMeta) -> KHopResult:
    """Assemble a ``KHopResult`` from depth columns whose ``<= k`` band is
    final — the ONE construction shared by the offline sweep below and the
    serving path's mid-sweep streaming read-out, so the two answers are
    bit-identical by construction (words/counts/members read only the
    band)."""
    from repro.core.packed import depth_slice_words
    band = (depth >= 0) & (depth <= k)
    words = np.asarray(depth_slice_words(depth, k))
    counts = band.sum(axis=0).astype(np.int64)
    return KHopResult(sources=sources, k=int(k), words=words, counts=counts,
                      depth=depth, meta=meta)


def khop_neighborhood(g_or_engine, sources, k: int,
                      **engine_kwargs) -> KHopResult:
    """All vertices within ``k`` hops of each source, one engine sweep.

    Sources share the sweep as bit lanes; the packed ``words`` output is
    ``MSBFSResult.reached_words(k)`` — the depth-sliced frontier surface
    the core engines expose.
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    eng = as_engine(g_or_engine, **engine_kwargs)
    sources = np.asarray(sources, np.int32).reshape(-1)
    res = eng.sweep(sources)
    depth = np.asarray(res.depth)
    meta = QueryMeta(kind="khop",
                     layers=int(np.asarray(res.num_layers).max()),
                     lanes=eng.lanes_for(sources.size), ndev=eng.ndev)
    return khop_result_from_depth(sources, k, depth, meta)


def bfs_depths(g_or_engine, sources, **engine_kwargs) -> BFSResult:
    """Full BFS from each source — the ``BFSQuery`` handler: one engine
    sweep, depth columns plus per-source layer/reach counts."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    sources = np.asarray(sources, np.int32).reshape(-1)
    res = eng.sweep(sources)
    depth = np.asarray(res.depth)
    num_layers = np.asarray(res.num_layers).astype(np.int64)
    return BFSResult(
        sources=sources, depth=depth, num_layers=num_layers,
        reached=(depth >= 0).sum(axis=0).astype(np.int64),
        meta=QueryMeta(kind="bfs", layers=int(num_layers.max()),
                       lanes=eng.lanes_for(sources.size), ndev=eng.ndev))


def reach_hops(g_or_engine, sources, targets=None,
               **engine_kwargs) -> ReachResult:
    """Pairwise hop distances between source and target batches — the
    ``ReachQuery`` handler wrapping ``reachability``'s raw matrix in the
    typed envelope. ``targets=None`` uses the sources (all-pairs)."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    sources = np.asarray(sources, np.int32).reshape(-1)
    targets = sources if targets is None else np.asarray(
        targets, np.int32).reshape(-1)
    res = eng.sweep(sources)
    hops = np.asarray(res.depth)[targets].T.astype(np.int64)
    return ReachResult(
        sources=sources, targets=targets, hops=hops,
        meta=QueryMeta(kind="reach",
                       layers=int(np.asarray(res.num_layers).max()),
                       lanes=eng.lanes_for(sources.size), ndev=eng.ndev))


def reachability(g_or_engine, sources, targets=None,
                 **engine_kwargs) -> np.ndarray:
    """Pairwise hop distances ``int64[S, T]`` between source and target
    batches (-1 unreachable) — one sweep from the sources, gathered at the
    target rows. ``targets=None`` uses the sources (all-pairs among
    them). The raw-array surface; ``reach_hops`` returns the typed
    envelope."""
    return reach_hops(g_or_engine, sources, targets, **engine_kwargs).hops
