"""Closeness centrality from per-lane BFS depths.

Closeness needs distances from many sources — precisely what one MS-BFS
sweep produces as its ``depth[n, R]`` output. Two estimators share the
accumulation path:

* **exact** — every vertex is a source, swept in fixed-width chunks
  through the pipelined engine. Undirected distances are symmetric, so
  column sums over the chunks accumulate each vertex's distance total.
* **sampled** — the Eppstein–Wang style estimator over ``k`` sampled
  sources, scaled by ``n / k``. The scaling is constructed so that
  sampling ALL vertices reproduces the exact numbers bit-for-bit (the
  exact-vs-sampled agreement property tested in
  ``tests/test_analytics.py``).

The closeness definition is the Wasserman–Faust form (as in NetworkX),
which stays meaningful on disconnected graphs::

    c(v) = (r_v - 1)^2 / (sum_d(v) * (n - 1))

with ``r_v`` the size of v's component (reachable count including v) and
``sum_d(v)`` the sum of distances from v within its component; isolated
vertices score 0.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analytics.engine import as_engine, pad_roots
from repro.analytics.meta import QueryMeta

__all__ = ["ClosenessResult", "closeness_centrality",
           "closeness_from_depths", "closeness_from_dists",
           "select_sources"]

# auto mode: below this vertex count the exact sweep is cheap enough
EXACT_N_THRESHOLD = 2048
SAMPLED_SOURCES_DEFAULT = 256


@dataclass(frozen=True)
class ClosenessResult:
    closeness: np.ndarray        # float64[n]
    method: str                  # "exact" | "sampled"
    num_sources: int
    seed: int | None
    meta: QueryMeta = field(default_factory=QueryMeta)

    def top(self, k: int = 5) -> list[tuple[int, float]]:
        """The k most central vertices as (vertex, closeness), descending
        (ties broken by vertex id via the stable argsort)."""
        order = np.argsort(-self.closeness, kind="stable")[:k]
        return [(int(v), float(self.closeness[v])) for v in order]


def select_sources(n: int, sources,
                   seed: int) -> tuple[np.ndarray, str]:
    """The closeness source-selection rule, shared by the hop-count and
    weighted estimators (ONE implementation — the sampling scheme is part
    of the estimator's contract): ``None`` -> all n vertices (exact), an
    int -> that many distinct sampled vertices, ``"auto"`` -> exact for
    small n, a capped sample otherwise, an explicit id sequence -> used
    as-is (the serving path pins its sample this way so offline replays
    reproduce it). Returns (sources, method)."""
    if isinstance(sources, str):
        if sources != "auto":
            raise ValueError(
                f"sources must be None, 'auto', an int, or an id "
                f"sequence — got {sources!r}")
        sources = None if n <= EXACT_N_THRESHOLD else min(
            n, SAMPLED_SOURCES_DEFAULT)
    if sources is None:
        return np.arange(n, dtype=np.int32), "exact"
    if not isinstance(sources, (int, np.integer)):
        src = np.asarray(sources, np.int32).reshape(-1)
        if src.size < 1 or src.min() < 0 or src.max() >= n:
            raise ValueError(
                f"explicit closeness sources must be non-empty vertex "
                f"ids in [0, {n}), got {src!r}")
        return src, ("sampled" if src.size < n else "exact")
    k = int(sources)
    if not 1 <= k <= n:
        raise ValueError(f"sources must be in [1, {n}], got {k}")
    rng = np.random.default_rng(seed)
    src = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
    return src, ("sampled" if k < n else "exact")


def closeness_from_dists(dist: np.ndarray, n: int) -> np.ndarray:
    """Wasserman–Faust closeness from a float distance matrix with one
    SOURCE PER COLUMN (rows: vertices, inf unreached) — the weighted-path
    generalization the SSSP lanes feed (``analytics.weighted``); the
    hop-count form below is this with integer distances.

    With n columns (all sources) this IS the exact formula; the
    ``scale = n / k`` factor extrapolates reach counts and distance sums
    from a sample. Shared by the offline estimators here and the serving
    path's closeness queries (``repro.launch.serve_bfs``).
    """
    dist = np.asarray(dist, np.float64)
    reached = np.isfinite(dist)
    cnt = reached.sum(axis=1)                       # sources reaching v
    sum_d = np.where(reached, dist, 0.0).sum(axis=1)
    scale = n / dist.shape[1]
    r_hat = scale * cnt                              # est. component size
    s_hat = scale * sum_d                            # est. distance sum
    out = np.zeros(dist.shape[0], np.float64)
    ok = (cnt > 0) & (s_hat > 0) & (r_hat > 1)
    out[ok] = (r_hat[ok] - 1.0) ** 2 / (s_hat[ok] * max(n - 1, 1))
    return out


def closeness_from_depths(depth: np.ndarray, n: int) -> np.ndarray:
    """Hop-count closeness: int depth matrix, -1 unreached — the BFS-lane
    instantiation of ``closeness_from_dists`` (int32 depths are exact in
    float64, so the two agree bit-for-bit on unweighted sweeps)."""
    depth = np.asarray(depth, np.int64)
    return closeness_from_dists(np.where(depth >= 0, depth, np.inf), n)


def closeness_centrality(g_or_engine, sources: int | str | None = "auto",
                         seed: int = 0, chunk: int = 256,
                         **engine_kwargs) -> ClosenessResult:
    """Closeness centrality of every vertex.

    ``sources``: ``None`` forces the exact all-sources computation,
    an int samples that many distinct source vertices, and ``"auto"``
    (default) picks exact for small graphs (n <= EXACT_N_THRESHOLD) and a
    capped sample otherwise — the small-n/large-n dispatch rule of the
    analytics API. ``chunk`` bounds roots per engine sweep; the last chunk
    is padded (ignored lanes) so every sweep hits one compiled executable.
    """
    eng = as_engine(g_or_engine, **engine_kwargs)
    n = eng.n
    src, method = select_sources(n, sources, seed)
    chunk = max(1, min(chunk, src.size))

    depth_cols = np.empty((n, src.size), np.int32)
    sweeps = 0
    layers = 0
    for lo in range(0, src.size, chunk):
        real = min(chunk, src.size - lo)
        res = eng.sweep(pad_roots(src[lo:lo + chunk], chunk))
        depth_cols[:, lo:lo + real] = np.asarray(res.depth)[:, :real]
        layers += int(np.asarray(res.num_layers).max())
        sweeps += 1
    closeness = closeness_from_depths(depth_cols, n)
    return ClosenessResult(
        closeness=closeness, method=method, num_sources=int(src.size),
        seed=None if method == "exact" else seed,
        meta=QueryMeta(kind="closeness", layers=layers,
                       lanes=eng.lanes_for(chunk), sweeps=sweeps,
                       ndev=eng.ndev, extra=dict(chunk=chunk)))
