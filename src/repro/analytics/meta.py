"""Uniform result metadata for the analytics subsystem.

Every ``*Result`` used to carry its own convention — a bare ``meta`` dict
on closeness, ``truncated`` as a first-class field on SSSP, nothing at all
on ``KHopResult``. ``QueryMeta`` is the one shape they all carry now:
layers/steps consumed, lane-pool width, sweep count, the partition, the
truncation flag, and exchange bytes when a distributed engine metered
them. Workload-specific facts (delta, chunk size, ...) live under
``extra`` instead of colliding with the common fields.

``run_query`` and the serving path (``repro.serving``) return it
uniformly, so sojourn accounting and answer envelopes never need
per-type spelling knowledge.

Deprecation shim: the old dict spellings (``res.meta["ndev"]``,
``res.meta["weighted"]``) keep working — ``QueryMeta`` answers
``__getitem__``/``get``/``in`` over the merged common fields + extras,
with a ``DeprecationWarning`` pointing at the attribute form.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, fields

__all__ = ["QueryMeta"]


@dataclass(frozen=True)
class QueryMeta:
    """Common metadata carried by every analytics ``*Result``."""
    kind: str = ""               # query tag (api.QUERY_KINDS key)
    layers: int = 0              # engine layers/steps consumed
    truncated: bool = False      # any lane hit its step/layer cap
    lanes: int = 0               # lane-pool width the sweep(s) ran with
    sweeps: int = 1              # engine sweeps issued
    ndev: int = 1                # devices the engine partitioned over
    exch_bytes: int | None = None  # exchange volume, when metered
    extra: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        """Flat dict view: common fields merged with ``extra`` (extras
        win on collision — they are the workload's own spelling)."""
        out = {f.name: getattr(self, f.name) for f in fields(self)
               if f.name != "extra"}
        out.update(self.extra)
        return out

    # -- deprecated dict-style access (the pre-QueryMeta spellings) -------
    def _warn(self, key):
        warnings.warn(
            f"dict-style access to QueryMeta ({key!r}) is deprecated — "
            f"use the attribute form (meta.{key} for common fields, "
            f"meta.extra[{key!r}] for workload extras)",
            DeprecationWarning, stacklevel=3)

    def __getitem__(self, key):
        self._warn(key)
        return self.as_dict()[key]

    def get(self, key, default=None):
        self._warn(key)
        return self.as_dict().get(key, default)

    def __contains__(self, key) -> bool:
        return key in self.as_dict()

    def keys(self):
        return self.as_dict().keys()
