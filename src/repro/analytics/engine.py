"""Engine facade for the analytics subsystem.

Every analytics workload (components, closeness, k-hop, diameter bounds)
reduces to the same primitive: *one pipelined MS-BFS sweep over a batch of
roots, returning per-lane depths*. ``LaneEngine`` is that primitive with
the host/distributed choice and the lane-pool sizing folded in:

* ``ndev <= 1`` — ``repro.core.msbfs.msbfs_pipelined`` on the full graph;
* ``ndev > 1`` (or an explicit ``mesh``) — ``repro.core.dist_msbfs`` over
  a 1-D partition, results trimmed back to the original vertex count, so
  callers see identical shapes either way (the engines are bit-identical
  per ``tests/test_dist_msbfs.py``);
* ``grid=(pr, pc)`` — ``repro.core.dist2d`` over the 2-D adjacency
  partition (``compress=True`` ships the per-layer exchanges through the
  sparse frontier-word codec); bit-identical again, per
  ``tests/test_dist2d.py``;
* ``lanes=None`` — adaptive pool sizing per sweep
  (``packed.adaptive_lane_pool``), exactly the ``lanes=0`` surface of the
  graph500 / serve_bfs harnesses.

The graph is partitioned ONCE at construction; repeated sweeps (closeness
chunks, component batches, diameter re-sweeps) reuse the partition and the
compiled engine executables (one compile per distinct root-batch size —
the algorithms pad their batches to a fixed width for exactly this
reason).

Built from a ``WeightedCSRGraph`` the engine additionally serves
*weighted* sweeps: ``sssp_sweep`` runs the delta-stepping tropical-lane
engine (``repro.traversal.sssp``) over the same graph, and the weighted
analytics workloads (``SSSPQuery`` / ``WeightedClosenessQuery``) dispatch
through it. Boolean sweeps on a weighted engine simply ignore the
weights (``WeightedCSRGraph.csr`` is the identical CSR).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRGraph, WeightedCSRGraph
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.core.msbfs import MSBFSResult, msbfs_pipelined
from repro.core.packed import MODES, adaptive_lane_pool

__all__ = ["LaneEngine", "as_engine", "pad_roots"]


def pad_roots(roots: np.ndarray, width: int) -> np.ndarray:
    """Pad a root batch to the fixed sweep ``width`` by repeating the
    first root — every sweep then reuses ONE compiled engine executable;
    callers discard the padded lanes' results. Shared by the analytics
    batch loops (components / closeness / diameter)."""
    roots = np.asarray(roots, np.int32)
    if roots.size > width:
        raise ValueError(
            f"{roots.size} roots exceed the fixed sweep width {width} — "
            f"an over-width batch would silently recompile per size")
    if roots.size == width:
        return roots
    return np.concatenate(
        [roots, np.full(width - roots.size, roots[0], np.int32)])


class LaneEngine:
    """Host- or mesh-backed MS-BFS sweep runner shared by all analytics."""

    def __init__(self, g: CSRGraph | WeightedCSRGraph, *, ndev: int = 1,
                 mesh=None, grid: tuple[int, int] | None = None,
                 compress: bool = False, lanes: int | None = None,
                 mode: str = "hybrid", alpha: float = ALPHA_DEFAULT,
                 beta: float = BETA_DEFAULT, max_pos: int = 8,
                 probe_impl: str = "xla", telemetry=None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        # a repro.obs.Telemetry bundle; None (the default) keeps every
        # sweep on the recorder-off fused-drain path
        self.telemetry = telemetry
        self.wg = g if isinstance(g, WeightedCSRGraph) else None
        self.g = g.csr if self.wg is not None else g
        g = self.g
        self.lanes = lanes
        self.mode = mode
        self.alpha = alpha
        self.beta = beta
        self.max_pos = max_pos
        self.probe_impl = probe_impl
        self.mesh = mesh
        self.grid = tuple(grid) if grid is not None else None
        self.compress = compress
        self.dg = self.dg2 = None
        self.dwg = self.dwg2 = None
        if self.grid is not None:
            # 2-D adjacency partition on a (pr, pc) grid mesh
            if mesh is not None:
                raise ValueError(
                    "pass grid=(pr, pc) OR a prebuilt mesh, not both — the "
                    "2-D engine builds its own ('row', 'col') grid mesh")
            from repro.core.dist2d import mesh2d, partition_graph_2d
            pr, pc = self.grid
            self.ndev = pr * pc
            self.mesh = mesh2d(pr, pc)
            self.dg2 = partition_graph_2d(g, pr, pc)
            if self.wg is not None:
                from repro.core.dist_sssp import partition_weighted_graph_2d
                self.dwg2 = partition_weighted_graph_2d(self.wg, pr, pc)
            return
        if compress:
            raise ValueError(
                "compress=True is the 2-D exchange knob — it needs "
                "grid=(pr, pc); the 1-D engine's allreduce is always dense")
        if mesh is not None:
            ndev = int(np.prod(mesh.devices.shape))
        self.ndev = max(int(ndev), 1)
        # an EXPLICIT mesh always takes the dist path, even at one device
        # (the caller asked for it; silently swapping in the host engine
        # would leave the requested code path unexercised)
        if self.ndev > 1 or mesh is not None:
            from repro.core.dist_msbfs import host_mesh, partition_graph
            if self.mesh is None:
                self.mesh = host_mesh(self.ndev)
            self.dg = partition_graph(g, self.ndev)
            if self.wg is not None:
                from repro.core.dist_sssp import partition_weighted_graph
                self.dwg = partition_weighted_graph(self.wg, self.ndev)

    @property
    def n(self) -> int:
        return self.g.n

    @property
    def m(self) -> int:
        return self.g.m

    def lanes_for(self, num_roots: int) -> int:
        """Lane-pool width for a sweep of ``num_roots`` — the pinned value
        or the adaptive sizing rule."""
        if self.lanes:
            return self.lanes
        return adaptive_lane_pool(num_roots, self.n, self.m)

    def _recorder(self, engine_name: str, **meta):
        """A fresh per-sweep ``SweepRecorder`` from the telemetry bundle
        (None when telemetry is absent or sweep recording is off — the
        drivers then take their fused-drain fast path)."""
        if self.telemetry is None:
            return None
        return self.telemetry.recorder(engine_name, ndev=self.ndev, **meta)

    def sweep(self, roots, derive_parents: bool = False) -> MSBFSResult:
        """One pipelined engine sweep; ``depth`` is [n, R] with the
        original vertex count regardless of ndev. By default ``parent``
        is zero-width: every analytics workload reads depths only, and
        skipping the parent derivation saves an O(m) scatter-min pass per
        lane chunk on every sweep — pass ``derive_parents=True`` to get
        Graph500-grade parents."""
        roots = np.asarray(roots, np.int32).reshape(-1)
        if roots.size < 1:
            raise ValueError("need at least one root")
        lanes = self.lanes_for(roots.size)
        if self.dg2 is not None:
            from repro.core.dist2d import dist2d_msbfs
            return dist2d_msbfs(self.dg2, roots, self.mesh, self.mode,
                                self.alpha, self.beta, self.max_pos,
                                self.probe_impl, lanes=lanes,
                                compress=self.compress,
                                derive_parents=derive_parents,
                                recorder=self._recorder("dist2d"))
        if self.dg is not None:
            from repro.core.dist_msbfs import dist_msbfs
            return dist_msbfs(self.dg, roots, self.mesh, self.mode,
                              self.alpha, self.beta, self.max_pos,
                              self.probe_impl, lanes=lanes,
                              derive_parents=derive_parents,
                              recorder=self._recorder("dist_msbfs"))
        return msbfs_pipelined(self.g, roots, self.mode, self.alpha,
                               self.beta, self.max_pos, self.probe_impl,
                               lanes, derive_parents=derive_parents,
                               recorder=self._recorder("msbfs"))

    @property
    def weighted(self) -> bool:
        return self.wg is not None

    def sssp_lanes_for(self, num_roots: int) -> int:
        """Dense-lane pool width for a weighted sweep: dense float32
        lanes cost ~32x a packed bit lane, so a pinned bit-pool width is
        NOT taken at face value — the tropical engine's own default caps
        it (same rule as the serving loop); call ``sssp_pipelined``
        directly to run a wider dense pool deliberately."""
        from repro.traversal.sssp import DEFAULT_LANES
        cap = min(self.lanes, DEFAULT_LANES) if self.lanes else DEFAULT_LANES
        return max(1, min(num_roots, cap))

    def sssp_sweep(self, roots, delta=None):
        """One pipelined delta-stepping sweep over the engine's weighted
        graph; returns ``repro.traversal.sssp.SSSPResult`` (``dist`` is
        [n, R] float32 with the original vertex count, inf unreached).
        Requires the engine to have been built from a
        ``WeightedCSRGraph``. Dispatches on the engine's partition
        exactly like ``sweep``: host lanes at ndev 1, the 1-D sharded
        engine on a mesh, the 2-D grid engine under ``grid=(pr, pc)``
        (``compress=True`` ships the per-step value exchanges through the
        sparse codec) — all bit-identical per ``tests/test_dist_sssp.py``.
        ``delta`` is a scalar width or a per-lane tuple (the engines'
        static knob; None picks the graph default)."""
        if self.wg is None:
            raise TypeError(
                "weighted sweep on an unweighted engine — build the "
                "LaneEngine from a WeightedCSRGraph (e.g. "
                "graph.generator.rmat_weighted_graph) to serve "
                "sssp/weighted-closeness queries")
        roots = np.asarray(roots, np.int32).reshape(-1)
        if roots.size < 1:
            raise ValueError("need at least one source")
        lanes = self.sssp_lanes_for(roots.size)
        if self.dwg2 is not None:
            from repro.core.dist_sssp import dist2d_sssp
            return dist2d_sssp(self.dwg2, roots, self.mesh, delta=delta,
                               lanes=lanes, max_pos=self.max_pos,
                               relax_impl=self.probe_impl,
                               compress=self.compress,
                               recorder=self._recorder("dist2d_sssp"))
        if self.dwg is not None:
            from repro.core.dist_sssp import dist_sssp
            return dist_sssp(self.dwg, roots, self.mesh, delta=delta,
                             lanes=lanes, max_pos=self.max_pos,
                             relax_impl=self.probe_impl,
                             recorder=self._recorder("dist_sssp"))
        from repro.traversal.sssp import sssp_pipelined
        return sssp_pipelined(self.wg, roots, delta=delta,
                              lanes=lanes,
                              max_pos=self.max_pos,
                              relax_impl=self.probe_impl,
                              recorder=self._recorder("sssp"))


def as_engine(g_or_engine, **kwargs) -> LaneEngine:
    """Accept either a ``CSRGraph`` (build an engine with ``kwargs``) or an
    already-built ``LaneEngine`` (reuse it — kwargs must then be empty, a
    half-applied override would silently diverge from the engine's
    config)."""
    if isinstance(g_or_engine, LaneEngine):
        if kwargs:
            raise ValueError(
                f"engine already built; unexpected overrides {sorted(kwargs)}")
        return g_or_engine
    return LaneEngine(g_or_engine, **kwargs)
