"""BFS-based diameter / eccentricity estimation (double sweep, iFUB-style).

The classic BFS diameter recipe, run on lane batches instead of single
traversals: sweep a seed batch, take each lane's *deepest* vertex, sweep
those, repeat. Every BFS from s gives

* ``ecc(s) = max_v d(s, v)`` (within s's component) — a LOWER bound on
  that component's diameter, and
* ``2 * ecc(s)`` — an UPPER bound (any path re-routes through s).

Re-sweeping from the deepest vertex of the deepest lane is the double
sweep / iFUB descent: on trees it reaches the exact diameter in two
sweeps, and on the Graph500 small-world graphs it converges within a
couple of rounds. With a whole lane batch per round, each round refines
from ``num_seeds`` starting points for the price of one sweep.

Disconnected graphs: eccentricities are per-component (a lane only sees
its root's component). Bounds are reported for the component where the
best lower bound was found, identified by its minimum vertex id — the
same canonical id ``analytics.components`` assigns.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.analytics.engine import as_engine, pad_roots
from repro.analytics.meta import QueryMeta

__all__ = ["DiameterResult", "diameter_bounds"]


@dataclass(frozen=True)
class DiameterResult:
    lower: int                   # best BFS eccentricity found
    upper: int                   # 2 * min ecc within the witness component
    component: int               # min vertex id of the witness component
    sources: np.ndarray          # int64[k] every BFS source used
    eccentricities: np.ndarray   # int64[k] ecc per source, aligned
    sweeps: int
    meta: QueryMeta = field(default_factory=QueryMeta)

    @property
    def exact(self) -> bool:
        return self.lower == self.upper


def _ecc_and_comp(depth: np.ndarray):
    """Per-lane (eccentricity, component-min-vertex, deepest vertex)."""
    reached = depth >= 0
    ecc = np.where(reached, depth, -1).max(axis=0)
    n = depth.shape[0]
    ids = np.arange(n)[:, None]
    comp = np.where(reached, ids, n).min(axis=0)     # min reached vertex
    # deepest vertex per lane, ties to the smallest id (argmax is first hit)
    deepest = np.argmax(np.where(reached, depth, -1), axis=0)
    return ecc.astype(np.int64), comp.astype(np.int64), deepest


def diameter_bounds(g_or_engine, num_seeds: int = 4, sweeps: int = 2,
                    seed: int = 0, **engine_kwargs) -> DiameterResult:
    """Bracket the diameter with ``sweeps`` lane-batch BFS rounds.

    Round 1 sweeps ``num_seeds`` random roots (degree > 0 preferred, the
    Graph500 sampling rule); each later round re-sweeps from the previous
    round's per-lane deepest vertices — the double-sweep descent. Returns
    ``lower <= diameter(component) <= upper`` for the witness component.
    """
    if num_seeds < 1 or sweeps < 1:
        raise ValueError(f"num_seeds and sweeps must be >= 1, got "
                         f"num_seeds={num_seeds} sweeps={sweeps}")
    eng = as_engine(g_or_engine, **engine_kwargs)
    n = eng.n
    rng = np.random.default_rng(seed)
    deg = np.asarray(eng.g.deg)
    pool = np.flatnonzero(deg > 0)
    if pool.size == 0:
        pool = np.arange(n)
    num_seeds = min(num_seeds, pool.size)
    roots = np.sort(rng.choice(pool, size=num_seeds,
                               replace=False)).astype(np.int32)

    all_src, all_ecc, all_comp = [], [], []
    layers = 0
    for rnd in range(sweeps):
        res = eng.sweep(roots)
        layers += int(np.asarray(res.num_layers).max())
        depth = np.asarray(res.depth)
        ecc, comp, deepest = _ecc_and_comp(depth)
        all_src.append(roots.astype(np.int64))
        all_ecc.append(ecc)
        all_comp.append(comp)
        nxt = pad_roots(np.unique(deepest), num_seeds)
        if rnd + 1 < sweeps and np.array_equal(np.unique(roots),
                                               np.unique(nxt)):
            break  # descent converged: re-sweeping the same set is a no-op
        roots = nxt

    src = np.concatenate(all_src)
    ecc = np.concatenate(all_ecc)
    comp = np.concatenate(all_comp)
    best = int(np.argmax(ecc))
    witness = int(comp[best])
    in_comp = comp == witness
    lower = int(ecc[best])
    upper = max(lower, 2 * int(ecc[in_comp].min()))
    return DiameterResult(
        lower=lower, upper=upper, component=witness, sources=src,
        eccentricities=ecc, sweeps=len(all_src),
        meta=QueryMeta(kind="diameter", layers=layers,
                       lanes=eng.lanes_for(num_seeds), sweeps=len(all_src),
                       ndev=eng.ndev,
                       extra=dict(num_seeds=num_seeds,
                                  requested_sweeps=sweeps)))
