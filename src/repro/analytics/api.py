"""Typed query surface of the analytics subsystem.

One dataclass per workload, one dispatcher. Callers build a query value,
hand it to ``run_query`` with a graph (or a prebuilt ``LaneEngine``), and
get the workload's typed result back::

    from repro.analytics import (ComponentsQuery, KHopQuery, LaneEngine,
                                 run_query)

    eng = LaneEngine(g, ndev=2, lanes=None)       # sharded, adaptive pool
    comps = run_query(eng, ComponentsQuery())
    hops = run_query(eng, KHopQuery(sources=(3, 17, 42), k=2))

The engine choice (host vs ``dist_msbfs`` mesh) and the lane-pool sizing
(``lanes=None`` -> ``packed.adaptive_lane_pool``) live in ``LaneEngine``;
queries stay pure descriptions, so the serving loop
(``repro.launch.serve_bfs``) can tag, queue, and account for them per
type.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.analytics.closeness import ClosenessResult, closeness_centrality
from repro.analytics.components import (ComponentsResult,
                                        connected_components)
from repro.analytics.diameter import DiameterResult, diameter_bounds
from repro.analytics.engine import as_engine
from repro.analytics.khop import KHopResult, khop_neighborhood
from repro.analytics.weighted import (SSSPDistancesResult, sssp_distances,
                                      weighted_closeness_centrality)

__all__ = [
    "ClosenessQuery", "ComponentsQuery", "DiameterQuery", "KHopQuery",
    "QUERY_TYPES", "SSSPQuery", "WeightedClosenessQuery", "run_query",
]


@dataclass(frozen=True)
class ComponentsQuery:
    """Connected components of the whole graph."""
    batch: int = 64              # BFS lanes seeded per sweep

    kind = "components"


@dataclass(frozen=True)
class ClosenessQuery:
    """Closeness centrality for every vertex.

    ``sources=None`` forces exact, an int samples that many sources,
    ``"auto"`` (default) picks exact for small n, sampled for large n.
    """
    sources: int | str | None = "auto"
    seed: int = 0
    chunk: int = 256             # roots per engine sweep

    kind = "closeness"


@dataclass(frozen=True)
class KHopQuery:
    """All vertices within ``k`` hops of each source (one lane each)."""
    sources: tuple[int, ...]
    k: int

    kind = "khop"


@dataclass(frozen=True)
class DiameterQuery:
    """Diameter lower/upper bounds by double-sweep lane batches."""
    num_seeds: int = 4
    sweeps: int = 2
    seed: int = 0

    kind = "diameter"


@dataclass(frozen=True)
class SSSPQuery:
    """Shortest-path distances from each source (one tropical lane each,
    delta-stepping sweep). Needs a weighted engine; ``delta=None`` uses
    the ``traversal.sssp.default_delta`` bucket width."""
    sources: tuple[int, ...]
    delta: float | None = None

    kind = "sssp"


@dataclass(frozen=True)
class WeightedClosenessQuery:
    """Weighted closeness centrality for every vertex — ``sources``
    follows the ``ClosenessQuery`` rule (None exact / int sampled /
    "auto" dispatch on n). Needs a weighted engine."""
    sources: int | str | None = "auto"
    seed: int = 0
    chunk: int = 64              # dense float lanes per engine sweep
    delta: float | None = None

    kind = "weighted_closeness"


QUERY_TYPES = (ComponentsQuery, ClosenessQuery, KHopQuery, DiameterQuery,
               SSSPQuery, WeightedClosenessQuery)

Query = (ComponentsQuery | ClosenessQuery | KHopQuery | DiameterQuery
         | SSSPQuery | WeightedClosenessQuery)
Result = (ComponentsResult | ClosenessResult | KHopResult | DiameterResult
          | SSSPDistancesResult)


def run_query(g_or_engine, query: Query, **engine_kwargs) -> Result:
    """Dispatch one analytics query. ``g_or_engine`` is a ``CSRGraph``
    (engine built from ``engine_kwargs``: ``ndev=``, ``mesh=``,
    ``lanes=``, ``mode=``, ...) or a shared ``LaneEngine`` — build one
    engine when issuing several queries so sweeps reuse the partition and
    compiled executables."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    if isinstance(query, ComponentsQuery):
        return connected_components(eng, batch=query.batch)
    if isinstance(query, ClosenessQuery):
        return closeness_centrality(eng, sources=query.sources,
                                    seed=query.seed, chunk=query.chunk)
    if isinstance(query, KHopQuery):
        return khop_neighborhood(eng, list(query.sources), query.k)
    if isinstance(query, DiameterQuery):
        return diameter_bounds(eng, num_seeds=query.num_seeds,
                               sweeps=query.sweeps, seed=query.seed)
    if isinstance(query, SSSPQuery):
        return sssp_distances(eng, list(query.sources), delta=query.delta)
    if isinstance(query, WeightedClosenessQuery):
        return weighted_closeness_centrality(
            eng, sources=query.sources, seed=query.seed, chunk=query.chunk,
            delta=query.delta)
    raise TypeError(f"unknown analytics query type {type(query).__name__!r}"
                    f" — expected one of "
                    f"{[t.__name__ for t in QUERY_TYPES]}")
