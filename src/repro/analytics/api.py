"""Typed query surface of the analytics subsystem.

One dataclass per workload, one dispatcher, one request/answer envelope.
Callers build a query value, hand it to ``run_query`` with a graph (or a
prebuilt ``LaneEngine``), and get the workload's typed result back::

    from repro.analytics import (ComponentsQuery, KHopQuery, LaneEngine,
                                 run_query)

    eng = LaneEngine(g, ndev=2, lanes=None)       # sharded, adaptive pool
    comps = run_query(eng, ComponentsQuery())
    hops = run_query(eng, KHopQuery(sources=(3, 17, 42), k=2))

The engine choice (host vs ``dist_msbfs`` mesh) and the lane-pool sizing
(``lanes=None`` -> ``packed.adaptive_lane_pool``) live in ``LaneEngine``;
queries stay pure descriptions.

**Tags.** Every query class declares its wire tag as an explicit
``kind`` ClassVar, surfaced through ``query_kind`` and collected into the
``QUERY_KINDS`` registry at import time — with validation, so a query
type that forgets (or typos) its tag fails the import instead of
silently dropping out of envelope serialization. ``QUERY_KINDS`` is the
single source of truth: the serving mix parser, ``from_wire``, and the
service's per-type stats all derive from it (unknown tags are ONE error
path).

**Envelope.** ``AnalyticsRequest(id, tenant, query, arrival)`` /
``AnalyticsAnswer(id, result, meta)`` wrap queries for the serving path
(``repro.serving.AnalyticsService``); ``answer_request`` is the shared
offline handler — the service and ``run_query`` route through the SAME
per-type handler table (``_HANDLERS``), never a parallel string-tag
dispatch.
"""
from __future__ import annotations

import base64
import itertools
from dataclasses import asdict, dataclass, field, fields
from typing import ClassVar

import numpy as np

from repro.analytics.closeness import ClosenessResult, closeness_centrality
from repro.analytics.components import (ComponentsResult,
                                        connected_components)
from repro.analytics.diameter import DiameterResult, diameter_bounds
from repro.analytics.engine import as_engine
from repro.analytics.khop import (BFSResult, KHopResult, ReachResult,
                                  bfs_depths, khop_neighborhood, reach_hops)
from repro.analytics.meta import QueryMeta
from repro.analytics.weighted import (SSSPDistancesResult, sssp_distances,
                                      weighted_closeness_centrality)

__all__ = [
    "AnalyticsAnswer", "AnalyticsRequest", "BFSQuery", "ClosenessQuery",
    "ComponentsQuery", "DiameterQuery", "KHopQuery", "QUERY_KINDS",
    "QUERY_TYPES", "RESULT_TYPES", "ReachQuery", "SSSPQuery",
    "WeightedClosenessQuery", "answer_request", "query_kind",
    "result_from_wire", "result_to_wire", "run_query",
]


@dataclass(frozen=True)
class ComponentsQuery:
    """Connected components of the whole graph."""
    batch: int = 64              # BFS lanes seeded per sweep

    kind: ClassVar[str] = "components"


@dataclass(frozen=True)
class ClosenessQuery:
    """Closeness centrality for every vertex.

    ``sources=None`` forces exact, an int samples that many sources,
    ``"auto"`` (default) picks exact for small n, sampled for large n,
    and an explicit id tuple pins the sample (the serving path uses this
    so offline replays reproduce it bit-for-bit).
    """
    sources: int | str | tuple[int, ...] | None = "auto"
    seed: int = 0
    chunk: int = 256             # roots per engine sweep

    kind: ClassVar[str] = "closeness"


@dataclass(frozen=True)
class BFSQuery:
    """Full BFS traversal from each source (one lane each): depth columns
    plus per-source layer/reach counts."""
    sources: tuple[int, ...]

    kind: ClassVar[str] = "bfs"


@dataclass(frozen=True)
class KHopQuery:
    """All vertices within ``k`` hops of each source (one lane each)."""
    sources: tuple[int, ...]
    k: int

    kind: ClassVar[str] = "khop"


@dataclass(frozen=True)
class ReachQuery:
    """Pairwise source->target hop distances (one lane per source);
    ``targets=None`` means all-pairs among the sources."""
    sources: tuple[int, ...]
    targets: tuple[int, ...] | None = None

    kind: ClassVar[str] = "reach"


@dataclass(frozen=True)
class DiameterQuery:
    """Diameter lower/upper bounds by double-sweep lane batches."""
    num_seeds: int = 4
    sweeps: int = 2
    seed: int = 0

    kind: ClassVar[str] = "diameter"


@dataclass(frozen=True)
class SSSPQuery:
    """Shortest-path distances from each source (one tropical lane each,
    delta-stepping sweep). Needs a weighted engine; ``delta=None`` uses
    the ``traversal.sssp.default_delta`` bucket width."""
    sources: tuple[int, ...]
    delta: float | None = None

    kind: ClassVar[str] = "sssp"


@dataclass(frozen=True)
class WeightedClosenessQuery:
    """Weighted closeness centrality for every vertex — ``sources``
    follows the ``ClosenessQuery`` rule (None exact / int sampled /
    "auto" dispatch on n / explicit id tuple). Needs a weighted
    engine."""
    sources: int | str | tuple[int, ...] | None = "auto"
    seed: int = 0
    chunk: int = 64              # dense float lanes per engine sweep
    delta: float | None = None

    kind: ClassVar[str] = "weighted_closeness"


QUERY_TYPES = (ComponentsQuery, ClosenessQuery, BFSQuery, KHopQuery,
               ReachQuery, DiameterQuery, SSSPQuery, WeightedClosenessQuery)

Query = (ComponentsQuery | ClosenessQuery | BFSQuery | KHopQuery
         | ReachQuery | DiameterQuery | SSSPQuery | WeightedClosenessQuery)
Result = (ComponentsResult | ClosenessResult | BFSResult | KHopResult
          | ReachResult | DiameterResult | SSSPDistancesResult)


def query_kind(query_type: type) -> str:
    """The explicit wire tag of a query class. The tag must be declared
    by the class ITSELF (``kind`` ClassVar in its own ``__dict__``) — an
    inherited or missing tag is a wiring bug that would silently break
    envelope serialization, so it raises here instead."""
    k = query_type.__dict__.get("kind")
    if not isinstance(k, str) or not k:
        raise TypeError(
            f"{query_type.__name__} declares no wire tag — every query "
            f"class must define its own `kind: ClassVar[str]`")
    return k


def _build_registry() -> dict[str, type]:
    reg: dict[str, type] = {}
    for t in QUERY_TYPES:
        k = query_kind(t)
        if k in reg:
            raise TypeError(
                f"duplicate query tag {k!r}: {reg[k].__name__} and "
                f"{t.__name__}")
        reg[k] = t
    return reg


# tag -> query class; THE registry every tag consumer derives from
QUERY_KINDS: dict[str, type] = _build_registry()


# ---------------------------------------------------------------------------
# Request/answer envelope — shared by offline run_query and the service.
# ---------------------------------------------------------------------------

_req_ids = itertools.count(1)


@dataclass
class AnalyticsRequest:
    """One serving request: a typed query plus routing/accounting fields.

    ``arrival`` is the layer-clock tick the request becomes visible in a
    replayed trace (0 = immediately); the service stamps real submit
    times itself. ``id`` auto-assigns when left empty."""
    query: Query
    id: str = ""
    tenant: str = "default"
    arrival: int = 0

    def __post_init__(self):
        if type(self.query) not in QUERY_KINDS.values():
            raise TypeError(
                f"unknown analytics query type "
                f"{type(self.query).__name__!r} — expected one of "
                f"{sorted(t.__name__ for t in QUERY_TYPES)}")
        if not self.id:
            self.id = f"q{next(_req_ids)}"

    @property
    def kind(self) -> str:
        return query_kind(type(self.query))

    def to_wire(self) -> dict:
        """JSON-serializable envelope; ``from_wire`` round-trips it."""
        q = {k: (list(v) if isinstance(v, tuple) else v)
             for k, v in asdict(self.query).items()}
        return dict(id=self.id, tenant=self.tenant, arrival=self.arrival,
                    kind=self.kind, query=q)

    @classmethod
    def from_wire(cls, wire: dict) -> "AnalyticsRequest":
        kind = wire.get("kind")
        qtype = QUERY_KINDS.get(kind)
        if qtype is None:       # the ONE unknown-tag error path
            raise ValueError(
                f"unknown query tag {kind!r} — expected one of "
                f"{sorted(QUERY_KINDS)}")
        q = {k: (tuple(v) if isinstance(v, list) else v)
             for k, v in wire.get("query", {}).items()}
        return cls(query=qtype(**q), id=wire.get("id", ""),
                   tenant=wire.get("tenant", "default"),
                   arrival=int(wire.get("arrival", 0)))


# ---------------------------------------------------------------------------
# Result wire codec — full typed results over JSON, bit-identical.
# ---------------------------------------------------------------------------

# result-class-name -> class; the decode allow-list (mirrors QUERY_KINDS
# on the answer side — an unknown result tag is ONE error path here too)
RESULT_TYPES: dict[str, type] = {
    t.__name__: t for t in (BFSResult, ClosenessResult, ComponentsResult,
                            DiameterResult, KHopResult, ReachResult,
                            SSSPDistancesResult)}


def _encode_value(v):
    """JSON-encode one result field. Arrays ship as raw little-endian
    bytes (base64) + dtype/shape, so every dtype — int32 depths, uint64
    frontier words, float32 distances, bools — round-trips BIT-identical
    (no float-to-decimal detour). Tuples and QueryMeta are tagged so the
    decode side rebuilds the exact in-process types."""
    if isinstance(v, np.ndarray):
        a = np.ascontiguousarray(v)
        return {"__nd__": [a.dtype.str,  # byte-order-explicit dtype tag
                           list(a.shape),
                           base64.b64encode(a.tobytes()).decode("ascii")]}
    if isinstance(v, np.generic):
        return v.item()              # bare numpy scalar -> python scalar
    if isinstance(v, QueryMeta):
        d = {f.name: _encode_value(getattr(v, f.name))
             for f in fields(QueryMeta)}
        return {"__meta__": d}
    if isinstance(v, tuple):
        return {"__tuple__": [_encode_value(x) for x in v]}
    if isinstance(v, dict):
        return {k: _encode_value(x) for k, x in v.items()}
    if v is None or isinstance(v, (str, int, float, bool)):
        return v
    raise TypeError(
        f"result field of type {type(v).__name__!r} has no wire encoding")


def _decode_value(v):
    if isinstance(v, dict):
        if "__nd__" in v:
            dtype, shape, payload = v["__nd__"]
            raw = base64.b64decode(payload.encode("ascii"))
            return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(
                shape).copy()
        if "__meta__" in v:
            kw = {k: _decode_value(x) for k, x in v["__meta__"].items()}
            return QueryMeta(**kw)
        if "__tuple__" in v:
            return tuple(_decode_value(x) for x in v["__tuple__"])
        return {k: _decode_value(x) for k, x in v.items()}
    return v


def result_to_wire(result) -> dict:
    """JSON-serializable envelope of a full typed result;
    ``result_from_wire`` rebuilds an equal value — every array
    bit-identical (pinned in tests)."""
    cls = type(result)
    if cls.__name__ not in RESULT_TYPES:
        raise TypeError(
            f"unknown result type {cls.__name__!r} — expected one of "
            f"{sorted(RESULT_TYPES)}")
    data = {f.name: _encode_value(getattr(result, f.name))
            for f in fields(cls)}
    return {"type": cls.__name__, "fields": data}


def result_from_wire(wire: dict):
    cls = RESULT_TYPES.get(wire.get("type"))
    if cls is None:
        raise ValueError(
            f"unknown result type {wire.get('type')!r} — expected one "
            f"of {sorted(RESULT_TYPES)}")
    kw = {k: _decode_value(v) for k, v in wire.get("fields", {}).items()}
    return cls(**kw)


@dataclass
class AnalyticsAnswer:
    """The answer to one request: the workload's typed result plus the
    uniform ``QueryMeta`` (same object as ``result.meta``)."""
    id: str
    result: Result
    meta: QueryMeta = field(default_factory=QueryMeta)

    def to_wire(self, include_result: bool = False) -> dict:
        """JSON-serializable envelope. The default is the summary form
        (meta only — cheap poll/debug surface); ``include_result=True``
        ships the full typed result through ``result_to_wire``, so the
        HTTP transport's answers decode bit-identical to the in-process
        ones."""
        meta = {k: v for k, v in self.meta.as_dict().items()
                if isinstance(v, (str, int, float, bool, type(None)))}
        wire = dict(id=self.id, kind=self.meta.kind, meta=meta)
        if include_result:
            wire["result"] = result_to_wire(self.result)
        return wire

    @classmethod
    def from_wire(cls, wire: dict) -> "AnalyticsAnswer":
        """Rebuild a full answer from a ``to_wire(include_result=True)``
        envelope (summary-only envelopes have no result to rebuild —
        that raises)."""
        if "result" not in wire:
            raise ValueError(
                "summary envelope has no result payload — produce it "
                "with to_wire(include_result=True)")
        result = result_from_wire(wire["result"])
        return cls(id=wire["id"], result=result, meta=result.meta)


# ---------------------------------------------------------------------------
# Dispatch: ONE handler table keyed on the query class.
# ---------------------------------------------------------------------------

_HANDLERS = {
    ComponentsQuery: lambda eng, q: connected_components(eng, batch=q.batch),
    ClosenessQuery: lambda eng, q: closeness_centrality(
        eng, sources=q.sources, seed=q.seed, chunk=q.chunk),
    BFSQuery: lambda eng, q: bfs_depths(eng, list(q.sources)),
    KHopQuery: lambda eng, q: khop_neighborhood(eng, list(q.sources), q.k),
    ReachQuery: lambda eng, q: reach_hops(
        eng, list(q.sources),
        None if q.targets is None else list(q.targets)),
    DiameterQuery: lambda eng, q: diameter_bounds(
        eng, num_seeds=q.num_seeds, sweeps=q.sweeps, seed=q.seed),
    SSSPQuery: lambda eng, q: sssp_distances(
        eng, list(q.sources), delta=q.delta),
    WeightedClosenessQuery: lambda eng, q: weighted_closeness_centrality(
        eng, sources=q.sources, seed=q.seed, chunk=q.chunk, delta=q.delta),
}


def run_query(g_or_engine, query: Query, **engine_kwargs) -> Result:
    """Dispatch one analytics query. ``g_or_engine`` is a ``CSRGraph``
    (engine built from ``engine_kwargs``: ``ndev=``, ``mesh=``,
    ``lanes=``, ``mode=``, ...) or a shared ``LaneEngine`` — build one
    engine when issuing several queries so sweeps reuse the partition and
    compiled executables."""
    eng = as_engine(g_or_engine, **engine_kwargs)
    handler = _HANDLERS.get(type(query))
    if handler is None:
        raise TypeError(
            f"unknown analytics query type {type(query).__name__!r} — "
            f"expected one of {[t.__name__ for t in QUERY_TYPES]}")
    return handler(eng, query)


def answer_request(g_or_engine, request: AnalyticsRequest,
                   **engine_kwargs) -> AnalyticsAnswer:
    """Answer one enveloped request offline — the reference path the
    serving answers are parity-tested against (and the fallback the
    service itself uses for batch-only workloads)."""
    result = run_query(g_or_engine, request.query, **engine_kwargs)
    return AnalyticsAnswer(id=request.id, result=result, meta=result.meta)
