"""Lane-parallel graph analytics served on top of the MS-BFS engine.

The paper's hybrid BFS is a building block; this package is the payoff:
connected components, closeness centrality, k-hop neighbourhood /
reachability queries, and diameter bounds, all computed by batching
traversals through the bit-lane engines (``repro.core.msbfs`` on one
host, ``repro.core.dist_msbfs`` across a mesh) — many analytics
traversals per packed sweep. Engines built from a ``WeightedCSRGraph``
additionally serve the weighted workloads (``SSSPQuery``,
``WeightedClosenessQuery``) on the delta-stepping tropical lanes of
``repro.traversal``.

Entry points: build queries from ``api`` (``ComponentsQuery``, ...) and
dispatch with ``run_query``, or call the workload functions directly
(``connected_components``, ``closeness_centrality``,
``khop_neighborhood``, ``reachability``, ``diameter_bounds``). Share one
``LaneEngine`` across queries to reuse the graph partition and compiled
sweeps.
"""
from repro.analytics.api import (ClosenessQuery, ComponentsQuery,
                                 DiameterQuery, KHopQuery, QUERY_TYPES,
                                 SSSPQuery, WeightedClosenessQuery,
                                 run_query)
from repro.analytics.closeness import (ClosenessResult, closeness_centrality,
                                       closeness_from_depths,
                                       closeness_from_dists)
from repro.analytics.components import (ComponentsResult,
                                        connected_components)
from repro.analytics.diameter import DiameterResult, diameter_bounds
from repro.analytics.engine import LaneEngine, as_engine
from repro.analytics.khop import (KHopResult, khop_neighborhood,
                                  reachability)
from repro.analytics.weighted import (SSSPDistancesResult, sssp_distances,
                                      weighted_closeness_centrality)

__all__ = [
    "ClosenessQuery", "ClosenessResult", "ComponentsQuery",
    "ComponentsResult", "DiameterQuery", "DiameterResult", "KHopQuery",
    "KHopResult", "LaneEngine", "QUERY_TYPES", "SSSPDistancesResult",
    "SSSPQuery", "WeightedClosenessQuery", "as_engine",
    "closeness_centrality", "closeness_from_depths", "closeness_from_dists",
    "connected_components", "diameter_bounds", "khop_neighborhood",
    "reachability", "run_query", "sssp_distances",
    "weighted_closeness_centrality",
]
