"""Lane-parallel graph analytics served on top of the MS-BFS engine.

The paper's hybrid BFS is a building block; this package is the payoff:
connected components, closeness centrality, BFS / k-hop neighbourhood /
reachability queries, and diameter bounds, all computed by batching
traversals through the bit-lane engines (``repro.core.msbfs`` on one
host, ``repro.core.dist_msbfs`` across a mesh) — many analytics
traversals per packed sweep. Engines built from a ``WeightedCSRGraph``
additionally serve the weighted workloads (``SSSPQuery``,
``WeightedClosenessQuery``) on the delta-stepping tropical lanes of
``repro.traversal``.

Entry points: build queries from ``api`` (``ComponentsQuery``, ...) and
dispatch with ``run_query``, or call the workload functions directly
(``connected_components``, ``closeness_centrality``,
``khop_neighborhood``, ``reachability``, ``diameter_bounds``). Share one
``LaneEngine`` across queries to reuse the graph partition and compiled
sweeps. For online serving, wrap the engine in
``repro.serving.AnalyticsService`` and submit
``AnalyticsRequest`` envelopes — every result carries the uniform
``QueryMeta`` either way.
"""
from repro.analytics.api import (AnalyticsAnswer, AnalyticsRequest,
                                 BFSQuery, ClosenessQuery, ComponentsQuery,
                                 DiameterQuery, KHopQuery, QUERY_KINDS,
                                 QUERY_TYPES, ReachQuery, SSSPQuery,
                                 WeightedClosenessQuery, answer_request,
                                 query_kind, run_query)
from repro.analytics.closeness import (ClosenessResult, closeness_centrality,
                                       closeness_from_depths,
                                       closeness_from_dists)
from repro.analytics.components import (ComponentsResult,
                                        connected_components)
from repro.analytics.diameter import DiameterResult, diameter_bounds
from repro.analytics.engine import LaneEngine, as_engine
from repro.analytics.khop import (BFSResult, KHopResult, ReachResult,
                                  bfs_depths, khop_neighborhood, reach_hops,
                                  reachability)
from repro.analytics.meta import QueryMeta
from repro.analytics.weighted import (SSSPDistancesResult, sssp_distances,
                                      weighted_closeness_centrality)

__all__ = [
    "AnalyticsAnswer", "AnalyticsRequest", "BFSQuery", "BFSResult",
    "ClosenessQuery", "ClosenessResult", "ComponentsQuery",
    "ComponentsResult", "DiameterQuery", "DiameterResult", "KHopQuery",
    "KHopResult", "LaneEngine", "QUERY_KINDS", "QUERY_TYPES", "QueryMeta",
    "ReachQuery", "ReachResult", "SSSPDistancesResult", "SSSPQuery",
    "WeightedClosenessQuery", "answer_request", "as_engine", "bfs_depths",
    "closeness_centrality", "closeness_from_depths", "closeness_from_dists",
    "connected_components", "diameter_bounds", "khop_neighborhood",
    "query_kind", "reach_hops", "reachability", "run_query",
    "sssp_distances", "weighted_closeness_centrality",
]
