"""Optimizers: AdamW and a factored (Adafactor-style) variant.

Self-contained (no optax). Moment dtype is configurable; ``factored=True``
replaces the full second moment with row/col statistics over the trailing
two axes (rank>=2 tensors) — this is what lets llama3-405b optimizer state
fit 16 GiB/chip HBM (DESIGN §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    factored: bool = False
    # microbatch gradient-accumulation dtype; bf16 halves the two biggest
    # training buffers (accumulator + clipped copy) for very large models
    accum_dtype: str = "float32"

    @property
    def mdt(self):
        return jnp.dtype(self.moment_dtype)


def _is_factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def init_opt_state(params, cfg: OptConfig):
    def one(p):
        st = {}
        if cfg.b1 > 0:
            st["m"] = jnp.zeros_like(p, dtype=cfg.mdt)
        if cfg.factored and _is_factorable(p.shape):
            st["vr"] = jnp.zeros(p.shape[:-1], cfg.mdt)      # row stats
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.mdt)
        else:
            st["v"] = jnp.zeros_like(p, dtype=cfg.mdt)
        return st
    return {"step": jnp.zeros((), jnp.int32),
            "per_param": jax.tree.map(one, params)}


def opt_state_specs(param_specs, cfg: OptConfig, params_shapes):
    """Logical-axis spec tree mirroring init_opt_state's structure."""
    is_leaf = lambda x: isinstance(x, tuple) or x is None

    def one(spec, shape):
        spec = tuple(spec) if spec is not None else (None,) * len(shape.shape)
        st = {}
        if cfg.b1 > 0:
            st["m"] = spec
        if cfg.factored and _is_factorable(shape.shape):
            st["vr"] = spec[:-1]
            st["vc"] = spec[:-2] + spec[-1:]
        else:
            st["v"] = spec
        return st

    per_param = jax.tree.map(one, param_specs, params_shapes, is_leaf=is_leaf)
    return {"step": None, "per_param": per_param}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # scale in native dtype: avoids materialising a full f32 copy of grads
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state). Handles both full and factored v."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def one(p, g, st):
        g32 = g.astype(jnp.float32)
        new_st = {}
        if cfg.b1 > 0:
            m = st["m"].astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
            new_st["m"] = m.astype(cfg.mdt)
            m_hat = m / bc1
        else:
            m_hat = g32
        if "v" in st:
            v = st["v"].astype(jnp.float32) * cfg.b2 + g32 * g32 * (1 - cfg.b2)
            new_st["v"] = v.astype(cfg.mdt)
            denom = jnp.sqrt(v / bc2) + cfg.eps
        else:
            g2 = g32 * g32
            vr = st["vr"].astype(jnp.float32) * cfg.b2 \
                + g2.mean(axis=-1) * (1 - cfg.b2)
            vc = st["vc"].astype(jnp.float32) * cfg.b2 \
                + g2.mean(axis=-2) * (1 - cfg.b2)
            new_st["vr"], new_st["vc"] = vr.astype(cfg.mdt), vc.astype(cfg.mdt)
            vr_hat, vc_hat = vr / bc2, vc / bc2
            v_est = (vr_hat[..., None] * vc_hat[..., None, :]
                     / jnp.maximum(vr_hat.mean(-1)[..., None, None], 1e-30))
            denom = jnp.sqrt(v_est) + cfg.eps
        upd = m_hat / denom + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = treedef.flatten_up_to(state["per_param"])
    new = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(treedef, [a for a, _ in new])
    new_per = jax.tree.unflatten(treedef, [b for _, b in new])
    return new_params, {"step": step, "per_param": new_per}
