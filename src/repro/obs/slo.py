"""SLO monitor for the serving stack — breach accounting behind /readyz.

The service already *measures* everything an operator would alert on
(sojourn layers, queue depth, admission outcomes); this module holds the
*targets* and the rolling evaluation:

* ``SLOConfig`` — the declared objectives: p99 submit-to-answer sojourn
  in layers, maximum pending-queue depth, maximum reject rate over the
  rolling request window. Any target left ``None`` is simply not
  evaluated (a service with no SLO config at all skips this module
  entirely — ``ServiceConfig(slo=None)`` is the default).
* ``SLOMonitor`` — fed by the service per event (admission outcome,
  answer sojourn) and per scheduler tick (queue depth); ``evaluate()``
  recomputes each objective over the window and maintains the registry
  surface: one ``slo_healthy`` gauge (1/0 — the /readyz bit), per-target
  ``slo_target_healthy{slo=...}`` gauges, observed-value gauges, and a
  monotone ``slo_breaches_total{slo=...}`` counter bumped on each
  healthy→breached TRANSITION (not per tick, so a sustained breach is
  one incident, not a rate).

Percentiles use the serving stack's nearest-rank ``percentile`` — the
same arithmetic the CI sojourn gates pin, so an SLO breach in production
and a bench regression in CI are the same number disagreeing with the
same target.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.serving.stats import percentile

__all__ = ["SLOConfig", "SLOMonitor"]

# target keys, wire-stable (metric label values + health JSON keys)
P99_SOJOURN = "p99_sojourn_layers"
QUEUE_DEPTH = "queue_depth"
REJECT_RATE = "reject_rate"


@dataclass(frozen=True)
class SLOConfig:
    """Declared service-level objectives (None = not evaluated).

    ``window`` bounds the rolling sample the rate/percentile targets are
    computed over — sojourns and admission outcomes beyond it age out,
    so a long-past incident cannot pin /readyz unhealthy forever."""
    p99_sojourn_layers: float | None = None
    max_queue_depth: int | None = None
    max_reject_rate: float | None = None
    window: int = 256

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if (self.max_reject_rate is not None
                and not 0.0 <= self.max_reject_rate <= 1.0):
            raise ValueError(
                f"max_reject_rate must be in [0, 1], got "
                f"{self.max_reject_rate}")

    def targets(self) -> dict[str, float]:
        """The configured objectives by wire key."""
        out = {}
        if self.p99_sojourn_layers is not None:
            out[P99_SOJOURN] = float(self.p99_sojourn_layers)
        if self.max_queue_depth is not None:
            out[QUEUE_DEPTH] = float(self.max_queue_depth)
        if self.max_reject_rate is not None:
            out[REJECT_RATE] = float(self.max_reject_rate)
        return out


class SLOMonitor:
    """Rolling SLO evaluation over one service's event stream.

    Not thread-safe on its own — the service calls it under its lock,
    exactly like the admission controller."""

    def __init__(self, config: SLOConfig, registry=None):
        self.config = config
        self.registry = registry
        self._sojourns: deque = deque(maxlen=config.window)
        self._admissions: deque = deque(maxlen=config.window)
        self._queue_depth = 0
        # target key -> currently breached? (drives transition counting)
        self._breached: dict[str, bool] = {
            k: False for k in config.targets()}
        self.breaches = 0            # total healthy->breached transitions

    # -- event feed (called by the service) -------------------------------

    def observe_admission(self, admitted: bool) -> None:
        self._admissions.append(bool(admitted))

    def observe_sojourn(self, layers: float) -> None:
        self._sojourns.append(float(layers))

    def observe_queue_depth(self, depth: int) -> None:
        self._queue_depth = int(depth)

    # -- evaluation -------------------------------------------------------

    def observed(self) -> dict[str, float]:
        """Current observed value per configured target key."""
        out = {}
        for key in self.config.targets():
            if key == P99_SOJOURN:
                out[key] = percentile(list(self._sojourns), 99)
            elif key == QUEUE_DEPTH:
                out[key] = float(self._queue_depth)
            else:
                n = len(self._admissions)
                rej = sum(1 for a in self._admissions if not a)
                out[key] = rej / n if n else 0.0
        return out

    def evaluate(self) -> dict[str, bool]:
        """Re-evaluate every configured objective; returns per-target
        health, updates the registry gauges/counters, and records breach
        transitions."""
        targets = self.config.targets()
        observed = self.observed()
        ok: dict[str, bool] = {}
        for key, target in targets.items():
            ok[key] = observed[key] <= target
            if not ok[key] and not self._breached[key]:
                self.breaches += 1
                if self.registry is not None:
                    self.registry.counter(
                        "slo_breaches_total",
                        "healthy-to-breached SLO transitions",
                        ("slo",)).labels(slo=key).inc()
            self._breached[key] = not ok[key]
        if self.registry is not None:
            for key in targets:
                self.registry.gauge(
                    "slo_observed", "current observed value per SLO",
                    ("slo",)).labels(slo=key).set(observed[key])
                self.registry.gauge(
                    "slo_target", "configured target per SLO",
                    ("slo",)).labels(slo=key).set(targets[key])
                self.registry.gauge(
                    "slo_target_healthy", "1 while the SLO holds",
                    ("slo",)).labels(slo=key).set(float(ok[key]))
            self.registry.gauge(
                "slo_healthy",
                "1 while every configured SLO holds (the /readyz bit)",
            ).set(float(all(ok.values())) if ok else 1.0)
        return ok

    def healthy(self) -> bool:
        """True while every configured objective holds (vacuously true
        with no targets). Evaluates fresh — the /readyz read path."""
        return all(self.evaluate().values())

    def peek(self) -> dict:
        """JSON-ready view for /readyz: targets, observed values,
        per-target health, breach transitions so far. NON-mutating —
        no registry writes, no breach-transition accounting — so the
        lock-free health probe can call it concurrently with the
        service's own per-tick ``evaluate()``."""
        targets = self.config.targets()
        observed = self.observed()
        ok = {k: observed[k] <= t for k, t in targets.items()}
        return dict(targets=targets, observed=observed,
                    healthy_per_target=ok,
                    healthy=all(ok.values()),
                    breaches=self.breaches,
                    window=self.config.window)

    def snapshot(self) -> dict:
        """``peek()`` after a full ``evaluate()`` (registry + breach
        accounting refreshed)."""
        self.evaluate()
        return self.peek()
