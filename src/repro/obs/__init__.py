"""repro.obs — unified telemetry: metrics, sweep flight recorder, traces.

The paper's argument is made of per-layer counters (frontier density,
TD/BU phase, edges inspected, exchange volume); this package is where
they all land, for every engine and for the serving front door:

* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  Prometheus text exposition (``metrics_text``).
* :mod:`repro.obs.sweeplog` — the canonical per-layer ``LayerRecord``
  schema + ``SweepRecorder`` hook every engine driver emits through
  (``recorder=`` kwarg; off by default, zero-cost when disabled).
* :mod:`repro.obs.traceviz` — Chrome trace-event JSON export (Perfetto-
  loadable) of sweeps and service request lifecycles, + JSONL sink.

``Telemetry`` is the bundle the stack threads through — pass one to
``LaneEngine(telemetry=...)`` / ``ServiceConfig(telemetry=...)`` and it
collects the sweeps, feeds the registry, and optionally streams a JSONL
flight log::

    tel = Telemetry()
    eng = LaneEngine(g, telemetry=tel)
    eng.sweep(roots)
    print(tel.metrics_text())
    write_chrome_trace("sweep.json", sweep_trace_events(tel.last_sweep()))
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.doctor import (DoctorReport, Finding, diagnose, diagnose_log,
                              records_from_jsonl, replay_switch,
                              split_sweeps)
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, default_registry,
                               metrics_text)
from repro.obs.server import ObservabilityServer
from repro.obs.slo import SLOConfig, SLOMonitor
from repro.obs.sweeplog import (LayerRecord, SweepRecorder, drive_recorded,
                                record_step, snapshot_state)
from repro.obs.traceviz import (FlightSink, service_trace_events,
                                sweep_trace_events, validate_trace_events,
                                write_chrome_trace)

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "DoctorReport", "Finding", "FlightSink",
    "Gauge", "Histogram", "LayerRecord", "MetricsRegistry",
    "ObservabilityServer", "SLOConfig", "SLOMonitor", "SweepRecorder",
    "Telemetry", "default_registry", "diagnose", "diagnose_log",
    "drive_recorded", "metrics_text", "record_step",
    "records_from_jsonl", "replay_switch", "service_trace_events",
    "snapshot_state", "split_sweeps", "sweep_trace_events",
    "validate_trace_events", "write_chrome_trace",
]


@dataclass
class Telemetry:
    """One telemetry bundle for a stack of components.

    ``record_sweeps=False`` keeps the registry live but makes
    ``recorder()`` return None — components then take their recorder-off
    fast path (the fused jitted drains) untouched. ``flight_path``
    streams every ``LayerRecord`` to a JSONL flight log as it is
    recorded. Completed/ongoing recorders are kept in ``sweeps``
    (bounded by ``max_sweeps``, oldest dropped)."""
    record_sweeps: bool = True
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    flight_path: str | None = None
    max_sweeps: int = 64
    sweeps: list = field(default_factory=list)
    _sink: FlightSink | None = field(default=None, repr=False)

    def recorder(self, engine: str, **meta) -> SweepRecorder | None:
        """A fresh per-sweep recorder (None when sweep recording is off
        — callers pass it straight through as the ``recorder=`` kwarg)."""
        if not self.record_sweeps:
            return None
        if self.flight_path and self._sink is None:
            self._sink = FlightSink(self.flight_path)
        rec = SweepRecorder(engine=engine, meta=meta,
                            registry=self.registry, sink=self._sink)
        self.sweeps.append(rec)
        dropped = len(self.sweeps) - self.max_sweeps
        if dropped > 0:
            # no silent caps: eviction from the bounded sweep list is
            # visible on the scrape surface
            self.registry.counter(
                "obs_sweeps_dropped_total",
                "recorded sweeps evicted by the max_sweeps bound").inc(
                    dropped)
            del self.sweeps[:-self.max_sweeps]
        return rec

    def last_sweep(self) -> SweepRecorder | None:
        return self.sweeps[-1] if self.sweeps else None

    def metrics_text(self) -> str:
        return metrics_text(self.registry)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None
