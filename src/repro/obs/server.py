"""ObservabilityServer — the live HTTP plane over a running service.

Everything the stack already measures becomes reachable from OUTSIDE the
process, with zero dependencies beyond the stdlib ``http.server``:

====================  ======================================================
``GET /metrics``      Prometheus text scrape of the service registry
                      (``AnalyticsService.metrics_text``)
``GET /healthz``      liveness: worker thread up and not stopping
                      (200/503 + JSON detail; lock-free read path, so a
                      probe never blocks behind a long jitted layer)
``GET /readyz``       readiness: healthz AND queue depth within
                      ``max_pending`` AND every configured SLO holding
``GET /debug/sweeps``   recorded sweep summaries (``Telemetry.sweeps``;
                      ``?full=1`` inlines the per-layer records)
``GET /debug/requests`` every request record's lifecycle view
``POST /v1/submit``   submit an ``AnalyticsRequest`` wire envelope
``GET /v1/poll/{id}`` lifecycle status of one request
``GET /v1/result/{id}`` the full answer as a wire envelope
                      (``to_wire(include_result=True)`` — decodes
                      BIT-identical to the in-process answer; 202 while
                      pending, 409 when rejected)
====================  ======================================================

This is the ROADMAP's "real socket/HTTP transport" rung: the submit/
poll/result routes ride the SAME ``AnalyticsRequest``/``AnalyticsAnswer``
envelopes as the in-process API, so a remote client sees exactly what
``run_query`` returns. The server wraps an already-``start()``-ed
service — it never drives ``step()`` itself::

    with AnalyticsService(g, telemetry=tel) as svc:
        with ObservabilityServer(svc) as obs:
            print(obs.url)           # http://127.0.0.1:<port>
            ...                      # curl away

Every handled request bumps ``http_requests_total{path, code}`` on the
service registry (paths normalized — ids stripped — so the label set
stays bounded).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["ObservabilityServer"]

# normalized path label values (bounded metric cardinality)
_ROUTES = ("/metrics", "/healthz", "/readyz", "/debug/sweeps",
           "/debug/requests", "/v1/submit", "/v1/poll", "/v1/result")


def _route_label(path: str) -> str:
    for r in _ROUTES:
        if path == r or path.startswith(r + "/"):
            return r
    return "other"


def _request_view(rec) -> dict:
    """JSON-ready lifecycle view of one ``RequestRecord``."""
    return dict(
        id=rec.request.id, kind=rec.kind, tenant=rec.request.tenant,
        status=rec.status, reason=rec.reason, engine=rec.engine,
        lanes=rec.lanes_used, submit_layer=rec.submit_layer,
        dispatch_layer=rec.dispatch_layer, answer_layer=rec.answer_layer,
        sojourn=rec.sojourn, answered_early=rec.answered_early)


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-obs/1"

    # the wrapping ObservabilityServer; set on the subclass at build time
    obs: "ObservabilityServer" = None

    def log_message(self, *args):     # no stderr chatter per request
        pass

    # -- plumbing ---------------------------------------------------------

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self.obs._count(_route_label(self.path), code)

    def _json(self, code: int, payload) -> None:
        self._send(code, json.dumps(payload).encode(),
                   "application/json")

    def _text(self, code: int, text: str) -> None:
        self._send(code, text.encode(),
                   "text/plain; version=0.0.4; charset=utf-8")

    # -- routes -----------------------------------------------------------

    def do_GET(self):
        svc = self.obs.service
        path = self.path.split("?", 1)[0]
        try:
            if path == "/metrics":
                self._text(200, svc.metrics_text())
            elif path == "/healthz":
                h = svc.health()
                self._json(200 if h["alive"] else 503, h)
            elif path == "/readyz":
                h = svc.health()
                self._json(200 if h["ready"] else 503, h)
            elif path == "/debug/sweeps":
                full = "full=1" in (self.path.split("?", 1) + [""])[1]
                self._json(200, self.obs._sweeps_view(full))
            elif path == "/debug/requests":
                with svc._cv:
                    views = [_request_view(r)
                             for r in svc._records.values()]
                self._json(200, views)
            elif path.startswith("/v1/poll/"):
                self._poll(path[len("/v1/poll/"):])
            elif path.startswith("/v1/result/"):
                self._result(path[len("/v1/result/"):])
            else:
                self._json(404, dict(error=f"no route {path!r}"))
        except Exception as e:          # noqa: BLE001 — server must live
            self._json(500, dict(error=f"{type(e).__name__}: {e}"))

    def do_POST(self):
        path = self.path.split("?", 1)[0]
        try:
            if path == "/v1/submit":
                self._submit()
            else:
                self._json(404, dict(error=f"no route {path!r}"))
        except Exception as e:          # noqa: BLE001
            self._json(500, dict(error=f"{type(e).__name__}: {e}"))

    # -- wire transport ---------------------------------------------------

    def _submit(self) -> None:
        from repro.analytics.api import AnalyticsRequest
        length = int(self.headers.get("Content-Length", 0))
        try:
            wire = json.loads(self.rfile.read(length) or b"")
            request = AnalyticsRequest.from_wire(wire)
        except (ValueError, TypeError) as e:
            self._json(400, dict(error=str(e)))
            return
        try:
            rec = self.obs.service.submit(request)
        except (ValueError, TypeError) as e:
            self._json(400, dict(error=str(e)))
            return
        self._json(200, dict(id=rec.request.id, kind=rec.kind,
                             status=rec.status, reason=rec.reason))

    def _find(self, request_id: str):
        svc = self.obs.service
        with svc._cv:
            return svc._records.get(request_id)

    def _poll(self, request_id: str) -> None:
        rec = self._find(request_id)
        if rec is None:
            self._json(404, dict(error=f"unknown request {request_id!r}"))
            return
        self._json(200, dict(id=request_id, status=rec.status,
                             reason=rec.reason))

    def _result(self, request_id: str) -> None:
        from repro.serving.admission import DONE, REJECTED
        rec = self._find(request_id)
        if rec is None:
            self._json(404, dict(error=f"unknown request {request_id!r}"))
            return
        if rec.status == REJECTED:
            self._json(409, dict(id=request_id, status=rec.status,
                                 reason=rec.reason))
        elif rec.status != DONE:
            self._json(202, dict(id=request_id, status=rec.status))
        else:
            self._json(200, rec.answer.to_wire(include_result=True))


class ObservabilityServer:
    """HTTP observability + wire-transport plane over one running
    ``AnalyticsService`` (see module docstring for the routes).

    ``port=0`` (the default) binds an OS-assigned free port — read it
    back from ``.port`` / ``.url``. The server runs on a daemon thread
    (one more per in-flight request, ``ThreadingHTTPServer``); it never
    steps the service, so start the worker (``service.start()``) or
    drive ``step()`` yourself for submitted work to finish."""

    def __init__(self, service, host: str = "127.0.0.1", port: int = 0):
        self.service = service
        handler = type("_BoundHandler", (_Handler,), {"obs": self})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    # -- metrics ----------------------------------------------------------

    def _count(self, path: str, code: int) -> None:
        self.service._registry.counter(
            "http_requests_total", "observability HTTP requests",
            ("path", "code")).labels(path=path, code=str(code)).inc()

    def _sweeps_view(self, full: bool) -> list:
        tel = self.service.telemetry
        if tel is None:
            return []
        out = []
        for rec in list(tel.sweeps):
            view = rec.summary()
            if full:
                view["records"] = [r.as_dict() for r in rec.records]
            out.append(view)
        return out

    # -- lifecycle --------------------------------------------------------

    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObservabilityServer":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="obs-http",
                daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "ObservabilityServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
