"""Per-layer sweep flight recorder — ONE schema across every engine.

The engines already account their work per layer, but each in its own
place: the MS-BFS engines write per-lane ``trace_dir``/``trace_vf``/
``trace_ef``/``trace_eu`` rows into jitted state, the SSSP engines write
``trace_bucket``/``trace_phase``, and the distributed engines meter
exchange bytes in ``exch_bytes``/``exch_log``. This module unifies them
behind one host-side record stream:

* ``LayerRecord`` — the canonical per-engine-step schema: sweep-step
  index, TD/BU (or light/heavy) mode, active lanes, frontier words set
  and density, edges relaxed, words touched, exchange bytes + wire
  format, wall ms — plus the per-lane detail (queue slot, the lane's own
  trace row, and the exact trace values) that makes the stream
  *bit-identical* to the engines' in-state traces.
* ``SweepRecorder`` — collects ``LayerRecord``s for one sweep,
  optionally feeding a ``MetricsRegistry`` and a JSONL flight sink;
  ``reconstruct_traces`` rebuilds the engine trace arrays from the
  record stream (the parity surface ``tests/test_obs.py`` pins against
  ``MSBFSResult``/``SSSPResult``).
* ``snapshot_state`` / ``record_step`` / ``drive_recorded`` — the
  host-side hook the engine drivers call when a recorder is passed:
  instead of the fused ``lax.while_loop`` drain, the sweep is stepped
  layer by layer and each step's trace delta is read back. Recording is
  **off by default and zero-cost when disabled** — with ``recorder=None``
  the drivers run the unchanged jitted drain and nothing here executes.

How the delta read-back works: within one sweep every (trace row, queue
slot) cell is written at most once, from its init value (-1 direction /
-1 bucket) to a live value — so diffing the trace arrays across one step
recovers exactly the cells that step wrote, whichever lane wrote them
and wherever the lane was in its own layer counter. The one blind spot
is the SSSP trace row clip (steps past ``MAX_SSSP_TRACE`` overwrite the
last row): a clipped overwrite with identical bucket AND phase is
invisible to the diff — the reconstructed arrays still match the engine
bit-for-bit (the overwrite was idempotent), only the per-step lane list
of those tail steps is thinner.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field

import numpy as np

__all__ = [
    "LayerRecord", "SweepRecorder", "drive_recorded", "record_step",
    "snapshot_state",
]

# mode strings per engine family (index = the trace's dir/phase value)
_BFS_MODES = ("td", "bu")
_SSSP_MODES = ("light", "heavy")


@dataclass(frozen=True)
class LayerRecord:
    """One engine step of one sweep, in the unified schema.

    ``dirs`` holds the engine's own trace values — TD(0)/BU(1) for the
    packed engines, light(0)/heavy(1) phase for the tropical ones — so
    the stream replays ``trace_dir``/``trace_phase`` bit-for-bit;
    ``buckets`` rides along for SSSP (empty for BFS), ``vf``/``ef``/
    ``eu`` for BFS (empty for SSSP). ``exch_bytes`` is the mesh-total
    wire bytes this step (0 on host engines — their exchange-equivalent
    work is ``edges_relaxed``/``words_touched``, the satellite that makes
    host and distributed sweep logs directly comparable).
    """
    layer: int                  # engine sweep-step index, 0-based
    engine: str                 # "msbfs" | "dist_msbfs" | "dist2d" | ...
    kind: str                   # "bfs" | "sssp"
    mode: str                   # td | bu | light | heavy | mixed | idle
    active_lanes: int
    frontier_words: int         # packed words set (BFS) / finite lane
    #                             entries (SSSP) entering the step
    frontier_density: float     # frontier_words / total storage words
    edges_relaxed: int          # BFS: e_f (TD) / e_u (BU) summed over
    #                             live lanes; SSSP: distances improved
    words_touched: int          # BFS: frontier words read + written;
    #                             SSSP: finite entries after the step
    exch_bytes: int             # exchange wire bytes this step
    exch_format: str            # "none" | "dense" | "compressed"
    wall_ms: float
    slots: tuple = ()           # queue slot per recorded lane (sorted)
    rows: tuple = ()            # the lane's own trace row this step
    dirs: tuple = ()            # trace_dir / trace_phase values
    vf: tuple = ()              # BFS frontier-vertex counts per lane
    ef: tuple = ()              # BFS frontier-edge counts per lane
    eu: tuple = ()              # BFS unvisited-edge counts per lane
    buckets: tuple = ()         # SSSP bucket index per lane

    def as_dict(self) -> dict:
        return asdict(self)


@dataclass
class SweepRecorder:
    """Record stream of one sweep; the one hook every engine emits to.

    ``registry`` (a ``metrics.MetricsRegistry``) and ``sink`` (anything
    with ``write(dict)`` — e.g. ``traceviz.FlightSink``) are optional
    fan-outs applied per record."""
    engine: str = ""
    meta: dict = field(default_factory=dict)
    registry: object = None
    sink: object = None
    kind: str = ""                       # set by the first record
    records: list = field(default_factory=list)

    def record(self, rec: LayerRecord) -> None:
        if not self.kind:
            self.kind = rec.kind
        self.records.append(rec)
        if self.registry is not None:
            self.registry.counter(
                "obs_sweep_layers_total", "engine steps recorded",
                ("engine", "mode")).labels(
                    engine=rec.engine, mode=rec.mode).inc()
            self.registry.counter(
                "obs_edges_relaxed_total", "edges relaxed per engine",
                ("engine",)).labels(engine=rec.engine).inc(
                    rec.edges_relaxed)
            if rec.exch_bytes:
                self.registry.counter(
                    "obs_exchange_bytes_total", "exchange wire bytes",
                    ("engine", "format")).labels(
                        engine=rec.engine,
                        format=rec.exch_format).inc(rec.exch_bytes)
        if self.sink is not None:
            self.sink.write(rec.as_dict())

    @property
    def num_layers(self) -> int:
        return len(self.records)

    def modes(self) -> list[str]:
        return [r.mode for r in self.records]

    def total(self, name: str) -> float:
        return sum(getattr(r, name) for r in self.records)

    def summary(self) -> dict:
        return dict(
            engine=self.engine, kind=self.kind, layers=self.num_layers,
            edges_relaxed=int(self.total("edges_relaxed")),
            exch_bytes=int(self.total("exch_bytes")),
            wall_ms=round(self.total("wall_ms"), 3), **self.meta)

    def reconstruct_traces(self, max_trace: int,
                           capacity: int) -> dict[str, np.ndarray]:
        """Rebuild the engine's per-root trace arrays from the record
        stream — BFS: ``trace_dir``/``trace_vf``/``trace_ef``/
        ``trace_eu``; SSSP: ``trace_bucket``/``trace_phase`` — shaped
        [max_trace, capacity] exactly like the engine buffers (minus the
        trailing trash column). The bit-for-bit parity surface."""
        if self.kind == "sssp":
            out = dict(
                trace_bucket=np.full((max_trace, capacity), -1, np.int32),
                trace_phase=np.full((max_trace, capacity), -1, np.int32))
            for r in self.records:
                for s, row, d, b in zip(r.slots, r.rows, r.dirs, r.buckets):
                    out["trace_bucket"][row, s] = b
                    out["trace_phase"][row, s] = d
            return out
        out = dict(
            trace_dir=np.full((max_trace, capacity), -1, np.int32),
            trace_vf=np.zeros((max_trace, capacity), np.int32),
            trace_ef=np.zeros((max_trace, capacity), np.int32),
            trace_eu=np.zeros((max_trace, capacity), np.int32))
        for r in self.records:
            for s, row, d, v, e, u in zip(r.slots, r.rows, r.dirs, r.vf,
                                          r.ef, r.eu):
                out["trace_dir"][row, s] = d
                out["trace_vf"][row, s] = v
                out["trace_ef"][row, s] = e
                out["trace_eu"][row, s] = u
        return out


# ---------------------------------------------------------------------------
# Host-side step hooks (called by the engine drivers when recording).
# ---------------------------------------------------------------------------


def snapshot_state(state, kind: str) -> dict:
    """Pre-step host snapshot of the trace surfaces the step will write.

    Works on every engine state shape: the trace arrays are replicated
    [rows, capacity+1] everywhere; the frontier / distance arrays carry
    each vertex exactly once (host ``[n, W]``, 1-D replicated ``[n, W]``,
    2-D row blocks ``[pr, n_loc_r, W]``), so flat nonzero / finite counts
    are partition-invariant."""
    if kind == "sssp":
        dist = np.asarray(state.dist)
        return dict(
            t0=time.perf_counter(),
            trace_bucket=np.asarray(state.trace_bucket),
            trace_phase=np.asarray(state.trace_phase),
            dist=dist,
            frontier_words=int(np.isfinite(dist).sum()),
            total_words=int(dist.size),
            exch=int(getattr(state, "exch_bytes", 0)),
        )
    frontier = np.asarray(state.frontier)
    return dict(
        t0=time.perf_counter(),
        trace_dir=np.asarray(state.trace_dir),
        frontier_words=int(np.count_nonzero(frontier)),
        total_words=int(frontier.size),
        exch=int(getattr(state, "exch_bytes", 0)),
    )


def _mode_of(dirs: np.ndarray, names: tuple) -> str:
    if dirs.size == 0:
        return "idle"
    lo, hi = int(dirs.min()), int(dirs.max())
    return names[lo] if lo == hi else "mixed"


def record_step(recorder: SweepRecorder, pre: dict, state, kind: str,
                exch_format: str = "none") -> None:
    """Diff ``state`` against the pre-step ``snapshot_state`` dict and
    append the step's ``LayerRecord`` (see module docstring for why the
    trace diff recovers exactly the cells the step wrote)."""
    cap = state.capacity
    exch_after = int(getattr(state, "exch_bytes", 0))
    step_bytes = exch_after - pre["exch"]
    if kind == "sssp":
        bucket = np.asarray(state.trace_bucket)
        phase = np.asarray(state.trace_phase)
        changed = ((bucket != pre["trace_bucket"])
                   | (phase != pre["trace_phase"]))
        changed[:, cap] = False
        rows, slots = np.nonzero(changed)
        order = np.argsort(slots, kind="stable")
        rows, slots = rows[order], slots[order]
        dirs = phase[rows, slots]
        dist = np.asarray(state.dist)
        improved = int((dist < pre["dist"]).sum())
        rec = LayerRecord(
            layer=int(state.sweep_steps) - 1, engine=recorder.engine,
            kind=kind, mode=_mode_of(dirs, _SSSP_MODES),
            active_lanes=int(slots.size),
            frontier_words=pre["frontier_words"],
            frontier_density=pre["frontier_words"]
            / max(pre["total_words"], 1),
            edges_relaxed=improved,
            words_touched=int(np.isfinite(dist).sum()),
            exch_bytes=step_bytes, exch_format=exch_format,
            wall_ms=round((time.perf_counter() - pre["t0"]) * 1e3, 6),
            slots=tuple(int(x) for x in slots),
            rows=tuple(int(x) for x in rows),
            dirs=tuple(int(x) for x in dirs),
            buckets=tuple(int(x) for x in bucket[rows, slots]))
        recorder.record(rec)
        return
    trace_dir = np.asarray(state.trace_dir)
    changed = trace_dir != pre["trace_dir"]
    changed[:, cap] = False
    rows, slots = np.nonzero(changed)
    order = np.argsort(slots, kind="stable")
    rows, slots = rows[order], slots[order]
    dirs = trace_dir[rows, slots]
    vf = np.asarray(state.trace_vf)[rows, slots]
    ef = np.asarray(state.trace_ef)[rows, slots]
    eu = np.asarray(state.trace_eu)[rows, slots]
    # the paper's per-layer work counter: TD lanes inspect the frontier's
    # out-edges (e_f), BU lanes the unvisited set's (e_u)
    edges = int(np.where(dirs == 0, ef, eu).sum())
    frontier_after = int(np.count_nonzero(np.asarray(state.frontier)))
    rec = LayerRecord(
        layer=int(state.sweep_layers) - 1, engine=recorder.engine,
        kind=kind, mode=_mode_of(dirs, _BFS_MODES),
        active_lanes=int(slots.size),
        frontier_words=pre["frontier_words"],
        frontier_density=pre["frontier_words"] / max(pre["total_words"], 1),
        edges_relaxed=edges,
        words_touched=pre["frontier_words"] + frontier_after,
        exch_bytes=step_bytes, exch_format=exch_format,
        wall_ms=round((time.perf_counter() - pre["t0"]) * 1e3, 6),
        slots=tuple(int(x) for x in slots),
        rows=tuple(int(x) for x in rows),
        dirs=tuple(int(x) for x in dirs),
        vf=tuple(int(x) for x in vf),
        ef=tuple(int(x) for x in ef),
        eu=tuple(int(x) for x in eu))
    recorder.record(rec)


def drive_recorded(recorder: SweepRecorder, state, step_fn, idle_fn, *,
                   kind: str, exch_format: str = "none"):
    """Step an engine to idleness, recording every layer — the recorded
    twin of the fused jitted drain loops. ``step_fn(state) -> state`` and
    ``idle_fn(state) -> bool`` are the engine's own streaming API, so the
    state sequence (and therefore every result and trace) is bit-identical
    to the drain's; only the host gets to look between layers."""
    while not idle_fn(state):
        pre = snapshot_state(state, kind)
        state = step_fn(state)
        record_step(recorder, pre, state, kind, exch_format)
    return state
