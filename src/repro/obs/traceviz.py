"""Chrome trace-event export + JSONL flight-recorder sink.

Two recorded timelines become Perfetto-loadable JSON
(https://ui.perfetto.dev → "Open trace file", or chrome://tracing):

* ``sweep_trace_events`` — a ``SweepRecorder``'s layer stream as one
  "X" (complete) span per engine step, positioned by cumulative recorded
  wall time, with "C" counter tracks for frontier density, edges
  relaxed, and exchange bytes riding underneath. Span args carry the
  full ``LayerRecord`` aggregates, so clicking a layer in Perfetto shows
  mode / active lanes / words / bytes.
* ``service_trace_events`` — ``AnalyticsService`` request lifecycles on
  the service's layer clock (1 layer = ``layer_us`` µs): a QUEUED span
  from submission to dispatch, a RUNNING span from dispatch to answer,
  and an "i" instant marker on answers streamed mid-sweep before lane
  flush (the early read-outs). One Perfetto track ("thread") per
  request, grouped under a service process.

Everything is the plain trace-event JSON array format wrapped as
``{"traceEvents": [...]}``; ``validate_trace_events`` is the schema
check the tests pin (and a cheap guard before handing a file to a UI).
``FlightSink`` is the append-only JSONL sink a ``SweepRecorder`` can
stream records into as they happen — the post-mortem flight recorder
for sweeps that never finish.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

__all__ = [
    "FlightSink", "service_trace_events", "sweep_trace_events",
    "validate_trace_events", "write_chrome_trace",
]

_PHASES = {"X", "B", "E", "i", "M", "C"}
# per-phase required keys on top of the common name/ph/pid/tid
_REQUIRED = {"X": ("ts", "dur"), "B": ("ts",), "E": ("ts",),
             "i": ("ts",), "C": ("ts", "args"), "M": ("args",)}


def _meta(pid: int, tid: int | None, key: str, value: str) -> dict:
    ev = dict(name=key, ph="M", pid=pid, tid=0 if tid is None else tid,
              args={"name": value})
    return ev


def sweep_trace_events(recorder, *, pid: int = 1) -> list[dict]:
    """One "X" span per recorded engine step + counter tracks, on the
    recorder's own wall-clock (µs since sweep start)."""
    name = f"sweep:{recorder.engine or 'engine'}"
    events = [_meta(pid, None, "process_name", name),
              _meta(pid, 1, "thread_name", "layers")]
    ts = 0.0
    for r in recorder.records:
        dur = max(r.wall_ms * 1e3, 1.0)
        events.append(dict(
            name=f"L{r.layer} {r.mode}", ph="X", pid=pid, tid=1,
            ts=round(ts, 3), dur=round(dur, 3), cat=r.kind,
            args=dict(layer=r.layer, mode=r.mode,
                      active_lanes=r.active_lanes,
                      frontier_words=r.frontier_words,
                      frontier_density=round(r.frontier_density, 6),
                      edges_relaxed=r.edges_relaxed,
                      words_touched=r.words_touched,
                      exch_bytes=r.exch_bytes,
                      exch_format=r.exch_format)))
        events.append(dict(name="frontier_density", ph="C", pid=pid,
                           tid=1, ts=round(ts, 3),
                           args={"density":
                                 round(r.frontier_density, 6)}))
        events.append(dict(name="edges_relaxed", ph="C", pid=pid, tid=1,
                           ts=round(ts, 3),
                           args={"edges": r.edges_relaxed}))
        if r.exch_bytes:
            events.append(dict(name="exch_bytes", ph="C", pid=pid,
                               tid=1, ts=round(ts, 3),
                               args={"bytes": r.exch_bytes}))
        ts += dur
    return events


def service_trace_events(records, *, pid: int = 2,
                         layer_us: float = 1000.0) -> list[dict]:
    """Request lifecycles (iterable of ``RequestRecord``) as spans on the
    service layer clock — QUEUED wait, RUNNING sweep residency, and an
    instant marker where the answer streamed out before lane flush."""
    events = [_meta(pid, None, "process_name", "analytics-service")]
    recs = sorted(records, key=lambda r: (r.submit_layer, r.request.id))
    for tid, rec in enumerate(recs, start=1):
        rid = rec.request.id
        events.append(_meta(pid, tid, "thread_name",
                            f"{rec.kind}:{rid}"))
        args = dict(id=rid, kind=rec.kind, tenant=rec.request.tenant,
                    status=rec.status)
        if rec.status == "REJECTED":
            events.append(dict(name=f"REJECTED {rid}", ph="i", pid=pid,
                               tid=tid, ts=rec.submit_layer * layer_us,
                               s="t",
                               args=dict(**args, reason=rec.reason)))
            continue
        dispatch = (rec.dispatch_layer if rec.dispatch_layer >= 0
                    else rec.submit_layer)
        queued = max(dispatch - rec.submit_layer, 0) * layer_us
        events.append(dict(name=f"QUEUED {rid}", ph="X", pid=pid,
                           tid=tid, ts=rec.submit_layer * layer_us,
                           dur=max(queued, 1.0), cat="lifecycle",
                           args=args))
        if rec.dispatch_layer < 0:
            continue
        end = rec.answer_layer if rec.answer_layer >= 0 else dispatch
        running = max(end - dispatch, 0) * layer_us
        events.append(dict(
            name=f"RUNNING {rid}", ph="X", pid=pid, tid=tid,
            ts=dispatch * layer_us, dur=max(running, 1.0),
            cat="lifecycle",
            args=dict(**args, engine=rec.engine,
                      lanes=rec.lanes_used, sojourn=rec.sojourn)))
        if rec.answer_layer >= 0 and rec.answered_early:
            events.append(dict(name=f"early-readout {rid}", ph="i",
                               pid=pid, tid=tid,
                               ts=rec.answer_layer * layer_us, s="t",
                               args=args))
    return events


def validate_trace_events(events) -> list[dict]:
    """Schema-check a trace-event list; returns it (for chaining) or
    raises ``ValueError`` naming the first offending event."""
    if not isinstance(events, list):
        raise ValueError(f"trace events must be a list, got "
                         f"{type(events).__name__}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object: {ev!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        for k in ("name", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} ({ph}) missing {k!r}")
        if not isinstance(ev["pid"], int) or not isinstance(
                ev["tid"], int):
            raise ValueError(f"event {i}: pid/tid must be integers")
        for k in _REQUIRED[ph]:
            if k not in ev:
                raise ValueError(f"event {i} ({ph}) missing {k!r}")
        if "ts" in ev and not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i}: ts must be a number")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: args must be an object")
        if ph == "M" and "name" not in ev.get("args", {}):
            raise ValueError(f"event {i}: metadata needs args.name")
    return events


def write_chrome_trace(path: str, events: list[dict]) -> str:
    """Validate + write ``{"traceEvents": [...]}`` JSON to ``path``."""
    validate_trace_events(events)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        f.write("\n")
    return path


@dataclass
class FlightSink:
    """Append-only JSONL sink — one line per ``LayerRecord`` dict, flushed
    per write so a crashed sweep still leaves its flight log behind.
    Usable directly as ``SweepRecorder(sink=FlightSink(path))`` and as a
    context manager."""
    path: str
    _fh: object = field(default=None, repr=False)

    def write(self, record: dict) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
