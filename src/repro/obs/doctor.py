"""Sweep doctor — post-hoc audit of a recorded sweep's decisions.

The paper's hybrid BFS stands on one claim: the alpha/beta switch picks
the cheaper direction every layer. PR 9's flight recorder captures the
evidence (per-lane e_f/v_f/e_u counters AND the direction the engine
actually took); this module is the audit that replays the switch rule as
an *oracle* on the recorded counters and flags every layer where the
recorded direction disagrees — plus two more anomaly families the
records expose:

* **mis_switch** — per lane, per layer: replay
  ``core.hybrid.switch_direction`` (in float32, bit-matching the jitted
  rule — pinned in tests) from the lane's previous recorded direction
  over the recorded counters; a disagreement is a mis-switched layer,
  reported with the estimated wasted edges (edges the recorded direction
  inspected minus what the oracle's choice would have: TD inspects
  ``e_f``, BU inspects ``e_u`` — the paper's per-layer work model). On a
  healthy recording the oracle agrees everywhere by construction, so ANY
  finding means the trace was produced by different alpha/beta/mode than
  the audit assumes, or the recording is corrupt — both worth an alarm.
* **exchange_regression** — layers where the compressed wire format cost
  MORE bytes than the dense form would have. Dense is population-blind
  (constant per layer), so the dense baseline is inferred from the
  recording's own dense-format layers when present, else passed
  explicitly (``dense_bytes=``); with neither, the exchange audit is
  skipped and says so.
* **queue_stall / lane_starvation** — engine steps that did no lane work
  (``active_lanes == 0``) while the sweep continued, and sustained
  low-occupancy runs that RECOVER later (occupancy back above threshold
  afterwards — the natural drain tail of a finishing sweep never flags).

Findings land three ways: structured ``Finding`` values in a
``DoctorReport``, registry counters (``obs_doctor_findings_total`` by
kind), and a human-readable ``report.text()``. The CLI audits a JSONL
flight log (``obs.FlightSink`` output)::

    PYTHONPATH=src python -m repro.obs.doctor out/flight.jsonl \
        --n 1024 [--alpha 14 --beta 24] [--out out/doctor.txt]
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.obs.sweeplog import LayerRecord

__all__ = [
    "DoctorReport", "Finding", "diagnose", "diagnose_log",
    "records_from_jsonl", "replay_switch", "split_sweeps",
]

# finding kinds (wire-stable strings)
MIS_SWITCH = "mis_switch"
EXCHANGE_REGRESSION = "exchange_regression"
QUEUE_STALL = "queue_stall"
LANE_STARVATION = "lane_starvation"


@dataclass(frozen=True)
class Finding:
    """One audited anomaly in one recorded sweep."""
    kind: str                    # mis_switch | exchange_regression | ...
    layer: int                   # engine sweep-step index
    slot: int = -1               # queue slot (lane audits; -1 sweep-wide)
    wasted_edges: int = 0        # estimated extra edges inspected
    message: str = ""
    detail: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return dict(kind=self.kind, layer=self.layer, slot=self.slot,
                    wasted_edges=self.wasted_edges, message=self.message,
                    detail=self.detail)


@dataclass
class DoctorReport:
    """The audit result over one recorded sweep."""
    engine: str = ""
    kind: str = ""
    layers: int = 0
    decisions_audited: int = 0   # per-lane switch decisions replayed
    exchange_audited: bool = False
    notes: list = field(default_factory=list)
    findings: list = field(default_factory=list)

    def ok(self) -> bool:
        return not self.findings

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def wasted_edges(self) -> int:
        return sum(f.wasted_edges for f in self.findings
                   if f.kind == MIS_SWITCH)

    def as_dict(self) -> dict:
        return dict(engine=self.engine, kind=self.kind, layers=self.layers,
                    decisions_audited=self.decisions_audited,
                    exchange_audited=self.exchange_audited,
                    notes=list(self.notes), counts=self.counts(),
                    wasted_edges=self.wasted_edges(),
                    findings=[f.as_dict() for f in self.findings])

    def text(self) -> str:
        """Human-readable audit report."""
        head = (f"sweep doctor: engine={self.engine or '?'} "
                f"kind={self.kind or '?'} layers={self.layers} "
                f"decisions_audited={self.decisions_audited}")
        lines = [head]
        for note in self.notes:
            lines.append(f"  note: {note}")
        if self.ok():
            lines.append("  OK — no anomalies")
            return "\n".join(lines)
        counts = ", ".join(f"{k}={v}" for k, v in
                           sorted(self.counts().items()))
        lines.append(f"  ANOMALIES ({counts}, "
                     f"~{self.wasted_edges()} wasted edges):")
        for f in self.findings:
            where = f"layer {f.layer}" + (f" slot {f.slot}"
                                          if f.slot >= 0 else "")
            lines.append(f"    [{f.kind}] {where}: {f.message}")
        return "\n".join(lines)


def replay_switch(topdown_prev: bool, e_f: int, v_f: int, e_u: int,
                  n: int, alpha: float, beta: float) -> bool:
    """The oracle: ``core.hybrid.switch_direction`` replayed host-side in
    float32 — same comparisons, same casts, so the replayed decision is
    bit-identical to the jitted rule (pinned in tests). Returns the
    direction the rule picks for THIS layer given the PREVIOUS layer's
    direction and this layer's counters."""
    f32 = np.float32
    if topdown_prev:
        go_bu = f32(e_f) > f32(e_u) / f32(alpha)
        return not bool(go_bu)
    go_td = f32(v_f) < f32(n) / f32(beta)
    return bool(go_td)


def _lane_sequences(records) -> dict[int, list]:
    """slot -> [(row, layer, dir, vf, ef, eu)] sorted by trace row —
    each slot's recorded decision sequence, whichever layers it spanned."""
    seqs: dict[int, list] = {}
    for r in records:
        for s, row, d, v, e, u in zip(r.slots, r.rows, r.dirs, r.vf,
                                      r.ef, r.eu):
            seqs.setdefault(int(s), []).append(
                (int(row), int(r.layer), int(d), int(v), int(e), int(u)))
    for seq in seqs.values():
        seq.sort()
    return seqs


def _audit_switch(records, n: int, alpha: float, beta: float,
                  report: DoctorReport) -> None:
    for slot, seq in sorted(_lane_sequences(records).items()):
        prev_td = True               # lanes seat top-down (engine _refill)
        for row, layer, d, vf, ef, eu in seq:
            oracle_td = replay_switch(prev_td, ef, vf, eu, n, alpha, beta)
            recorded_td = d == 0
            report.decisions_audited += 1
            if oracle_td != recorded_td:
                cost_rec = ef if recorded_td else eu
                cost_ora = ef if oracle_td else eu
                report.findings.append(Finding(
                    kind=MIS_SWITCH, layer=layer, slot=slot,
                    wasted_edges=int(cost_rec - cost_ora),
                    message=(f"recorded {'TD' if recorded_td else 'BU'} "
                             f"but oracle picks "
                             f"{'TD' if oracle_td else 'BU'} "
                             f"(e_f={ef} v_f={vf} e_u={eu}, "
                             f"~{cost_rec - cost_ora} wasted edges)"),
                    detail=dict(row=row, e_f=ef, v_f=vf, e_u=eu,
                                prev_topdown=prev_td)))
            # continue from what the engine ACTUALLY did, so one
            # disagreement cannot cascade into false findings downstream
            prev_td = recorded_td


def _audit_exchange(records, dense_bytes: int | None,
                    report: DoctorReport) -> None:
    compressed = [r for r in records
                  if r.exch_format == "compressed" and r.exch_bytes > 0]
    if not compressed:
        return
    if dense_bytes is None:
        dense_steps = [r.exch_bytes for r in records
                       if r.exch_format == "dense" and r.exch_bytes > 0]
        # dense is population-blind: every dense layer costs the same
        dense_bytes = max(dense_steps) if dense_steps else None
    if dense_bytes is None:
        report.notes.append(
            "exchange audit skipped: no dense-format layers recorded and "
            "no dense_bytes baseline given")
        return
    report.exchange_audited = True
    for r in compressed:
        if r.exch_bytes > dense_bytes:
            report.findings.append(Finding(
                kind=EXCHANGE_REGRESSION, layer=r.layer,
                wasted_edges=0,
                message=(f"compressed wire cost {r.exch_bytes} B > dense "
                         f"{dense_bytes} B — density switch should have "
                         f"shipped dense"),
                detail=dict(exch_bytes=r.exch_bytes,
                            dense_bytes=int(dense_bytes),
                            frontier_words=r.frontier_words)))


def _audit_occupancy(records, starvation_frac: float,
                     starvation_layers: int,
                     report: DoctorReport) -> None:
    active = [r.active_lanes for r in records]
    if not active:
        return
    # queue stalls: steps that advanced no lane while the sweep went on
    for i, r in enumerate(records[:-1]):
        if r.active_lanes == 0:
            report.findings.append(Finding(
                kind=QUEUE_STALL, layer=r.layer,
                message=("engine stepped with zero active lanes while "
                         "work remained — queue/refill stall"),
                detail=dict(index=i)))
    # starvation: sustained low occupancy that RECOVERS later (the drain
    # tail of a finishing sweep never recovers, so it never flags)
    peak = max(active)
    threshold = max(1, int(np.ceil(peak * starvation_frac)))
    last_healthy = max((i for i, a in enumerate(active) if a >= threshold),
                      default=-1)
    run_start = None
    for i, a in enumerate(active):
        starved = 0 < a < threshold and i < last_healthy
        if starved and run_start is None:
            run_start = i
        elif not starved and run_start is not None:
            if i - run_start >= starvation_layers:
                report.findings.append(Finding(
                    kind=LANE_STARVATION, layer=records[run_start].layer,
                    message=(f"{i - run_start} consecutive layers below "
                             f"{threshold}/{peak} active lanes with "
                             f"pending work (occupancy recovered at "
                             f"layer {records[i].layer})"),
                    detail=dict(run_layers=i - run_start,
                                threshold=threshold, peak=peak)))
            run_start = None


def diagnose(records, *, n: int | None = None, alpha: float | None = None,
             beta: float | None = None, mode: str = "hybrid",
             dense_bytes: int | None = None, registry=None,
             starvation_frac: float = 0.25, starvation_layers: int = 3,
             ) -> DoctorReport:
    """Audit one recorded sweep (a ``SweepRecorder.records`` list or any
    ``LayerRecord`` iterable from one sweep).

    ``n``/``alpha``/``beta``/``mode`` describe the run that produced the
    recording (defaults: the engine defaults). The switch audit runs only
    for BFS-kind records under ``mode="hybrid"`` with ``n`` known —
    forced-direction sweeps and SSSP phase traces have no alpha/beta
    decision to audit (noted in the report)."""
    records = list(records)
    report = DoctorReport(
        engine=records[0].engine if records else "",
        kind=records[0].kind if records else "",
        layers=len(records))
    if not records:
        report.notes.append("empty recording — nothing to audit")
        return report
    if alpha is None or beta is None:
        from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
        alpha = ALPHA_DEFAULT if alpha is None else alpha
        beta = BETA_DEFAULT if beta is None else beta
    if report.kind != "bfs":
        report.notes.append(
            f"switch audit skipped: {report.kind} records carry no "
            f"TD/BU decision")
    elif mode != "hybrid":
        report.notes.append(
            f"switch audit skipped: mode={mode!r} forces the direction")
    elif n is None:
        report.notes.append(
            "switch audit skipped: pass n (the switch-rule vertex count)")
    else:
        _audit_switch(records, int(n), float(alpha), float(beta), report)
    _audit_exchange(records, dense_bytes, report)
    _audit_occupancy(records, starvation_frac, starvation_layers, report)
    report.findings.sort(key=lambda f: (f.layer, f.slot, f.kind))
    if registry is not None:
        registry.counter(
            "obs_doctor_decisions_total",
            "switch decisions replayed by the sweep doctor").inc(
                report.decisions_audited)
        for kind, count in report.counts().items():
            registry.counter(
                "obs_doctor_findings_total", "doctor findings by kind",
                ("kind",)).labels(kind=kind).inc(count)
    return report


# ---------------------------------------------------------------------------
# Flight-log (JSONL) surface — the post-mortem path.
# ---------------------------------------------------------------------------

_RECORD_FIELDS = set(LayerRecord.__dataclass_fields__)
_TUPLE_FIELDS = ("slots", "rows", "dirs", "vf", "ef", "eu", "buckets")


def records_from_jsonl(path: str) -> list[LayerRecord]:
    """Parse a ``FlightSink`` JSONL flight log back into ``LayerRecord``
    values (unknown keys ignored — forward-compatible with schema
    growth; non-record lines are skipped)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if not isinstance(d, dict) or "layer" not in d:
                continue
            kw = {k: v for k, v in d.items() if k in _RECORD_FIELDS}
            for k in _TUPLE_FIELDS:
                if k in kw:
                    kw[k] = tuple(kw[k])
            out.append(LayerRecord(**kw))
    return out


def split_sweeps(records) -> list[list[LayerRecord]]:
    """Group a mixed record stream (one flight log may interleave several
    engines' recorders) into per-sweep record lists: records are bucketed
    by engine, and a non-increasing layer index starts a new sweep."""
    by_engine: dict[str, list] = {}
    for r in records:
        by_engine.setdefault(r.engine, []).append(r)
    sweeps = []
    for engine in sorted(by_engine):
        cur: list = []
        for r in by_engine[engine]:
            if cur and r.layer <= cur[-1].layer:
                sweeps.append(cur)
                cur = []
            cur.append(r)
        if cur:
            sweeps.append(cur)
    return sweeps


def diagnose_log(records, **kwargs) -> list[DoctorReport]:
    """``diagnose`` every sweep in a mixed record stream."""
    return [diagnose(sweep, **kwargs) for sweep in split_sweeps(records)]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Audit a JSONL flight log (obs.FlightSink output).")
    ap.add_argument("flight_log", help="JSONL flight log path")
    ap.add_argument("--n", type=int, default=None,
                    help="switch-rule vertex count of the recorded run "
                         "(enables the alpha/beta mis-switch audit)")
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--beta", type=float, default=None)
    ap.add_argument("--mode", default="hybrid")
    ap.add_argument("--dense-bytes", type=int, default=None,
                    help="dense wire bytes per exchange step (baseline "
                         "for the compression-regression audit)")
    ap.add_argument("--out", default=None,
                    help="also write the report text here")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured reports as JSON instead")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 when any sweep has anomalies")
    args = ap.parse_args(argv)

    records = records_from_jsonl(args.flight_log)
    reports = diagnose_log(records, n=args.n, alpha=args.alpha,
                           beta=args.beta, mode=args.mode,
                           dense_bytes=args.dense_bytes)
    if args.json:
        text = json.dumps([r.as_dict() for r in reports], indent=2)
    else:
        text = "\n".join(r.text() for r in reports) or (
            "sweep doctor: no records in flight log")
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    anomalies = sum(len(r.findings) for r in reports)
    print(f"audited {len(reports)} sweep(s), {len(records)} layer "
          f"records: {anomalies} anomalies")
    return 1 if (args.fail_on_findings and anomalies) else 0


if __name__ == "__main__":
    raise SystemExit(main())
