"""Lightweight metrics registry with Prometheus-style text exposition.

The serving stack needs scrape-able operational counters (requests by
kind and status, sojourn histograms, engine layers, exchange bytes)
without pulling a client library into the container. This module is the
minimal registry that covers the repo's needs:

* three instrument kinds — ``Counter`` (monotone ``inc``), ``Gauge``
  (``set``/``inc``/``dec``), ``Histogram`` (``observe`` into cumulative
  buckets + sum/count) — each optionally labelled;
* one ``MetricsRegistry`` holding them, thread-safe (the service worker
  thread and the submitting threads touch the same series);
* ``metrics_text()`` — the Prometheus text exposition format
  (``# HELP`` / ``# TYPE`` / one line per series), so the output pastes
  straight into a Prometheus scrape or ``promtool check metrics``.

Registration is idempotent: asking for an existing name with the same
kind and label names returns the existing instrument; a mismatched
re-registration raises (two subsystems silently sharing one name with
different schemas is the bug this catches). Per-instrument label
cardinality is bounded (``max_series``) so a label value leaking request
ids cannot grow memory without bound — crossing the bound raises.
"""
from __future__ import annotations

import threading

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "metrics_text",
]

# layer-clock sojourns and per-layer wall-ms both land comfortably here
DEFAULT_BUCKETS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
                   1000.0)

_MAX_SERIES_DEFAULT = 1000


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _format_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _series_key(labelnames, labelvalues) -> tuple:
    return tuple(str(labelvalues[k]) for k in labelnames)


def _labels_text(labelnames, key: tuple, extra: str = "") -> str:
    parts = [f'{k}="{_escape_label(v)}"' for k, v in zip(labelnames, key)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Instrument:
    """Shared plumbing: label validation, bounded series map, locking."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labelnames=(),
                 max_series: int = _MAX_SERIES_DEFAULT):
        if not name or not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.max_series = max_series
        self._series: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _child(self):
        raise NotImplementedError

    def labels(self, **labelvalues):
        """The series for one label-value combination (created on first
        use; raises past ``max_series`` distinct combinations)."""
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {sorted(self.labelnames)}, "
                f"got {sorted(labelvalues)}")
        key = _series_key(self.labelnames, labelvalues)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                if len(self._series) >= self.max_series:
                    raise ValueError(
                        f"{self.name}: label cardinality bound "
                        f"{self.max_series} exceeded — a label value is "
                        f"probably carrying an unbounded id")
                s = self._series[key] = self._child()
            return s

    def _default(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} is labelled {self.labelnames} — call "
                f".labels(...) first")
        return self.labels()

    def _sorted_series(self):
        with self._lock:
            return sorted(self._series.items())


class _CounterSeries:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount


class Counter(_Instrument):
    kind = "counter"

    def _child(self):
        return _CounterSeries()

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def expose(self) -> list[str]:
        return [f"{self.name}{_labels_text(self.labelnames, key)} "
                f"{_format_value(s.value)}"
                for key, s in self._sorted_series()]


class _GaugeSeries:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Gauge(_Instrument):
    kind = "gauge"

    def _child(self):
        return _GaugeSeries()

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)

    def expose(self) -> list[str]:
        return [f"{self.name}{_labels_text(self.labelnames, key)} "
                f"{_format_value(s.value)}"
                for key, s in self._sorted_series()]


class _HistogramSeries:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets):
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                break


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", labelnames=(),
                 buckets=DEFAULT_BUCKETS,
                 max_series: int = _MAX_SERIES_DEFAULT):
        super().__init__(name, help, labelnames, max_series)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = b

    def _child(self):
        return _HistogramSeries(self.buckets)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def expose(self) -> list[str]:
        lines = []
        for key, s in self._sorted_series():
            cum = 0
            for bound, c in zip(s.buckets, s.counts):
                cum += c
                le = _labels_text(self.labelnames, key,
                                  f'le="{_format_value(bound)}"')
                lines.append(f"{self.name}_bucket{le} {cum}")
            inf = _labels_text(self.labelnames, key, 'le="+Inf"')
            lines.append(f"{self.name}_bucket{inf} {s.count}")
            lt = _labels_text(self.labelnames, key)
            lines.append(f"{self.name}_sum{lt} {_format_value(s.sum)}")
            lines.append(f"{self.name}_count{lt} {s.count}")
        return lines


class MetricsRegistry:
    """Named instruments + the text exposition over all of them."""

    def __init__(self):
        self._metrics: dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, labelnames, **kw):
        labelnames = tuple(labelnames)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls or m.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{m.kind} with labels {m.labelnames}")
                return m
            m = self._metrics[name] = cls(name, help, labelnames, **kw)
            return m

    def counter(self, name: str, help: str = "", labelnames=()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def expose(self) -> str:
        """Prometheus text exposition over every registered instrument."""
        out = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            out.extend(m.expose())
        return "\n".join(out) + ("\n" if out else "")


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (for callers that don't thread their own
    ``Telemetry`` bundle through)."""
    return _DEFAULT


def metrics_text(registry: MetricsRegistry | None = None) -> str:
    """Text exposition of ``registry`` (the process default when None)."""
    return (registry or _DEFAULT).expose()
