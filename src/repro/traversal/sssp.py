"""Bucketed delta-stepping SSSP — dense tropical lanes, pipelined sources.

Meyer & Sanders' delta-stepping, reformulated as lane-batched tropical
semiring relaxations so it runs on the same machinery as the packed
MS-BFS engines:

* R concurrent single-source problems occupy R dense float32 *lanes*
  (``dist[n, L]``, inf = unreached) — the numeric analog of the packed
  bit lanes; sources stream through a fixed lane pool from a pending
  queue, claimed/flushed/refilled mid-sweep with the SAME
  ``packed.queue_claims`` rule as ``msbfs_pipelined``.
* Each lane walks its buckets independently (``lane_bucket[l]``): bucket
  ``b`` holds unsettled vertices with ``dist in [b*delta, (b+1)*delta)``.
  Per engine step every lane is in one of two phases — the delta-stepping
  analog of the per-lane alpha/beta direction switch:

  - **light iteration**: relax light edges (w <= delta) from bucket
    members whose distance changed since their last relaxation (the
    request set, tracked by the ``relaxed`` flags); repeated until the
    bucket reaches fixpoint;
  - **heavy settle**: the bucket is at fixpoint — its members' distances
    are final; relax their heavy edges (w > delta) once and advance to
    the next non-empty bucket (computed directly from the unsettled
    minimum, so empty buckets cost nothing).

  Both phases are ONE masked min-plus relaxation
  (``traversal.semiring.tropical_relax``): inactive sources carry inf
  values and phase-excluded edges inf weights, so light and heavy lanes
  share each edge-parallel pass, cond-skipped when no lane is in that
  phase (exactly the TD/BU dispatch pattern of
  ``packed.dispatch_packed_step``).

With unit weights and ``delta = 1`` bucket ``b`` IS the BFS layer ``b``
frontier and the engine reproduces ``msbfs_pipelined`` depths exactly —
the boolean-semiring anchor ``tests/test_traversal.py`` pins.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import WeightedCSRGraph
from repro.core.packed import queue_claims
from repro.traversal.semiring import INF, tropical_relax

__all__ = [
    "DEFAULT_LANES", "MAX_SSSP_STEPS", "MAX_SSSP_TRACE", "SSSPResult",
    "adaptive_delta", "default_delta", "sssp_engine_drain",
    "sssp_engine_enqueue", "sssp_engine_idle", "sssp_engine_init",
    "sssp_engine_result", "sssp_engine_step", "sssp_pipelined",
]

# dense float lanes cost 32x the state of packed bit lanes — the default
# pool is correspondingly narrower than MAX_LANES * words
DEFAULT_LANES = 32

# hard per-lane step bound (safety net mirroring MAX_TRACE): every light
# iteration either changes a distance or settles the bucket, so real
# workloads finish in O(buckets + light rounds) << this
MAX_SSSP_STEPS = 4096

# per-lane bucket/phase trace depth: rows are engine steps (clipped —
# steps past the buffer overwrite the last row identically on the host
# and distributed engines, so traces stay bit-comparable either way)
MAX_SSSP_TRACE = 256


class SSSPResult(NamedTuple):
    sources: jnp.ndarray       # int32[R] root vertex per lane
    dist: jnp.ndarray          # float32[n, R], inf unreached
    steps: jnp.ndarray         # int32[R] engine steps the lane ran
    truncated: jnp.ndarray     # bool[R] — lane hit max_steps; dist is a
    #                            PARTIAL relaxation, not shortest paths
    trace_bucket: jnp.ndarray  # int32[MAX_SSSP_TRACE, R] bucket per step
    #                            (-1 = lane idle / step never ran)
    trace_phase: jnp.ndarray   # int32[MAX_SSSP_TRACE, R] 0 light-iterate,
    #                            1 heavy-settle, -1 idle

    def reached(self) -> jnp.ndarray:
        """bool[n, R] — vertices with a finite distance per lane."""
        return jnp.isfinite(self.dist)

    def as_depth(self) -> jnp.ndarray:
        """int32[n, R] MS-BFS-style depths (-1 unreached) — exact for
        unit weights, where distance == hop count; the representation the
        boolean-anchor equivalence test compares bit-for-bit."""
        return jnp.where(jnp.isfinite(self.dist),
                         jnp.round(self.dist), -1).astype(jnp.int32)


class SSSPState(NamedTuple):
    dist: jnp.ndarray          # float32[n, L]  lane distances (inf idle)
    relaxed: jnp.ndarray       # bool[n, L]     light edges relaxed at dist
    lane_bucket: jnp.ndarray   # int32[L]       current bucket per lane
    lane_steps: jnp.ndarray    # int32[L]       steps run for the lane's root
    lane_qidx: jnp.ndarray     # int32[L]       queue slot served; capacity = idle
    queue: jnp.ndarray         # int32[capacity] enqueued source ids
    queued: jnp.ndarray        # int32 scalar
    next_root: jnp.ndarray     # int32 scalar
    sweep_steps: jnp.ndarray   # int32 scalar   total engine steps
    out_dist: jnp.ndarray      # float32[n, capacity+1] (+1 = trash column)
    out_steps: jnp.ndarray     # int32[capacity+1]  0 = unanswered
    out_truncated: jnp.ndarray  # bool[capacity+1]  lane flushed by the cap
    trace_bucket: jnp.ndarray  # int32[MAX_SSSP_TRACE, capacity+1]
    trace_phase: jnp.ndarray   # int32[MAX_SSSP_TRACE, capacity+1]

    @property
    def num_lanes(self) -> int:
        return self.lane_qidx.shape[0]

    @property
    def capacity(self) -> int:
        return self.queue.shape[0]


def default_delta(wg: WeightedCSRGraph) -> float:
    """Meyer & Sanders' Theta(1/d) rule scaled to the weight range:
    ``max_w / avg_degree`` — buckets wide enough that light phases do a
    few iterations, narrow enough that heavy edges skip bucket work.
    Falls back to 1.0 on edgeless or all-zero-weight graphs (one bucket
    holds everything and light iteration degenerates to Bellman-Ford)."""
    if wg.m == 0:
        return 1.0
    w_max = float(np.asarray(wg.weights).max())
    avg_deg = wg.m / max(wg.n, 1)
    delta = w_max / max(avg_deg, 1.0)
    return delta if delta > 0 else 1.0


def adaptive_delta(wg: WeightedCSRGraph, lanes: int | None = None):
    """Bucket width from the weight HISTOGRAM, not just the range.

    ``default_delta`` is one global ``max_w / avg_deg`` width — on a
    bimodal weight distribution (many light local edges + a heavy long-
    haul mode, the classic road-network/R-MAT-with-tiers shape) that
    width lands inside the light mode, so every heavy edge spans many
    buckets and the settle phase walks them one by one. This rule finds
    the dominant gap in the log-weight histogram and, when a real
    light/heavy split exists (gap >= 4x, both modes carrying >= 5% of the
    edges), widens delta to the geometric midpoint of the gap: light
    edges stay light (few intra-bucket iterations), heavy edges cross in
    one hop (far fewer buckets). Unimodal weights see no gap and fall
    back to ``default_delta`` unchanged. Distances are delta-invariant —
    any positive width yields exact shortest paths at fixpoint — so the
    knob only moves step/bucket counts.

    With ``lanes`` the width is broadcast to a ``lanes``-tuple: the engine
    accepts per-lane deltas (a static tuple), so callers with per-source
    heuristics can hand different sources different widths.
    """
    base = default_delta(wg)
    w = np.asarray(wg.weights, np.float64).reshape(-1)
    w = w[np.isfinite(w) & (w > 0)]
    delta = base
    if w.size >= 2:
        logw = np.sort(np.log(w))
        gaps = np.diff(logw)
        k = int(np.argmax(gaps))
        heavy_frac = (logw.size - (k + 1)) / logw.size
        light_frac = (k + 1) / logw.size
        if (gaps[k] >= np.log(4.0) and heavy_frac >= 0.05
                and light_frac >= 0.05):
            mid = float(np.exp((logw[k] + logw[k + 1]) / 2.0))
            delta = max(base, mid)
    if lanes is None:
        return float(delta)
    return (float(delta),) * lanes


def _delta_lanes(delta, lanes: int) -> jnp.ndarray:
    """Per-lane bucket widths [L] from a scalar or a lanes-length tuple."""
    if isinstance(delta, tuple):
        if len(delta) != lanes:
            raise ValueError(
                f"per-lane delta needs {lanes} entries, got {len(delta)}")
        return jnp.asarray(delta, jnp.float32)
    return jnp.full((lanes,), jnp.float32(delta))


def _check_delta(delta) -> None:
    vals = delta if isinstance(delta, tuple) else (delta,)
    if len(vals) == 0 or not all(v > 0 for v in vals):
        raise ValueError(f"delta must be > 0, got {delta}")


def sssp_engine_init(wg: WeightedCSRGraph, capacity: int,
                     lanes: int = DEFAULT_LANES) -> SSSPState:
    """Fresh SSSP engine: all lanes idle, empty source queue of
    ``capacity`` slots — the weighted mirror of ``msbfs_engine_init``."""
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    n = wg.n
    cap = capacity
    return SSSPState(
        dist=jnp.full((n, lanes), jnp.inf, jnp.float32),
        relaxed=jnp.zeros((n, lanes), jnp.bool_),
        lane_bucket=jnp.zeros((lanes,), jnp.int32),
        lane_steps=jnp.zeros((lanes,), jnp.int32),
        lane_qidx=jnp.full((lanes,), cap, jnp.int32),
        queue=jnp.zeros((cap,), jnp.int32),
        queued=jnp.int32(0),
        next_root=jnp.int32(0),
        sweep_steps=jnp.int32(0),
        out_dist=jnp.full((n, cap + 1), jnp.inf, jnp.float32),
        out_steps=jnp.zeros((cap + 1,), jnp.int32),
        out_truncated=jnp.zeros((cap + 1,), jnp.bool_),
        trace_bucket=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
        trace_phase=jnp.full((MAX_SSSP_TRACE, cap + 1), -1, jnp.int32),
    )


def sssp_engine_enqueue(state: SSSPState, roots) -> SSSPState:
    """Append sources to the pending queue (host helper, mid-sweep safe) —
    same contract as ``msbfs_engine_enqueue``."""
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    k = roots.shape[0]
    queued = int(state.queued)
    if queued + k > state.capacity:
        raise ValueError(
            f"queue overflow: {queued} queued + {k} new > capacity "
            f"{state.capacity}")
    queue = jax.lax.dynamic_update_slice(state.queue, roots,
                                         (state.queued,))
    return state._replace(queue=queue, queued=state.queued + jnp.int32(k))


def sssp_engine_idle(state: SSSPState) -> bool:
    """True when no lane is active and no enqueued source is pending."""
    return (int(state.next_root) >= int(state.queued)
            and not bool(jnp.any(state.lane_qidx < state.capacity)))


def _refill(wg: WeightedCSRGraph, s: SSSPState) -> SSSPState:
    """Claim pending queue slots for idle lanes and seat their sources at
    distance 0, bucket 0 — ``packed.queue_claims`` keeps the claim rule
    bit-identical to the MS-BFS engines'."""
    n = wg.n

    def do_refill(s: SSSPState) -> SSSPState:
        claim, cand, root = queue_claims(s.lane_qidx, s.next_root,
                                         s.queued, s.queue)
        onehot = claim[None, :] & (root[None, :]
                                   == jnp.arange(n, dtype=jnp.int32)[:, None])
        return s._replace(
            dist=jnp.where(claim[None, :],
                           jnp.where(onehot, jnp.float32(0), INF), s.dist),
            relaxed=jnp.where(claim[None, :], False, s.relaxed),
            lane_bucket=jnp.where(claim, 0, s.lane_bucket),
            lane_steps=jnp.where(claim, 0, s.lane_steps),
            lane_qidx=jnp.where(claim, cand, s.lane_qidx),
            next_root=s.next_root + jnp.sum(claim, dtype=jnp.int32),
        )

    needed = jnp.any(s.lane_qidx >= s.capacity) & (s.next_root < s.queued)
    return jax.lax.cond(needed, do_refill, lambda s: s, s)


def _phase_relax(g, sel: jnp.ndarray, dist: jnp.ndarray,
                 phase_w: jnp.ndarray, max_pos: int,
                 relax_impl: str) -> jnp.ndarray:
    """One cond-skipped masked relaxation: sources where ``sel``, edge
    weights ``phase_w`` (inf = excluded). Returns the min-plus candidate
    distances [n, L] (inf when the phase is empty this step)."""
    def run(dist):
        vals = jnp.where(sel, dist, INF)
        return tropical_relax(g, phase_w, vals, max_pos, relax_impl)

    return jax.lax.cond(jnp.any(sel), run,
                        lambda dist: jnp.full_like(dist, jnp.inf), dist)


def _sssp_body(wg: WeightedCSRGraph, s: SSSPState, delta,
               max_pos: int, relax_impl: str,
               max_steps: int) -> SSSPState:
    """One engine step: refill idle lanes, run the light/heavy phase each
    lane is in, settle + advance fixpoint buckets, flush finished lanes."""
    g = wg.csr
    cap = s.capacity
    s = _refill(wg, s)

    d32 = _delta_lanes(delta, s.num_lanes)                    # [L]
    active = s.lane_qidx < cap
    # membership is CEILING-ONLY (dist < (b+1)*delta, no lower bound):
    # already-settled vertices re-enter the mask but their re-relaxations
    # are idempotent, and no vertex can fall between buckets when float32
    # rounding of floor(dist/delta) disagrees with the boundary product —
    # the correctness-over-thrift call for the masked dense formulation,
    # where the per-step edge-parallel cost is O(m*L) regardless
    b_hi = (s.lane_bucket.astype(jnp.float32) + 1) * d32      # [L]
    in_bucket = active[None, :] & (s.dist < b_hi[None, :])    # [n, L]
    light_pending = in_bucket & ~s.relaxed

    # phase per lane: request set non-empty -> keep iterating light edges;
    # empty -> the bucket is at fixpoint, settle it (heavy relax + advance)
    iterating = light_pending.any(axis=0)                     # bool[L]
    settling = active & ~iterating

    # the light/heavy edge split depends on the lane's OWN delta, but the
    # weight masks are per-edge (shared across lanes) — so lanes are
    # grouped by DISTINCT width and each group runs its own masked relax
    # pair, min-folded into the shared candidates. A scalar delta is one
    # group with an all-lanes selector: the exact relaxations of the
    # single-width engine, bit for bit.
    cand_light = jnp.full_like(s.dist, jnp.inf)
    cand_heavy = jnp.full_like(s.dist, jnp.inf)
    widths = (sorted(set(delta)) if isinstance(delta, tuple)
              else [float(delta)])
    lane_widths = (delta if isinstance(delta, tuple)
                   else (float(delta),) * s.num_lanes)
    for dv in widths:
        gsel = jnp.asarray([lw == dv for lw in lane_widths], jnp.bool_)
        dv32 = jnp.float32(dv)
        light_w = jnp.where(wg.weights <= dv32, wg.weights, INF)
        heavy_w = jnp.where(wg.weights > dv32, wg.weights, INF)
        g_light = _phase_relax(
            g, light_pending & (iterating & gsel)[None, :],
            s.dist, light_w, max_pos, relax_impl)
        g_heavy = _phase_relax(
            g, in_bucket & (settling & gsel)[None, :],
            s.dist, heavy_w, max_pos, relax_impl)
        cand_light = jnp.minimum(cand_light, g_light)
        cand_heavy = jnp.minimum(cand_heavy, g_heavy)

    new_dist = jnp.minimum(s.dist, jnp.minimum(cand_light, cand_heavy))
    changed = new_dist < s.dist
    # sources just relaxed are served at their current distance; any
    # vertex whose distance improved re-enters its bucket's request set
    relaxed2 = (s.relaxed | (light_pending & iterating[None, :])) & ~changed

    # settling lanes advance straight to the next non-empty bucket: the
    # minimum unsettled distance names it, empty buckets are never
    # visited; the max() keeps the advance strictly monotone even when
    # float32 division rounds the quotient below the bucket boundary
    unsettled = jnp.where(new_dist >= b_hi[None, :], new_dist, INF)
    min_unsettled = jnp.min(unsettled, axis=0)                # [L]
    exhausted = settling & ~jnp.isfinite(min_unsettled)
    next_bucket = jnp.where(
        settling & jnp.isfinite(min_unsettled),
        jnp.maximum(jnp.floor(min_unsettled / d32).astype(jnp.int32),
                    s.lane_bucket + 1),
        s.lane_bucket)

    lane_steps2 = s.lane_steps + active.astype(jnp.int32)
    # the cap is a safety net, not an answer: a capped lane's distances
    # are a PARTIAL relaxation, so its flush is marked truncated — the
    # one bit that separates "converged" from "gave up" downstream
    capped = active & (lane_steps2 >= max_steps) & ~exhausted
    finished = exhausted | capped

    # bucket/phase trace: one row per engine step of the lane's root
    # (clipped to the buffer — overwrites land identically everywhere),
    # written to the lane's OUTPUT column so finished traces persist
    tr_row = jnp.clip(s.lane_steps, 0, MAX_SSSP_TRACE - 1)
    tr_col = jnp.where(active, s.lane_qidx, cap)
    trace_bucket = s.trace_bucket.at[tr_row, tr_col].set(
        jnp.where(active, s.lane_bucket, -1))
    trace_phase = s.trace_phase.at[tr_row, tr_col].set(
        jnp.where(active, jnp.where(iterating, 0, 1), -1).astype(jnp.int32))

    fcol = jnp.where(finished, s.lane_qidx, cap)
    out_dist = s.out_dist.at[:, fcol].set(new_dist)
    out_steps = s.out_steps.at[fcol].set(lane_steps2)
    out_truncated = s.out_truncated.at[fcol].set(capped)

    return s._replace(
        dist=jnp.where(finished[None, :], INF, new_dist),
        relaxed=relaxed2 & ~finished[None, :],
        lane_bucket=jnp.where(finished, 0, next_bucket),
        lane_steps=jnp.where(finished, 0, lane_steps2),
        lane_qidx=jnp.where(finished, cap, s.lane_qidx),
        sweep_steps=s.sweep_steps + 1,
        out_dist=out_dist, out_steps=out_steps,
        out_truncated=out_truncated,
        trace_bucket=trace_bucket, trace_phase=trace_phase,
    )


@partial(jax.jit, static_argnums=(2, 3, 4, 5))
def sssp_engine_step(wg: WeightedCSRGraph, state: SSSPState, delta,
                     max_pos: int = 8, relax_impl: str = "xla",
                     max_steps: int = MAX_SSSP_STEPS) -> SSSPState:
    """Advance the SSSP engine by one phase step (streaming API).

    ``delta`` is a scalar bucket width or a per-lane tuple (static either
    way). Compiles once per (graph shape, lanes, capacity, delta); the
    serving loop interleaves ``sssp_engine_enqueue`` between steps to
    feed idle lanes mid-sweep, exactly like the MS-BFS engine it mirrors.
    """
    _check_delta(delta)
    return _sssp_body(wg, state, delta, max_pos, relax_impl, max_steps)


@partial(jax.jit, static_argnums=(2, 3, 4, 5))
def _drain(wg: WeightedCSRGraph, state: SSSPState, delta,
           max_pos: int, relax_impl: str, max_steps: int) -> SSSPState:
    cap = state.queue.shape[0]

    def cond_fn(s: SSSPState):
        return (s.next_root < s.queued) | jnp.any(s.lane_qidx < cap)

    def body_fn(s: SSSPState):
        return _sssp_body(wg, s, delta, max_pos, relax_impl, max_steps)

    return jax.lax.while_loop(cond_fn, body_fn, state)


def sssp_engine_drain(wg: WeightedCSRGraph, state: SSSPState, delta,
                      max_pos: int = 8, relax_impl: str = "xla",
                      max_steps: int = MAX_SSSP_STEPS) -> SSSPState:
    """Step the engine until every enqueued source has been answered."""
    _check_delta(delta)
    return _drain(wg, state, delta, max_pos, relax_impl, max_steps)


def sssp_engine_result(state: SSSPState) -> SSSPResult:
    """Assemble an ``SSSPResult`` over the answered queue slots (columns
    of unanswered slots hold init values: inf distances, 0 steps).
    ``truncated`` lanes hit the ``max_steps`` cap — their distances are
    partial relaxations, NOT shortest paths (re-run with a larger delta
    or a larger cap)."""
    r = int(state.queued)
    return SSSPResult(sources=state.queue[:r],
                      dist=state.out_dist[:, :r],
                      steps=state.out_steps[:r],
                      truncated=state.out_truncated[:r],
                      trace_bucket=state.trace_bucket[:, :r],
                      trace_phase=state.trace_phase[:, :r])


def sssp_pipelined(wg: WeightedCSRGraph, roots, delta=None,
                   lanes: int = DEFAULT_LANES, max_pos: int = 8,
                   relax_impl: str = "xla",
                   max_steps: int = MAX_SSSP_STEPS,
                   recorder=None) -> SSSPResult:
    """Answer an arbitrary number of SSSP sources in ONE pipelined sweep.

    Sources beyond the lane pool wait in the pending queue and stream
    into lanes as they free up — no barrier between lane generations, so
    a many-bucket source never stalls shallow ones. ``delta=None`` picks
    ``default_delta(wg)``; a per-lane tuple (length == the effective lane
    count) hands each lane its own bucket width.

    ``recorder`` (a ``repro.obs.SweepRecorder``) records a ``LayerRecord``
    per engine step by stepping instead of the fused drain (shared
    ``_sssp_body`` — distances, steps and traces bit-identical); None
    (the default) touches nothing in ``repro.obs``.
    """
    roots = jnp.asarray(roots, jnp.int32).reshape(-1)
    num_roots = roots.shape[0]
    if num_roots < 1:
        raise ValueError("need at least one source")
    if delta is None:
        delta = default_delta(wg)
    lanes = max(1, min(lanes, num_roots))
    delta = delta if isinstance(delta, tuple) else float(delta)
    state = sssp_engine_init(wg, capacity=num_roots, lanes=lanes)
    state = sssp_engine_enqueue(state, roots)
    if recorder is None:
        state = sssp_engine_drain(wg, state, delta, max_pos, relax_impl,
                                  max_steps)
    else:
        from repro.obs.sweeplog import drive_recorded
        state = drive_recorded(
            recorder, state,
            lambda s: sssp_engine_step(wg, s, delta, max_pos, relax_impl,
                                       max_steps),
            sssp_engine_idle, kind="sssp")
    return sssp_engine_result(state)
