"""Semiring abstraction over the lane-batched traversal step.

Every traversal this repo runs is one masked multi-lane semiring SpMV
(Buluc & Madduri's linear-algebra BFS, SlimSell's semiring generalization):

    out[v, l] = ADD_{e in row v} ( vals[col_idx[e], l]  MUL  w[e] )

The packed MS-BFS engines are the *boolean* instantiation — ADD = OR,
MUL = AND, with 32/64 lanes packed per machine word and the adjacency
weight identically ``one`` (``packed.segment_or`` is this module's
``segment_reduce`` specialised to bitwise words). This module carries the
same step shape over *numeric* semirings:

* ``TROPICAL``  (min, +,  zero=inf, one=0)  — shortest paths: one relax
  round of delta-stepping / Bellman-Ford per SpMV (``repro.traversal.sssp``
  runs the bucketed engine on top);
* ``PLUS_TIMES`` (+, *, zero=0, one=1)     — weighted aggregation /
  PageRank-style iteration;
* ``BOOLEAN``    (|, &, zero=0, one=1 over uint lane words) — the packed
  engines' own algebra, here in dense per-lane form so the generic path
  can be cross-checked bit-for-bit against ``packed.topdown_packed_step``.

Two execution strategies mirror the packed TD/BU split:

* ``segment_reduce`` — edge-parallel associative scan over CSR rows (the
  generalized ``segment_or``): O(m * L), covers any degree; and
* the MAX_POS-style *gather-relax* for the tropical semiring
  (``repro.kernels.semiring_relax``): each vertex gathers its first
  ``max_pos`` neighbours' lane values (+ edge weight, min-accumulate),
  with rows deeper than ``max_pos`` falling back to the segmented scan —
  the same probe + cond-skipped fallback structure as
  ``packed.bottomup_packed_step``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.csr import CSRGraph

__all__ = ["BOOLEAN", "PLUS_TIMES", "SEMIRINGS", "Semiring", "TROPICAL",
           "segment_reduce", "semiring_spmv", "tropical_relax"]

INF = jnp.float32(jnp.inf)


@dataclass(frozen=True)
class Semiring:
    """(ADD, MUL, zero, one) with ADD associative+commutative, ``zero``
    the ADD identity (and MUL annihilator), ``one`` the MUL identity.
    ``dtype`` is the lane-value element type the ops run in."""
    name: str
    add: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    mul: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    zero: float
    one: float
    dtype: jnp.dtype

    def zeros(self, shape) -> jnp.ndarray:
        return jnp.full(shape, self.zero, self.dtype)


TROPICAL = Semiring("tropical", jnp.minimum, jnp.add,
                    zero=float("inf"), one=0.0, dtype=jnp.float32)
PLUS_TIMES = Semiring("plus_times", jnp.add, jnp.multiply,
                      zero=0.0, one=1.0, dtype=jnp.float32)
# dense boolean lanes as uint8 0/1 (bitwise ops ARE or/and there); the
# packed engines implement the same algebra 32/64 lanes per word
BOOLEAN = Semiring("boolean", jnp.bitwise_or, jnp.bitwise_and,
                   zero=0, one=1, dtype=jnp.uint8)

SEMIRINGS = {sr.name: sr for sr in (BOOLEAN, TROPICAL, PLUS_TIMES)}


def segment_reduce(vals: jnp.ndarray, row_ptr: jnp.ndarray,
                   sr: Semiring) -> jnp.ndarray:
    """Per-CSR-row semiring ADD of edge-lane values [m, L] -> [n, L] —
    ``packed.segment_or`` generalized to any (ADD, zero): an inclusive
    ``lax.associative_scan`` over (value, segment-start-flag) pairs read
    out at each row's last slot. Empty rows produce ``sr.zero``; slots
    past ``row_ptr[-1]`` only extend the last segment beyond every
    read-out point."""
    m = vals.shape[0]
    flags = jnp.zeros((m,), jnp.bool_).at[row_ptr[:-1]].set(True, mode="drop")

    def comb(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb[..., None], vb, sr.add(va, vb)), fa | fb

    scanned, _ = jax.lax.associative_scan(comb, (vals, flags))
    deg = row_ptr[1:] - row_ptr[:-1]
    last = jnp.clip(row_ptr[1:] - 1, 0, m - 1)
    return jnp.where((deg > 0)[:, None], scanned[last],
                     jnp.asarray(sr.zero, vals.dtype))


def semiring_spmv(g: CSRGraph, vals: jnp.ndarray, weights, sr: Semiring,
                  ) -> jnp.ndarray:
    """One lane-batched semiring SpMV: ``out[v, l] = ADD_e vals[col_e, l]
    MUL w_e`` over row v's edge slots. ``vals`` is [nf, L] with nf >= n
    (the distributed local-block shape: rows are LOCAL, ``col_idx`` holds
    global ids into ``vals``); ``weights`` is float-like [m] or None for
    the adjacency pattern (every edge weighs ``sr.one``).

    Boolean instantiation: with 0/1 lanes and weights None this is
    exactly the unpacked top-down frontier expansion
    (``packed.topdown_packed_step`` modulo the visited mask) — the
    cross-check pinning the generic path to the packed engines.
    """
    contrib = vals[jnp.clip(g.col_idx, 0, vals.shape[0] - 1)]   # [m, L]
    if weights is not None:
        contrib = sr.mul(contrib, weights.astype(vals.dtype)[:, None])
    return segment_reduce(contrib, g.row_ptr, sr)


def _relax_fallback(g: CSRGraph, weights: jnp.ndarray, vals: jnp.ndarray,
                    max_pos: int) -> jnp.ndarray:
    """Segmented-min over edge slots at position >= ``max_pos`` of rows
    deeper than ``max_pos`` — the residue the gather-relax probe skipped.
    Inert slots contribute inf; pad slots (distributed edge slabs) sit
    past every read-out point, same argument as ``segment_or``."""
    pos_e = jnp.arange(g.m, dtype=jnp.int32) - g.row_ptr[g.src_idx]
    act = (pos_e >= max_pos) & (pos_e < g.deg[g.src_idx])
    cand = vals[jnp.clip(g.col_idx, 0, vals.shape[0] - 1)] \
        + weights.astype(vals.dtype)[:, None]
    cand = jnp.where(act[:, None], cand, INF)
    return segment_reduce(cand, g.row_ptr, TROPICAL)


def tropical_relax(g: CSRGraph, weights: jnp.ndarray, vals: jnp.ndarray,
                   max_pos: int = 8, impl: str = "xla") -> jnp.ndarray:
    """Masked min-plus gather-relax: ``out[v, l] = min_e vals[col_e, l] +
    w_e`` (inf where nothing relaxes). Masking is by value: callers encode
    inactive source vertices as ``vals == inf`` and phase-excluded edges
    as ``w == inf`` — both vanish under min-plus, so ONE contract serves
    every delta-stepping phase.

    ``impl='xla'`` runs the edge-parallel segmented scan over all edges;
    ``impl='pallas'`` runs the ``semiring_relax`` kernel over each row's
    first ``max_pos`` neighbours (the MAX_POS gather shape) with the
    deeper-row residue cond-skipped into the segmented scan — the same
    probe + fallback structure as the packed bottom-up step.
    """
    if g.m == 0:   # edgeless: the associative scan has no slots to scan
        return jnp.full((g.n, vals.shape[1]), jnp.inf, vals.dtype)
    if impl == "pallas":
        from repro.kernels import semiring_relax
        acc = semiring_relax(g.row_ptr, g.col_idx, weights, vals,
                             max_pos=max_pos)
        residue = jnp.any(g.deg > max_pos)
        return jax.lax.cond(
            residue,
            lambda a: jnp.minimum(
                a, _relax_fallback(g, weights, vals, max_pos)),
            lambda a: a, acc)
    return semiring_spmv(g, vals, weights, TROPICAL)
