"""Weighted semiring traversal — SSSP lanes on the packed-engine pattern.

The MS-BFS engines instantiate ONE semiring (boolean OR/AND over packed
lane words); this package generalizes the step to arbitrary semirings
(SlimSell; Buluc & Madduri's masked-SpMV formulation) and builds the
first weighted workload family on top:

* ``semiring``  — the ``Semiring`` abstraction (boolean / tropical
  min-plus / plus-times), the generalized segmented reduction, the
  lane-batched semiring SpMV, and the masked tropical gather-relax
  (XLA scan or the ``repro.kernels.semiring_relax`` Pallas kernel);
* ``sssp``      — bucketed delta-stepping with multiple sources as dense
  float lanes streamed through the pipelined root-queue pattern
  (light/heavy bucket phases standing where alpha/beta direction
  switches stand in MS-BFS);
* ``ref``       — host NumPy Dijkstra oracle for the property suites.

Downstream: ``repro.analytics`` serves ``SSSPQuery`` /
``WeightedClosenessQuery`` over this engine,
``repro.launch.serve_bfs`` mixes ``sssp``-tagged requests into its
serving loop, and ``repro.core.dist_sssp`` shards the engine over the
1-D and 2-D device partitions through the MIN-monoid surface of the
shared exchange (bit-identical on every partition shape).
"""
from repro.traversal.ref import dijkstra_reference, to_numpy_weighted
from repro.traversal.semiring import (BOOLEAN, PLUS_TIMES, SEMIRINGS,
                                      Semiring, TROPICAL, segment_reduce,
                                      semiring_spmv, tropical_relax)
from repro.traversal.sssp import (DEFAULT_LANES, MAX_SSSP_STEPS,
                                  MAX_SSSP_TRACE, SSSPResult, adaptive_delta,
                                  default_delta, sssp_engine_drain,
                                  sssp_engine_enqueue, sssp_engine_idle,
                                  sssp_engine_init, sssp_engine_result,
                                  sssp_engine_step, sssp_pipelined)

__all__ = [
    "BOOLEAN", "DEFAULT_LANES", "MAX_SSSP_STEPS", "MAX_SSSP_TRACE",
    "PLUS_TIMES", "SEMIRINGS", "SSSPResult", "Semiring", "TROPICAL",
    "adaptive_delta", "default_delta", "dijkstra_reference",
    "segment_reduce", "semiring_spmv", "sssp_engine_drain",
    "sssp_engine_enqueue", "sssp_engine_idle", "sssp_engine_init",
    "sssp_engine_result", "sssp_engine_step", "sssp_pipelined",
    "to_numpy_weighted", "tropical_relax",
]
