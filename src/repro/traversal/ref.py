"""Host-side NumPy oracles for the weighted traversal subsystem."""
from __future__ import annotations

import heapq

import numpy as np


def dijkstra_reference(row_ptr: np.ndarray, col_idx: np.ndarray,
                       weights: np.ndarray, root: int) -> np.ndarray:
    """Textbook binary-heap Dijkstra over a host CSR copy — the oracle the
    delta-stepping engine is property-tested against. Returns float64[n]
    distances with inf unreached; handles parallel edges, zero weights and
    disconnected graphs (non-negative weights assumed, as enforced by
    ``from_weighted_edges``)."""
    n = len(row_ptr) - 1
    dist = np.full(n, np.inf)
    dist[root] = 0.0
    heap = [(0.0, root)]
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue                   # stale entry
        for e in range(row_ptr[u], row_ptr[u + 1]):
            v = col_idx[e]
            nd = d + weights[e]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist


def to_numpy_weighted(wg) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host copies of (row_ptr, col_idx, weights) for oracle use."""
    return (np.asarray(wg.row_ptr), np.asarray(wg.col_idx),
            np.asarray(wg.weights))
