"""Serving statistics: sojourn percentiles, per-type breakdowns, TEPS.

Sojourn is measured on the service's LAYER CLOCK (one engine step per
tick), not wall time — layer counts are deterministic across machines,
which is what lets the CI bench gate p50/p99 sojourn the way it gates
TEPS. ``answered_early`` marks requests whose answer came from the
mid-sweep streaming read-out (depth-k band final) rather than waiting
for their lane to flush; the answered-early fraction is the headline
win of the streaming surface.
"""
from __future__ import annotations

import numpy as np

from repro.serving.admission import DONE, REJECTED

__all__ = ["percentile", "sojourn_summary", "summarize"]


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile of a sequence (0 on empty): the smallest
    observed value with at least ``p`` percent of the sample at or below
    it — always an actual sample, never an interpolation. (The CI gates
    pin p50/p99 sojourn; interpolated percentiles shift with sample size
    even when the observed latencies don't.)"""
    xs = np.sort(np.asarray(xs, np.float64))
    if xs.size == 0:
        return 0.0
    rank = int(np.ceil(p / 100.0 * xs.size))      # 1-based nearest rank
    return float(xs[min(max(rank, 1), xs.size) - 1])


def sojourn_summary(sojourns) -> dict:
    """mean/p50/p95/p99/max over a sequence of layer sojourns."""
    xs = np.asarray(sojourns, np.float64)
    if xs.size == 0:
        return dict(mean=0.0, p50=0.0, p95=0.0, p99=0.0, max=0)
    return dict(mean=round(float(xs.mean()), 2),
                p50=percentile(xs, 50), p95=percentile(xs, 95),
                p99=percentile(xs, 99), max=int(xs.max()))


def summarize(records, *, layers: int, wall_s: float, edges: int,
              lanes: int, ndev: int, occupancy=(),
              sssp_steps: int = 0, delta=None) -> dict:
    """Aggregate service stats over request records.

    Records are duck-typed: ``.kind``, ``.status``, ``.sojourn``,
    ``.answered_early``, ``.lanes_used`` (see ``service.RequestRecord``).
    """
    done = [r for r in records if r.status == DONE]
    rejected = sum(1 for r in records if r.status == REJECTED)
    sojourns = [r.sojourn for r in done]
    early = sum(1 for r in done if r.answered_early)

    per_type: dict[str, dict] = {}
    for r in done:
        per_type.setdefault(r.kind, []).append(r)
    per_type = {
        kind: dict(count=len(rs),
                   lanes=int(sum(r.lanes_used for r in rs)),
                   answered_early=sum(1 for r in rs if r.answered_early),
                   sojourn_layers=sojourn_summary([r.sojourn for r in rs]))
        for kind, rs in sorted(per_type.items())}

    occ = np.asarray(list(occupancy), np.float64)
    wall = max(float(wall_s), 1e-9)
    return dict(
        requests=len(records), done=len(done), rejected=rejected,
        layers=int(layers), wall_s=round(wall_s, 4),
        lanes=int(lanes), ndev=int(ndev),
        sojourn_layers=sojourn_summary(sojourns),
        answered_early=early,
        answered_early_frac=round(early / max(len(done), 1), 4),
        per_type=per_type,
        aggregate_mteps=round(edges / wall / 1e6, 2),
        mean_lane_occupancy=round(float(occ.mean()), 4) if occ.size else 0.0,
        sssp_steps=int(sssp_steps),
        delta=delta,
    )
