"""Workload traces for the analytics service.

A trace is just a list of ``AnalyticsRequest`` envelopes ordered by
``arrival`` (layer-clock ticks). ``parse_mix`` turns a ``"bfs:4,khop:2"``
spec into weights — validated against the ONE tag registry
(``analytics.api.QUERY_KINDS``), so the CLI, the bench, and wire
deserialization share a single unknown-tag error path. ``synthetic_trace``
builds a deterministic mixed-workload trace from those weights: bursts of
``burst`` requests arriving every ``every`` layers, tenants assigned
round-robin — the replayed-trace input of the serve bench and the
admission tests.
"""
from __future__ import annotations

import numpy as np

from repro.analytics.api import (AnalyticsRequest, BFSQuery, ClosenessQuery,
                                 ComponentsQuery, DiameterQuery, KHopQuery,
                                 QUERY_KINDS, ReachQuery, SSSPQuery,
                                 WeightedClosenessQuery)

__all__ = ["parse_mix", "synthetic_trace"]


def parse_mix(spec: str) -> dict[str, float]:
    """``"bfs:4,khop:2,reach:1"`` -> normalized weights by tag.

    Tags are validated against ``QUERY_KINDS`` — the same registry the
    envelope codec uses, so a typo fails here with the same vocabulary
    instead of surfacing later as a missing handler."""
    weights: dict[str, float] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, w = part.partition(":")
        kind = kind.strip()
        if kind not in QUERY_KINDS:        # the ONE unknown-tag error path
            raise ValueError(
                f"unknown query tag {kind!r} — expected one of "
                f"{sorted(QUERY_KINDS)}")
        try:
            weight = float(w) if w else 1.0
        except ValueError:
            raise ValueError(
                f"bad weight {w!r} for tag {kind!r} in mix {spec!r}")
        if weight < 0:
            raise ValueError(f"negative weight for tag {kind!r}")
        weights[kind] = weights.get(kind, 0.0) + weight
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"empty workload mix {spec!r}")
    return {k: v / total for k, v in weights.items()}


def _make_query(kind: str, rng, n: int, *, khop_k: int,
                closeness_sources: int, delta):
    root = int(rng.integers(n))
    if kind == "bfs":
        return BFSQuery(sources=(root,))
    if kind == "khop":
        return KHopQuery(sources=(root,), k=khop_k)
    if kind == "reach":
        return ReachQuery(sources=(root,), targets=(int(rng.integers(n)),))
    if kind == "closeness":
        k = min(closeness_sources, n)
        src = np.sort(rng.choice(n, size=k, replace=False))
        return ClosenessQuery(sources=tuple(int(v) for v in src),
                              chunk=k)
    if kind == "sssp":
        return SSSPQuery(sources=(root,), delta=delta)
    if kind == "components":
        return ComponentsQuery()
    if kind == "diameter":
        return DiameterQuery(seed=int(rng.integers(1 << 30)))
    if kind == "weighted_closeness":
        return WeightedClosenessQuery(sources=min(closeness_sources, n),
                                      seed=int(rng.integers(1 << 30)),
                                      delta=delta)
    raise ValueError(f"unknown query tag {kind!r} — expected one of "
                     f"{sorted(QUERY_KINDS)}")


def synthetic_trace(n: int, num: int, mix: str = "bfs", seed: int = 0,
                    *, khop_k: int = 2, closeness_sources: int = 8,
                    delta=None, burst: int = 4, every: int = 2,
                    tenants: tuple[str, ...] = ("default",)
                    ) -> list[AnalyticsRequest]:
    """Deterministic mixed-workload trace over an ``n``-vertex graph.

    Request ``i`` arrives at layer ``(i // burst) * every`` with tenant
    ``tenants[i % len(tenants)]``; kinds are drawn from the normalized
    ``mix`` weights. Same (n, num, mix, seed, knobs) -> bit-identical
    trace, which is what makes replay benches and parity tests stable.
    """
    if num < 1:
        raise ValueError(f"num must be >= 1, got {num}")
    weights = parse_mix(mix)
    kinds = sorted(weights)
    probs = np.asarray([weights[k] for k in kinds], np.float64)
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(kinds), size=num, p=probs)
    trace = []
    for i, pick in enumerate(picks):
        q = _make_query(kinds[int(pick)], rng, n, khop_k=khop_k,
                        closeness_sources=closeness_sources, delta=delta)
        trace.append(AnalyticsRequest(
            query=q, tenant=tenants[i % len(tenants)],
            arrival=(i // burst) * every))
    return trace
