"""Online serving of analytics queries — the production front door.

``AnalyticsService`` wraps the lane engines behind an admission-
controlled, optionally threaded submit/poll/result API over the unified
``AnalyticsRequest``/``AnalyticsAnswer`` envelope of
``repro.analytics.api``:

* ``service`` — the service itself: per-engine FIFO dispatch into the
  packed MS-BFS and delta-stepping tropical lane pools, mid-sweep
  streaming read-outs (depth-k khop / reach answers BEFORE lane flush,
  bit-identical to offline ``run_query`` by construction), epoch slot
  recycling, and a worker thread for async use;
* ``admission`` — the REJECTED/QUEUED/RUNNING/DONE lifecycle plus the
  bounded-queue and per-tenant-quota front door;
* ``trace`` — workload-mix parsing (validated against the ONE tag
  registry ``QUERY_KINDS``) and deterministic synthetic traces;
* ``stats`` — layer-clock sojourn percentiles (p50/p99 gated in CI by
  ``benchmarks/serve_bench.py``), answered-early fraction, TEPS.

Quick start::

    from repro.analytics import KHopQuery
    from repro.serving import AnalyticsService

    with AnalyticsService(g, slots=64, tenant_quota=8) as svc:
        rec = svc.submit(KHopQuery(sources=(3,), k=2))
        print(svc.result(rec.request.id).result.counts)
"""
from repro.serving.admission import (AdmissionController, DONE, LIFECYCLE,
                                     QUEUED, REJECTED, RUNNING)
from repro.serving.service import (AnalyticsService, RequestRecord,
                                   ServiceConfig)
from repro.serving.stats import sojourn_summary, summarize
from repro.serving.trace import parse_mix, synthetic_trace

__all__ = [
    "AdmissionController", "AnalyticsService", "DONE", "LIFECYCLE",
    "QUEUED", "REJECTED", "RequestRecord", "RUNNING", "ServiceConfig",
    "parse_mix", "sojourn_summary", "summarize", "synthetic_trace",
]
