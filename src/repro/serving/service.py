"""AnalyticsService — the async front door over the lane engines.

One service instance owns the two lane pools (the packed MS-BFS engine
and, on weighted graphs, the delta-stepping tropical engine) and serves
typed ``AnalyticsRequest`` envelopes through an explicit lifecycle::

    submit() -> REJECTED | QUEUED          (admission.AdmissionController)
    step()      QUEUED   -> RUNNING        (lanes enqueued, FIFO per engine)
                RUNNING  -> DONE           (answer collected)

The service is driven one *layer* at a time — ``step()`` dispatches
pending requests into free queue slots, advances both engines by one
layer/phase, and collects answers. Drive it synchronously
(``run_until_idle`` / ``replay``) or start the worker thread
(``start()``) and use ``submit``/``poll``/``result`` from any thread.

**Streaming read-outs** are the engine-side unlock this service exists
for: BFS depths already assigned are FINAL, so a depth-k ``KHopQuery``
is answerable the moment its lane's layer counter passes ``k`` — the
service reads the mid-sweep ``LayerReadout`` surface
(``msbfs_engine_readout``), assembles the answer through the SAME
``khop_result_from_depth`` constructor as the offline path (bit-identical
by construction), and retires the lane early
(``msbfs_engine_retire``) so the pool capacity goes back to work.
``ReachQuery`` answers stream the same way once every target vertex has
a depth. ``streaming=False`` falls back to answer-at-flush.

**Scheduling.** Each engine's queue is FIFO with head-of-line blocking:
a request that doesn't fit the remaining queue slots blocks later
requests *for that engine only* (no starvation by reordering; the other
engine keeps dispatching). When a pool drains — no running requests and
the engine idle — its queue slots recycle for the next epoch.
Whole-graph workloads (components, diameter, weighted closeness) and
sssp requests whose delta differs from the service's pinned bucket
width don't ride the shared pools at all: they execute inline through
``answer_request`` on the shared ``LaneEngine`` — the SAME handler table
as ``run_query``, so every answer the service produces is parity-checked
against the offline path by construction.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.analytics.api import (AnalyticsAnswer, AnalyticsRequest,
                                 answer_request)
from repro.analytics.closeness import (ClosenessResult,
                                       closeness_from_depths,
                                       select_sources)
from repro.analytics.engine import LaneEngine
from repro.analytics.khop import (BFSResult, ReachResult,
                                  khop_result_from_depth)
from repro.analytics.meta import QueryMeta
from repro.analytics.weighted import SSSPDistancesResult, _resolve_delta
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.serving.admission import (AdmissionController, DONE, QUEUED,
                                     REJECTED, RUNNING)
from repro.serving.stats import summarize

__all__ = ["AnalyticsService", "RequestRecord", "ServiceConfig"]


@dataclass
class ServiceConfig:
    """Knobs of one service instance.

    ``slots``/``sssp_slots`` bound the per-epoch queue capacity of the
    packed / tropical pool (requests that don't fit wait for a recycle);
    ``max_pending``/``tenant_quota`` are the front-door bounds
    (``serving.admission``). ``delta`` pins the tropical engine's bucket
    width for the WHOLE service (delta is a compile-time constant of the
    engine executable) — sssp requests asking for a different width fall
    back to the inline batch path. ``streaming=False`` disables the
    mid-sweep read-outs (answers wait for lane flush). ``telemetry`` is
    a ``repro.obs.Telemetry`` bundle: its registry backs
    ``service.metrics_text()`` and, when ``record_sweeps`` is on, every
    pool epoch records a per-layer ``SweepRecorder`` stream (None — the
    default — keeps the pools on the recorder-off fast path; a private
    registry still serves the request/sojourn metrics). ``slo`` is an
    optional ``repro.obs.slo.SLOConfig`` — the service then runs an
    ``SLOMonitor`` fed per admission/answer/tick, and its health feeds
    ``health()['ready']`` (the /readyz bit)."""
    lanes: int = 0               # packed pool width; 0 = adaptive
    slots: int = 256             # packed queue slots per epoch
    sssp_lanes: int = 0          # tropical pool width; 0 = engine default
    sssp_slots: int = 64         # tropical queue slots per epoch
    max_pending: int = 1024
    tenant_quota: int | None = None
    mode: str = "hybrid"
    probe_impl: str = "xla"
    alpha: float = ALPHA_DEFAULT
    beta: float = BETA_DEFAULT
    max_pos: int = 8
    ndev: int = 1
    delta: float | str | None = None
    streaming: bool = True
    telemetry: object = None     # repro.obs.Telemetry bundle (optional)
    slo: object = None           # repro.obs.slo.SLOConfig (optional)

    def __post_init__(self):
        if self.slots < 1 or self.sssp_slots < 1:
            raise ValueError(
                f"queue slots must be >= 1, got slots={self.slots} "
                f"sssp_slots={self.sssp_slots}")


@dataclass
class RequestRecord:
    """Service-side view of one request's lifecycle (returned by
    ``submit``; live object — fields update as the request advances)."""
    request: AnalyticsRequest
    status: str = QUEUED
    reason: str | None = None    # REJECTED only
    engine: str = ""             # "packed" | "tropical" | "batch"
    roots: np.ndarray | None = None
    slots: slice | None = None   # engine queue slots, set at dispatch
    submit_layer: int = 0
    dispatch_layer: int = -1
    answer_layer: int = -1
    answered_early: bool = False  # streamed mid-sweep, before lane flush
    answer: AnalyticsAnswer | None = None
    # kind-specific plan fields
    k: int = 0
    targets: np.ndarray | None = None
    cl_method: str = ""
    cl_seed: int | None = None
    delta: float | tuple | None = None

    @property
    def kind(self) -> str:
        return self.request.kind

    @property
    def sojourn(self) -> int:
        """Layers from submission to answer (-1 while unanswered)."""
        return (self.answer_layer - self.submit_layer
                if self.answer_layer >= 0 else -1)

    @property
    def lanes_used(self) -> int:
        return 0 if self.roots is None else int(self.roots.size)


class _PackedPool:
    """The packed MS-BFS engine behind one bounded queue of ``slots``
    root slots per epoch (host or 1-D sharded, chosen by the engine's
    partition)."""

    def __init__(self, svc: "AnalyticsService"):
        cfg, eng = svc.config, svc.engine
        from repro.core.msbfs import adaptive_lane_pool
        self.slots = cfg.slots
        self.lanes = cfg.lanes or adaptive_lane_pool(cfg.slots, eng.n,
                                                     eng.m)
        self.slot_hi = 0
        self.state = None
        self.epochs = 0
        self._edges_done = 0
        self._kind = "bfs"
        self.recorder = None     # live epoch's SweepRecorder (or None)
        self._new_recorder = svc._sweep_recorder_factory(
            "dist_msbfs" if eng.dg is not None else "msbfs")
        if eng.dg is not None:
            from repro.core import dist_msbfs as dm
            self._init = lambda: dm.dist_msbfs_engine_init(
                eng.dg, eng.mesh, cfg.slots, self.lanes)
            self._enqueue = dm.dist_msbfs_engine_enqueue
            self._step = lambda s: dm.dist_msbfs_engine_step(
                eng.dg, s, eng.mesh, cfg.mode, cfg.alpha, cfg.beta,
                cfg.max_pos, cfg.probe_impl)
            self._idle = dm.dist_msbfs_engine_idle
            self._readout = lambda s: dm.dist_msbfs_engine_readout(
                eng.dg, s)
            self._retire = lambda s, m: dm.dist_msbfs_engine_retire(
                eng.dg, s, m)
            self._result = lambda s, p: dm.dist_msbfs_engine_result(
                eng.dg, s, eng.mesh, derive_parents=p)
        else:
            from repro.core import msbfs as ms
            g = eng.g
            self._init = lambda: ms.msbfs_engine_init(
                g, capacity=cfg.slots, lanes=self.lanes)
            self._enqueue = ms.msbfs_engine_enqueue
            self._step = lambda s: ms.msbfs_engine_step(
                g, s, cfg.mode, cfg.alpha, cfg.beta, cfg.max_pos,
                cfg.probe_impl)
            self._idle = ms.msbfs_engine_idle
            self._readout = ms.msbfs_engine_readout
            self._retire = lambda s, m: ms.msbfs_engine_retire(g, s, m)
            self._result = lambda s, p: ms.msbfs_engine_result(
                g, s, derive_parents=p)

    def fits(self, k: int) -> bool:
        return self.slot_hi + k <= self.slots

    def enqueue(self, roots: np.ndarray) -> slice:
        if self.state is None:
            self.state = self._init()
            self.recorder = self._new_recorder()   # one stream per epoch
        lo = self.slot_hi
        self.state = self._enqueue(self.state, roots)
        self.slot_hi += int(roots.size)
        return slice(lo, self.slot_hi)

    def step(self) -> bool:
        if self.state is not None and not self._idle(self.state):
            if self.recorder is None:
                self.state = self._step(self.state)
            else:
                from repro.obs.sweeplog import record_step, snapshot_state
                pre = snapshot_state(self.state, self._kind)
                self.state = self._step(self.state)
                record_step(self.recorder, pre, self.state, self._kind)
            return True
        return False

    def idle(self) -> bool:
        return self.state is None or self._idle(self.state)

    def readout(self):
        return self._readout(self.state)

    def retire(self, lane_mask: np.ndarray) -> None:
        self.state = self._retire(self.state, lane_mask)

    def result(self, derive_parents: bool = False):
        """``MSBFSResult`` over the CURRENT epoch's answered slots (the
        validation surface — parents live here, not in the answers)."""
        if self.state is None:
            raise RuntimeError("packed pool has no live epoch")
        return self._result(self.state, derive_parents)

    def _edges_now(self) -> int:
        if self.state is None or self.slot_hi == 0:
            return 0
        return int(
            np.asarray(self.state.out_edges[:self.slot_hi]).sum()) // 2

    def edges(self) -> int:
        """Undirected edges traversed across all epochs so far."""
        return self._edges_done + self._edges_now()

    def recycle(self) -> None:
        self._edges_done += self._edges_now()
        self.state = None
        self.recorder = None     # the telemetry bundle keeps the stream
        self.slot_hi = 0
        self.epochs += 1

    def active_lanes(self) -> int:
        if self.state is None:
            return 0
        return int((np.asarray(self.state.lane_qidx)
                    < self.state.capacity).sum())


class _TropicalPool:
    """The delta-stepping SSSP engine behind its own bounded queue.
    Delta is pinned per service (a compile-time constant); answers are
    collected at lane flush (``out_steps > 0``)."""

    def __init__(self, svc: "AnalyticsService"):
        cfg, eng = svc.config, svc.engine
        from repro.traversal.sssp import DEFAULT_LANES
        self.slots = cfg.sssp_slots
        self.lanes = max(1, min(cfg.sssp_lanes or DEFAULT_LANES,
                                cfg.sssp_slots))
        self.delta = svc.delta
        self.slot_hi = 0
        self.state = None
        self.epochs = 0
        self._steps_done = 0
        self._kind = "sssp"
        self.recorder = None
        self._new_recorder = svc._sweep_recorder_factory(
            "dist_sssp" if eng.dwg is not None else "sssp")
        if eng.dwg is not None:
            from repro.core import dist_sssp as ds
            dwg = eng.dwg
            self._trim = dwg.n_orig
            self._init = lambda: ds.dist_sssp_engine_init(
                dwg, eng.mesh, cfg.sssp_slots, self.lanes)
            self._enqueue = ds.dist_sssp_engine_enqueue
            self._step = lambda s: ds.dist_sssp_engine_step(
                dwg, s, eng.mesh, self.delta, cfg.max_pos,
                cfg.probe_impl)
            self._idle = ds.dist_sssp_engine_idle
        else:
            from repro.traversal import sssp as ts
            wg = eng.wg
            self._trim = eng.n
            self._init = lambda: ts.sssp_engine_init(
                wg, cfg.sssp_slots, self.lanes)
            self._enqueue = ts.sssp_engine_enqueue
            self._step = lambda s: ts.sssp_engine_step(
                wg, s, self.delta, cfg.max_pos, cfg.probe_impl)
            self._idle = ts.sssp_engine_idle

    def fits(self, k: int) -> bool:
        return self.slot_hi + k <= self.slots

    def enqueue(self, roots: np.ndarray) -> slice:
        if self.state is None:
            self.state = self._init()
            self.recorder = self._new_recorder()   # one stream per epoch
        lo = self.slot_hi
        self.state = self._enqueue(self.state, roots)
        self.slot_hi += int(roots.size)
        return slice(lo, self.slot_hi)

    def step(self) -> bool:
        if self.state is not None and not self._idle(self.state):
            if self.recorder is None:
                self.state = self._step(self.state)
            else:
                from repro.obs.sweeplog import record_step, snapshot_state
                pre = snapshot_state(self.state, self._kind)
                self.state = self._step(self.state)
                record_step(self.recorder, pre, self.state, self._kind)
            return True
        return False

    def idle(self) -> bool:
        return self.state is None or self._idle(self.state)

    def out_dist_cols(self, sl: slice) -> np.ndarray:
        return np.asarray(self.state.out_dist)[:self._trim, sl]

    def _steps_now(self) -> int:
        return 0 if self.state is None else int(self.state.sweep_steps)

    def steps(self) -> int:
        return self._steps_done + self._steps_now()

    def recycle(self) -> None:
        self._steps_done += self._steps_now()
        self.state = None
        self.recorder = None
        self.slot_hi = 0
        self.epochs += 1

    def active_lanes(self) -> int:
        if self.state is None:
            return 0
        return int((np.asarray(self.state.lane_qidx)
                    < self.state.capacity).sum())


# kinds that ride the packed pool as plain lane batches
_PACKED_KINDS = ("bfs", "khop", "reach", "closeness")


class AnalyticsService:
    """Async analytics server over one graph (see module docstring)."""

    def __init__(self, g, config: ServiceConfig | None = None, **overrides):
        if config is None:
            config = ServiceConfig(**overrides)
        elif overrides:
            raise ValueError(
                f"pass a ServiceConfig OR overrides, not both — got "
                f"config plus {sorted(overrides)}")
        self.config = config
        self.telemetry = config.telemetry
        # metrics always work (metrics_text() on a bare service exposes
        # request/sojourn counters); sweep recording needs a telemetry
        # bundle with record_sweeps on
        if self.telemetry is not None:
            self._registry = self.telemetry.registry
        else:
            from repro.obs.metrics import MetricsRegistry
            self._registry = MetricsRegistry()
        self.engine = LaneEngine(
            g, ndev=config.ndev, lanes=(config.lanes or None),
            mode=config.mode, alpha=config.alpha, beta=config.beta,
            max_pos=config.max_pos, probe_impl=config.probe_impl,
            telemetry=self.telemetry)   # inline batch sweeps record too
        # the service-wide tropical bucket width, resolved ONCE (the
        # engine executable compiles against it)
        self.delta = (_resolve_delta(self.engine, config.delta)
                      if self.engine.weighted else None)
        self._packed: _PackedPool | None = None
        self._tropical: _TropicalPool | None = None
        self._admission = AdmissionController(config.max_pending,
                                              config.tenant_quota)
        self._records: dict[str, RequestRecord] = {}
        self._pending: deque[RequestRecord] = deque()
        self._running: dict[str, list[RequestRecord]] = {
            "packed": [], "tropical": []}
        self._layer = 0
        self._wall = 0.0
        self._occupancy: list[int] = []
        self._cv = threading.Condition()
        self._thread: threading.Thread | None = None
        self._stopping = False
        if config.slo is not None:
            from repro.obs.slo import SLOMonitor
            self.slo: SLOMonitor | None = SLOMonitor(config.slo,
                                                     self._registry)
        else:
            self.slo = None

    # -- telemetry ----------------------------------------------------------

    def _sweep_recorder_factory(self, engine_name: str):
        """Per-epoch recorder factory handed to the pools: each call is
        one fresh ``SweepRecorder`` stream (or None when the service has
        no telemetry bundle / sweep recording is off — the pools then
        never touch ``repro.obs.sweeplog``)."""
        if self.telemetry is None:
            return lambda: None
        tel, cfg = self.telemetry, self.config
        return lambda: tel.recorder(engine_name, ndev=cfg.ndev,
                                    source="service")

    def metrics_text(self) -> str:
        """Prometheus text exposition of the service's registry (the
        telemetry bundle's registry when one was configured)."""
        from repro.obs.metrics import metrics_text
        return metrics_text(self._registry)

    def trace_events(self) -> list:
        """Chrome trace-event list of every request lifecycle seen so
        far (QUEUED/RUNNING spans + early-readout markers on the layer
        clock), plus one process per recorded sweep when a telemetry
        bundle is recording — ready for ``obs.write_chrome_trace``."""
        from repro.obs.traceviz import (service_trace_events,
                                        sweep_trace_events)
        with self._cv:
            events = service_trace_events(list(self._records.values()))
            sweeps = list(self.telemetry.sweeps) if self.telemetry else []
        for i, rec in enumerate(sweeps):
            events.extend(sweep_trace_events(rec, pid=10 + i))
        return events

    def _count_request(self, kind: str, status: str) -> None:
        self._registry.counter(
            "service_requests_total", "requests by admission outcome",
            ("kind", "status")).labels(kind=kind, status=status).inc()

    # -- planning -----------------------------------------------------------

    def _pool(self, name: str):
        if name == "packed":
            if self._packed is None:
                self._packed = _PackedPool(self)
            return self._packed
        if self._tropical is None:
            self._tropical = _TropicalPool(self)
        return self._tropical

    def _plan(self, rec: RequestRecord) -> None:
        """Classify the request: which engine, which lanes. Raises on
        requests the service cannot serve at all (invalid query /
        weighted workload on an unweighted graph)."""
        q = rec.request.query
        kind = rec.kind
        if kind == "sssp":
            if not self.engine.weighted:
                raise ValueError(
                    "sssp request on an unweighted service — build the "
                    "service from a WeightedCSRGraph (e.g. "
                    "graph.generator.rmat_weighted_graph)")
            rec.roots = np.asarray(q.sources, np.int32).reshape(-1)
            rec.delta = _resolve_delta(self.engine, q.delta)
            # a foreign delta would need its own engine executable —
            # answer it inline instead of recompiling the shared pool
            if (rec.delta == self.delta
                    and rec.roots.size <= self.config.sssp_slots):
                rec.engine = "tropical"
            else:
                rec.engine = "batch"
            return
        if kind in _PACKED_KINDS:
            if kind == "closeness":
                src, method = select_sources(self.engine.n, q.sources,
                                             q.seed)
                rec.roots = src
                rec.cl_method = method
                rec.cl_seed = None if method == "exact" else q.seed
            elif kind == "khop":
                if q.k < 0:
                    raise ValueError(f"k must be >= 0, got {q.k}")
                rec.roots = np.asarray(q.sources, np.int32).reshape(-1)
                rec.k = int(q.k)
            elif kind == "reach":
                rec.roots = np.asarray(q.sources, np.int32).reshape(-1)
                rec.targets = (rec.roots if q.targets is None
                               else np.asarray(q.targets,
                                               np.int32).reshape(-1))
            else:
                rec.roots = np.asarray(q.sources, np.int32).reshape(-1)
            if rec.roots.size < 1:
                raise ValueError("need at least one source")
            rec.engine = ("packed" if rec.roots.size <= self.config.slots
                          else "batch")
            return
        rec.engine = "batch"       # components / diameter / w-closeness

    # -- front door ---------------------------------------------------------

    def submit(self, request) -> RequestRecord:
        """Admit one request (an ``AnalyticsRequest`` or a bare query).
        Returns its live ``RequestRecord`` — status is ``QUEUED`` or
        ``REJECTED`` (with ``reason``) immediately; invalid requests
        raise instead of entering the lifecycle."""
        if not isinstance(request, AnalyticsRequest):
            request = AnalyticsRequest(query=request)
        with self._cv:
            if request.id in self._records:
                raise ValueError(f"duplicate request id {request.id!r}")
            rec = RequestRecord(request=request,
                                submit_layer=self._layer)
            self._plan(rec)
            ok, reason = self._admission.admit(request.tenant)
            if not ok:
                rec.status = REJECTED
                rec.reason = reason
            else:
                self._pending.append(rec)
            self._count_request(rec.kind, rec.status)
            if self.slo is not None:
                self.slo.observe_admission(ok)
            self._records[request.id] = rec
            self._cv.notify_all()
            return rec

    def poll(self, request_id: str) -> str:
        """Lifecycle status of a request id."""
        with self._cv:
            return self._records[request_id].status

    def record(self, request_id: str) -> RequestRecord:
        with self._cv:
            return self._records[request_id]

    def result(self, request_id: str,
               timeout: float | None = None) -> AnalyticsAnswer:
        """Block until the request is answered; raises on rejection or
        timeout. With no worker thread running the caller must drive
        ``step()`` itself, so waiting would deadlock — that raises too."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            rec = self._records[request_id]
            while rec.status not in (DONE, REJECTED):
                if self._thread is None or not self._thread.is_alive():
                    raise RuntimeError(
                        "service has no worker thread — call start() "
                        "or drive step()/run_until_idle() directly")
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"request {request_id} still {rec.status} after "
                        f"{timeout}s")
                self._cv.wait(0.05 if remaining is None
                              else min(0.05, remaining))
            if rec.status == REJECTED:
                raise RuntimeError(
                    f"request {request_id} rejected: {rec.reason}")
            return rec.answer

    # -- scheduler ----------------------------------------------------------

    def busy(self) -> bool:
        with self._cv:
            return self._busy_locked()

    def _busy_locked(self) -> bool:
        return bool(self._pending or self._running["packed"]
                    or self._running["tropical"])

    def step(self) -> bool:
        """One scheduler tick: dispatch, advance both engines one layer,
        collect answers. Returns True while there is work in flight."""
        with self._cv:
            t0 = time.perf_counter()
            self._layer += 1
            self._dispatch()
            if self._packed is not None:
                self._packed.step()
            if self._tropical is not None:
                self._tropical.step()
            self._collect_packed()
            self._collect_tropical()
            occ = 0
            if self._packed is not None:
                occ += self._packed.active_lanes()
            if self._tropical is not None:
                occ += self._tropical.active_lanes()
            self._occupancy.append(occ)
            self._registry.counter(
                "service_layers_total", "scheduler ticks").inc()
            self._registry.gauge(
                "service_occupancy_lanes",
                "active engine lanes after the tick").set(occ)
            if self.slo is not None:
                self.slo.observe_queue_depth(self._admission.pending)
                self.slo.evaluate()
            self._wall += time.perf_counter() - t0
            self._cv.notify_all()
            return self._busy_locked()

    def _dispatch(self) -> None:
        still: deque[RequestRecord] = deque()
        blocked: set[str] = set()
        for rec in self._pending:
            if rec.engine == "batch":
                self._run_batch(rec)
                continue
            if rec.engine in blocked:
                still.append(rec)     # FIFO per engine: no overtaking
                continue
            pool = self._pool(rec.engine)
            if (not pool.fits(rec.roots.size)
                    and not self._running[rec.engine] and pool.idle()
                    and pool.slot_hi > 0):
                pool.recycle()        # drained epoch: slots go back to work
            if pool.fits(rec.roots.size):
                rec.slots = pool.enqueue(rec.roots)
                rec.status = RUNNING
                rec.dispatch_layer = self._layer
                self._running[rec.engine].append(rec)
                self._admission.on_dispatch(rec.request.tenant)
            else:
                blocked.add(rec.engine)
                still.append(rec)
        self._pending = still

    def _run_batch(self, rec: RequestRecord) -> None:
        """Inline path for whole-graph / foreign-delta workloads: the
        SAME ``answer_request`` the offline dispatcher uses."""
        rec.status = RUNNING
        rec.dispatch_layer = self._layer
        self._admission.on_dispatch(rec.request.tenant)
        self._finish(rec, answer_request(self.engine, rec.request),
                     early=False)

    def _finish(self, rec: RequestRecord, answer: AnalyticsAnswer,
                early: bool) -> None:
        rec.answer = answer
        rec.answer_layer = self._layer
        rec.answered_early = early
        rec.status = DONE
        self._admission.on_done(rec.request.tenant)
        self._registry.counter(
            "service_answers_total", "answers by kind",
            ("kind", "early")).labels(
                kind=rec.kind, early=str(early).lower()).inc()
        self._registry.histogram(
            "service_sojourn_layers", "submit-to-answer layers",
            ("kind",)).labels(kind=rec.kind).observe(rec.sojourn)
        if self.slo is not None:
            self.slo.observe_sojourn(rec.sojourn)

    # -- answer collection --------------------------------------------------

    def _collect_packed(self) -> None:
        running = self._running["packed"]
        if not running:
            return
        pool = self._packed
        ro = pool.readout()
        retire: list[int] = []
        for rec in running:
            got = self._try_answer_packed(rec, ro)
            if got is None:
                continue
            answer, early, live_lanes = got
            self._finish(rec, answer, early)
            retire.extend(live_lanes)
        if retire:
            mask = np.zeros(pool.lanes, bool)
            mask[retire] = True
            pool.retire(mask)
        self._running["packed"] = [r for r in running if r.status != DONE]

    def _try_answer_packed(self, rec: RequestRecord, ro):
        """(answer, answered_early, live_lanes_to_retire) when the
        request is answerable NOW, else None. Streamed answers read the
        live depth band (final by BFS depth monotonicity); flushed slots
        read their output columns."""
        sl = rec.slots
        out_ok = ro.out_layers[sl] > 0
        kind = rec.kind
        streaming = self.config.streaming
        if streaming and kind in ("khop", "reach"):
            cols, live, layers = [], [], 0
            for j, q in enumerate(range(sl.start, sl.stop)):
                if out_ok[j]:
                    cols.append(ro.out_depth[:, q])
                    layers = max(layers, int(ro.out_layers[q]))
                    continue
                lane = ro.lane_of_slot(q)
                if lane < 0:
                    return None           # still waiting in the queue
                col = ro.depth[:, lane]
                if kind == "khop":
                    if int(ro.lane_layer[lane]) < rec.k:
                        return None       # depth-k band not final yet
                else:
                    if not (col[rec.targets] >= 0).all():
                        return None       # some target still undiscovered
                cols.append(col)
                live.append(lane)
                layers = max(layers, int(ro.lane_layer[lane]))
            depth = np.stack(cols, axis=1)
            early = bool(live)
            meta = QueryMeta(
                kind=kind, layers=layers, lanes=rec.lanes_used,
                ndev=self.config.ndev,
                extra=(dict(depth_partial=early) if early else {}))
            if kind == "khop":
                res = khop_result_from_depth(rec.roots, rec.k, depth,
                                             meta)
            else:
                res = ReachResult(
                    sources=rec.roots, targets=rec.targets,
                    hops=depth[rec.targets].T.astype(np.int64), meta=meta)
            return (AnalyticsAnswer(rec.request.id, res, res.meta),
                    early, live)
        if not out_ok.all():
            return None                   # flush path: wait for every lane
        depth = ro.out_depth[:, sl]
        num_layers = ro.out_layers[sl].astype(np.int64)
        meta = QueryMeta(kind=kind, layers=int(num_layers.max()),
                         lanes=rec.lanes_used, ndev=self.config.ndev)
        if kind == "bfs":
            res = BFSResult(
                sources=rec.roots, depth=depth, num_layers=num_layers,
                reached=(depth >= 0).sum(axis=0).astype(np.int64),
                meta=meta)
        elif kind == "khop":
            res = khop_result_from_depth(rec.roots, rec.k, depth, meta)
        elif kind == "reach":
            res = ReachResult(sources=rec.roots, targets=rec.targets,
                              hops=depth[rec.targets].T.astype(np.int64),
                              meta=meta)
        else:
            c = closeness_from_depths(depth, self.engine.n)
            res = ClosenessResult(
                closeness=c, method=rec.cl_method,
                num_sources=int(rec.roots.size), seed=rec.cl_seed,
                meta=QueryMeta(kind="closeness",
                               layers=int(num_layers.max()),
                               lanes=rec.lanes_used,
                               ndev=self.config.ndev,
                               extra=dict(chunk=int(rec.roots.size))))
        return AnalyticsAnswer(rec.request.id, res, res.meta), False, []

    def _collect_tropical(self) -> None:
        running = self._running["tropical"]
        if not running:
            return
        pool = self._tropical
        out_steps = np.asarray(pool.state.out_steps)
        out_trunc = np.asarray(pool.state.out_truncated)
        for rec in running:
            sl = rec.slots
            steps = out_steps[sl]
            if not (steps > 0).all():
                continue
            trunc = out_trunc[sl]
            delta = (pool.delta if isinstance(pool.delta, tuple)
                     else float(pool.delta))
            res = SSSPDistancesResult(
                sources=rec.roots, dist=pool.out_dist_cols(sl),
                delta=delta, steps=steps.astype(np.int32),
                truncated_lanes=trunc,
                meta=QueryMeta(kind="sssp", layers=int(steps.max()),
                               truncated=bool(trunc.any()),
                               lanes=rec.lanes_used,
                               ndev=self.config.ndev,
                               extra=dict(grid=None, compress=False,
                                          delta=delta)))
            self._finish(rec, AnalyticsAnswer(rec.request.id, res,
                                              res.meta), early=False)
        self._running["tropical"] = [r for r in running
                                     if r.status != DONE]

    def packed_result(self, derive_parents: bool = False):
        """``MSBFSResult`` over the packed pool's CURRENT epoch — the
        validation surface (BFS-tree parents live here; answers carry
        depths only). Raises when the pool has no live epoch; note a
        recycled epoch's outputs are gone."""
        if self._packed is None:
            raise RuntimeError("service has served no packed requests")
        return self._packed.result(derive_parents)

    # -- drivers ------------------------------------------------------------

    def warmup(self, packed: bool = True,
               tropical: bool | None = None) -> None:
        """Compile the step executables on throwaway states so the
        serving window measures traversal, not one-time XLA compilation
        (the graph500 harness discipline)."""
        import jax
        if packed:
            pool = self._pool("packed")
            st = pool._enqueue(pool._init(),
                               np.zeros(1, np.int32))
            jax.block_until_ready(pool._step(st).out_depth)
        if tropical is None:
            tropical = self.engine.weighted
        if tropical:
            pool = self._pool("tropical")
            st = pool._enqueue(pool._init(),
                               np.zeros(1, np.int32))
            jax.block_until_ready(pool._step(st).out_dist)

    def run_until_idle(self, max_layers: int = 100_000) -> dict:
        """Drive ``step()`` until every admitted request is DONE; returns
        ``stats()``."""
        while self.busy():
            self.step()
            if self._layer > max_layers:
                raise RuntimeError(
                    f"service still busy after {max_layers} layers — "
                    f"engine wedged or max_layers too small")
        return self.stats()

    def replay(self, trace, max_layers: int = 100_000) -> dict:
        """Replay a trace of ``AnalyticsRequest`` envelopes on the layer
        clock: requests become visible at their ``arrival`` tick, the
        service steps until drained. Returns ``stats()``."""
        trace = sorted(trace, key=lambda r: r.arrival)
        i = 0
        while i < len(trace) or self.busy():
            while i < len(trace) and trace[i].arrival <= self._layer:
                self.submit(trace[i])
                i += 1
            self.step()
            if self._layer > max_layers:
                raise RuntimeError(
                    f"replay still busy after {max_layers} layers")
        return self.stats()

    def stats(self) -> dict:
        with self._cv:
            packed = self._packed
            return summarize(
                list(self._records.values()), layers=self._layer,
                wall_s=self._wall,
                edges=packed.edges() if packed else 0,
                lanes=packed.lanes if packed else (self.config.lanes or 0),
                ndev=self.config.ndev, occupancy=self._occupancy,
                sssp_steps=(self._tropical.steps()
                            if self._tropical else 0),
                delta=(None if self._tropical is None else
                       (self.delta if isinstance(self.delta, tuple)
                        else float(self.delta))))

    # -- health -------------------------------------------------------------

    def worker_alive(self) -> bool:
        """True while the background worker thread is up and not asked
        to stop. Lock-free — safe to call from a liveness probe even
        while a long jitted layer holds the scheduler lock."""
        t = self._thread
        return t is not None and t.is_alive() and not self._stopping

    def health(self) -> dict:
        """JSON-ready liveness + readiness view (the /healthz and
        /readyz payload). Deliberately lock-free: every field is a
        single-attribute read or an SLO ``peek()`` (non-mutating), so a
        health probe never waits on the scheduler lock."""
        alive = self.worker_alive()
        depth = self._admission.pending
        queue_ok = depth < self.config.max_pending
        out = dict(alive=alive, stopping=self._stopping,
                   queue_depth=depth,
                   max_pending=self.config.max_pending,
                   queue_ok=queue_ok, layer=self._layer)
        slo_ok = True
        if self.slo is not None:
            out["slo"] = snap = self.slo.peek()
            slo_ok = snap["healthy"]
        out["ready"] = bool(alive and queue_ok and slo_ok)
        return out

    # -- worker thread ------------------------------------------------------

    def start(self) -> "AnalyticsService":
        """Start the background worker: steps whenever work is in
        flight, sleeps otherwise. Idempotent."""
        with self._cv:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stopping = False
            self._thread = threading.Thread(
                target=self._serve_loop, name="analytics-service",
                daemon=True)
            self._thread.start()
        return self

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                while not self._stopping and not self._busy_locked():
                    self._cv.wait(0.05)
                if self._stopping:
                    return
            self.step()

    def stop(self) -> None:
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=60.0)
            self._thread = None

    def __enter__(self) -> "AnalyticsService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
