"""Admission control for the analytics service.

Every request moves through ONE explicit lifecycle::

    submit -> REJECTED                  (front door said no — final)
           -> QUEUED -> RUNNING -> DONE (admitted, dispatched, answered)

``AdmissionController`` owns the two front-door bounds:

* ``max_pending`` — total requests sitting in the service's pending
  queue (QUEUED). When the queue is full, new submissions are REJECTED
  immediately instead of growing an unbounded backlog — backpressure is
  explicit and observable, never an OOM.
* ``tenant_quota`` — per-tenant cap on in-flight requests
  (QUEUED + RUNNING). One chatty tenant saturating the lane pool cannot
  starve the others: its submissions bounce with a quota reason while
  other tenants keep admitting.

The controller is pure bookkeeping (no locks — the service serializes
calls under its own lock) and deterministic, so admission decisions in a
replayed trace reproduce exactly.
"""
from __future__ import annotations

from collections import Counter

__all__ = ["AdmissionController", "DONE", "LIFECYCLE", "QUEUED",
           "REJECTED", "RUNNING"]

# request lifecycle states (wire-stable strings)
REJECTED = "REJECTED"
QUEUED = "QUEUED"
RUNNING = "RUNNING"
DONE = "DONE"
LIFECYCLE = (REJECTED, QUEUED, RUNNING, DONE)


class AdmissionController:
    """Bounded-queue + per-tenant-quota admission decisions."""

    def __init__(self, max_pending: int = 1024,
                 tenant_quota: int | None = None):
        if max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1, got {max_pending}")
        if tenant_quota is not None and tenant_quota < 1:
            raise ValueError(
                f"tenant_quota must be >= 1 (or None), got {tenant_quota}")
        self.max_pending = int(max_pending)
        self.tenant_quota = None if tenant_quota is None else int(
            tenant_quota)
        self._pending = 0            # QUEUED
        self._inflight = Counter()   # per-tenant QUEUED + RUNNING
        self.rejected = 0

    def admit(self, tenant: str) -> tuple[bool, str | None]:
        """Decide one submission. Returns ``(True, None)`` and takes the
        QUEUED + in-flight slots, or ``(False, reason)``."""
        if self._pending >= self.max_pending:
            self.rejected += 1
            return False, (f"queue full: {self._pending} pending >= "
                           f"max_pending={self.max_pending}")
        if (self.tenant_quota is not None
                and self._inflight[tenant] >= self.tenant_quota):
            self.rejected += 1
            return False, (f"tenant {tenant!r} quota: "
                           f"{self._inflight[tenant]} in flight >= "
                           f"tenant_quota={self.tenant_quota}")
        self._pending += 1
        self._inflight[tenant] += 1
        return True, None

    def on_dispatch(self, tenant: str) -> None:
        """QUEUED -> RUNNING: frees a pending-queue slot (the tenant's
        in-flight slot stays held until the answer lands)."""
        self._pending -= 1

    def on_done(self, tenant: str) -> None:
        """RUNNING (or batch-inline) -> DONE: frees the tenant slot."""
        self._inflight[tenant] -= 1
        if self._inflight[tenant] <= 0:
            del self._inflight[tenant]

    @property
    def pending(self) -> int:
        return self._pending

    def inflight(self, tenant: str) -> int:
        return self._inflight[tenant]
