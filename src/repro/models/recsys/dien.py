"""DIEN (Zhou et al., arXiv:1809.03672) — Deep Interest Evolution Network.

Pipeline: sparse id features -> embedding lookup (huge tables; JAX has no
EmbeddingBag so bags are take + segment ops — the shared gather/scatter
substrate) -> interest extraction GRU over the behaviour sequence ->
attention vs target -> interest evolution AUGRU (attention scales the update
gate) -> concat features -> MLP(200, 80) -> logit.

Aux loss (paper §4.2): next-behaviour discrimination on GRU hidden states
with provided negatives.

Serving heads:
  * ``dien_forward``      — CTR probability (serve_p99 / serve_bulk shapes);
  * ``dien_retrieval``    — user vector vs N candidate item embeddings as one
    batched matmul + top-k (retrieval_cand shape; never a loop).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple = (200, 80)
    n_items: int = 1_000_000
    n_cats: int = 1_000
    n_profiles: int = 100_000
    profile_bag: int = 8          # multi-hot profile ids per user
    use_aux_loss: bool = True
    dtype: str = "float32"

    @property
    def behav_dim(self) -> int:
        return 2 * self.embed_dim  # item ++ category


def _gru_init(key, d_in, d_h):
    ks = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(jnp.float32(d_in + d_h))
    return {
        "wx": jax.random.normal(ks[0], (d_in, 3 * d_h)) * s,
        "wh": jax.random.normal(ks[1], (d_h, 3 * d_h)) * s,
        "b": jnp.zeros((3 * d_h,)),
    }


def _gru_cell(p, h, x, att=None):
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    xz, xr, xn = jnp.split(gx, 3, -1)
    hz, hr, hn = jnp.split(gh, 3, -1)
    z = jax.nn.sigmoid(xz + hz)
    r = jax.nn.sigmoid(xr + hr)
    n = jnp.tanh(xn + r * hn)
    if att is not None:                 # AUGRU: attention scales update gate
        z = z * att[:, None]
    return (1.0 - z) * h + z * n


def init_dien(key, cfg: DIENConfig):
    ks = jax.random.split(key, 8)
    e = cfg.embed_dim
    params = {
        "item_table": jax.random.normal(ks[0], (cfg.n_items, e)) * 0.05,
        "cat_table": jax.random.normal(ks[1], (cfg.n_cats, e)) * 0.05,
        "profile_table": jax.random.normal(ks[2], (cfg.n_profiles, e)) * 0.05,
        "gru": _gru_init(ks[3], cfg.behav_dim, cfg.gru_dim),
        "augru": _gru_init(ks[4], cfg.behav_dim, cfg.gru_dim),
        "att": L.mlp_init(ks[5], [cfg.gru_dim + cfg.behav_dim, 36, 1],
                          jnp.float32)[0],
        "mlp": L.mlp_init(ks[6], [cfg.gru_dim + 2 * cfg.behav_dim + e,
                                  *cfg.mlp_dims, 1], jnp.float32)[0],
        "user_proj": L.dense(ks[7], cfg.gru_dim, e, jnp.float32,
                             (None, "embed"))[0],
    }
    specs = {
        "item_table": ("vocab", "embed"),
        "cat_table": (None, "embed"),
        "profile_table": ("vocab", "embed"),
        "gru": {"wx": (None, "mlp"), "wh": (None, "mlp"), "b": ("mlp",)},
        "augru": {"wx": (None, "mlp"), "wh": (None, "mlp"), "b": ("mlp",)},
        "att": [{"w": (None, None), "b": (None,)},
                {"w": (None, None), "b": (None,)}],
        "mlp": [{"w": (None, "mlp"), "b": ("mlp",)},
                {"w": ("mlp", "mlp"), "b": ("mlp",)},
                {"w": ("mlp", None), "b": (None,)}],
        "user_proj": {"w": (None, "embed")},
    }
    return params, specs


def embedding_bag(table, ids, mask, op: str = "mean"):
    """ids int32[B, M], mask bool[B, M] -> [B, e]. take + masked reduce —
    the manual EmbeddingBag (no native op in JAX)."""
    rows = table[ids]                                   # [B, M, e]
    rows = jnp.where(mask[..., None], rows, 0.0)
    s = rows.sum(axis=1)
    if op == "sum":
        return s
    return s / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)


def _behaviour_embed(params, items, cats):
    return jnp.concatenate([params["item_table"][items],
                            params["cat_table"][cats]], axis=-1)


def _interest_states(params, behav, mask, cfg: DIENConfig):
    """GRU over time: behav [B, T, 2e] -> states [B, T, H]."""
    b = behav.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), behav.dtype)

    def step(h, xs):
        x, m = xs
        h2 = _gru_cell(params["gru"], h, x)
        h2 = jnp.where(m[:, None], h2, h)
        return h2, h2

    xs = (jnp.swapaxes(behav, 0, 1), jnp.swapaxes(mask, 0, 1))
    _, states = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(states, 0, 1)                   # [B, T, H]


def _evolution(params, states, behav, target, mask, cfg: DIENConfig):
    """Attention vs target + AUGRU roll. Returns final interest [B, H]."""
    b, t, _ = states.shape
    tgt = jnp.broadcast_to(target[:, None, :], (b, t, target.shape[-1]))
    att_in = jnp.concatenate([states, tgt], axis=-1)
    scores = L.apply_mlp(params["att"], att_in, act="sigmoid")[..., 0]
    scores = jnp.where(mask, scores, -1e30)
    att = jax.nn.softmax(scores, axis=1)                # [B, T]

    h0 = jnp.zeros((b, cfg.gru_dim), states.dtype)

    def step(h, xs):
        x, a, m = xs
        h2 = _gru_cell(params["augru"], h, x, att=a)
        h2 = jnp.where(m[:, None], h2, h)
        return h2, None

    xs = (jnp.swapaxes(behav, 0, 1), jnp.swapaxes(att, 0, 1),
          jnp.swapaxes(mask, 0, 1))
    hT, _ = jax.lax.scan(step, h0, xs)
    return hT


def dien_user_state(params, batch, cfg: DIENConfig):
    """Shared trunk -> (final interest [B,H], feature vector [B,F])."""
    behav = _behaviour_embed(params, batch["hist_items"], batch["hist_cats"])
    mask = batch["hist_mask"]
    states = _interest_states(params, behav, mask, cfg)
    target = _behaviour_embed(params, batch["target_item"],
                              batch["target_cat"])
    hT = _evolution(params, states, behav, target, mask, cfg)
    pooled = jnp.where(mask[..., None], behav, 0.0).sum(1) / jnp.maximum(
        mask.sum(1, keepdims=True), 1.0)
    profile = embedding_bag(params["profile_table"], batch["profile_ids"],
                            batch["profile_mask"])
    feats = jnp.concatenate([hT, target, pooled, profile], axis=-1)
    return hT, states, behav, feats


def dien_forward(params, batch, cfg: DIENConfig):
    """CTR logit [B]."""
    _, _, _, feats = dien_user_state(params, batch, cfg)
    return L.apply_mlp(params["mlp"], feats, act="relu")[:, 0]


def _aux_loss(params, states, batch, cfg: DIENConfig):
    """Next-behaviour discrimination: sigma(h_t . e_{t+1}) vs negatives."""
    pos = _behaviour_embed(params, batch["hist_items"], batch["hist_cats"])
    neg = _behaviour_embed(params, batch["neg_items"], batch["hist_cats"])
    h = states[:, :-1]                                   # [B, T-1, H]
    proj = L.apply_dense(params["user_proj"], h)         # [B, T-1, e]
    # score against item part of next behaviour embedding
    pos_it = pos[:, 1:, :cfg.embed_dim]
    neg_it = neg[:, 1:, :cfg.embed_dim]
    m = batch["hist_mask"][:, 1:].astype(jnp.float32)
    lp = jax.nn.log_sigmoid(jnp.sum(proj * pos_it, -1))
    ln = jax.nn.log_sigmoid(-jnp.sum(proj * neg_it, -1))
    return -jnp.sum((lp + ln) * m) / jnp.maximum(jnp.sum(m), 1.0)


def dien_loss(params, batch, cfg: DIENConfig):
    hT, states, behav, feats = dien_user_state(params, batch, cfg)
    logit = L.apply_mlp(params["mlp"], feats, act="relu")[:, 0]
    y = batch["labels"].astype(jnp.float32)
    bce = -jnp.mean(y * jax.nn.log_sigmoid(logit)
                    + (1 - y) * jax.nn.log_sigmoid(-logit))
    aux = (_aux_loss(params, states, batch, cfg)
           if cfg.use_aux_loss and "neg_items" in batch else 0.0)
    return bce + 0.5 * aux, {"bce": bce, "aux": aux}


def dien_retrieval(params, batch, cfg: DIENConfig, top_k: int = 100):
    """Score one/few users against n_candidates items: batched matmul.

    batch['candidate_ids'] int32[Nc] — rows of the item table to score.
    Returns (scores [B, Nc], top-k ids [B, k]).
    """
    hT, _, _, _ = dien_user_state(params, batch, cfg)
    user_vec = L.apply_dense(params["user_proj"], hT)    # [B, e]
    cand = params["item_table"][batch["candidate_ids"]]  # [Nc, e]
    scores = user_vec @ cand.T                           # [B, Nc]
    _, top = jax.lax.top_k(scores, top_k)
    return scores, top
