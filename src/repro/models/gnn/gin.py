"""GIN (Xu et al., arXiv:1810.00826) — sum aggregation + learnable eps.

h' = MLP( (1 + eps) * h + sum_{j in N(i)} h_j ).  Graph-level readout: sum
pooling of every layer's representation (the paper's jumping-knowledge
readout), linear classifier per layer, summed.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import GraphBatch, graph_pool


@dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 64
    n_classes: int = 16
    task: str = "node"         # node | graph
    dtype: str = "float32"


def init_gin(key, cfg: GINConfig):
    ks = jax.random.split(key, cfg.n_layers * 2 + 1)
    params = {"eps": jnp.zeros((cfg.n_layers,), jnp.float32),
              "mlps": [], "heads": []}
    specs = {"eps": (None,), "mlps": [], "heads": []}
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        p, s = L.mlp_init(ks[2 * i], [d_in, cfg.d_hidden, cfg.d_hidden],
                          jnp.float32)
        params["mlps"].append(p)
        specs["mlps"].append(s)
        hp, hs = L.dense(ks[2 * i + 1], cfg.d_hidden, cfg.n_classes,
                         jnp.float32, ("mlp", None), bias=True)
        params["heads"].append(hp)
        specs["heads"].append(hs)
        d_in = cfg.d_hidden
    return params, specs


def gin_forward(params, gb: GraphBatch, cfg: GINConfig):
    """Returns summed per-layer logits ([N, C] node task, [G, C] graph)."""
    from repro.distributed.aggregate import owner_gather_scatter

    def masked(hj, mask):
        import jax.numpy as jnp
        return jnp.where(mask[:, None], hj, 0.0)

    h = gb.feats
    out = None
    for i in range(cfg.n_layers):
        agg = owner_gather_scatter(h, gb.senders, gb.receivers,
                                   gb.edge_mask, masked, gb.n_nodes)
        h = (1.0 + params["eps"][i]) * h + agg
        h = L.apply_mlp(params["mlps"][i], h, act="relu")
        h = jax.nn.relu(h)
        pooled = graph_pool(h, gb) if cfg.task == "graph" else h
        logits = L.apply_dense(params["heads"][i], pooled)
        out = logits if out is None else out + logits
    return out


def gin_loss(params, gb: GraphBatch, cfg: GINConfig):
    logits = gin_forward(params, gb, cfg)
    if cfg.task == "graph":
        labels = gb.labels[:gb.n_graphs]
        loss = L.softmax_xent(logits, labels)
    else:
        loss = L.softmax_xent(logits, gb.labels, gb.node_mask)
    return loss, {"xent": loss}
