"""EGNN (Satorras et al., arXiv:2102.09844) — E(n)-equivariant GNN.

m_ij = phi_e(h_i, h_j, ||x_i - x_j||^2)
x_i' = x_i + C * sum_j (x_i - x_j) phi_x(m_ij)
h_i' = phi_h(h_i, sum_j m_ij)

Scalars only in MLPs; coordinates updated along relative vectors — exactly
equivariant to rotations/translations (tested by property tests).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import GraphBatch, aggregate


@dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 64
    dtype: str = "float32"


def init_egnn(key, cfg: EGNNConfig):
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    params = {"embed": None, "layers": [], "readout": None}
    specs = {"embed": None, "layers": [], "readout": None}
    params["embed"], specs["embed"] = L.dense(ks[-1], cfg.d_feat, d,
                                              jnp.float32, ("embed", "mlp"),
                                              bias=True)
    for i in range(cfg.n_layers):
        pe, se = L.mlp_init(ks[3 * i], [2 * d + 1, d, d], jnp.float32)
        px, sx = L.mlp_init(ks[3 * i + 1], [d, d, 1], jnp.float32)
        ph, sh = L.mlp_init(ks[3 * i + 2], [2 * d, d, d], jnp.float32)
        params["layers"].append({"phi_e": pe, "phi_x": px, "phi_h": ph})
        specs["layers"].append({"phi_e": se, "phi_x": sx, "phi_h": sh})
    params["readout"], specs["readout"] = L.mlp_init(ks[-2], [d, d, 1],
                                                     jnp.float32)
    return params, specs


def egnn_forward(params, gb: GraphBatch, cfg: EGNNConfig):
    """Returns (h [N, d], x [N, 3], energy [G])."""
    h = L.apply_dense(params["embed"], gb.feats)
    x = gb.pos
    n = gb.n_nodes
    for lp in params["layers"]:
        xi, xj = x[gb.receivers], x[gb.senders]
        diff = xi - xj
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = L.apply_mlp(lp["phi_e"],
                        jnp.concatenate([h[gb.receivers], h[gb.senders], d2],
                                        -1), act="silu")
        m = jax.nn.silu(m)
        w = L.apply_mlp(lp["phi_x"], m, act="silu")
        dx = aggregate(diff * w, gb.receivers, n, gb.edge_mask, op="mean")
        x = x + dx
        agg = aggregate(m, gb.receivers, n, gb.edge_mask)
        h = h + L.apply_mlp(lp["phi_h"], jnp.concatenate([h, agg], -1),
                            act="silu")
    e_node = L.apply_mlp(params["readout"], h, act="silu")[:, 0]
    from repro.models.gnn.common import graph_pool
    energy = graph_pool(e_node, gb)
    return h, x, energy


def egnn_loss(params, gb: GraphBatch, cfg: EGNNConfig):
    _, _, energy = egnn_forward(params, gb, cfg)
    target = gb.labels[:gb.n_graphs].astype(jnp.float32)
    loss = jnp.mean((energy - target) ** 2)
    return loss, {"mse": loss}
