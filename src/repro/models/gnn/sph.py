"""Real spherical harmonics (l <= 2) + Gaunt coupling tensor.

The equivariant bilinear coupling used by MACE-style models. We use the
*Gaunt* tensor G[a,b,c] = ∫ Y_a Y_b Y_c dΩ as the coupling: it is a valid
(non-zero multiple of the real-basis Clebsch-Gordan) equivariant projector
for every (l1,l2,l3) channel, and each channel carries its own learnable
weight, so the constant is absorbed.

G is computed *exactly* at import time by Gauss-Legendre (cos θ) x trapezoid
(φ) quadrature: products of three l<=2 harmonics are spherical polynomials
of degree <= 6, integrated exactly by 16 GL nodes x 32 φ nodes.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

# Component order (l, m): index -> l
LS = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])
N_COMP = 9
L_SLICES = {0: slice(0, 1), 1: slice(1, 4), 2: slice(4, 9)}

_C0 = 0.28209479177387814      # 1/(2 sqrt(pi))
_C1 = 0.4886025119029199       # sqrt(3/(4 pi))
_C2a = 1.0925484305920792      # sqrt(15/(4 pi))
_C2b = 0.31539156525252005     # sqrt(5/(16 pi))
_C2c = 0.5462742152960396      # sqrt(15/(16 pi))


def real_sph_np(u: np.ndarray) -> np.ndarray:
    """u: [..., 3] unit vectors -> [..., 9] real SH values (numpy)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    return np.stack([
        np.full_like(x, _C0),
        _C1 * y, _C1 * z, _C1 * x,
        _C2a * x * y, _C2a * y * z, _C2b * (3 * z * z - 1),
        _C2a * x * z, _C2c * (x * x - y * y),
    ], axis=-1)


def real_sph(u: jnp.ndarray) -> jnp.ndarray:
    """u: [..., 3] unit vectors -> [..., 9] real SH values (jnp)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    return jnp.stack([
        jnp.full(x.shape, _C0, x.dtype),
        _C1 * y, _C1 * z, _C1 * x,
        _C2a * x * y, _C2a * y * z, _C2b * (3 * z * z - 1),
        _C2a * x * z, _C2c * (x * x - y * y),
    ], axis=-1)


@functools.lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[a, b, c] = ∫ Y_a Y_b Y_c dΩ, exact quadrature. float32 [9, 9, 9]."""
    nodes, weights = np.polynomial.legendre.leggauss(16)   # cos(theta)
    nphi = 32
    phi = np.arange(nphi) * (2 * np.pi / nphi)
    ct = nodes[:, None]
    st = np.sqrt(np.maximum(0.0, 1 - ct ** 2))
    x = st * np.cos(phi)[None, :]
    y = st * np.sin(phi)[None, :]
    z = np.broadcast_to(ct, x.shape)
    u = np.stack([x, y, z], axis=-1)                       # [16, 32, 3]
    ysh = real_sph_np(u)                                   # [16, 32, 9]
    w = weights[:, None] * (2 * np.pi / nphi)              # [16, 1]
    g = np.einsum("tpa,tpb,tpc,tp->abc", ysh, ysh, ysh,
                  np.broadcast_to(w, x.shape))
    g[np.abs(g) < 1e-12] = 0.0
    return g.astype(np.float32)


def check_orthonormal() -> float:
    """Max deviation of <Y_a Y_b> from identity — sanity for tests."""
    nodes, weights = np.polynomial.legendre.leggauss(16)
    nphi = 32
    phi = np.arange(nphi) * (2 * np.pi / nphi)
    ct = nodes[:, None]
    st = np.sqrt(np.maximum(0.0, 1 - ct ** 2))
    u = np.stack([st * np.cos(phi)[None], st * np.sin(phi)[None],
                  np.broadcast_to(ct, (16, nphi))], axis=-1)
    ysh = real_sph_np(u)
    w = weights[:, None] * (2 * np.pi / nphi)
    gram = np.einsum("tpa,tpb,tp->ab", ysh, ysh,
                     np.broadcast_to(w, (16, nphi)))
    return float(np.abs(gram - np.eye(N_COMP)).max())
