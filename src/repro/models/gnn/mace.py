"""MACE (Batatia et al., arXiv:2206.07697) — higher-order equivariant
message passing, adapted to a self-contained JAX implementation.

Per layer t (node irrep features H[N, C, 9], components ordered l=0,1,2):

  A_i[c, o]  = Σ_{j∈N(i)}  R[e, c] · Σ_{a,b} H_j[c, a] Y_b(r̂_ij) G[a, b, o]
  B2_i[c, o] = Σ_{a,b} A_i[c,a]  A_i[c,b] G[a,b,o]        (correlation 2)
  B3_i[c, o] = Σ_{a,b} B2_i[c,a] A_i[c,b] G[a,b,o]        (correlation 3)
  H'_i[:, o] = Σ_l 1[o∈l] ( W1_l A + W2_l B2 + W3_l B3 )[·, o]  + residual

R[e, c] are per-channel radial weights from an MLP over n_rbf Bessel basis
functions with a polynomial cutoff envelope; G is the Gaunt coupling
(repro.models.gnn.sph), so every operation is exactly E(3)-equivariant —
the readout uses only l=0 components (invariant site energies).

Simplifications vs the reference implementation (noted in DESIGN.md):
channel-diagonal tensor products with per-l channel-mixing matrices
(MACE's U-matrix contraction is channel-diagonal + linear mixing as well),
and a shared radial for all output l.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.gnn.common import GraphBatch, graph_pool
from repro.models.gnn.sph import LS, N_COMP, gaunt_tensor, real_sph


@dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128          # channels
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    d_feat: int = 64             # input node feature dim
    dtype: str = "float32"       # message/feature dtype (bf16 at scale:
                                 # halves the gather/scatter collective bytes)
    remat: bool = False          # checkpoint each interaction layer


def bessel_basis(r, n_rbf: int, r_cut: float):
    """e(n) = sqrt(2/rc) sin(n pi r / rc) / r with smooth polynomial cutoff."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(
        n[None, :] * np.pi * r[:, None] / r_cut) / r[:, None]
    t = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * t ** 3 + 15.0 * t ** 4 - 6.0 * t ** 5
    return basis * env[:, None]


def init_mace(key, cfg: MACEConfig):
    c = cfg.d_hidden
    ks = jax.random.split(key, 4 + 4 * cfg.n_layers)
    params = {
        "embed": L.dense(ks[0], cfg.d_feat, c, jnp.float32,
                         ("embed", "mlp"), bias=True)[0],
        "layers": [],
        "readout": L.mlp_init(ks[1], [c, c, 1], jnp.float32)[0],
    }
    specs = {"embed": {"w": ("embed", "mlp"), "b": ("mlp",)},
             "layers": [], "readout": [{"w": (None, None), "b": (None,)},
                                       {"w": (None, None), "b": (None,)}]}
    for t in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(ks[4 + t], 4)
        lp = {
            # radial MLP: n_rbf -> c (per-channel radial weight)
            "radial": L.mlp_init(k1, [cfg.n_rbf, c, c], jnp.float32)[0],
            # per-l channel mixing for each correlation order
            "w1": L._dense_init(k2, (3, c, c), jnp.float32),
            "w2": L._dense_init(k3, (3, c, c), jnp.float32,
                                scale=0.1 / np.sqrt(c)),
            "w3": L._dense_init(k4, (3, c, c), jnp.float32,
                                scale=0.01 / np.sqrt(c)),
        }
        params["layers"].append(lp)
        specs["layers"].append({
            "radial": [{"w": (None, "mlp"), "b": ("mlp",)},
                       {"w": ("mlp", "mlp"), "b": ("mlp",)}],
            "w1": (None, "mlp", "mlp"), "w2": (None, "mlp", "mlp"),
            "w3": (None, "mlp", "mlp")})
    return params, specs


def _per_l_mix(w_l, feats):
    """feats [N, C, 9], w_l [3, C, C] — channel mixing within each l block."""
    l_of = jnp.asarray(LS)
    w_per_comp = w_l[l_of]                     # [9, C, C]
    return jnp.einsum("nco,odc->ndo", feats, w_per_comp)


def mace_forward(params, gb: GraphBatch, cfg: MACEConfig):
    """Returns (H [N, C, 9], energy [G])."""
    adt = jnp.dtype(cfg.dtype)
    g = jnp.asarray(gaunt_tensor()).astype(adt)  # [9, 9, 9]
    n = gb.n_nodes
    c = cfg.d_hidden

    h0 = jax.nn.silu(gb.feats @ params["embed"]["w"] + params["embed"]["b"])
    H = jnp.zeros((n, c, N_COMP), adt).at[:, :, 0].set(h0.astype(adt))

    rel = gb.pos[gb.receivers] - gb.pos[gb.senders]
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-18)
    rbf = bessel_basis(r, cfg.n_rbf, cfg.r_cut)            # [E, n_rbf]
    y = real_sph(rel / jnp.maximum(r, 1e-6)[:, None])      # [E, 9]
    # Degenerate edges (self-loops / padding, r ~ 0) have no direction:
    # Y(0) is not a valid l>0 object (Y20(0) = -c != 0 would inject a
    # non-rotating pseudo-vector and silently break equivariance), so they
    # carry only their scalar (l=0) component.
    l0_only = jnp.asarray([1.0] + [0.0] * (N_COMP - 1), y.dtype)
    y = jnp.where((r > 1e-6)[:, None], y, y * l0_only)
    y = jnp.where(gb.edge_mask[:, None], y, 0.0).astype(adt)

    from repro.distributed.aggregate import owner_gather_scatter

    def layer(H, lp):
        radial = L.apply_mlp(lp["radial"], rbf, act="silu").astype(adt)

        def message(hj, ed):
            y_l, rad_l = ed
            # message tensor product: (H_j ⊗ Y)_o via Gaunt coupling
            return jnp.einsum("eca,eb,abo->eco", hj, y_l, g) \
                * rad_l[:, :, None]

        # owner-aligned exchange: one all-gather(H) fwd + one psum_scatter,
        # and their transposes bwd — vs GSPMD's scatter schedule (§Perf P2.4)
        A = owner_gather_scatter(H, gb.senders, gb.receivers, (y, radial),
                                 message, n)
        A = constrain(A, ("nodes", None, None))
        # higher-order (symmetric) products — correlation 2 and 3
        B2 = jnp.einsum("nca,ncb,abo->nco", A, A, g)
        B3 = jnp.einsum("nca,ncb,abo->nco", B2, A, g)
        upd = (_per_l_mix(lp["w1"].astype(adt), A)
               + _per_l_mix(lp["w2"].astype(adt), B2)
               + _per_l_mix(lp["w3"].astype(adt), B3))
        return constrain(H + upd, ("nodes", None, None))

    if cfg.remat:
        layer = jax.checkpoint(layer,
                               policy=jax.checkpoint_policies.nothing_saveable)
    for lp in params["layers"]:
        H = layer(H, lp)

    site_e = L.apply_mlp(params["readout"],
                         H[:, :, 0].astype(jnp.float32), act="silu")[:, 0]
    energy = graph_pool(site_e, gb)
    return H, energy


def mace_loss(params, gb: GraphBatch, cfg: MACEConfig):
    _, energy = mace_forward(params, gb, cfg)
    target = gb.labels[:gb.n_graphs].astype(jnp.float32)
    loss = jnp.mean((energy - target) ** 2)
    return loss, {"mse": loss}
