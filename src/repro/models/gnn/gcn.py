"""GCN (Kipf & Welling, arXiv:1609.02907) — sym-normalised SpMM layers.

h' = act( D^-1/2 (A + I) D^-1/2 h W ).  Aggregation mean/sym-norm via
segment ops; optionally routed through the ELL Pallas SpMM when the graph is
available in CSR form (beyond-paper locality path).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.gnn.common import GraphBatch, aggregate


@dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433
    n_classes: int = 16
    norm: str = "sym"          # sym | mean
    dtype: str = "float32"


def init_gcn(key, cfg: GCNConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, len(dims) - 1)
    params, specs = [], []
    for i in range(len(dims) - 1):
        p, s = L.dense(ks[i], dims[i], dims[i + 1], jnp.dtype(cfg.dtype),
                       ("embed", "mlp"), bias=True)
        params.append(p)
        specs.append(s)
    return {"layers": params}, {"layers": specs}


def gcn_forward(params, gb: GraphBatch, cfg: GCNConfig):
    n = gb.n_nodes
    ones = jnp.where(gb.edge_mask, 1.0, 0.0)
    deg = jax.ops.segment_sum(ones, gb.receivers, num_segments=n) + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    from repro.distributed.aggregate import owner_gather_scatter

    def masked(hj, mask):
        return jnp.where(mask[:, None], hj, 0.0)

    h = gb.feats
    for i, p in enumerate(params["layers"]):
        h = L.apply_dense(p, h)
        if cfg.norm == "sym":
            # owner-aligned exchange (DESIGN §3.4 pattern); the sym-norm
            # factor folds into the node features so edge_fn stays identity
            agg = owner_gather_scatter(h * inv_sqrt[:, None], gb.senders,
                                       gb.receivers, gb.edge_mask, masked, n)
            h = (agg + h * inv_sqrt[:, None]) * inv_sqrt[:, None]
        else:
            agg = aggregate(h[gb.senders], gb.receivers, n, gb.edge_mask,
                            op="mean")
            h = agg + h
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


def gcn_loss(params, gb: GraphBatch, cfg: GCNConfig):
    logits = gcn_forward(params, gb, cfg)
    loss = L.softmax_xent(logits, gb.labels, gb.node_mask)
    return loss, {"xent": loss}
