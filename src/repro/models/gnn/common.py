"""GNN substrate: graph batch container + segment-op message passing.

JAX has no sparse message-passing primitive (BCOO only) — aggregation is
built from ``jnp.take`` gathers + ``jax.ops.segment_sum`` scatters over an
edge index, exactly the same gather/scatter toolbox as the BFS steps. Batched
small graphs (molecule shape) are packed PyG-style into one big graph with
offset edge indices and a ``graph_ids`` vector for pooling.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass
class GraphBatch:
    senders: jnp.ndarray     # int32[E]
    receivers: jnp.ndarray   # int32[E]
    edge_mask: jnp.ndarray   # bool[E]
    feats: jnp.ndarray       # f32[N, F]
    pos: jnp.ndarray         # f32[N, 3] (synthetic for non-geometric tasks)
    labels: jnp.ndarray      # int32[N] node labels / f32[G] graph targets
    node_mask: jnp.ndarray   # bool[N]
    graph_ids: jnp.ndarray   # int32[N] — graph membership for pooling
    # static: feeds num_segments, must not be traced
    n_graphs: int = dataclasses.field(default=1,
                                      metadata=dict(static=True))

    @property
    def n_nodes(self) -> int:
        return self.feats.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]

    def _replace(self, **kw):
        return dataclasses.replace(self, **kw)


def aggregate(messages: jnp.ndarray, receivers: jnp.ndarray, n_nodes: int,
              edge_mask: jnp.ndarray | None = None,
              op: str = "sum") -> jnp.ndarray:
    """Scatter-reduce edge messages to nodes."""
    if edge_mask is not None:
        shape = (-1,) + (1,) * (messages.ndim - 1)
        messages = jnp.where(edge_mask.reshape(shape), messages, 0)
    if op == "sum":
        return jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
    if op == "mean":
        s = jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
        ones = jnp.ones((messages.shape[0],), jnp.float32)
        if edge_mask is not None:
            ones = jnp.where(edge_mask, ones, 0.0)
        cnt = jax.ops.segment_sum(ones, receivers, num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0).reshape((-1,) + (1,) * (s.ndim - 1))
    if op == "max":
        return jax.ops.segment_max(messages, receivers, num_segments=n_nodes)
    raise ValueError(op)


def degrees(gb: GraphBatch) -> jnp.ndarray:
    ones = jnp.where(gb.edge_mask, 1.0, 0.0)
    return jax.ops.segment_sum(ones, gb.receivers, num_segments=gb.n_nodes)


def graph_pool(node_values: jnp.ndarray, gb: GraphBatch,
               op: str = "sum") -> jnp.ndarray:
    """Pool node values to per-graph values: [N, ...] -> [G, ...]."""
    vals = jnp.where(gb.node_mask.reshape((-1,) + (1,) * (node_values.ndim - 1)),
                     node_values, 0)
    return jax.ops.segment_sum(vals, gb.graph_ids, num_segments=gb.n_graphs)


def synthetic_graph_batch(key, n_nodes: int, n_edges: int, d_feat: int,
                          n_classes: int = 16, n_graphs: int = 1,
                          dtype=jnp.float32) -> GraphBatch:
    """Random graph batch used by smoke tests and dry-run input builders."""
    ks = jax.random.split(key, 5)
    senders = jax.random.randint(ks[0], (n_edges,), 0, n_nodes, jnp.int32)
    receivers = jax.random.randint(ks[1], (n_edges,), 0, n_nodes, jnp.int32)
    if n_graphs > 1:
        per = n_nodes // n_graphs
        gid_e = jax.random.randint(ks[0], (n_edges,), 0, n_graphs, jnp.int32)
        senders = senders % per + gid_e * per
        receivers = receivers % per + gid_e * per
        graph_ids = jnp.repeat(jnp.arange(n_graphs, dtype=jnp.int32), per,
                               total_repeat_length=n_nodes)
    else:
        graph_ids = jnp.zeros((n_nodes,), jnp.int32)
    return GraphBatch(
        senders=senders, receivers=receivers,
        edge_mask=jnp.ones((n_edges,), jnp.bool_),
        feats=jax.random.normal(ks[2], (n_nodes, d_feat), dtype),
        pos=jax.random.normal(ks[3], (n_nodes, 3), dtype),
        labels=jax.random.randint(ks[4], (n_nodes,), 0, n_classes, jnp.int32),
        node_mask=jnp.ones((n_nodes,), jnp.bool_),
        graph_ids=graph_ids, n_graphs=n_graphs)
