"""Shared functional layers: norms, RoPE, GQA attention, SwiGLU MLP.

Pure functions over parameter pytrees; no framework. Every init function
returns ``(params, specs)`` where ``specs`` mirrors ``params`` with tuples of
*logical* axis names consumed by ``repro.distributed.sharding``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- init utils


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense(key, d_in, d_out, dtype, logical=("embed", "mlp"), bias=False):
    params = {"w": _dense_init(key, (d_in, d_out), dtype)}
    specs = {"w": logical}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        specs["b"] = (logical[-1],)
    return params, specs


def apply_dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------- norms


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype):
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., seq, heads, d_head]; positions: [..., seq] int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # [d_head/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, dh/2]
    cos = jnp.cos(ang)[..., None, :]                         # [..., seq, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention


def gqa_attention(q, k, v, *, causal: bool, q_offset=0, kv_len_mask=None,
                  seq_pin: bool = True):
    """Grouped-query attention.

    q: [B, Sq, Hq, Dh]; k,v: [B, Skv, Hkv, Dh]; Hq = G * Hkv.
    ``q_offset``: absolute position of q[0] (decode: Skv_filled).
    ``kv_len_mask``: optional bool[B, Skv] of valid cache slots.
    Returns [B, Sq, Hq, Dh].

    The grouped-query GROUP dim is pinned to the model axis (when it
    divides): without this GSPMD flip-flops layouts between the two dots
    and inserts "involuntary full rematerialization" copies + replicated
    score tensors (observed on llama3-405b + sequence-parallel residuals,
    EXPERIMENTS §Perf P1.6).
    """
    from repro.distributed.sharding import ambient_axes_size, constrain
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    # Pin the layout GSPMD should keep through both dots: the group dim when
    # it divides the model axis, else the q-seq dim (phi4's g=3 / qwen1.5's
    # g=1 can't head-shard; without a pin the scores replicate per device).
    # A *partial* pin on an indivisible dim would force de-sharding — hence
    # the divisibility guards.
    msize = ambient_axes_size(("model",))
    pin_heads = msize > 1 and g % msize == 0
    pin_seq = (seq_pin and msize > 1 and not pin_heads
               and sq % msize == 0)
    if pin_heads:
        qg = constrain(qg, ("batch", None, None, "heads", None))
    elif pin_seq:
        qg = constrain(qg, ("batch", "kv_seq", None, None, None))
    scale = 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if pin_heads:
        logits = constrain(logits, ("batch", None, "heads", None, None))
    elif pin_seq:
        logits = constrain(logits, ("batch", None, None, "kv_seq", None))
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = qpos >= kpos                              # [Sq, Skv]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len_mask is not None:
        logits = jnp.where(kv_len_mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    if pin_heads:
        out = constrain(out, ("batch", None, None, "heads", None))
    elif pin_seq:
        out = constrain(out, ("batch", "kv_seq", None, None, None))
    return out.reshape(b, sq, hq, dh)


def gqa_attention_chunked(q, k, v, *, causal: bool, q_offset=0,
                          q_chunk: int = 2048, kv_chunk: int = 2048):
    """Blockwise GQA attention with an online softmax (flash-attention
    schedule at the XLA level): peak memory O(q_chunk x kv_chunk) scores
    instead of O(Sq x Skv). Sequential scan over q blocks; inner scan over
    kv blocks carries (running max, denominator, weighted accumulator).

    Required for the 32k prefill shapes — naive attention materialises
    ~13 GiB of scores per layer per device there (see EXPERIMENTS §Perf).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    nq, nkv = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / np.sqrt(dh)

    qs = q.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # qs: [nq, b, hkv, g, qc, dh]

    def one_q_block(args):
        qb, iq = args                       # [b, hkv, g, qc, dh], scalar

        def kv_body(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bhgqd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk) + q_offset
                kpos = j * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where((qpos[:, None] >= kpos[None, :]
                               )[None, None, None], s, -1e30)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb).astype(jnp.float32)
            return (m2, l2, acc2), None

        init = (jnp.full((b, hkv, g, q_chunk), -1e30, jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init,
                                      jnp.arange(nkv, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)           # [b, hkv, g, qc, dh]

    outs = jax.lax.map(one_q_block, (qs, jnp.arange(nq, dtype=jnp.int32)))
    # outs: [nq, b, hkv, g, qc, dh] -> [b, sq, hq, dh]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, dh)


ATTN_CHUNK_THRESHOLD = 8192   # use the blockwise path beyond this q length


def attention_init(key, d_model, n_heads, n_kv, d_head, dtype,
                   qkv_bias=False):
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["wq"], specs["wq"] = dense(ks[0], d_model, n_heads * d_head, dtype,
                                      ("embed", "heads"), qkv_bias)
    params["wk"], specs["wk"] = dense(ks[1], d_model, n_kv * d_head, dtype,
                                      ("embed", "kv"), qkv_bias)
    params["wv"], specs["wv"] = dense(ks[2], d_model, n_kv * d_head, dtype,
                                      ("embed", "kv"), qkv_bias)
    params["wo"], specs["wo"] = dense(ks[3], n_heads * d_head, d_model, dtype,
                                      ("heads", "embed"))
    return params, specs


# --------------------------------------------------------------- SwiGLU MLP


def swiglu_init(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    params, specs = {}, {}
    params["w1"], specs["w1"] = dense(ks[0], d_model, d_ff, dtype,
                                      ("embed", "mlp"))
    params["w3"], specs["w3"] = dense(ks[1], d_model, d_ff, dtype,
                                      ("embed", "mlp"))
    params["w2"], specs["w2"] = dense(ks[2], d_ff, d_model, dtype,
                                      ("mlp", "embed"))
    return params, specs


def swiglu(p, x):
    return (jax.nn.silu(x @ p["w1"]["w"]) * (x @ p["w3"]["w"])) @ p["w2"]["w"]


def mlp_init(key, sizes, dtype, act="relu", logical_hidden="mlp"):
    """Plain MLP used by GNN/recsys heads. sizes = [d0, d1, ..., dk]."""
    ks = jax.random.split(key, len(sizes) - 1)
    params, specs = [], []
    for i in range(len(sizes) - 1):
        p, s = dense(ks[i], sizes[i], sizes[i + 1], dtype,
                     ("embed", logical_hidden) if i == 0
                     else (logical_hidden, logical_hidden), bias=True)
        params.append(p)
        specs.append(s)
    return params, specs


_ACTS = {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu,
         "tanh": jnp.tanh, "sigmoid": jax.nn.sigmoid}


def apply_mlp(params, x, act="relu", final_act=None):
    a = _ACTS[act]
    for i, p in enumerate(params):
        x = apply_dense(p, x)
        if i < len(params) - 1:
            x = a(x)
        elif final_act is not None:
            x = _ACTS[final_act](x)
    return x


# ------------------------------------------------------------ loss functions


def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy in f32. logits [..., V], labels int[...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
