"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Static-shape, expert-parallel friendly:
  1. router: softmax over experts, top-k per token;
  2. flatten the (token, k) assignments, sort by expert id;
  3. position-in-expert via sorted offsets; assignments past the per-expert
     capacity are dropped (weights renormalised not required for top-k>1 —
     standard GShard-style capacity semantics);
  4. scatter tokens into an [E, C, d] buffer, run all experts as one grouped
     einsum (experts dim shardable over 'experts' -> model axis), scatter-add
     back with routing weights.

Aux losses: load-balancing (Switch) + router z-loss.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense_init


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2
    # dispatch token-chunk: bounds the [E, C, d] buffer footprint at long
    # prefill (1M tokens) — the buffer exists per chunk, not per step
    token_chunk: int = 16384


def moe_init(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.d_ff_expert
    params = {
        "router": _dense_init(ks[0], (d_model, e), jnp.float32),
        "w1": _dense_init(ks[1], (e, d_model, f), dtype),
        "w3": _dense_init(ks[2], (e, d_model, f), dtype),
        "w2": _dense_init(ks[3], (e, f, d_model), dtype),
    }
    specs = {
        "router": ("embed", None),
        "w1": ("experts", "embed", "mlp"),
        "w3": ("experts", "embed", "mlp"),
        "w2": ("experts", "mlp", "embed"),
    }
    return params, specs


def moe_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(np.ceil(n_tokens * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)   # round up to 8 for TPU friendliness


def moe_ffn(p, x, cfg: MoEConfig):
    """x: [T, d] -> (y: [T, d], aux_loss scalar). Chunks long token streams
    (lax.map) so the dispatch buffers stay O(token_chunk)."""
    t, d = x.shape
    if t > cfg.token_chunk and t % cfg.token_chunk == 0:
        nc = t // cfg.token_chunk
        xs = x.reshape(nc, cfg.token_chunk, d)
        ys, auxs = jax.lax.map(lambda xc: _moe_ffn_chunk(p, xc, cfg), xs)
        return ys.reshape(t, d), jnp.mean(auxs)
    return _moe_ffn_chunk(p, x, cfg)


def _moe_ffn_chunk(p, x, cfg: MoEConfig):
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = moe_capacity(t, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                              # [T*k]
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)                # sort by expert
    se, sp, st = flat_e[order], flat_p[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=e)                 # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos_in_e < cap
    pos_safe = jnp.where(keep, pos_in_e, 0)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[se, pos_safe].add(
        jnp.where(keep[:, None], x[st], 0).astype(x.dtype))

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, p["w2"])

    contrib = out_buf[se, pos_safe] * jnp.where(keep, sp, 0.0)[:, None
                                                               ].astype(x.dtype)
    y = jnp.zeros_like(x).at[st].add(contrib)

    # Switch load-balance loss + router z-loss (f32).
    me = probs.mean(axis=0)                                  # mean router prob
    ce = (counts.astype(jnp.float32) / jnp.maximum(t * k, 1)).astype(jnp.float32)
    balance = cfg.balance_coef * e * jnp.sum(me * ce)
    zloss = cfg.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, balance + zloss


def moe_ffn_dense_ref(p, x, cfg: MoEConfig):
    """O(T*E) dense reference (no capacity drops) for unit tests."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->tef", x, p["w1"])
    g = jnp.einsum("td,edf->tef", x, p["w3"])
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * g, p["w2"])  # [T, E, d]
    w = jnp.zeros_like(probs).at[jnp.arange(x.shape[0])[:, None],
                                 top_e].set(top_p)
    return jnp.einsum("te,ted->td", w.astype(x.dtype), o)
