"""Decoder-only transformer (dense + MoE) with train / prefill / decode paths.

Design points:
  * layer stack via ``lax.scan`` over stacked per-layer params — keeps HLO
    size O(1) in depth (essential for the 126-layer llama3-405b dry-run);
  * GQA + RoPE + SwiGLU (or MoE FFN) + RMSNorm, optional QKV bias (qwen1.5);
  * serve path: ``prefill`` builds the KV cache, ``decode_step`` appends one
    token (the decode_* / long_* dry-run shapes lower decode_step);
  * every init returns (params, specs) — specs carry logical axis names
    ('embed', 'heads', 'kv', 'mlp', 'vocab', 'experts') resolved to mesh
    axes by repro.distributed.sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_ffn, moe_init


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 500000.0
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    dtype: str = "float32"
    param_dtype: str = "float32"
    remat: bool = True
    # Megatron-style sequence-parallel residual stream: the layer-scan carry
    # is stored seq-sharded over the model axis (16x less carry memory at
    # the cost of one all-gather per layer) — required for llama3-405b train.
    seq_parallel_residual: bool = False
    # KV cache storage dtype (serving): fp8 halves cache HBM — required for
    # MHA archs at 32k x 128 (qwen1.5's cache is 5.5 TB in bf16).
    kv_cache_dtype: str | None = None
    # pin attention q-seq dim to the model axis when heads can't shard
    # (helps GQA with small groups; hurts MHA — measured per arch, §Perf)
    attn_seq_pin: bool = True

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def cache_dtype(self):
        return jnp.dtype(self.kv_cache_dtype or self.dtype)

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.moe.d_ff_expert \
                + d * self.moe.num_experts
        else:
            ffn = 3 * d * self.d_ff
        return self.n_layers * (attn + ffn + 2 * d) + 2 * v * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts) — for 6*N*D FLOPs."""
        d, v = self.d_model, self.vocab
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        if self.moe is not None:
            ffn = self.moe.top_k * 3 * d * self.moe.d_ff_expert \
                + d * self.moe.num_experts
        else:
            ffn = 3 * d * self.d_ff
        return self.n_layers * (attn + ffn + 2 * d) + 2 * v * d + d


# ------------------------------------------------------------------- init


def _layer_init(key, cfg: LMConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["ln1"], specs["ln1"] = L.rmsnorm_init(cfg.d_model, pdt)
    params["ln2"], specs["ln2"] = L.rmsnorm_init(cfg.d_model, pdt)
    params["attn"], specs["attn"] = L.attention_init(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, pdt,
        qkv_bias=cfg.qkv_bias)
    if cfg.moe is not None:
        params["moe"], specs["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe, pdt)
    else:
        params["mlp"], specs["mlp"] = L.swiglu_init(ks[1], cfg.d_model,
                                                    cfg.d_ff, pdt)
    return params, specs


def init_lm(key, cfg: LMConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg)[0])(layer_keys)
    spec_box = {}

    def _one(k):  # specs are static python data — capture via side channel
        p, s = _layer_init(k, cfg)
        spec_box["s"] = s
        return p

    jax.eval_shape(_one, jax.random.PRNGKey(0))
    layer_specs = jax.tree.map(lambda s: (None,) + tuple(s), spec_box["s"],
                               is_leaf=lambda x: isinstance(x, tuple))
    params = {
        "embed": L._dense_init(k_emb, (cfg.vocab, cfg.d_model), pdt, scale=0.02),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model, pdt)[0],
        "head": L._dense_init(k_head, (cfg.d_model, cfg.vocab), pdt),
    }
    specs = {
        "embed": ("vocab", "embed"),
        "layers": layer_specs,
        "ln_f": L.rmsnorm_init(cfg.d_model, pdt)[1],
        "head": ("embed", "vocab"),
    }
    return params, specs


# ---------------------------------------------------------------- forward


def _ffn(lp, x2, cfg: LMConfig):
    if cfg.moe is not None:
        b, s, d = x2.shape
        y, aux = moe_ffn(lp["moe"], x2.reshape(b * s, d), cfg.moe)
        return y.reshape(b, s, d), aux
    return L.swiglu(lp["mlp"], x2), jnp.float32(0.0)


def _attn(lp, x1, cfg: LMConfig, positions, kv=None, kv_len_mask=None,
          q_offset=0, return_kv=False):
    b, s, _ = x1.shape
    q = L.apply_dense(lp["attn"]["wq"], x1).reshape(b, s, cfg.n_heads,
                                                    cfg.d_head)
    k = L.apply_dense(lp["attn"]["wk"], x1).reshape(b, s, cfg.n_kv_heads,
                                                    cfg.d_head)
    v = L.apply_dense(lp["attn"]["wv"], x1).reshape(b, s, cfg.n_kv_heads,
                                                    cfg.d_head)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if kv is None:
        if s > L.ATTN_CHUNK_THRESHOLD:
            o = L.gqa_attention_chunked(q, k, v, causal=True)
        else:
            o = L.gqa_attention(q, k, v, causal=True,
                                seq_pin=cfg.attn_seq_pin)
        new_kv = (k, v) if return_kv else None
    else:
        o = L.gqa_attention(q, kv[0], kv[1], causal=False,
                            kv_len_mask=kv_len_mask,
                            seq_pin=cfg.attn_seq_pin)
        new_kv = None
    o = L.apply_dense(lp["attn"]["wo"], o.reshape(b, s, -1))
    return o, new_kv


def _block_train(cfg: LMConfig):
    def body(x, lp):
        if cfg.seq_parallel_residual:
            # Megatron-SP: the scan carry (what backward saves per layer) is
            # the body INPUT — constrain it here so the saved buffer is
            # seq-sharded over 'model', and again on the output so the
            # constraint holds at both ends of every layer.
            x = constrain(x, ("batch", "kv_seq", None))
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        x1 = L.rmsnorm(lp["ln1"], x)
        if cfg.seq_parallel_residual:
            # Megatron-SP exchange: all-gather the (small) activations to
            # full seq before the projections, so GSPMD keeps the (huge)
            # weights model-sharded instead of gathering them per layer.
            x1 = constrain(x1, ("batch", None, None))
        a, _ = _attn(lp, x1, cfg, positions)
        x = x + a
        x2 = L.rmsnorm(lp["ln2"], x)
        if cfg.seq_parallel_residual:
            x2 = constrain(x2, ("batch", None, None))
        f, aux = _ffn(lp, x2, cfg)
        x = x + f
        if cfg.seq_parallel_residual:
            x = constrain(x, ("batch", "kv_seq", None))
        return x, aux
    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return body


def lm_forward(params, tokens, cfg: LMConfig):
    """tokens int32[B, S] -> (logits [B, S, V], aux_loss)."""
    adt = cfg.activation_dtype
    x = params["embed"][tokens].astype(adt)
    x = constrain(x, ("batch", None, None))
    body = _block_train(cfg)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["ln_f"], x)
    logits = x @ params["head"].astype(adt)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, jnp.sum(auxs)


def lm_loss(params, batch, cfg: LMConfig):
    logits, aux = lm_forward(params, batch["tokens"], cfg)
    loss = L.softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                          batch.get("mask", None))
    return loss + aux, {"xent": loss, "aux": aux}


# ------------------------------------------------------------------ serving


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return (jnp.zeros(shape, cfg.cache_dtype),
            jnp.zeros(shape, cfg.cache_dtype))


def lm_prefill(params, tokens, cfg: LMConfig):
    """tokens int32[B, S] -> (last-token logits [B, V], cache)."""
    adt = cfg.activation_dtype
    x = params["embed"][tokens].astype(adt)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]

    kv_spec = ("batch", "kv_seq", "kv_heads", None)

    def body(x, lp):
        a, kv = _attn(lp, L.rmsnorm(lp["ln1"], x), cfg, positions,
                      return_kv=True)
        x = x + a
        f, _ = _ffn(lp, L.rmsnorm(lp["ln2"], x), cfg)
        # cache layers are step OUTPUTS: constrain them model-axis sharded
        # and store in the (possibly fp8) cache dtype
        kv = tuple(constrain(t.astype(cfg.cache_dtype), kv_spec) for t in kv)
        return x + f, kv

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["ln_f"], x[:, -1:])
    logits = (x @ params["head"].astype(adt))[:, 0]
    logits = constrain(logits, ("batch", "vocab"))
    return logits, (ks, vs)


def lm_decode_step(params, token, cache, cache_len, cfg: LMConfig):
    """One decode step.

    token int32[B, 1]; cache ([L,B,S,KV,Dh] x2); cache_len int32 scalar —
    number of filled slots. Returns (logits [B, V], new cache).
    """
    adt = cfg.activation_dtype
    b = token.shape[0]
    max_len = cache[0].shape[2]
    x = params["embed"][token].astype(adt)
    positions = jnp.full((1, 1), cache_len, jnp.int32)
    slot_mask = jnp.broadcast_to((jnp.arange(max_len) <= cache_len)[None],
                                 (b, max_len))

    def body(x, layer_in):
        lp, k_l, v_l = layer_in
        x1 = L.rmsnorm(lp["ln1"], x)
        q = L.apply_dense(lp["attn"]["wq"], x1).reshape(b, 1, cfg.n_heads,
                                                        cfg.d_head)
        kn = L.apply_dense(lp["attn"]["wk"], x1).reshape(b, 1, cfg.n_kv_heads,
                                                         cfg.d_head)
        vn = L.apply_dense(lp["attn"]["wv"], x1).reshape(b, 1, cfg.n_kv_heads,
                                                         cfg.d_head)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        kn = L.apply_rope(kn, positions, cfg.rope_theta)
        k_l = jax.lax.dynamic_update_slice(k_l, kn.astype(k_l.dtype),
                                           (0, cache_len, 0, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, vn.astype(v_l.dtype),
                                           (0, cache_len, 0, 0))
        kv_spec = ("batch", "kv_seq", "kv_heads", None)
        k_l = constrain(k_l, kv_spec)
        v_l = constrain(v_l, kv_spec)
        # fp8 cache reads are converted inside the attention dots (fused)
        o = L.gqa_attention(q, k_l.astype(x.dtype), v_l.astype(x.dtype),
                            causal=False, kv_len_mask=slot_mask,
                            seq_pin=cfg.attn_seq_pin)
        x = x + L.apply_dense(lp["attn"]["wo"], o.reshape(b, 1, -1))
        f, _ = _ffn(lp, L.rmsnorm(lp["ln2"], x), cfg)
        return x + f, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],) + cache)
    x = L.rmsnorm(params["ln_f"], x)
    logits = (x @ params["head"].astype(adt))[:, 0]
    logits = constrain(logits, ("batch", "vocab"))
    return logits, (ks, vs)
