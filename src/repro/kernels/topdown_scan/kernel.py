"""Pallas TPU kernel for the edge-parallel top-down frontier scan.

One grid step owns a (8, 128) tile of 1024 edge slots. For each edge
(u -> v) the kernel fuses the two bitmap tests of the top-down inner loop
(`u in frontier`? `v visited`?) using the Listing-1 word/bit math, and emits
the parent *candidate* ``u`` (or the sentinel ``n``) per edge. The
deterministic scatter-min by destination happens outside the kernel (XLA
scatter) because cross-tile scatters from a parallel grid would race.

Both bitmaps stay whole in VMEM (n/32 words each — 8 KiB per 2^20 vertices),
the edge tiles stream through via BlockSpec double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, SUBLANES, TILE, cdiv


def _bit_test(words, ids):
    w = jnp.take(words, (ids >> 5).astype(jnp.int32), axis=0)
    return ((w >> (ids & 0x1F).astype(jnp.uint32)) & jnp.uint32(1)) == 1


def _scan_kernel(src_ref, dst_ref, fw_ref, vw_ref, cand_out, *, n: int):
    src = src_ref[...]
    dst = dst_ref[...]
    fw = fw_ref[...]
    vw = vw_ref[...]
    active = _bit_test(fw, src) & (~_bit_test(vw, dst))
    cand_out[...] = jnp.where(active, src, n).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def topdown_scan_pallas(src_idx, col_idx, frontier_words, visited_words,
                        n: int, interpret: bool = True):
    """Returns cand int32[m]: parent candidate per edge slot (n = inactive)."""
    m = src_idx.shape[0]
    m_pad = cdiv(m, TILE) * TILE
    pad = m_pad - m

    def pad1(x, value):
        return jnp.pad(x, (0, pad), constant_values=value) if pad else x

    # Padded lanes may emit spurious candidates; they are discarded by the
    # [:m] slice before the caller's scatter, so any pad value is safe.
    src2 = pad1(src_idx, 0).reshape(-1, SUBLANES, LANES)
    dst2 = pad1(col_idx, 0).reshape(-1, SUBLANES, LANES)

    grid = (m_pad // TILE,)
    tile_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    fw_spec = pl.BlockSpec(frontier_words.shape, lambda i: (0,))
    vw_spec = pl.BlockSpec(visited_words.shape, lambda i: (0,))

    cand = pl.pallas_call(
        functools.partial(_scan_kernel, n=n),
        grid=grid,
        in_specs=[tile_spec, tile_spec, fw_spec, vw_spec],
        out_specs=pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad // TILE, SUBLANES, LANES),
                                       jnp.int32),
        interpret=interpret,
    )(src2, dst2, frontier_words, visited_words)
    return cand.reshape(m_pad)[:m]
