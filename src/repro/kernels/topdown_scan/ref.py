"""Pure-jnp oracle for the topdown_scan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitmap


def topdown_scan_ref(src_idx, col_idx, frontier_words, visited_words, n: int):
    active = bitmap.test(frontier_words, src_idx) & ~bitmap.test(
        visited_words, col_idx)
    return jnp.where(active, src_idx, n).astype(jnp.int32)
