"""Jitted wrapper: full top-down step using the Pallas edge-scan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitmap
from repro.core.csr import CSRGraph
from repro.kernels.common import interpret_default
from repro.kernels.topdown_scan.kernel import topdown_scan_pallas


def topdown_step_pallas(g: CSRGraph, frontier, visited, parent):
    """Drop-in replacement for ``repro.core.topdown.topdown_step``."""
    n = g.n
    fw = bitmap.pack(frontier)
    vw = bitmap.pack(visited)
    cand = topdown_scan_pallas(g.src_idx, g.col_idx, fw, vw, n,
                               interpret=interpret_default())
    best = jnp.full((n,), n, dtype=jnp.int32).at[g.col_idx].min(cand)
    new = (best < n) & ~visited
    parent = jnp.where(new, best, parent)
    return new, visited | new, parent
