"""Jitted public wrapper for the msbfs_probe kernel.

``msbfs_probe`` is what ``repro.core.msbfs`` calls when
``probe_impl='pallas'``; it matches the ``_probe_xla`` contract: given the
packed frontier / need lane words (uint32[n, W]) it returns the probe OR
accumulator uint32[n, W] (caller masks with ``need``). Word planes are
independent, so the (static, W <= 2) planes are separate kernel launches.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.msbfs_probe.kernel import msbfs_probe_pallas


def msbfs_probe(row_ptr, col_idx, frontier_words, need_words,
                max_pos: int = 8):
    starts = row_ptr[:-1]
    deg = row_ptr[1:] - row_ptr[:-1]
    interpret = interpret_default()
    planes = [
        msbfs_probe_pallas(starts, deg, need_words[:, w], col_idx,
                           frontier_words[:, w], max_pos=max_pos,
                           interpret=interpret)
        for w in range(frontier_words.shape[1])
    ]
    return jnp.stack(planes, axis=1)
