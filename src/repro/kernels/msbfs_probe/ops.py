"""Jitted public wrapper for the msbfs_probe kernel.

``msbfs_probe`` is what ``repro.core.msbfs`` calls when
``probe_impl='pallas'``; it matches the ``_probe_xla`` contract: given the
packed frontier / need lane words (uint32[n, W]) it returns the probe OR
accumulator uint32[n, W] (caller masks with ``need``). The lane-word count
W is a kernel grid dimension — ONE launch serves every plane, however wide
the pipelined engine's lane pool is.
"""
from __future__ import annotations

from repro.kernels.common import interpret_default
from repro.kernels.msbfs_probe.kernel import msbfs_probe_pallas


def msbfs_probe(row_ptr, col_idx, frontier_words, need_words,
                max_pos: int = 8):
    starts = row_ptr[:-1]
    deg = row_ptr[1:] - row_ptr[:-1]
    return msbfs_probe_pallas(starts, deg, need_words, col_idx,
                              frontier_words, max_pos=max_pos,
                              interpret=interpret_default())
