"""Pure-jnp oracle for the msbfs_probe kernel."""
from __future__ import annotations

import jax.numpy as jnp


def msbfs_probe_ref(starts, deg, need_plane, col_idx, frontier_plane,
                    max_pos: int = 8):
    """Identical math to the kernel, plain jnp. Returns acc uint32[n]."""
    m = col_idx.shape[0]
    acc = jnp.zeros_like(need_plane)
    for pos in range(max_pos):
        live = ((need_plane & ~acc) != 0) & (pos < deg)
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = col_idx[idx]
        acc = acc | jnp.where(live, frontier_plane[vadj], jnp.uint32(0))
    return acc
