"""Pure-jnp oracle for the msbfs_probe kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import merge_u64_words, split_u64_words


def msbfs_probe_ref(starts, deg, need_words, col_idx, frontier_words,
                    max_pos: int = 8):
    """Identical math to the kernel, plain jnp. Accepts uint32[n, W] word
    planes (or uint32[n] as W=1); ``frontier_words`` may have MORE rows
    than ``need_words`` (distributed local-block probe against the full
    replicated frontier). Retirement is per plane, elementwise — a plane
    keeps gathering only while ITS need bits are unserved.

    uint64 planes mirror the kernel's u64 gather path exactly: split
    into (lo, hi) uint32 half-planes, probe with per-HALF-plane
    retirement, reassemble — so kernel == ref bit-for-bit at either
    word width (``acc & need``, the only bits the engines consume, is
    retirement-granularity invariant either way)."""
    flat = need_words.ndim == 1
    if flat:
        need_words = need_words[:, None]
        frontier_words = frontier_words[:, None]
    wide = need_words.dtype == jnp.uint64
    if wide:
        need_words = split_u64_words(need_words)
        frontier_words = split_u64_words(frontier_words)
    m = col_idx.shape[0]
    acc = jnp.zeros_like(need_words)
    for pos in range(max_pos):
        live = ((need_words & ~acc) != 0) & (pos < deg)[:, None]
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = col_idx[idx]
        acc = acc | jnp.where(live, frontier_words[vadj],
                              jnp.zeros((), frontier_words.dtype))
    if wide:
        acc = merge_u64_words(acc)
    return acc[:, 0] if flat else acc
