"""Pallas TPU kernel for the word-packed MS-BFS bottom-up probe.

The single-source probe (``bottom_up_probe``) tests ONE frontier bit per
gathered neighbour; here each gather pulls a whole uint32 *lane word* — 32
concurrent traversals answered by one load — and accumulates with bitwise
OR instead of a select. The lane-word count ``W`` is a GRID dimension, not
a host loop: one ``pallas_call`` answers every word plane (lane words for
roots [32w, 32w+32)), so the pipelined engine's wider lane pools (W > 2)
cost extra grid steps, not extra launches.

Per probe round ``pos`` (within one word plane):

  live = ((need & ~acc) != 0) & (pos < deg)   # lanes still unserved
  vadj = col_idx[start + pos]                 # LoadAdj: masked gather
  acc |= frontier_plane[vadj]  (where live)   # word-OR, 32 lanes at once

Retirement is PER PLANE (a plane stops gathering once all its needed lanes
found a parent); ``msbfs_probe_ref`` mirrors that exactly, and the caller
masks ``acc & need`` so cross-plane retirement differences cannot leak.

VMEM residency mirrors ``bottom_up_probe``: vertex-tile operands stream
via BlockSpec (auto double-buffered) with the plane index as the outer
grid dimension (each plane's frontier word column stays resident across
its vertex tiles), while ``col_idx`` is held whole in VMEM. MAX_POS is
statically unrolled.

64-bit lane words (``LANE_WORD_BITS=64``) take the *u64 gather path*:
TPU Pallas has no 64-bit vector loads, so each uint64 word column is
split into interleaved (lo, hi) uint32 half-planes OUTSIDE the kernel
(``common.split_u64_words``) and the unchanged uint32 kernel runs over
2W half-planes; the accumulator halves are reassembled afterwards.
Retirement then happens per HALF-plane rather than per 64-bit plane,
which changes which *extra* bits are gathered but never which needed
bits: a needed bit is found iff some live round's neighbour carries it,
and a half-plane only retires once every one of its needed bits is
already accumulated — so ``acc & need`` is retirement-granularity
invariant (the engines mask exactly that way). ``msbfs_probe_ref``
mirrors the split so kernel == ref bit-for-bit even unmasked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (LANES, SUBLANES, TILE, cdiv,
                                  merge_u64_words, split_u64_words)


def _msbfs_probe_kernel(starts_ref, deg_ref, need_ref, col_ref, fp_ref,
                        acc_out, *, max_pos: int, m: int):
    starts = starts_ref[...]
    deg = deg_ref[...]
    need = need_ref[0]          # uint32 lane words still unserved per vertex
    col = col_ref[...]          # local edge slab, VMEM-resident
    fp = fp_ref[0]              # this plane's frontier word per vertex

    acc = jnp.zeros_like(need)
    for pos in range(max_pos):  # static unroll — the paper's MAX_POS loop
        live = ((need & ~acc) != 0) & (pos < deg)
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = jnp.take(col, idx, axis=0)                  # LoadAdj gather
        w = jnp.take(fp, vadj, axis=0)                     # lane-word gather
        acc = acc | jnp.where(live, w, jnp.uint32(0))

    acc_out[0] = acc


@functools.partial(jax.jit, static_argnames=("max_pos", "interpret"))
def msbfs_probe_pallas(starts: jnp.ndarray, deg: jnp.ndarray,
                       need_words: jnp.ndarray, col_idx: jnp.ndarray,
                       frontier_words: jnp.ndarray, max_pos: int = 8,
                       interpret: bool = True):
    """Returns acc — OR of the first ``max_pos`` neighbours' frontier
    words, per vertex and word plane, retired per plane once ``need`` is
    fully served.

    Shapes: starts/deg int32[n]; need_words uint32[n, W] (uint32[n]
    accepted as W=1 and returned flat); col_idx int32[m];
    frontier_words uint32[nf, W] where nf >= n — the distributed engine
    probes a LOCAL row block (n = n_loc) against the FULL replicated
    frontier (nf = global n), with ``col_idx`` holding global neighbour
    ids. Single-host callers pass nf == n. Both row counts are padded to a
    multiple of 1024 internally; W is a static grid dimension.

    uint64[n, W] word planes are accepted under jax x64 (the
    ``LANE_WORD_BITS=64`` engine configuration): each 64-bit word is
    gathered as two 32-bit half-planes and reassembled — see the module
    docstring for why ``acc & need`` is unaffected.
    """
    flat = need_words.ndim == 1
    if flat:
        need_words = need_words[:, None]
        frontier_words = frontier_words[:, None]
    wide = need_words.dtype == jnp.uint64
    if wide:
        need_words = split_u64_words(need_words)
        frontier_words = split_u64_words(frontier_words)
    n, w = need_words.shape
    nf = frontier_words.shape[0]
    m = col_idx.shape[0]
    n_pad = cdiv(n, TILE) * TILE
    pad = n_pad - n
    nf_pad = cdiv(nf, TILE) * TILE

    def pad1(x, value=0):
        return jnp.pad(x, (0, pad), constant_values=value) if pad else x

    starts2 = pad1(starts).reshape(-1, SUBLANES, LANES)
    deg2 = pad1(deg).reshape(-1, SUBLANES, LANES)
    # plane-major [W, ...] so the w grid index selects a contiguous plane
    need2 = jnp.pad(need_words, ((0, pad), (0, 0))).T.reshape(
        w, -1, SUBLANES, LANES)
    fp = jnp.pad(frontier_words, ((0, nf_pad - nf), (0, 0))).T  # [W, nf_pad]
    # padded rows keep gathers of padded/sentinel vadj safe

    tiles = n_pad // TILE
    grid = (w, tiles)
    vert_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda pw, i: (i, 0, 0))
    plane_tile_spec = pl.BlockSpec((1, 1, SUBLANES, LANES),
                                   lambda pw, i: (pw, i, 0, 0))
    full_col = pl.BlockSpec(col_idx.shape, lambda pw, i: (0,))
    plane_fp = pl.BlockSpec((1, nf_pad), lambda pw, i: (pw, 0))

    acc = pl.pallas_call(
        functools.partial(_msbfs_probe_kernel, max_pos=max_pos, m=m),
        grid=grid,
        in_specs=[vert_spec, vert_spec, plane_tile_spec, full_col, plane_fp],
        out_specs=plane_tile_spec,
        out_shape=jax.ShapeDtypeStruct((w, tiles, SUBLANES, LANES),
                                       jnp.uint32),
        interpret=interpret,
    )(starts2, deg2, need2, col_idx, fp)

    acc = acc.reshape(w, n_pad)[:, :n].T
    if wide:
        acc = merge_u64_words(acc)
    return acc[:, 0] if flat else acc
