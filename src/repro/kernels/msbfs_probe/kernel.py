"""Pallas TPU kernel for the word-packed MS-BFS bottom-up probe.

The single-source probe (``bottom_up_probe``) tests ONE frontier bit per
gathered neighbour; here each gather pulls a whole uint32 *lane word* — 32
concurrent traversals answered by one load — and accumulates with bitwise
OR instead of a select. One kernel invocation handles one word plane
(lane words for roots [32w, 32w+32)); the ops wrapper loops the (static,
<= 2) planes.

Per probe round ``pos``:

  live = ((need & ~acc) != 0) & (pos < deg)   # lanes still unserved
  vadj = col_idx[start + pos]                 # LoadAdj: masked gather
  acc |= frontier_plane[vadj]  (where live)   # word-OR, 32 lanes at once

VMEM residency mirrors ``bottom_up_probe``: vertex-tile operands stream
via BlockSpec (auto double-buffered), while ``col_idx`` and the per-vertex
frontier plane are held whole in VMEM. MAX_POS is statically unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, SUBLANES, TILE, cdiv


def _msbfs_probe_kernel(starts_ref, deg_ref, need_ref, col_ref, fp_ref,
                        acc_out, *, max_pos: int, m: int):
    starts = starts_ref[...]
    deg = deg_ref[...]
    need = need_ref[...]        # uint32 lane words still unserved per vertex
    col = col_ref[...]          # local edge slab, VMEM-resident
    fp = fp_ref[...]            # frontier plane (uint32 word per vertex)

    acc = jnp.zeros_like(need)
    for pos in range(max_pos):  # static unroll — the paper's MAX_POS loop
        live = ((need & ~acc) != 0) & (pos < deg)
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = jnp.take(col, idx, axis=0)                  # LoadAdj gather
        w = jnp.take(fp, vadj, axis=0)                     # lane-word gather
        acc = acc | jnp.where(live, w, jnp.uint32(0))

    acc_out[...] = acc


@functools.partial(jax.jit, static_argnames=("max_pos", "interpret"))
def msbfs_probe_pallas(starts: jnp.ndarray, deg: jnp.ndarray,
                       need_plane: jnp.ndarray, col_idx: jnp.ndarray,
                       frontier_plane: jnp.ndarray, max_pos: int = 8,
                       interpret: bool = True):
    """Returns acc uint32[n] — OR of the first ``max_pos`` neighbours'
    frontier words, per vertex, retired once ``need`` is fully served.

    Shapes: starts/deg int32[n]; need_plane/frontier_plane uint32[n];
    col_idx int32[m]. n is padded to a multiple of 1024 internally.
    """
    n = starts.shape[0]
    m = col_idx.shape[0]
    n_pad = cdiv(n, TILE) * TILE
    pad = n_pad - n

    def pad1(x, value=0):
        return jnp.pad(x, (0, pad), constant_values=value) if pad else x

    starts2 = pad1(starts).reshape(-1, SUBLANES, LANES)
    deg2 = pad1(deg).reshape(-1, SUBLANES, LANES)
    need2 = pad1(need_plane).reshape(-1, SUBLANES, LANES)
    fp = pad1(frontier_plane)   # padded so gathers of padded vadj are safe

    grid = (n_pad // TILE,)
    tile_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    full_col = pl.BlockSpec(col_idx.shape, lambda i: (0,))
    full_fp = pl.BlockSpec(fp.shape, lambda i: (0,))

    acc = pl.pallas_call(
        functools.partial(_msbfs_probe_kernel, max_pos=max_pos, m=m),
        grid=grid,
        in_specs=[tile_spec, tile_spec, tile_spec, full_col, full_fp],
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad // TILE, SUBLANES, LANES),
                                       jnp.uint32),
        interpret=interpret,
    )(starts2, deg2, need2, col_idx, fp)

    return acc.reshape(n_pad)[:n]
