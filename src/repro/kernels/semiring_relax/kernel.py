"""Pallas TPU kernel for the masked min-plus (tropical) gather-relax.

The MS-BFS probe (``msbfs_probe``) gathers uint lane *words* and
OR-accumulates; this kernel is the same MAX_POS gather shape carried to
the tropical semiring — each vertex gathers its first ``max_pos``
neighbours' float lane values, adds the edge weight, and min-accumulates:

  idx  = starts + pos                          # pos = 0..max_pos-1
  vadj = col_idx[idx]                          # LoadAdj: masked gather
  acc  = min(acc, vals_plane[vadj] + w[idx])   # min-plus, where pos < deg

Masking is by VALUE, not by selector words: inactive source vertices hold
``inf`` lane values and phase-excluded edges hold ``inf`` weights (both
are absorbing under min-plus), so one kernel serves every delta-stepping
phase (light iteration, heavy settle) and any future tropical workload.
There is NO retirement test — unlike the boolean probe, a later neighbour
can always improve a served minimum — so the unroll runs all ``max_pos``
rounds; rows deeper than ``max_pos`` are finished by the caller's
segmented-scan fallback (``traversal.semiring.tropical_relax``).

Grid/VMEM layout mirrors ``msbfs_probe``: the dense lane count L is the
outer grid dimension (one float value plane per lane, resident across its
vertex tiles), vertex-tile operands stream via BlockSpec, and ``col_idx``
/ ``weights`` are held whole in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, SUBLANES, TILE, cdiv


def _semiring_relax_kernel(starts_ref, deg_ref, col_ref, w_ref, vp_ref,
                           acc_out, *, max_pos: int, m: int):
    starts = starts_ref[...]
    deg = deg_ref[...]
    col = col_ref[...]          # local edge slab, VMEM-resident
    w = w_ref[...]              # per-edge weights alongside it
    vp = vp_ref[0]              # this lane's value per vertex

    acc = jnp.full(starts.shape, jnp.inf, jnp.float32)
    for pos in range(max_pos):  # static unroll — the paper's MAX_POS loop
        live = pos < deg
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = jnp.take(col, idx, axis=0)                  # LoadAdj gather
        v = jnp.take(vp, vadj, axis=0)                     # lane-value gather
        we = jnp.take(w, idx, axis=0)
        acc = jnp.minimum(acc, jnp.where(live, v + we, jnp.inf))

    acc_out[0] = acc


@functools.partial(jax.jit, static_argnames=("max_pos", "interpret"))
def semiring_relax_pallas(starts: jnp.ndarray, deg: jnp.ndarray,
                          col_idx: jnp.ndarray, weights: jnp.ndarray,
                          vals: jnp.ndarray, max_pos: int = 8,
                          interpret: bool = True):
    """Returns acc — min over the first ``max_pos`` neighbours of
    ``vals[neighbour] + weight``, per vertex and lane (``inf`` where no
    neighbour relaxes).

    Shapes: starts/deg int32[n]; col_idx int32[m]; weights float32[m];
    vals float32[nf, L] (float32[nf] accepted as L=1 and returned flat)
    with nf >= n — the distributed shape probes a LOCAL row block against
    full-range values, ``col_idx`` holding global ids. Row counts are
    padded to a multiple of 1024 internally; L is a static grid dimension.
    """
    flat = vals.ndim == 1
    if flat:
        vals = vals[:, None]
    n = starts.shape[0]
    nf, lanes = vals.shape
    m = col_idx.shape[0]
    n_pad = cdiv(n, TILE) * TILE
    pad = n_pad - n
    nf_pad = cdiv(nf, TILE) * TILE

    def pad1(x, value=0):
        return jnp.pad(x, (0, pad), constant_values=value) if pad else x

    starts2 = pad1(starts).reshape(-1, SUBLANES, LANES)
    deg2 = pad1(deg).reshape(-1, SUBLANES, LANES)
    # plane-major [L, nf_pad] so the lane grid index selects one value plane
    vp = jnp.pad(vals, ((0, nf_pad - nf), (0, 0)),
                 constant_values=jnp.inf).T
    # padded rows carry inf values: a clipped/sentinel vadj gather reads
    # them as non-improving, never as a spurious zero-distance source
    w = weights.astype(jnp.float32)

    tiles = n_pad // TILE
    grid = (lanes, tiles)
    vert_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda pl_, i: (i, 0, 0))
    full_col = pl.BlockSpec(col_idx.shape, lambda pl_, i: (0,))
    full_w = pl.BlockSpec(w.shape, lambda pl_, i: (0,))
    plane_vp = pl.BlockSpec((1, nf_pad), lambda pl_, i: (pl_, 0))
    plane_tile_out = pl.BlockSpec((1, 1, SUBLANES, LANES),
                                  lambda pl_, i: (pl_, i, 0, 0))

    acc = pl.pallas_call(
        functools.partial(_semiring_relax_kernel, max_pos=max_pos, m=m),
        grid=grid,
        in_specs=[vert_spec, vert_spec, full_col, full_w, plane_vp],
        out_specs=plane_tile_out,
        out_shape=jax.ShapeDtypeStruct((lanes, tiles, SUBLANES, LANES),
                                       jnp.float32),
        interpret=interpret,
    )(starts2, deg2, col_idx, w, vp)

    acc = acc.reshape(lanes, n_pad)[:, :n].T
    return acc[:, 0] if flat else acc
