"""Jitted public wrapper for the semiring_relax kernel.

``semiring_relax`` is what ``repro.traversal.semiring.tropical_relax``
calls when ``impl='pallas'``: given per-edge weights and dense float lane
values (inf = inactive) it returns the min-plus accumulator over each
row's first ``max_pos`` neighbours; the caller folds in the deeper-row
residue via the segmented-scan fallback. The lane count L is a kernel
grid dimension — ONE launch serves every value plane.
"""
from __future__ import annotations

from repro.kernels.common import interpret_default
from repro.kernels.semiring_relax.kernel import semiring_relax_pallas


def semiring_relax(row_ptr, col_idx, weights, vals, max_pos: int = 8):
    starts = row_ptr[:-1]
    deg = row_ptr[1:] - row_ptr[:-1]
    return semiring_relax_pallas(starts, deg, col_idx, weights, vals,
                                 max_pos=max_pos,
                                 interpret=interpret_default())
