"""Pure-jnp oracle for the semiring_relax kernel."""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def semiring_relax_ref(starts, deg, col_idx, weights, vals,
                       max_pos: int = 8):
    """Identical math to the kernel, plain jnp: per row, min-plus over the
    first ``max_pos`` neighbours' lane values (inf where nothing relaxes).
    Accepts float32[nf, L] value planes (or float32[nf] as L=1);
    ``vals`` may have MORE rows than ``starts`` (distributed local-block
    relax against full-range values)."""
    flat = vals.ndim == 1
    if flat:
        vals = vals[:, None]
    m = col_idx.shape[0]
    w = weights.astype(jnp.float32)
    acc = jnp.full((starts.shape[0], vals.shape[1]), INF, jnp.float32)
    for pos in range(max_pos):
        live = (pos < deg)[:, None]
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = col_idx[idx]
        cand = vals[vadj] + w[idx][:, None]
        acc = jnp.minimum(acc, jnp.where(live, cand, INF))
    return acc[:, 0] if flat else acc
