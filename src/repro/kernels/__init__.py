# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Public kernel entry points.

Callers import from here (``from repro.kernels import msbfs_probe``)
instead of deep module paths — the op-level wrappers, their Pallas
kernels, and the pure-jnp references are all re-exported. Three op names
(``bottom_up_probe``, ``msbfs_probe``, ``semiring_relax``) intentionally shadow their
subpackages: the function bindings below land after the import system
binds the submodules, and deep *from*-imports
(``from repro.kernels.msbfs_probe.ops import msbfs_probe``) resolve
through ``sys.modules``, so they keep working. What the shadowing DOES
break: attribute traversal (``repro.kernels.msbfs_probe.ops``) and the
aliased deep-import form (``import repro.kernels.msbfs_probe.ops as m``),
both of which walk package attributes — use from-imports, as all in-repo
callers now do.

Importing this package pulls the Pallas machinery; the core engines keep
their pay-only-when-``probe_impl="pallas"`` discipline by importing it
inside the pallas branches only.
"""
from repro.kernels.bottom_up_probe.kernel import bottom_up_probe_pallas
from repro.kernels.bottom_up_probe.ops import bottom_up_probe
from repro.kernels.bottom_up_probe.ref import bottom_up_probe_ref
from repro.kernels.common import interpret_default
from repro.kernels.ell_spmm.kernel import ell_spmm_pallas
from repro.kernels.ell_spmm.ops import spmm_aggregate
from repro.kernels.ell_spmm.ref import ell_spmm_ref
from repro.kernels.msbfs_probe.kernel import msbfs_probe_pallas
from repro.kernels.msbfs_probe.ops import msbfs_probe
from repro.kernels.msbfs_probe.ref import msbfs_probe_ref
from repro.kernels.semiring_relax.kernel import semiring_relax_pallas
from repro.kernels.semiring_relax.ops import semiring_relax
from repro.kernels.semiring_relax.ref import semiring_relax_ref
from repro.kernels.topdown_scan.kernel import topdown_scan_pallas
from repro.kernels.topdown_scan.ops import topdown_step_pallas
from repro.kernels.topdown_scan.ref import topdown_scan_ref

__all__ = [
    "bottom_up_probe", "bottom_up_probe_pallas", "bottom_up_probe_ref",
    "ell_spmm_pallas", "ell_spmm_ref", "interpret_default", "msbfs_probe",
    "msbfs_probe_pallas", "msbfs_probe_ref", "semiring_relax",
    "semiring_relax_pallas", "semiring_relax_ref", "spmm_aggregate",
    "topdown_scan_pallas", "topdown_scan_ref", "topdown_step_pallas",
]
