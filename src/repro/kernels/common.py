"""Shared Pallas kernel utilities.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True`` (the kernel body runs as pure JAX).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# One VPU tile: 8 sublanes x 128 lanes of int32/f32.
SUBLANES = 8
LANES = 128
TILE = SUBLANES * LANES  # 1024 vertices / edges per grid step


def interpret_default() -> bool:
    """Run in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def split_u64_words(words: jnp.ndarray) -> jnp.ndarray:
    """uint64[..., W] -> uint32[..., 2W] as interleaved (lo, hi) planes.

    TPU Pallas has no 64-bit vector loads, so the u64 lane-word kernels
    gather each 64-bit word as two 32-bit half-words instead: plane 2k is
    word k's low half, plane 2k+1 its high half. Bitwise OR distributes
    over the split, so any OR-accumulating kernel runs unchanged on the
    half-planes (``merge_u64_words`` reassembles). Requires jax x64 —
    enforced upstream by ``packed.word_dtype``.
    """
    lo = (words & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    hi = (words >> jnp.uint64(32)).astype(jnp.uint32)
    return jnp.stack([lo, hi], axis=-1).reshape(
        words.shape[:-1] + (2 * words.shape[-1],))


def merge_u64_words(half_words: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``split_u64_words``: uint32[..., 2W] -> uint64[..., W]."""
    pairs = half_words.reshape(half_words.shape[:-1] + (-1, 2))
    return (pairs[..., 0].astype(jnp.uint64)
            | (pairs[..., 1].astype(jnp.uint64) << jnp.uint64(32)))


def pad_to(x: jnp.ndarray, multiple: int, axis: int = 0, value=0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
