"""Shared Pallas kernel utilities.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with ``interpret=True`` (the kernel body runs as pure JAX).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# One VPU tile: 8 sublanes x 128 lanes of int32/f32.
SUBLANES = 8
LANES = 128
TILE = SUBLANES * LANES  # 1024 vertices / edges per grid step


def interpret_default() -> bool:
    """Run in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def pad_to(x: jnp.ndarray, multiple: int, axis: int = 0, value=0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
