"""Jitted wrapper: full sum-aggregation with ELL + edge-parallel residue.

``spmm_aggregate(g, x, k_max)`` computes ``Y[v] = sum_{u in adj(v)} X[u]``
exactly: the ELL slab (Pallas kernel) covers positions < k_max, the residue
(positions >= k_max, heavy hubs) goes through segment_sum — the same
bounded-probe + fallback split as the BFS bottom-up.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import CSRGraph, ell_pad
from repro.kernels.common import interpret_default
from repro.kernels.ell_spmm.kernel import ell_spmm_pallas


def spmm_aggregate(g: CSRGraph, x: jnp.ndarray, k_max: int = 16,
                   use_pallas: bool = True) -> jnp.ndarray:
    n, m = g.n, g.m
    neigh, valid = ell_pad(g, k_max)
    if use_pallas:
        y = ell_spmm_pallas(neigh, valid, x, interpret=interpret_default())
    else:
        from repro.kernels.ell_spmm.ref import ell_spmm_ref
        y = ell_spmm_ref(neigh, valid, x)
    # Residue: adjacency positions >= k_max (rows longer than the slab).
    pos_e = jnp.arange(m, dtype=jnp.int32) - g.row_ptr[g.src_idx]
    tail = pos_e >= k_max
    contrib = jnp.where(tail[:, None], x[g.col_idx], 0.0)
    y_tail = jax.ops.segment_sum(contrib, g.src_idx, num_segments=n)
    return y + y_tail
