"""Pallas TPU kernel: ELL-slab SpMM (sum-aggregation message passing).

The paper's core insight — restructure irregular adjacency data into a
vector-friendly layout and bound the per-lane probe depth — applied to GNN
aggregation. CSR rows are restructured into an ELL slab of ``k_max``
neighbour slots per vertex (``repro.core.csr.ell_pad``); rows longer than
``k_max`` are handled by the caller through the edge-parallel residue path
(exactly the MAX_POS + fallback split of the BFS kernel).

Grid: (row tiles). Per step the kernel holds a (R, k_max) neighbour tile and
the full feature matrix X (f32[n_pad, d]) in VMEM, and accumulates
``Y[i] = sum_k valid[i,k] * X[neigh[i,k]]`` with a statically unrolled k loop
of masked VMEM row-gathers — the dense-lane analog of SpMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv

ROW_TILE = 256


def _spmm_kernel(neigh_ref, valid_ref, x_ref, y_out, *, k_max: int):
    neigh = neigh_ref[...]          # (R, k_max) int32
    valid = valid_ref[...]          # (R, k_max) int32
    x = x_ref[...]                  # (n_pad, d) f32 — VMEM resident
    acc = jnp.zeros((neigh.shape[0], x.shape[1]), dtype=jnp.float32)
    n_pad = x.shape[0]
    for k in range(k_max):          # static unroll — bounded probe depth
        idx = jnp.clip(neigh[:, k], 0, n_pad - 1)
        rows = jnp.take(x, idx, axis=0)
        acc = acc + jnp.where((valid[:, k] != 0)[:, None], rows, 0.0)
    y_out[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def ell_spmm_pallas(neigh: jnp.ndarray, valid: jnp.ndarray, x: jnp.ndarray,
                    interpret: bool = True) -> jnp.ndarray:
    """Y[i] = sum_k valid[i,k] * X[neigh[i,k]].

    neigh/valid: int32[n, k_max]; x: f32[n_src, d]. Returns f32[n, d].
    """
    n, k_max = neigh.shape
    n_pad = cdiv(n, ROW_TILE) * ROW_TILE
    pad = n_pad - n
    if pad:
        neigh = jnp.pad(neigh, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))

    grid = (n_pad // ROW_TILE,)
    row_spec = pl.BlockSpec((ROW_TILE, k_max), lambda i: (i, 0))
    x_spec = pl.BlockSpec(x.shape, lambda i: (0, 0))

    y = pl.pallas_call(
        functools.partial(_spmm_kernel, k_max=k_max),
        grid=grid,
        in_specs=[row_spec, row_spec, x_spec],
        out_specs=pl.BlockSpec((ROW_TILE, x.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(neigh, valid.astype(jnp.int32), x.astype(jnp.float32))
    return y[:n]
