"""Pure-jnp oracle for the ell_spmm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ell_spmm_ref(neigh, valid, x):
    n_src = x.shape[0]
    idx = jnp.clip(neigh, 0, n_src - 1)
    rows = x[idx]                                  # (n, k_max, d)
    mask = (valid != 0)[..., None]
    return jnp.sum(jnp.where(mask, rows, 0.0), axis=1).astype(jnp.float32)
