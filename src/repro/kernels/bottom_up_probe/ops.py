"""Jitted public wrapper for the bottom_up_probe kernel.

``bottom_up_probe`` is what ``repro.core.bottomup`` calls when
``probe_impl='pallas'``; it matches the `_probe_xla` contract:
(found bool[n], parent int32[n]).
"""
from __future__ import annotations


from repro.kernels.common import interpret_default
from repro.kernels.bottom_up_probe.kernel import bottom_up_probe_pallas


def bottom_up_probe(row_ptr, col_idx, frontier_words, unvisited, parent,
                    max_pos: int = 8):
    starts = row_ptr[:-1]
    deg = row_ptr[1:] - row_ptr[:-1]
    found, par = bottom_up_probe_pallas(
        starts, deg, unvisited, parent, col_idx, frontier_words,
        max_pos=max_pos, interpret=interpret_default())
    return found != 0, par
