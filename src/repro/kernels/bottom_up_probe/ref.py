"""Pure-jnp oracle for the bottom_up_probe kernel."""
from __future__ import annotations

import jax.numpy as jnp


def bottom_up_probe_ref(starts, deg, unvisited, parent, col_idx,
                        frontier_words, max_pos: int = 8):
    """Identical math to the kernel, plain jnp. Returns (found int32, parent)."""
    m = col_idx.shape[0]
    found = jnp.zeros_like(unvisited)
    par = parent
    for pos in range(max_pos):
        live = unvisited & (~found) & (pos < deg)
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = col_idx[idx]
        word = (vadj >> 5).astype(jnp.int32)
        bit = (vadj & 0x1F).astype(jnp.uint32)
        w = frontier_words[word]
        hit = live & (((w >> bit) & jnp.uint32(1)) == 1)
        par = jnp.where(hit, vadj, par)
        found = found | hit
    return found.astype(jnp.int32), par
