"""Pallas TPU kernel for the paper's LookingParents probe loop (Listing 1).

One grid step owns a (8, 128) tile of 1024 input vertices — the TPU analog
of the paper's 16-lane half-word tile. Per probe round ``pos``:

  live  = unvisited & ~found & (pos < deg)          # paper: mask_vis & ~mask
  vadj  = col_idx[start + pos]                      # LoadAdj: masked gather
  word  = vadj >> 5 ; bit = vadj & 0x1F             # Listing-1 bit math
  hit   = live & ((frontier_words[word] >> bit) & 1)  # in.Gather + Test
  parent= select(hit, vadj, parent)                 # P.Scatter
  found|= hit                                       # mask |= frontier

VMEM residency: the vertex tile operands are streamed via BlockSpec
(auto double-buffered — this replaces the paper's software prefetch), while
``col_idx`` (the local partition's edge slab) and the frontier bitmap words
are held whole in VMEM, mirroring the paper's reliance on bitmap words being
cache-resident. The MAX_POS loop is statically unrolled (MAX_POS=8, §5.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, SUBLANES, TILE, cdiv


def _probe_kernel(starts_ref, deg_ref, unv_ref, par_ref, col_ref, fw_ref,
                  found_out, par_out, *, max_pos: int, m: int):
    starts = starts_ref[...]
    deg = deg_ref[...]
    unv = unv_ref[...] != 0
    par = par_ref[...]
    col = col_ref[...]          # local edge slab, VMEM-resident
    fw = fw_ref[...]            # frontier bitmap words, VMEM-resident

    found = jnp.zeros_like(unv)
    for pos in range(max_pos):  # static unroll — the paper's MAX_POS loop
        live = unv & (~found) & (pos < deg)
        idx = jnp.clip(starts + pos, 0, m - 1)
        vadj = jnp.take(col, idx, axis=0)                  # LoadAdj gather
        word = (vadj >> 5).astype(jnp.int32)
        bit = (vadj & 0x1F).astype(jnp.uint32)
        w = jnp.take(fw, word, axis=0)                     # bitmap gather
        hit = live & (((w >> bit) & jnp.uint32(1)) == 1)
        par = jnp.where(hit, vadj, par)
        found = found | hit

    found_out[...] = found.astype(jnp.int32)
    par_out[...] = par


@functools.partial(jax.jit, static_argnames=("max_pos", "interpret"))
def bottom_up_probe_pallas(starts: jnp.ndarray, deg: jnp.ndarray,
                           unvisited: jnp.ndarray, parent: jnp.ndarray,
                           col_idx: jnp.ndarray, frontier_words: jnp.ndarray,
                           max_pos: int = 8, interpret: bool = True):
    """Returns (found int32[n], parent int32[n]).

    Shapes: starts/deg/unvisited/parent int32[n]; col_idx int32[m];
    frontier_words uint32[nw]. n is padded to a multiple of 1024 internally.
    """
    n = starts.shape[0]
    m = col_idx.shape[0]
    n_pad = cdiv(n, TILE) * TILE
    pad = n_pad - n

    def pad1(x, value=0):
        return jnp.pad(x, (0, pad), constant_values=value) if pad else x

    starts2 = pad1(starts).reshape(-1, SUBLANES, LANES)
    deg2 = pad1(deg).reshape(-1, SUBLANES, LANES)
    unv2 = pad1(unvisited.astype(jnp.int32)).reshape(-1, SUBLANES, LANES)
    par2 = pad1(parent, -1).reshape(-1, SUBLANES, LANES)

    grid = (n_pad // TILE,)
    tile_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    full_col = pl.BlockSpec(col_idx.shape, lambda i: (0,))
    full_fw = pl.BlockSpec(frontier_words.shape, lambda i: (0,))

    found, par = pl.pallas_call(
        functools.partial(_probe_kernel, max_pos=max_pos, m=m),
        grid=grid,
        in_specs=[tile_spec, tile_spec, tile_spec, tile_spec, full_col,
                  full_fw],
        out_specs=[tile_spec, tile_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad // TILE, SUBLANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((n_pad // TILE, SUBLANES, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(starts2, deg2, unv2, par2, col_idx, frontier_words)

    return found.reshape(n_pad)[:n], par.reshape(n_pad)[:n]
