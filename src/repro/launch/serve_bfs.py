"""Streaming BFS serving loop — roots enqueue into idle lanes MID-SWEEP.

The serving scenario from ROADMAP: queries (BFS roots) arrive over time,
and the pipelined MS-BFS engine (``repro.core.msbfs``) never drains
between them — an arriving root waits in the pending queue only until any
lane finishes its current traversal, then takes over that lane's bit slot
while the other lanes keep traversing. Latency is measured in engine
*layers* (the deterministic unit of work), so runs are reproducible.

  PYTHONPATH=src python -m repro.launch.serve_bfs --scale 12 --lanes 32 \
      --queries 96 --burst 8 --every 2 [--validate] [--ndev 4]

``--lanes 0`` sizes the bit-lane pool adaptively from the query count and
the graph's degree stats; ``--ndev N`` serves the SAME loop on the sharded
engine (``repro.core.dist_msbfs``) over N devices (force host devices with
XLA_FLAGS=--xla_force_host_platform_device_count=N before launch).

Reports per-query sojourn layers (arrival -> answer), lane occupancy, and
aggregate TEPS of the whole serving window.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.core.msbfs import (adaptive_lane_pool, msbfs_engine_enqueue,
                              msbfs_engine_idle, msbfs_engine_init,
                              msbfs_engine_result, msbfs_engine_step)
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree


def _engine(g, mode: str, probe_impl: str, ndev: int):
    """(init, enqueue, step, idle, result) for the chosen engine — the
    serving loop is engine-agnostic; only these five calls differ between
    the single-host and the sharded pipelined engine."""
    if ndev <= 1:
        return (
            lambda cap, lanes: msbfs_engine_init(g, capacity=cap,
                                                 lanes=lanes),
            msbfs_engine_enqueue,
            lambda s: msbfs_engine_step(g, s, mode, ALPHA_DEFAULT,
                                        BETA_DEFAULT, 8, probe_impl),
            msbfs_engine_idle,
            lambda s: msbfs_engine_result(g, s),
        )
    from repro.core import dist_msbfs as dm
    mesh = dm.host_mesh(ndev)
    dg = dm.partition_graph(g, ndev)
    return (
        lambda cap, lanes: dm.dist_msbfs_engine_init(dg, mesh, cap, lanes),
        dm.dist_msbfs_engine_enqueue,
        lambda s: dm.dist_msbfs_engine_step(dg, s, mesh, mode,
                                            ALPHA_DEFAULT, BETA_DEFAULT, 8,
                                            probe_impl),
        dm.dist_msbfs_engine_idle,
        lambda s: dm.dist_msbfs_engine_result(dg, s, mesh),
    )


def serve(g, roots: np.ndarray, lanes: int, burst: int, every: int,
          mode: str = "hybrid", probe_impl: str = "xla",
          validate: bool = False, ndev: int = 1) -> dict:
    """Feed ``roots`` to the engine ``burst`` at a time every ``every``
    layers; run until all are answered. Returns serving statistics.
    ``lanes=0`` picks the pool width adaptively; ``ndev>1`` runs the
    sharded engine."""
    num_q = len(roots)
    if num_q < 1:
        raise ValueError("need at least one query")
    if burst < 1 or every < 1:
        raise ValueError(f"burst and every must be >= 1, "
                         f"got burst={burst} every={every}")
    if not lanes:
        lanes = adaptive_lane_pool(num_q, g.n, g.m)
    eng_init, eng_enqueue, eng_step, eng_idle, eng_result = _engine(
        g, mode, probe_impl, ndev)
    state = eng_init(num_q, lanes)

    arrival = np.full(num_q, -1, np.int64)   # layer each query arrived
    answered = np.full(num_q, -1, np.int64)  # layer each query was answered
    occupancy = []

    def enqueue(s, lo, hi, layer):
        arrival[lo:hi] = layer
        return eng_enqueue(s, roots[lo:hi])

    # warm the step executable on a throwaway state so the serving window
    # measures traversal, not one-time XLA compilation (same discipline as
    # the graph500 harness's warmup)
    jax.block_until_ready(
        eng_step(eng_enqueue(state, roots[:1])).out_depth)

    state = enqueue(state, 0, min(burst, num_q), 0)
    fed = min(burst, num_q)
    layer = 0
    t0 = time.perf_counter()
    while fed < num_q or not eng_idle(state):
        state = eng_step(state)
        layer += 1
        occupancy.append(int(np.sum(np.asarray(state.lane_qidx) < num_q)))
        done = np.asarray(state.out_layers[:num_q]) > 0
        answered[done & (answered < 0)] = layer
        if layer % every == 0 and fed < num_q:
            nxt = min(fed + burst, num_q)
            state = enqueue(state, fed, nxt, layer)
            fed = nxt
    jax.block_until_ready(state.out_depth)
    wall = time.perf_counter() - t0

    out = eng_result(state)
    if validate:
        from repro.core.csr import to_numpy_adj
        rp, ci = to_numpy_adj(g)
        parent = np.asarray(out.parent)
        for i, r in enumerate(roots):
            validate_bfs_tree(rp, ci, parent[:, i], int(r))

    sojourn = answered - arrival
    edges = int(np.asarray(out.edges_traversed).sum()) // 2
    return dict(
        queries=num_q, lanes=lanes, ndev=ndev, layers=layer,
        wall_s=round(wall, 4),
        sojourn_layers=dict(
            mean=float(sojourn.mean()), p50=float(np.percentile(sojourn, 50)),
            p95=float(np.percentile(sojourn, 95)), max=int(sojourn.max())),
        mean_lane_occupancy=float(np.mean(occupancy)),
        aggregate_mteps=round(edges / wall / 1e6, 2) if wall > 0 else 0.0,
        validated=bool(validate),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=32,
                    help="bit-lane pool size; 0 = adaptive from queue "
                         "depth + degree stats")
    ap.add_argument("--ndev", type=int, default=1,
                    help="shard the engine over this many devices")
    ap.add_argument("--queries", type=int, default=96)
    ap.add_argument("--burst", type=int, default=8,
                    help="queries arriving per burst")
    ap.add_argument("--every", type=int, default=2,
                    help="layers between arrival bursts")
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup"))
    ap.add_argument("--probe-impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    g = rmat_graph(args.scale, args.edgefactor, args.seed)
    roots = sample_roots(g, args.queries, seed=args.seed + 1)
    stats = serve(g, roots, args.lanes, args.burst, args.every,
                  mode=args.mode, probe_impl=args.probe_impl,
                  validate=args.validate, ndev=args.ndev)
    print(json.dumps(stats, indent=2))


if __name__ == "__main__":
    main()
