"""Streaming analytics serving loop — mixed query types, ONE engine sweep.

PR 2's serving scenario grown into a multi-workload analytics server: the
pipelined MS-BFS engine (``repro.core.msbfs``; ``--ndev N`` swaps in the
sharded ``repro.core.dist_msbfs``) never drains between requests, and the
requests themselves are no longer only BFS roots. Every analytics query
type that reduces to lane traversals rides the same bit-lane pool:

* ``bfs``       — one root, full traversal (parents/depths);
* ``khop``      — one root, answer = the depth <= k band of its lane
                  (read from the dense depth column here; the offline
                  ``analytics.khop`` query exposes the same band as
                  packed ``MSBFSResult.reached_words``);
* ``reach``     — one root + target vertex, answer = hop distance;
* ``closeness`` — a sampled-source centrality estimate: S roots enqueued
                  as one request, answered when ALL S lanes flush, the
                  estimator is ``analytics.closeness.closeness_from_depths``.

Each enqueued request is tagged with its query type; the loop reports
per-type sojourn (arrival layer -> answer layer) and latency statistics on
top of the aggregate TEPS / occupancy numbers, so a mixed workload shows
which query class is starving.

  PYTHONPATH=src python -m repro.launch.serve_bfs --scale 12 --lanes 32 \
      --queries 64 --mix bfs:4,khop:2,reach:1,closeness:1 \
      --burst 4 --every 2 [--validate] [--ndev 4]

``--lanes 0`` sizes the bit-lane pool adaptively; latency is measured in
engine *layers* (the deterministic unit of work), so runs are
reproducible.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.core.msbfs import (adaptive_lane_pool, msbfs_engine_enqueue,
                              msbfs_engine_idle, msbfs_engine_init,
                              msbfs_engine_result, msbfs_engine_step)
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree

QUERY_KINDS = ("bfs", "khop", "reach", "closeness")


@dataclass
class Request:
    """One tagged serving request = 1+ BFS lanes through the shared engine."""
    qtype: str                   # one of QUERY_KINDS
    roots: np.ndarray            # int32[s] lanes this request enqueues
    k: int = 0                   # khop radius
    target: int = -1             # reach target vertex
    slots: slice | None = None   # engine queue slots, set at enqueue time
    answer: dict = field(default_factory=dict)


def bfs_requests(roots) -> list[Request]:
    """Plain BFS workload (the PR-2 serving loop): one request per root."""
    return [Request("bfs", np.asarray([r], np.int32)) for r in roots]


def _parse_mix(spec: str) -> dict[str, float]:
    """'bfs:4,khop:2' -> normalized weights; bare names weigh 1."""
    weights = {}
    for part in spec.split(","):
        name, _, w = part.strip().partition(":")
        if name not in QUERY_KINDS:
            raise ValueError(f"unknown query type {name!r} in mix {spec!r} "
                             f"— expected {QUERY_KINDS}")
        weights[name] = float(w) if w else 1.0
        if weights[name] < 0:
            raise ValueError(
                f"negative weight for {name!r} in mix {spec!r}")
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"mix {spec!r} has no positive weight")
    return {k: v / total for k, v in weights.items()}


def make_requests(g, num: int, mix: str = "bfs", seed: int = 0,
                  khop_k: int = 2, closeness_sources: int = 8,
                  ) -> list[Request]:
    """Draw ``num`` requests from the workload mix. Roots follow the
    Graph500 sampling rule (degree > 0); reach targets are arbitrary
    vertices (unreachable answers are part of the workload)."""
    weights = _parse_mix(mix)
    rng = np.random.default_rng(seed)
    kinds = rng.choice(list(weights), size=num, p=list(weights.values()))
    # a degree>0 pool for traversal roots; requests may reuse roots (they
    # are independent traversals). Closeness sources are NOT drawn from
    # the pool: the closeness_from_depths n/k scaling assumes sources
    # uniform over ALL n vertices (zero-degree ones included), exactly
    # like the offline estimator — a deg>0 pool would inflate the
    # estimates by ~n/pool.size.
    pool = sample_roots(g, g.n, seed=seed + 1)
    closeness_sources = min(max(1, closeness_sources), g.n)
    out = []
    for kind in kinds:
        if kind == "closeness":
            s = np.sort(rng.choice(g.n, size=closeness_sources,
                                   replace=False)).astype(np.int32)
            out.append(Request("closeness", s))
        elif kind == "reach":
            out.append(Request(
                "reach", np.asarray([rng.choice(pool)], np.int32),
                target=int(rng.integers(g.n))))
        elif kind == "khop":
            out.append(Request(
                "khop", np.asarray([rng.choice(pool)], np.int32), k=khop_k))
        else:
            out.append(Request(
                "bfs", np.asarray([rng.choice(pool)], np.int32)))
    return out


def _engine(g, mode: str, probe_impl: str, ndev: int):
    """(init, enqueue, step, idle, result) for the chosen engine — the
    serving loop is engine-agnostic; only these five calls differ between
    the single-host and the sharded pipelined engine."""
    if ndev <= 1:
        return (
            lambda cap, lanes: msbfs_engine_init(g, capacity=cap,
                                                 lanes=lanes),
            msbfs_engine_enqueue,
            lambda s: msbfs_engine_step(g, s, mode, ALPHA_DEFAULT,
                                        BETA_DEFAULT, 8, probe_impl),
            msbfs_engine_idle,
            lambda s, parents=True: msbfs_engine_result(
                g, s, derive_parents=parents),
        )
    from repro.core import dist_msbfs as dm
    mesh = dm.host_mesh(ndev)
    dg = dm.partition_graph(g, ndev)
    return (
        lambda cap, lanes: dm.dist_msbfs_engine_init(dg, mesh, cap, lanes),
        dm.dist_msbfs_engine_enqueue,
        lambda s: dm.dist_msbfs_engine_step(dg, s, mesh, mode,
                                            ALPHA_DEFAULT, BETA_DEFAULT, 8,
                                            probe_impl),
        dm.dist_msbfs_engine_idle,
        lambda s, parents=True: dm.dist_msbfs_engine_result(
            dg, s, mesh, derive_parents=parents),
    )


def _sojourn_stats(sojourn: np.ndarray) -> dict:
    return dict(
        mean=float(sojourn.mean()), p50=float(np.percentile(sojourn, 50)),
        p95=float(np.percentile(sojourn, 95)), max=int(sojourn.max()))


def _answers(g, requests: list[Request], depth: np.ndarray) -> dict:
    """Post-process each request's lanes into its typed answer; returns a
    small per-type summary for the stats dict."""
    from repro.analytics.closeness import closeness_from_depths
    n = g.n
    summary: dict[str, dict] = {}
    for req in requests:
        d = depth[:, req.slots]
        if req.qtype == "bfs":
            req.answer = dict(reached=int((d[:, 0] >= 0).sum()),
                              layers=int(d[:, 0].max()) + 1)
        elif req.qtype == "khop":
            band = (d[:, 0] >= 0) & (d[:, 0] <= req.k)
            req.answer = dict(k=req.k, size=int(band.sum()))
        elif req.qtype == "reach":
            hops = int(d[req.target, 0])
            req.answer = dict(target=req.target, hops=hops,
                              reachable=hops >= 0)
        elif req.qtype == "closeness":
            c = closeness_from_depths(d, n)
            v = int(np.argmax(c))
            req.answer = dict(sources=int(req.roots.size), top_vertex=v,
                              top_closeness=float(c[v]))
    summary["bfs"] = dict(mean_reached=float(np.mean(
        [r.answer["reached"] for r in requests if r.qtype == "bfs"] or [0])))
    summary["khop"] = dict(mean_size=float(np.mean(
        [r.answer["size"] for r in requests if r.qtype == "khop"] or [0])))
    reach = [r for r in requests if r.qtype == "reach"]
    summary["reach"] = dict(reachable_frac=float(np.mean(
        [r.answer["reachable"] for r in reach])) if reach else 0.0)
    clo = [r for r in requests if r.qtype == "closeness"]
    summary["closeness"] = dict(top_vertices=sorted(
        {r.answer["top_vertex"] for r in clo}))
    return {k: v for k, v in summary.items()
            if any(r.qtype == k for r in requests)}


def serve(g, requests: list[Request], lanes: int, burst: int, every: int,
          mode: str = "hybrid", probe_impl: str = "xla",
          validate: bool = False, ndev: int = 1) -> dict:
    """Feed tagged ``requests`` to the engine ``burst`` requests at a time
    every ``every`` layers; run until all are answered. Returns serving
    statistics with per-query-type sojourn breakdowns. ``lanes=0`` picks
    the pool width adaptively; ``ndev>1`` runs the sharded engine."""
    num_req = len(requests)
    if num_req < 1:
        raise ValueError("need at least one request")
    if burst < 1 or every < 1:
        raise ValueError(f"burst and every must be >= 1, "
                         f"got burst={burst} every={every}")
    capacity = int(sum(r.roots.size for r in requests))
    if not lanes:
        lanes = adaptive_lane_pool(capacity, g.n, g.m)
    eng_init, eng_enqueue, eng_step, eng_idle, eng_result = _engine(
        g, mode, probe_impl, ndev)
    state = eng_init(capacity, lanes)

    arrival = np.full(num_req, -1, np.int64)   # layer the request arrived
    answered = np.full(num_req, -1, np.int64)  # layer it was fully answered
    occupancy = []

    slot_hi = 0

    def enqueue(s, lo, hi, layer):
        nonlocal slot_hi
        for req in requests[lo:hi]:
            req.slots = slice(slot_hi, slot_hi + req.roots.size)
            slot_hi += req.roots.size
            s = eng_enqueue(s, req.roots)
        arrival[lo:hi] = layer
        return s

    # warm the step executable on a throwaway state so the serving window
    # measures traversal, not one-time XLA compilation (same discipline as
    # the graph500 harness's warmup)
    jax.block_until_ready(
        eng_step(eng_enqueue(state, requests[0].roots[:1])).out_depth)

    state = enqueue(state, 0, min(burst, num_req), 0)
    fed = min(burst, num_req)
    layer = 0
    t0 = time.perf_counter()
    while fed < num_req or not eng_idle(state):
        state = eng_step(state)
        layer += 1
        occupancy.append(
            int(np.sum(np.asarray(state.lane_qidx) < capacity)))
        done_slots = np.asarray(state.out_layers[:capacity]) > 0
        for i, req in enumerate(requests[:fed]):
            if answered[i] < 0 and done_slots[req.slots].all():
                answered[i] = layer   # a request answers when EVERY lane has
        if layer % every == 0 and fed < num_req:
            nxt = min(fed + burst, num_req)
            state = enqueue(state, fed, nxt, layer)
            fed = nxt
    jax.block_until_ready(state.out_depth)
    wall = time.perf_counter() - t0

    # parents cost an O(m) scatter-min pass per lane chunk and only the
    # validator reads them — the answers post-processing is depth-only
    out = eng_result(state, validate)
    depth = np.asarray(out.depth)
    if validate:
        from repro.core.csr import to_numpy_adj
        rp, ci = to_numpy_adj(g)
        parent = np.asarray(out.parent)
        col = 0
        for req in requests:
            for r in req.roots:   # every lane is a BFS tree, whatever the tag
                validate_bfs_tree(rp, ci, parent[:, col], int(r))
                col += 1

    sojourn = answered - arrival
    qtypes = np.asarray([r.qtype for r in requests])
    per_type = {
        kind: dict(count=int((qtypes == kind).sum()),
                   lanes=int(sum(r.roots.size for r in requests
                                 if r.qtype == kind)),
                   sojourn_layers=_sojourn_stats(sojourn[qtypes == kind]))
        for kind in QUERY_KINDS if (qtypes == kind).any()}
    edges = int(np.asarray(out.edges_traversed).sum()) // 2
    return dict(
        requests=num_req, total_lanes=capacity, lanes=lanes, ndev=ndev,
        layers=layer, wall_s=round(wall, 4),
        sojourn_layers=_sojourn_stats(sojourn),
        per_type=per_type,
        answers=_answers(g, requests, depth),
        mean_lane_occupancy=float(np.mean(occupancy)),
        aggregate_mteps=round(edges / wall / 1e6, 2) if wall > 0 else 0.0,
        validated=bool(validate),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=32,
                    help="bit-lane pool size; 0 = adaptive from queue "
                         "depth + degree stats")
    ap.add_argument("--ndev", type=int, default=1,
                    help="shard the engine over this many devices")
    ap.add_argument("--queries", type=int, default=64,
                    help="number of requests (a closeness request costs "
                         "--closeness-sources lanes)")
    ap.add_argument("--mix", default="bfs",
                    help="workload mix, e.g. bfs:4,khop:2,reach:1,"
                         "closeness:1 (weights optional)")
    ap.add_argument("--khop-k", type=int, default=2)
    ap.add_argument("--closeness-sources", type=int, default=8,
                    help="sampled sources (lanes) per closeness request")
    ap.add_argument("--burst", type=int, default=8,
                    help="requests arriving per burst")
    ap.add_argument("--every", type=int, default=2,
                    help="layers between arrival bursts")
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup"))
    ap.add_argument("--probe-impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    g = rmat_graph(args.scale, args.edgefactor, args.seed)
    requests = make_requests(g, args.queries, mix=args.mix, seed=args.seed,
                             khop_k=args.khop_k,
                             closeness_sources=args.closeness_sources)
    stats = serve(g, requests, args.lanes, args.burst, args.every,
                  mode=args.mode, probe_impl=args.probe_impl,
                  validate=args.validate, ndev=args.ndev)
    print(json.dumps(stats, indent=2))


if __name__ == "__main__":
    main()
