"""Analytics serving CLI — a thin front end over ``repro.serving``.

The serving loop itself lives in ``repro.serving.AnalyticsService``:
admission control (bounded pending queue, per-tenant quotas), FIFO
dispatch into the packed MS-BFS and delta-stepping tropical lane pools,
and mid-sweep STREAMING read-outs — a depth-k ``khop`` (or ``reach``)
request is answered the moment its lane's layer counter passes k,
bit-identical to the offline ``run_query`` answer, and its lane is
retired back to the pool. This module provides:

* ``main`` — the CLI: generate an R-MAT graph, build a deterministic
  mixed-workload trace (``repro.serving.trace.synthetic_trace`` — every
  request is an ``AnalyticsRequest`` envelope, so the CLI and
  ``run_query`` route through the SAME tag registry and handler table),
  replay it through the service, print the stats JSON;
* ``serve`` / ``Request`` / ``make_requests`` / ``bfs_requests`` — the
  PR-5 compatibility surface: the old tuple-tagged request API
  implemented ON TOP of the service (streaming off, single epoch) so the
  flush-time answers, sojourn accounting, and BFS-tree validation of the
  original loop are preserved exactly.

  PYTHONPATH=src python -m repro.launch.serve_bfs --scale 12 --lanes 32 \
      --queries 64 --mix bfs:4,khop:2,reach:1,closeness:1,sssp:2 \
      --burst 4 --every 2 [--validate] [--ndev 4] [--delta 0.05] \
      [--slots 256] [--tenants 2] [--tenant-quota 16] [--no-streaming]

``--listen PORT`` switches to the LIVE path: the service runs its worker
thread, an ``ObservabilityServer`` exposes /metrics, /healthz, /readyz,
/debug/* and the /v1 submit/poll/result wire transport, the synthetic
trace is submitted through the real front door, and the process stays up
``--serve-seconds`` for external scrapes (the CI trace-smoke job curls
it). ``--flight-out`` streams the per-layer flight log (JSONL),
``--doctor-out`` writes the sweep-doctor audit of the recorded sweeps
(see ``repro.obs.doctor``), and ``--slo-p99`` / ``--slo-queue-depth`` /
``--slo-reject-rate`` arm the SLO watchdog behind /readyz.

Latency is measured in engine *layers* (the deterministic unit of work);
aggregate TEPS counts the packed engine's traversed edges only (weighted
relaxation work is reported as ``sssp_steps``).
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass, field

import numpy as np

from repro.analytics.api import (AnalyticsRequest, BFSQuery, ClosenessQuery,
                                 KHopQuery, ReachQuery, SSSPQuery)
from repro.analytics.api import QUERY_KINDS as _API_KINDS
from repro.core.csr import WeightedCSRGraph
from repro.graph.generator import rmat_weighted_graph, sample_roots
from repro.serving import AnalyticsService, ServiceConfig
from repro.serving.trace import parse_mix, synthetic_trace

# the streamable subset of the query registry this harness's compat
# surface understands (whole-graph kinds go through the service's inline
# batch path and have no tuple-tagged Request spelling)
QUERY_KINDS = ("bfs", "khop", "reach", "closeness", "sssp")
assert set(QUERY_KINDS) <= set(_API_KINDS)


@dataclass
class Request:
    """One tagged serving request = 1+ BFS lanes through the shared engine."""
    qtype: str                   # one of QUERY_KINDS
    roots: np.ndarray            # int32[s] lanes this request enqueues
    k: int = 0                   # khop radius
    target: int = -1             # reach target vertex
    slots: slice | None = None   # engine queue slots, set at enqueue time
    answer: dict = field(default_factory=dict)


def bfs_requests(roots) -> list[Request]:
    """Plain BFS workload (the PR-2 serving loop): one request per root."""
    return [Request("bfs", np.asarray([r], np.int32)) for r in roots]


def make_requests(g, num: int, mix: str = "bfs", seed: int = 0,
                  khop_k: int = 2, closeness_sources: int = 8,
                  ) -> list[Request]:
    """Draw ``num`` requests from the workload mix (tags validated by
    ``repro.serving.trace.parse_mix`` — the ONE registry-backed error
    path). Roots follow the Graph500 sampling rule (degree > 0); reach
    targets are arbitrary vertices (unreachable answers are part of the
    workload)."""
    weights = parse_mix(mix)
    bad = sorted(set(weights) - set(QUERY_KINDS))
    if bad:
        raise ValueError(
            f"mix {mix!r} includes non-streamable tags {bad} — the "
            f"tuple-tagged request surface serves {QUERY_KINDS}; submit "
            f"those kinds to AnalyticsService as envelopes instead")
    rng = np.random.default_rng(seed)
    kinds = rng.choice(list(weights), size=num, p=list(weights.values()))
    # a degree>0 pool for traversal roots; requests may reuse roots (they
    # are independent traversals). Closeness sources are NOT drawn from
    # the pool: the closeness_from_depths n/k scaling assumes sources
    # uniform over ALL n vertices (zero-degree ones included), exactly
    # like the offline estimator — a deg>0 pool would inflate the
    # estimates by ~n/pool.size.
    pool = sample_roots(g, g.n, seed=seed + 1)
    closeness_sources = min(max(1, closeness_sources), g.n)
    out = []
    for kind in kinds:
        if kind == "closeness":
            s = np.sort(rng.choice(g.n, size=closeness_sources,
                                   replace=False)).astype(np.int32)
            out.append(Request("closeness", s))
        elif kind == "sssp":
            out.append(Request(
                "sssp", np.asarray([rng.choice(pool)], np.int32)))
        elif kind == "reach":
            out.append(Request(
                "reach", np.asarray([rng.choice(pool)], np.int32),
                target=int(rng.integers(g.n))))
        elif kind == "khop":
            out.append(Request(
                "khop", np.asarray([rng.choice(pool)], np.int32), k=khop_k))
        else:
            out.append(Request(
                "bfs", np.asarray([rng.choice(pool)], np.int32)))
    return out


def _to_envelope(req: Request, arrival: int) -> AnalyticsRequest:
    """Lift a tuple-tagged compat request into the unified envelope —
    explicit sources everywhere, so the service's answers reproduce the
    old loop's references bit-for-bit."""
    roots = tuple(int(r) for r in req.roots)
    if req.qtype == "bfs":
        q = BFSQuery(sources=roots)
    elif req.qtype == "khop":
        q = KHopQuery(sources=roots, k=int(req.k))
    elif req.qtype == "reach":
        q = ReachQuery(sources=roots, targets=(int(req.target),))
    elif req.qtype == "closeness":
        q = ClosenessQuery(sources=roots, chunk=len(roots))
    elif req.qtype == "sssp":
        q = SSSPQuery(sources=roots)   # delta pinned at the service level
    else:
        raise ValueError(
            f"unknown query type {req.qtype!r} — expected {QUERY_KINDS}")
    return AnalyticsRequest(query=q, arrival=int(arrival))


def _compat_answer(req: Request, result) -> dict:
    """The old loop's per-request answer dict from the typed result."""
    if req.qtype == "bfs":
        d = np.asarray(result.depth)[:, 0]
        return dict(reached=int((d >= 0).sum()), layers=int(d.max()) + 1)
    if req.qtype == "khop":
        return dict(k=req.k, size=int(result.counts[0]))
    if req.qtype == "reach":
        hops = int(result.hops[0, 0])
        return dict(target=req.target, hops=hops, reachable=hops >= 0)
    if req.qtype == "closeness":
        c = result.closeness
        v = int(np.argmax(c))
        return dict(sources=int(req.roots.size), top_vertex=v,
                    top_closeness=float(c[v]))
    d = np.asarray(result.dist)[:, 0]
    fin = np.isfinite(d)
    return dict(reached=int(fin.sum()),
                max_dist=float(d[fin].max()) if fin.any() else 0.0,
                truncated=bool(result.truncated_lanes.any()))


def _answers_summary(requests: list[Request]) -> dict:
    """Per-type answer summary (the old stats['answers'] block)."""
    summary: dict[str, dict] = {}
    summary["bfs"] = dict(mean_reached=float(np.mean(
        [r.answer["reached"] for r in requests if r.qtype == "bfs"] or [0])))
    summary["khop"] = dict(mean_size=float(np.mean(
        [r.answer["size"] for r in requests if r.qtype == "khop"] or [0])))
    reach = [r for r in requests if r.qtype == "reach"]
    summary["reach"] = dict(reachable_frac=float(np.mean(
        [r.answer["reachable"] for r in reach])) if reach else 0.0)
    clo = [r for r in requests if r.qtype == "closeness"]
    summary["closeness"] = dict(top_vertices=sorted(
        {r.answer["top_vertex"] for r in clo}))
    summary["sssp"] = dict(mean_reached=float(np.mean(
        [r.answer["reached"] for r in requests if r.qtype == "sssp"] or [0])))
    return {k: v for k, v in summary.items()
            if any(r.qtype == k for r in requests)}


def serve(g, requests: list[Request], lanes: int, burst: int, every: int,
          mode: str = "hybrid", probe_impl: str = "xla",
          validate: bool = False, ndev: int = 1,
          delta: float | None = None) -> dict:
    """Feed tagged ``requests`` to the engines ``burst`` requests at a
    time every ``every`` layers; run until all are answered. Returns
    serving statistics with per-query-type sojourn breakdowns.

    This is the compatibility surface over ``AnalyticsService``: one
    epoch sized to the exact lane demand, streaming OFF (every answer at
    lane flush — the validator needs complete depth columns and BFS-tree
    parents), ``lanes=0`` adaptive pool sizing, ``ndev>1`` sharding both
    engines, ``delta=None`` the weighted default — all exactly the old
    loop's semantics, now scheduled by the service."""
    wg = g if isinstance(g, WeightedCSRGraph) else None
    num_req = len(requests)
    if num_req < 1:
        raise ValueError("need at least one request")
    if burst < 1 or every < 1:
        raise ValueError(f"burst and every must be >= 1, "
                         f"got burst={burst} every={every}")
    for r in requests:
        if r.qtype not in QUERY_KINDS:
            raise ValueError(
                f"unknown query type {r.qtype!r} — expected {QUERY_KINDS}")
    sssp_reqs = [r for r in requests if r.qtype == "sssp"]
    if sssp_reqs and wg is None:
        raise ValueError("sssp requests need a WeightedCSRGraph — "
                         "generate the serving graph with "
                         "rmat_weighted_graph")
    bool_cap = int(sum(r.roots.size for r in requests
                       if r.qtype != "sssp"))
    sssp_cap = int(sum(r.roots.size for r in sssp_reqs))
    if not lanes:
        from repro.core.msbfs import adaptive_lane_pool
        base = wg.csr if wg is not None else g
        lanes = adaptive_lane_pool(max(bool_cap, 1), base.n, base.m)
    from repro.traversal.sssp import DEFAULT_LANES
    svc = AnalyticsService(g, ServiceConfig(
        lanes=int(lanes), slots=max(bool_cap, 1),
        sssp_lanes=max(1, min(lanes, max(sssp_cap, 1), DEFAULT_LANES)),
        sssp_slots=max(sssp_cap, 1),
        max_pending=num_req + 1, mode=mode, probe_impl=probe_impl,
        ndev=ndev, delta=delta, streaming=False))
    svc.warmup(packed=bool_cap > 0, tropical=sssp_cap > 0)

    pairs = [(req, _to_envelope(req, (i // burst) * every))
             for i, req in enumerate(requests)]
    svc.replay([env for _, env in pairs])

    for req, env in pairs:
        rec = svc.record(env.id)
        req.slots = rec.slots
        req.answer = _compat_answer(req, rec.answer.result)

    if validate and bool_cap:
        from repro.core.csr import to_numpy_adj
        from repro.graph.validate import validate_bfs_tree
        out = svc.packed_result(derive_parents=True)
        rp, ci = to_numpy_adj(svc.engine.g)
        parent = np.asarray(out.parent)
        for req in requests:
            if req.qtype == "sssp":   # tropical lanes carry no BFS tree
                continue
            for j, r in enumerate(req.roots):  # every boolean lane is a
                validate_bfs_tree(                 # BFS tree, whatever the tag
                    rp, ci, parent[:, req.slots][:, j], int(r))

    s = svc.stats()
    stats = dict(
        requests=num_req, total_lanes=bool_cap + sssp_cap,
        lanes=int(lanes), ndev=ndev, layers=s["layers"],
        wall_s=s["wall_s"], sojourn_layers=s["sojourn_layers"],
        per_type=s["per_type"],
        answers=_answers_summary(requests),
        mean_lane_occupancy=s["mean_lane_occupancy"],
        aggregate_mteps=s["aggregate_mteps"],
        validated=bool(validate and bool_cap),
    )
    if sssp_cap:
        stats["delta"] = float(svc.delta)
        stats["sssp_steps"] = s["sssp_steps"]
    return stats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=32,
                    help="bit-lane pool size; 0 = adaptive from queue "
                         "depth + degree stats")
    ap.add_argument("--ndev", type=int, default=1,
                    help="shard the engine over this many devices")
    ap.add_argument("--queries", type=int, default=64,
                    help="number of requests (a closeness request costs "
                         "--closeness-sources lanes)")
    ap.add_argument("--mix", default="bfs",
                    help="workload mix, e.g. bfs:4,khop:2,reach:1,"
                         "closeness:1,sssp:1 (weights optional; any tag "
                         "from the analytics registry)")
    ap.add_argument("--delta", type=float, default=None,
                    help="delta-stepping bucket width for sssp requests "
                         "(default: the graph's default_delta)")
    ap.add_argument("--khop-k", type=int, default=2)
    ap.add_argument("--closeness-sources", type=int, default=8,
                    help="sampled sources (lanes) per closeness request")
    ap.add_argument("--burst", type=int, default=8,
                    help="requests arriving per burst")
    ap.add_argument("--every", type=int, default=2,
                    help="layers between arrival bursts")
    ap.add_argument("--slots", type=int, default=256,
                    help="packed queue slots per epoch")
    ap.add_argument("--sssp-slots", type=int, default=64,
                    help="tropical queue slots per epoch")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="admission bound on the pending queue")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="per-tenant in-flight request cap")
    ap.add_argument("--tenants", type=int, default=1,
                    help="synthetic tenants, assigned round-robin")
    ap.add_argument("--no-streaming", action="store_true",
                    help="disable mid-sweep read-outs (answer at flush)")
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup"))
    ap.add_argument("--probe-impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true",
                    help="validate BFS trees (forces the flush-time "
                         "compat path: one exact-capacity epoch)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the service's Prometheus text exposition "
                         "here after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace JSON of "
                         "request lifecycles + per-layer sweep records "
                         "here after the run (enables sweep recording)")
    ap.add_argument("--listen", type=int, default=None, metavar="PORT",
                    help="serve the live observability/wire HTTP plane "
                         "on this port (0 = auto-assign); the synthetic "
                         "trace goes through the real submit/result "
                         "front door and the process stays up "
                         "--serve-seconds for external scrapes")
    ap.add_argument("--serve-seconds", type=float, default=0.0,
                    help="keep the HTTP plane up this long after the "
                         "trace drains (Ctrl-C exits early)")
    ap.add_argument("--flight-out", default=None, metavar="PATH",
                    help="stream the per-layer JSONL flight log here "
                         "(enables sweep recording)")
    ap.add_argument("--doctor-out", default=None, metavar="PATH",
                    help="write the sweep-doctor audit of the recorded "
                         "sweeps here (enables sweep recording)")
    ap.add_argument("--slo-p99", type=float, default=None,
                    help="SLO: p99 submit-to-answer sojourn (layers)")
    ap.add_argument("--slo-queue-depth", type=int, default=None,
                    help="SLO: max pending-queue depth")
    ap.add_argument("--slo-reject-rate", type=float, default=None,
                    help="SLO: max reject rate over the rolling window")
    args = ap.parse_args()
    if args.validate and (args.metrics_out or args.trace_out
                          or args.listen is not None or args.flight_out
                          or args.doctor_out):
        ap.error("--metrics-out/--trace-out/--listen/--flight-out/"
                 "--doctor-out ride the service path — drop --validate "
                 "(the compat path has no telemetry)")

    # weights always ride along: the CSR is bit-identical to rmat_graph's,
    # boolean-only mixes simply never read them
    g = rmat_weighted_graph(args.scale, args.edgefactor, args.seed)
    telemetry = None
    record = bool(args.trace_out or args.flight_out or args.doctor_out
                  or args.listen is not None)
    if record or args.metrics_out:
        from repro.obs import Telemetry
        telemetry = Telemetry(record_sweeps=record,
                              flight_path=args.flight_out)
    slo = None
    if (args.slo_p99 is not None or args.slo_queue_depth is not None
            or args.slo_reject_rate is not None):
        from repro.obs import SLOConfig
        slo = SLOConfig(p99_sojourn_layers=args.slo_p99,
                        max_queue_depth=args.slo_queue_depth,
                        max_reject_rate=args.slo_reject_rate)
    if args.validate:
        requests = make_requests(g, args.queries, mix=args.mix,
                                 seed=args.seed, khop_k=args.khop_k,
                                 closeness_sources=args.closeness_sources)
        stats = serve(g, requests, args.lanes, args.burst, args.every,
                      mode=args.mode, probe_impl=args.probe_impl,
                      validate=True, ndev=args.ndev, delta=args.delta)
        print(json.dumps(stats, indent=2))
        return
    weights = parse_mix(args.mix)
    trace = synthetic_trace(
        g.n, args.queries, mix=args.mix, seed=args.seed,
        khop_k=args.khop_k, closeness_sources=args.closeness_sources,
        burst=args.burst, every=args.every,
        tenants=tuple(f"tenant{i}" for i in range(max(args.tenants, 1))))
    svc = AnalyticsService(g, ServiceConfig(
        lanes=args.lanes, slots=args.slots, sssp_slots=args.sssp_slots,
        max_pending=args.max_pending, tenant_quota=args.tenant_quota,
        mode=args.mode, probe_impl=args.probe_impl, ndev=args.ndev,
        delta=args.delta, streaming=not args.no_streaming,
        telemetry=telemetry, slo=slo))
    svc.warmup(tropical="sssp" in weights)
    if args.listen is not None:
        stats = _serve_live(svc, trace, args)
    else:
        stats = svc.replay(trace)
        _write_outputs(svc, telemetry, args, stats)
        print(json.dumps(stats, indent=2))
    if telemetry is not None:
        telemetry.close()
    return stats


def _write_outputs(svc, telemetry, args, stats) -> None:
    """Post-run artifacts shared by the replay and live paths."""
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(svc.metrics_text())
        stats["metrics_out"] = args.metrics_out
    if args.trace_out:
        from repro.obs import write_chrome_trace
        write_chrome_trace(args.trace_out, svc.trace_events())
        stats["trace_out"] = args.trace_out
    if args.doctor_out:
        from repro.obs.doctor import diagnose
        reports = [diagnose(rec.records, n=svc.engine.n,
                            alpha=svc.config.alpha, beta=svc.config.beta,
                            mode=svc.config.mode,
                            registry=svc._registry)
                   for rec in telemetry.sweeps if rec.records]
        anomalies = sum(len(r.findings) for r in reports)
        with open(args.doctor_out, "w") as f:
            f.write("\n".join(r.text() for r in reports) + "\n")
        stats["doctor_out"] = args.doctor_out
        stats["doctor_anomalies"] = anomalies
    if args.flight_out:
        stats["flight_out"] = args.flight_out


def _serve_live(svc, trace, args) -> dict:
    """The ``--listen`` path: worker thread + HTTP plane, the synthetic
    trace submitted through the REAL front door, artifacts written as
    soon as the trace drains (so an external watcher may kill the
    process any time after the 'trace drained' line), then the server
    held open ``--serve-seconds`` for external scrapes."""
    import time

    from repro.obs import ObservabilityServer

    svc.start()
    with ObservabilityServer(svc, port=args.listen) as obs:
        # the readiness marker external drivers (CI) wait for
        print(f"listening on {obs.url}", flush=True)
        for env in sorted(trace, key=lambda r: r.arrival):
            svc.submit(env)
        from repro.serving.admission import REJECTED
        for env in trace:
            if svc.record(env.id).status != REJECTED:
                svc.result(env.id, timeout=600.0)
        stats = svc.stats()
        _write_outputs(svc, svc.telemetry, args, stats)
        print(json.dumps(stats, indent=2), flush=True)
        print("trace drained; serving until deadline", flush=True)
        deadline = time.monotonic() + max(args.serve_seconds, 0.0)
        try:
            while time.monotonic() < deadline:
                time.sleep(0.2)
        except KeyboardInterrupt:
            pass
    svc.stop()
    return stats


if __name__ == "__main__":
    main()
