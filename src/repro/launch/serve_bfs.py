"""Streaming analytics serving loop — mixed query types, ONE engine sweep.

PR 2's serving scenario grown into a multi-workload analytics server: the
pipelined MS-BFS engine (``repro.core.msbfs``; ``--ndev N`` swaps in the
sharded ``repro.core.dist_msbfs``) never drains between requests, and the
requests themselves are no longer only BFS roots. Every analytics query
type that reduces to lane traversals rides the same bit-lane pool:

* ``bfs``       — one root, full traversal (parents/depths);
* ``khop``      — one root, answer = the depth <= k band of its lane
                  (read from the dense depth column here; the offline
                  ``analytics.khop`` query exposes the same band as
                  packed ``MSBFSResult.reached_words``);
* ``reach``     — one root + target vertex, answer = hop distance;
* ``closeness`` — a sampled-source centrality estimate: S roots enqueued
                  as one request, answered when ALL S lanes flush, the
                  estimator is ``analytics.closeness.closeness_from_depths``;
* ``sssp``      — one source, WEIGHTED shortest paths: the request rides a
                  dense tropical lane of the delta-stepping engine
                  (``repro.traversal.sssp``) stepped side by side with the
                  packed engine in the same loop — the two engines share
                  the arrival schedule and the layer clock, so sojourn
                  stats stay comparable across boolean and weighted
                  queries. Needs a weighted graph (the harness generates
                  ``rmat_weighted_graph``; plain CSR still works for
                  boolean-only mixes).

Each enqueued request is tagged with its query type; the loop reports
per-type sojourn (arrival layer -> answer layer) and latency statistics on
top of the aggregate TEPS / occupancy numbers, so a mixed workload shows
which query class is starving.

  PYTHONPATH=src python -m repro.launch.serve_bfs --scale 12 --lanes 32 \
      --queries 64 --mix bfs:4,khop:2,reach:1,closeness:1,sssp:2 \
      --burst 4 --every 2 [--validate] [--ndev 4] [--delta 0.05]

``--lanes 0`` sizes the bit-lane pool adaptively; latency is measured in
engine *layers* (the deterministic unit of work), so runs are
reproducible. Aggregate TEPS counts the packed engine's traversed edges
only (weighted relaxation work is reported as ``sssp_steps``).
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.csr import WeightedCSRGraph
from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.core.msbfs import (adaptive_lane_pool, msbfs_engine_enqueue,
                              msbfs_engine_idle, msbfs_engine_init,
                              msbfs_engine_result, msbfs_engine_step)
from repro.graph.generator import rmat_weighted_graph, sample_roots
from repro.graph.validate import validate_bfs_tree

QUERY_KINDS = ("bfs", "khop", "reach", "closeness", "sssp")


@dataclass
class Request:
    """One tagged serving request = 1+ BFS lanes through the shared engine."""
    qtype: str                   # one of QUERY_KINDS
    roots: np.ndarray            # int32[s] lanes this request enqueues
    k: int = 0                   # khop radius
    target: int = -1             # reach target vertex
    slots: slice | None = None   # engine queue slots, set at enqueue time
    answer: dict = field(default_factory=dict)


def bfs_requests(roots) -> list[Request]:
    """Plain BFS workload (the PR-2 serving loop): one request per root."""
    return [Request("bfs", np.asarray([r], np.int32)) for r in roots]


def _parse_mix(spec: str) -> dict[str, float]:
    """'bfs:4,khop:2' -> normalized weights; bare names weigh 1."""
    weights = {}
    for part in spec.split(","):
        name, _, w = part.strip().partition(":")
        if name not in QUERY_KINDS:
            raise ValueError(f"unknown query type {name!r} in mix {spec!r} "
                             f"— expected {QUERY_KINDS}")
        weights[name] = float(w) if w else 1.0
        if weights[name] < 0:
            raise ValueError(
                f"negative weight for {name!r} in mix {spec!r}")
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"mix {spec!r} has no positive weight")
    return {k: v / total for k, v in weights.items()}


def make_requests(g, num: int, mix: str = "bfs", seed: int = 0,
                  khop_k: int = 2, closeness_sources: int = 8,
                  ) -> list[Request]:
    """Draw ``num`` requests from the workload mix. Roots follow the
    Graph500 sampling rule (degree > 0); reach targets are arbitrary
    vertices (unreachable answers are part of the workload)."""
    weights = _parse_mix(mix)
    rng = np.random.default_rng(seed)
    kinds = rng.choice(list(weights), size=num, p=list(weights.values()))
    # a degree>0 pool for traversal roots; requests may reuse roots (they
    # are independent traversals). Closeness sources are NOT drawn from
    # the pool: the closeness_from_depths n/k scaling assumes sources
    # uniform over ALL n vertices (zero-degree ones included), exactly
    # like the offline estimator — a deg>0 pool would inflate the
    # estimates by ~n/pool.size.
    pool = sample_roots(g, g.n, seed=seed + 1)
    closeness_sources = min(max(1, closeness_sources), g.n)
    out = []
    for kind in kinds:
        if kind == "closeness":
            s = np.sort(rng.choice(g.n, size=closeness_sources,
                                   replace=False)).astype(np.int32)
            out.append(Request("closeness", s))
        elif kind == "sssp":
            out.append(Request(
                "sssp", np.asarray([rng.choice(pool)], np.int32)))
        elif kind == "reach":
            out.append(Request(
                "reach", np.asarray([rng.choice(pool)], np.int32),
                target=int(rng.integers(g.n))))
        elif kind == "khop":
            out.append(Request(
                "khop", np.asarray([rng.choice(pool)], np.int32), k=khop_k))
        else:
            out.append(Request(
                "bfs", np.asarray([rng.choice(pool)], np.int32)))
    return out


def _engine(g, mode: str, probe_impl: str, ndev: int):
    """(init, enqueue, step, idle, result) for the chosen engine — the
    serving loop is engine-agnostic; only these five calls differ between
    the single-host and the sharded pipelined engine."""
    if ndev <= 1:
        return (
            lambda cap, lanes: msbfs_engine_init(g, capacity=cap,
                                                 lanes=lanes),
            msbfs_engine_enqueue,
            lambda s: msbfs_engine_step(g, s, mode, ALPHA_DEFAULT,
                                        BETA_DEFAULT, 8, probe_impl),
            msbfs_engine_idle,
            lambda s, parents=True: msbfs_engine_result(
                g, s, derive_parents=parents),
        )
    from repro.core import dist_msbfs as dm
    mesh = dm.host_mesh(ndev)
    dg = dm.partition_graph(g, ndev)
    return (
        lambda cap, lanes: dm.dist_msbfs_engine_init(dg, mesh, cap, lanes),
        dm.dist_msbfs_engine_enqueue,
        lambda s: dm.dist_msbfs_engine_step(dg, s, mesh, mode,
                                            ALPHA_DEFAULT, BETA_DEFAULT, 8,
                                            probe_impl),
        dm.dist_msbfs_engine_idle,
        lambda s, parents=True: dm.dist_msbfs_engine_result(
            dg, s, mesh, derive_parents=parents),
    )


def _sssp_engine(wg: WeightedCSRGraph, probe_impl: str, ndev: int,
                 delta):
    """(init, enqueue, step, idle, result) for the tropical engine —
    the weighted mirror of ``_engine``: ndev<=1 runs the host
    delta-stepping engine, ndev>1 the 1-D sharded ``dist_sssp`` over the
    shared exchange (bit-identical per ``tests/test_dist_sssp.py``, so
    the serving answers cannot depend on the partition)."""
    if ndev <= 1:
        from repro.traversal.sssp import (sssp_engine_enqueue,
                                          sssp_engine_idle,
                                          sssp_engine_init,
                                          sssp_engine_result,
                                          sssp_engine_step)
        return (
            lambda cap, lanes: sssp_engine_init(wg, cap, lanes),
            sssp_engine_enqueue,
            lambda s: sssp_engine_step(wg, s, delta, 8, probe_impl),
            sssp_engine_idle,
            sssp_engine_result,
        )
    from repro.core import dist_sssp as ds
    mesh = ds.host_mesh(ndev)
    dwg = ds.partition_weighted_graph(wg, ndev)
    return (
        lambda cap, lanes: ds.dist_sssp_engine_init(dwg, mesh, cap, lanes),
        ds.dist_sssp_engine_enqueue,
        lambda s: ds.dist_sssp_engine_step(dwg, s, mesh, delta, 8,
                                           probe_impl),
        ds.dist_sssp_engine_idle,
        lambda s: ds.dist_sssp_engine_result(dwg, s),
    )


def _sojourn_stats(sojourn: np.ndarray) -> dict:
    return dict(
        mean=float(sojourn.mean()), p50=float(np.percentile(sojourn, 50)),
        p95=float(np.percentile(sojourn, 95)), max=int(sojourn.max()))


def _answers(g, requests: list[Request], depth: np.ndarray,
             sssp_res=None) -> dict:
    """Post-process each request's lanes into its typed answer; returns a
    small per-type summary for the stats dict. Boolean requests index the
    packed engine's ``depth`` columns, sssp requests the tropical
    engine's result columns (each engine numbers its own slots)."""
    from repro.analytics.closeness import closeness_from_depths
    n = g.n
    summary: dict[str, dict] = {}
    for req in requests:
        if req.qtype == "sssp":
            d = np.asarray(sssp_res.dist)[:, req.slots]
            fin = np.isfinite(d[:, 0])
            req.answer = dict(
                reached=int(fin.sum()),
                max_dist=float(d[fin, 0].max()) if fin.any() else 0.0,
                # a capped lane's distances are partial — the answer says so
                truncated=bool(
                    np.asarray(sssp_res.truncated)[req.slots].any()))
            continue
        d = depth[:, req.slots]
        if req.qtype == "bfs":
            req.answer = dict(reached=int((d[:, 0] >= 0).sum()),
                              layers=int(d[:, 0].max()) + 1)
        elif req.qtype == "khop":
            band = (d[:, 0] >= 0) & (d[:, 0] <= req.k)
            req.answer = dict(k=req.k, size=int(band.sum()))
        elif req.qtype == "reach":
            hops = int(d[req.target, 0])
            req.answer = dict(target=req.target, hops=hops,
                              reachable=hops >= 0)
        elif req.qtype == "closeness":
            c = closeness_from_depths(d, n)
            v = int(np.argmax(c))
            req.answer = dict(sources=int(req.roots.size), top_vertex=v,
                              top_closeness=float(c[v]))
    summary["bfs"] = dict(mean_reached=float(np.mean(
        [r.answer["reached"] for r in requests if r.qtype == "bfs"] or [0])))
    summary["khop"] = dict(mean_size=float(np.mean(
        [r.answer["size"] for r in requests if r.qtype == "khop"] or [0])))
    reach = [r for r in requests if r.qtype == "reach"]
    summary["reach"] = dict(reachable_frac=float(np.mean(
        [r.answer["reachable"] for r in reach])) if reach else 0.0)
    clo = [r for r in requests if r.qtype == "closeness"]
    summary["closeness"] = dict(top_vertices=sorted(
        {r.answer["top_vertex"] for r in clo}))
    summary["sssp"] = dict(mean_reached=float(np.mean(
        [r.answer["reached"] for r in requests if r.qtype == "sssp"] or [0])))
    return {k: v for k, v in summary.items()
            if any(r.qtype == k for r in requests)}


def serve(g, requests: list[Request], lanes: int, burst: int, every: int,
          mode: str = "hybrid", probe_impl: str = "xla",
          validate: bool = False, ndev: int = 1,
          delta: float | None = None) -> dict:
    """Feed tagged ``requests`` to the engines ``burst`` requests at a
    time every ``every`` layers; run until all are answered. Returns
    serving statistics with per-query-type sojourn breakdowns.

    Boolean requests (bfs/khop/reach/closeness) ride the packed MS-BFS
    engine; ``sssp`` requests ride the delta-stepping tropical engine,
    stepped in the SAME loop iteration so both share the arrival schedule
    and the layer clock. ``lanes=0`` picks the packed pool width
    adaptively; ``ndev>1`` shards BOTH engines over the same device pool
    (the packed one via ``dist_msbfs``, the tropical one via
    ``dist_sssp`` — answers are bit-identical to the host engines);
    ``delta=None`` uses the weighted graph's default bucket width."""
    wg = g if isinstance(g, WeightedCSRGraph) else None
    if wg is not None:
        g = wg.csr
    num_req = len(requests)
    if num_req < 1:
        raise ValueError("need at least one request")
    if burst < 1 or every < 1:
        raise ValueError(f"burst and every must be >= 1, "
                         f"got burst={burst} every={every}")
    sssp_reqs = [r for r in requests if r.qtype == "sssp"]
    if sssp_reqs and wg is None:
        raise ValueError("sssp requests need a WeightedCSRGraph — "
                         "generate the serving graph with "
                         "rmat_weighted_graph")
    bool_cap = int(sum(r.roots.size for r in requests
                       if r.qtype != "sssp"))
    sssp_cap = int(sum(r.roots.size for r in sssp_reqs))
    if not lanes:
        lanes = adaptive_lane_pool(max(bool_cap, 1), g.n, g.m)

    state = sstate = None
    if bool_cap:
        eng_init, eng_enqueue, eng_step, eng_idle, eng_result = _engine(
            g, mode, probe_impl, ndev)
        state = eng_init(bool_cap, lanes)
    if sssp_cap:
        from repro.traversal.sssp import DEFAULT_LANES, default_delta
        if delta is None:
            delta = default_delta(wg)
        sssp_lanes = max(1, min(lanes, sssp_cap, DEFAULT_LANES))
        (sssp_init, sssp_enqueue, sssp_step, sssp_idle,
         sssp_result) = _sssp_engine(wg, probe_impl, ndev, float(delta))
        sstate = sssp_init(sssp_cap, sssp_lanes)

    arrival = np.full(num_req, -1, np.int64)   # layer the request arrived
    answered = np.full(num_req, -1, np.int64)  # layer it was fully answered
    occupancy = []

    slot_hi = {"bool": 0, "sssp": 0}           # per-engine slot numbering

    def enqueue(s, ss, lo, hi, layer):
        for req in requests[lo:hi]:
            kind = "sssp" if req.qtype == "sssp" else "bool"
            req.slots = slice(slot_hi[kind], slot_hi[kind] + req.roots.size)
            slot_hi[kind] += req.roots.size
            if kind == "sssp":
                ss = sssp_enqueue(ss, req.roots)
            else:
                s = eng_enqueue(s, req.roots)
        arrival[lo:hi] = layer
        return s, ss

    # warm the step executables on throwaway states so the serving window
    # measures traversal, not one-time XLA compilation (same discipline as
    # the graph500 harness's warmup)
    if bool_cap:
        first = next(r for r in requests if r.qtype != "sssp")
        jax.block_until_ready(
            eng_step(eng_enqueue(state, first.roots[:1])).out_depth)
    if sssp_cap:
        jax.block_until_ready(sssp_step(
            sssp_enqueue(sstate, sssp_reqs[0].roots[:1])).out_dist)

    state, sstate = enqueue(state, sstate, 0, min(burst, num_req), 0)
    fed = min(burst, num_req)
    layer = 0

    def all_idle():
        return ((state is None or eng_idle(state))
                and (sstate is None or sssp_idle(sstate)))

    t0 = time.perf_counter()
    while fed < num_req or not all_idle():
        if state is not None and not eng_idle(state):
            state = eng_step(state)
        if sstate is not None and not sssp_idle(sstate):
            sstate = sssp_step(sstate)
        layer += 1
        occ = 0
        if state is not None:
            occ += int(np.sum(np.asarray(state.lane_qidx) < bool_cap))
        if sstate is not None:
            occ += int(np.sum(np.asarray(sstate.lane_qidx) < sssp_cap))
        occupancy.append(occ)
        done_bool = (np.asarray(state.out_layers[:bool_cap]) > 0
                     if state is not None else None)
        done_sssp = (np.asarray(sstate.out_steps[:sssp_cap]) > 0
                     if sstate is not None else None)
        for i, req in enumerate(requests[:fed]):
            done = done_sssp if req.qtype == "sssp" else done_bool
            if answered[i] < 0 and done[req.slots].all():
                answered[i] = layer   # a request answers when EVERY lane has
        if layer % every == 0 and fed < num_req:
            nxt = min(fed + burst, num_req)
            state, sstate = enqueue(state, sstate, fed, nxt, layer)
            fed = nxt
    if state is not None:
        jax.block_until_ready(state.out_depth)
    if sstate is not None:
        jax.block_until_ready(sstate.out_dist)
    wall = time.perf_counter() - t0

    # parents cost an O(m) scatter-min pass per lane chunk and only the
    # validator reads them — the answers post-processing is depth-only
    depth = sssp_res = None
    edges = 0
    if state is not None:
        out = eng_result(state, validate)
        depth = np.asarray(out.depth)
        edges = int(np.asarray(out.edges_traversed).sum()) // 2
    if sstate is not None:
        sssp_res = sssp_result(sstate)
    if validate and state is not None:
        from repro.core.csr import to_numpy_adj
        rp, ci = to_numpy_adj(g)
        parent = np.asarray(out.parent)
        for req in requests:
            if req.qtype == "sssp":   # tropical lanes carry no BFS tree
                continue
            for j, r in enumerate(req.roots):  # every boolean lane is a
                validate_bfs_tree(                 # BFS tree, whatever the tag
                    rp, ci, parent[:, req.slots][:, j], int(r))

    sojourn = answered - arrival
    qtypes = np.asarray([r.qtype for r in requests])
    per_type = {
        kind: dict(count=int((qtypes == kind).sum()),
                   lanes=int(sum(r.roots.size for r in requests
                                 if r.qtype == kind)),
                   sojourn_layers=_sojourn_stats(sojourn[qtypes == kind]))
        for kind in QUERY_KINDS if (qtypes == kind).any()}
    stats = dict(
        requests=num_req, total_lanes=bool_cap + sssp_cap, lanes=lanes,
        ndev=ndev, layers=layer, wall_s=round(wall, 4),
        sojourn_layers=_sojourn_stats(sojourn),
        per_type=per_type,
        answers=_answers(g, requests, depth, sssp_res),
        mean_lane_occupancy=float(np.mean(occupancy)),
        aggregate_mteps=round(edges / wall / 1e6, 2) if wall > 0 else 0.0,
        validated=bool(validate and state is not None),
    )
    if sstate is not None:
        stats["delta"] = float(delta)
        stats["sssp_steps"] = int(sstate.sweep_steps)
    return stats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=32,
                    help="bit-lane pool size; 0 = adaptive from queue "
                         "depth + degree stats")
    ap.add_argument("--ndev", type=int, default=1,
                    help="shard the engine over this many devices")
    ap.add_argument("--queries", type=int, default=64,
                    help="number of requests (a closeness request costs "
                         "--closeness-sources lanes)")
    ap.add_argument("--mix", default="bfs",
                    help="workload mix, e.g. bfs:4,khop:2,reach:1,"
                         "closeness:1,sssp:1 (weights optional)")
    ap.add_argument("--delta", type=float, default=None,
                    help="delta-stepping bucket width for sssp requests "
                         "(default: the graph's default_delta)")
    ap.add_argument("--khop-k", type=int, default=2)
    ap.add_argument("--closeness-sources", type=int, default=8,
                    help="sampled sources (lanes) per closeness request")
    ap.add_argument("--burst", type=int, default=8,
                    help="requests arriving per burst")
    ap.add_argument("--every", type=int, default=2,
                    help="layers between arrival bursts")
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup"))
    ap.add_argument("--probe-impl", default="xla", choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    # weights always ride along: the CSR is bit-identical to rmat_graph's,
    # boolean-only mixes simply never read them
    g = rmat_weighted_graph(args.scale, args.edgefactor, args.seed)
    requests = make_requests(g, args.queries, mix=args.mix, seed=args.seed,
                             khop_k=args.khop_k,
                             closeness_sources=args.closeness_sources)
    stats = serve(g, requests, args.lanes, args.burst, args.every,
                  mode=args.mode, probe_impl=args.probe_impl,
                  validate=args.validate, ndev=args.ndev, delta=args.delta)
    print(json.dumps(stats, indent=2))


if __name__ == "__main__":
    main()
