import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks on
# first backend init). Everything else follows.
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs.base import (get_arch, list_archs,  # noqa: E402
                                make_step, step_arg_specs)
from repro.distributed.sharding import tree_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch import roofline as rl                # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory/cost/collective
analysis to artifacts/dryrun/*.json — the §Dry-run / §Roofline source data.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def dryrun_cell(arch_id: str, shape_id: str, multi_pod: bool,
                donate: bool = True) -> dict:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_id)
    rec = dict(arch=arch_id, shape=shape_id, mesh=_mesh_tag(multi_pod),
               kind=shape.kind)
    if shape.skip_reason:
        rec.update(status="skipped", skip_reason=shape.skip_reason)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    args_shapes, args_specs = step_arg_specs(arch, shape)
    in_shardings = tree_shardings(args_shapes, args_specs, mesh)
    step = make_step(arch, shape)
    if not donate:
        donate_argnums = ()
    elif shape.kind == "train":
        donate_argnums = (0, 1)        # params + opt state
    elif shape.kind == "decode":
        donate_argnums = (1,)          # KV cache buffers update in place
    else:
        donate_argnums = ()

    with jax.set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*args_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    coll = rl.parse_collectives(hlo, n_dev)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hbm_bytes = rl.parse_hbm_bytes(hlo)
    from repro.launch.flops import analytic_flops
    an = analytic_flops(arch, shape)
    # cost_analysis counts scan bodies once -> use the analytic executed
    # FLOPs (global / n_dev) for the compute term; the memory term comes from
    # the loop-weighted HLO traffic parse (see roofline.py + EXPERIMENTS).
    exec_per_dev = an["executed_flops"] / n_dev
    terms = rl.roofline_terms(max(flops, exec_per_dev), hbm_bytes,
                              coll.wire_bytes)

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    rec.update(
        status="ok", n_devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_device=flops, cost_bytes_accessed=bytes_acc,
        hbm_bytes_per_device=hbm_bytes,
        model_flops_global=an["model_flops"],
        executed_flops_global=an["executed_flops"],
        model_to_hlo_ratio=(an["model_flops"] / (flops * n_dev)
                            if flops else None),
        collective=dict(wire_bytes_per_device=coll.wire_bytes,
                        num_collectives=coll.count, by_op=coll.by_op),
        memory=dict(
            argument_bytes=_mem_attr("argument_size_in_bytes"),
            output_bytes=_mem_attr("output_size_in_bytes"),
            temp_bytes=_mem_attr("temp_size_in_bytes"),
            generated_code_bytes=_mem_attr("generated_code_size_in_bytes"),
            alias_bytes=_mem_attr("alias_size_in_bytes"),
        ),
        roofline=terms,
        hlo_bytes=len(hlo),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for a in list_archs():
            for s in get_arch(a).shapes:
                cells.append((a, s.shape_id))
    else:
        arch = get_arch(args.arch)
        shapes = ([args.shape] if args.shape
                  else [s.shape_id for s in arch.shapes])
        cells = [(args.arch, s) for s in shapes]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch_id, shape_id in cells:
        for mp in meshes:
            tag = f"{arch_id}__{shape_id}__{_mesh_tag(mp)}"
            path = out / f"{tag}.json"
            try:
                rec = dryrun_cell(arch_id, shape_id, mp,
                                  donate=not args.no_donate)
            except Exception as e:  # a failing cell is a bug — record it
                rec = dict(arch=arch_id, shape=shape_id, mesh=_mesh_tag(mp),
                           status="error", error=repr(e),
                           traceback=traceback.format_exc())
                failures += 1
            path.write_text(json.dumps(rec, indent=2, default=str))
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" compile={rec['compile_s']}s"
                         f" dom={r['dominant']}"
                         f" frac={r['roofline_fraction']:.3f}")
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
