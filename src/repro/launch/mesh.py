"""Mesh construction. Importing this module never touches jax device state —
everything is behind functions (dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: 16x16 (one v5e pod) or 2x16x16 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic re-mesh use this)."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(model_parallel: int = 1):
    """Best-effort mesh over whatever devices exist (CPU smoke: 1 device)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
