"""Serving launcher: batched prefill + decode loop for LM archs, batched
scoring for recsys.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --reduced --requests 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, list_archs, param_builders
from repro.configs.reduced import reduce_arch


def serve_lm(arch, requests: int, prompt_len: int, new_tokens: int, seed=0):
    from repro.models.transformer import lm_decode_step, lm_prefill
    cfg = arch.model_cfg
    init_fn, _ = param_builders(arch)
    params, _ = init_fn(jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                              (requests, prompt_len), 0, cfg.vocab)

    prefill = jax.jit(lambda p, t: lm_prefill(p, t, cfg))
    decode = jax.jit(lambda p, tok, cache, ln: lm_decode_step(
        p, tok, cache, ln, cfg), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, toks)
    cache = tuple(jnp.pad(c, ((0, 0), (0, 0), (0, new_tokens), (0, 0),
                              (0, 0))) for c in cache)
    out = [jnp.argmax(logits, -1)[:, None]]
    for i in range(new_tokens - 1):
        logits, cache = decode(params, out[-1], cache,
                               jnp.int32(prompt_len + i))
        out.append(jnp.argmax(logits, -1)[:, None])
    tokens = jnp.concatenate(out, 1)
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    print(f"served {requests} requests x {new_tokens} tokens "
          f"in {dt:.2f}s ({requests * new_tokens / dt:.1f} tok/s)")
    return tokens


def serve_recsys(arch, requests: int, seed=0):
    from repro.configs.base import Shape
    from repro.data.pipeline import recsys_batch
    from repro.models.recsys.dien import dien_forward
    cfg = arch.model_cfg
    init_fn, _ = param_builders(arch)
    params, _ = init_fn(jax.random.PRNGKey(seed))
    shape = Shape("serve", "serve", dims=dict(batch=requests))
    batch = recsys_batch(arch, shape, 0, seed)
    fwd = jax.jit(lambda p, b: jax.nn.sigmoid(dien_forward(p, b, cfg)))
    t0 = time.time()
    probs = jax.block_until_ready(fwd(params, batch))
    print(f"scored {requests} requests in {time.time() - t0:.3f}s; "
          f"mean ctr={float(probs.mean()):.4f}")
    return probs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    arch = reduce_arch(args.arch) if args.reduced else get_arch(args.arch)
    if arch.family == "recsys":
        serve_recsys(arch, args.requests)
    else:
        serve_lm(arch, args.requests, args.prompt_len, args.new_tokens)


if __name__ == "__main__":
    main()
