import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", ""))
# Must precede any jax import (device count locks on first init).
import argparse      # noqa: E402
import json          # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.core.dist_bfs import MAX_LAYERS, _dist_bfs_impl  # noqa: E402
from repro.launch import roofline as rl                     # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402

"""Dry-run of the distributed hybrid BFS itself on the production meshes —
the paper's technique at datacenter scale (Graph500 SCALE 22-26).

Shapes are analytic: n padded to ndev*32 multiples; per-device edge slabs
sized at 1.5x the mean (R-MAT skew headroom). The while loop bound is
MAX_LAYERS=64; R-MAT diameters are ~6-8, so per-layer collective costs are
reported as total/64 alongside the loop-bound totals.
"""


def bfs_cell(scale: int, edgefactor: int, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(mesh.devices.shape))
    n_orig = 1 << scale
    m_directed = n_orig * edgefactor * 2            # symmetrised
    block = -(-n_orig // (ndev * 32)) * 32
    n = block * ndev
    m_loc = int(np.ceil(m_directed / ndev * 1.5))

    args = (
        jax.ShapeDtypeStruct((ndev, block + 1), jnp.int32),   # row_ptr
        jax.ShapeDtypeStruct((ndev, m_loc), jnp.int32),       # col_idx
        jax.ShapeDtypeStruct((ndev, m_loc), jnp.int32),       # src_loc
        jax.ShapeDtypeStruct((ndev, block), jnp.int32),       # deg
        jax.ShapeDtypeStruct((), jnp.int32),                  # root
    )
    kw = dict(mesh=mesh, mode="hybrid", alpha=14.0, beta=24.0, max_pos=8,
              n=n, n_loc=block, m_loc=m_loc, n_orig=n_orig)
    lowered = jax.jit(
        lambda *a: _dist_bfs_impl(*a, **kw)).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo, ndev)
    hbm = rl.parse_hbm_bytes(hlo)
    cost = compiled.cost_analysis()
    rec = dict(
        kind="dist_bfs", scale=scale, edgefactor=edgefactor,
        mesh="pod2x16x16" if multi_pod else "pod16x16", n_devices=ndev,
        n=n, m_loc=m_loc, status="ok",
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=hbm,
        collective=dict(wire_bytes_per_device=coll.wire_bytes,
                        per_layer_wire_bytes=coll.wire_bytes / MAX_LAYERS,
                        num_collectives=coll.count, by_op=coll.by_op),
        memory=dict(
            argument_bytes=int(mem.argument_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes)),
        roofline=rl.roofline_terms(float(cost.get("flops", 0.0)), hbm,
                                   coll.wire_bytes),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=22)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for mp in (False, True):
        rec = bfs_cell(args.scale, args.edgefactor, mp)
        tag = (f"bfs-graph500__scale{args.scale}_ef{args.edgefactor}"
               f"__{rec['mesh']}")
        (out / f"{tag}.json").write_text(json.dumps(rec, indent=2))
        t = rec["roofline"]
        print(f"[ok] {tag} mem_temp={rec['memory']['temp_bytes'] / 1e9:.2f}GB"
              f" wire/layer={rec['collective']['per_layer_wire_bytes'] / 1e6:.1f}MB"
              f" dom={t['dominant']}", flush=True)


if __name__ == "__main__":
    main()
