"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = model_flops_per_chip / 197e12 bf16 FLOP/s
  memory     = hbm_bytes_per_chip   / 819e9  B/s
  collective = wire_bytes_per_chip  / 50e9   B/s per ICI link

``cost_analysis()`` supplies FLOPs / bytes for the *per-device* partitioned
module. Collective bytes are NOT in cost_analysis: we parse the post-SPMD
HLO text and sum per-op wire traffic with ring-algorithm estimates:

  all-gather       R*(k-1)/k      (R = result bytes, k = group size)
  all-reduce       2*R*(k-1)/k
  reduce-scatter   R*(k-1)        (result is the per-shard output)
  all-to-all       R*(k-1)/k
  collective-permute  R
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _type_bytes(type_str: str) -> int:
    """Bytes of one HLO (possibly tuple) type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                 # per-device, ring estimate
    result_bytes: float = 0.0
    count: int = 0
    by_op: dict = field(default_factory=dict)

    def add(self, op: str, wire: float, result: float):
        self.wire_bytes += wire
        self.result_bytes += result
        self.count += 1
        d = self.by_op.setdefault(op, dict(wire_bytes=0.0, count=0))
        d["wire_bytes"] += wire
        d["count"] += 1


_COMP_HEADER_RE = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")  # args may nest parens
_WHILE_RE = re.compile(r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?"
                       r"body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(r"while\(.*?\)[^\n]*?body=%?([\w.\-]+)[^\n]*?"
                        r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_CONST_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\)[^\n]*direction=(LT|LE|GT|GE)")


def _split_computations(hlo_text: str):
    """name -> (body_text, is_entry). Robust line scanner over HLO text."""
    comps: dict[str, str] = {}
    entry = None
    name, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m and ("->" in line):
            if name is not None:
                comps[name] = "\n".join(buf)
            name = m.group(2)
            if m.group(1):
                entry = name
            buf = [line]
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps, entry


def _trip_count(cond_text: str) -> int:
    """Loop bound of a counted while: resolve the constant operand of the
    condition's compare instruction (not just any constant in the
    computation — conditions can embed unrelated literals)."""
    consts = {m.group(1): int(m.group(2))
              for m in _CONST_DEF_RE.finditer(cond_text)}
    for m in _COMPARE_RE.finditer(cond_text):
        for o in _OPERAND_RE.findall(m.group(1)):
            if o in consts:
                return max(1, consts[o])
    vals = list(consts.values())
    return max(vals) if vals else 1


def _loop_multipliers(comps: dict[str, str], entry: str) -> dict[str, float]:
    """Execution count per computation, walking while-loops from ENTRY.

    cost_analysis / naive text scans count a scan body ONCE; this recovers
    the trip counts so per-layer / per-microbatch collectives are weighted
    correctly (DESIGN §6)."""
    mult = {c: 0.0 for c in comps}
    if entry not in comps:
        entry = next(iter(comps), None)
        if entry is None:
            return mult
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        cur = order.pop(0)
        text = comps.get(cur, "")
        whiles = list(_WHILE_RE.finditer(text)) or []
        pairs = [(m.group(1), m.group(2)) for m in whiles]
        for m in _WHILE_RE2.finditer(text):
            pairs.append((m.group(2), m.group(1)))
        for cond, body in set(pairs):
            trips = _trip_count(comps.get(cond, ""))
            if body in comps:
                mult[body] = mult.get(body, 0.0) + mult[cur] * trips
                if body not in seen:
                    seen.add(body)
                    order.append(body)
    # computations never reached via a while (fusions, branches) execute with
    # their caller: give them the entry multiplier so their collectives count
    for c in comps:
        if mult.get(c, 0.0) == 0.0:
            mult[c] = 1.0
    return mult


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    comps, entry = _split_computations(hlo_text)
    if not comps:
        comps, entry = {"__all__": hlo_text}, "__all__"
    mult = _loop_multipliers(comps, entry)
    for cname, text in comps.items():
        weight = mult.get(cname, 1.0)
        for line in text.splitlines():
            stripped = line.strip()
            op = next((c for c in _COLLECTIVES
                       if f" {c}(" in stripped or f"{c}-start(" in stripped),
                      None)
            if op is None:
                continue
            lhs = stripped.split(" = ", 1)
            if len(lhs) != 2:
                continue
            type_part = lhs[1].split(op)[0]
            r = _type_bytes(type_part)
            if r == 0:
                continue
            k = _group_size(stripped, n_devices)
            if op == "all-gather":
                wire = r * (k - 1) / max(k, 1)
            elif op == "all-reduce":
                wire = 2 * r * (k - 1) / max(k, 1)
            elif op == "reduce-scatter":
                wire = r * (k - 1)
            elif op == "all-to-all":
                wire = r * (k - 1) / max(k, 1)
            else:  # collective-permute
                wire = r
            stats.add(op, wire * weight, r)
    return stats


_NO_WRITE_OPS = (" parameter(", " constant(", " get-tuple-element(",
                 " tuple(", " bitcast(", " while(", " conditional(",
                 "-done(", " iota(", " after-all(", " copy-start(")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_OPNAME_RE = re.compile(r"\s([a-z][\w\-]*)\(")


def parse_hbm_bytes(hlo_text: str) -> float:
    """HBM traffic estimate from optimized post-fusion HLO, weighted by loop
    trip counts (cost_analysis counts scan bodies once).

    Model: every instruction writes its result (result bytes) and reads its
    operands (looked up in a per-computation symbol table — covers values
    arriving via parameter/get-tuple-element, e.g. the KV cache inside a
    layer scan). dynamic-update-slice is in-place on TPU: it writes/reads
    only the update slice. Zero-cost view/control ops write nothing.
    Fusion-internal values never appear (post-fusion HLO), so this tracks
    the values that actually round-trip HBM.
    """
    comps, entry = _split_computations(hlo_text)
    if not comps:
        return 0.0
    mult = _loop_multipliers(comps, entry)
    total = 0.0
    for cname, text in comps.items():
        weight = mult.get(cname, 1.0)
        # symbol table: value name -> bytes
        table: dict[str, int] = {}
        lines = text.splitlines()
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = m.group(3)
            opm = _OPNAME_RE.search(" " + rhs)
            if not opm:
                continue
            type_part = rhs[:opm.start()]
            table[m.group(2).lstrip("%")] = _type_bytes(type_part)
        comp_bytes = 0.0
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            rhs = " " + m.group(3)
            opm = _OPNAME_RE.search(rhs)
            if not opm:
                continue
            opname = opm.group(1)
            name = m.group(2).lstrip("%")
            args_part = rhs[opm.end():].split("),")[0]
            operands = [o for o in _OPERAND_RE.findall(args_part)
                        if o in table]
            if opname == "dynamic-update-slice":
                # in-place: traffic = update slice rw (2nd operand)
                upd = operands[1] if len(operands) > 1 else None
                comp_bytes += 2 * table.get(upd, 0)
                continue
            if any(s in f" {opname}(" for s in _NO_WRITE_OPS):
                continue
            comp_bytes += table.get(name, 0)                  # write result
            comp_bytes += sum(table[o] for o in operands)     # read operands
        total += comp_bytes * weight
    return total


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    compute = flops_per_dev / PEAK_FLOPS
    memory = hbm_bytes_per_dev / HBM_BW
    collective = wire_bytes_per_dev / ICI_BW
    terms = dict(compute_s=compute, memory_s=memory, collective_s=collective)
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update(
        dominant=dom.replace("_s", ""),
        step_time_bound_s=bound,
        # fraction of the bound that is useful compute = roofline fraction
        roofline_fraction=(compute / bound) if bound > 0 else 0.0,
    )
    return terms
