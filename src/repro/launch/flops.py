"""Analytic FLOP estimates per (arch x shape) — the napkin-math layer.

Two numbers per cell:
  model_flops    — useful work: 6*N_active*D for LM training (2*N*D per
                   forward), causal attention at half the score matrix,
                   analytic per-op counts for GNN/recsys;
  executed_flops — what the compiled program actually has to run: full
                   (masked) score matrices, remat recompute (fwd twice),
                   MoE capacity slack.

Why this module exists: XLA's ``cost_analysis()`` counts a ``scan`` body
ONCE (trip count is opaque to it), so HLO FLOPs undercount deep stacked-scan
models by ~n_layers. The roofline table reports HLO numbers raw plus these
estimates; the compute term uses executed_flops (documented in
EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from repro.configs.base import Arch, Shape


def _lm_flops(arch: Arch, shape: Shape) -> dict:
    cfg = arch.model_cfg
    d = shape.dims
    n_act = cfg.active_param_count()
    L, Hq, Dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    if shape.kind == "train":
        b, s = d["global_batch"], d["seq_len"]
        tokens = b * s
        attn_fwd_full = 4 * L * b * s * s * Hq * Dh       # QK^T + PV
        model = 6 * n_act * tokens + 3 * (attn_fwd_full / 2)   # causal half
        executed = 8 * n_act * tokens + 4 * attn_fwd_full      # remat fwd x2
        if cfg.moe is not None:
            cap_slack = cfg.moe.capacity_factor
            ffn_act = cfg.moe.top_k * 3 * cfg.d_model * cfg.moe.d_ff_expert * L
            executed += (cap_slack - 1.0) * 8 * ffn_act * tokens / 2
        return dict(model_flops=model, executed_flops=executed)
    if shape.kind == "prefill":
        b, s = d["global_batch"], d["seq_len"]
        tokens = b * s
        attn_fwd_full = 4 * L * b * s * s * Hq * Dh
        return dict(model_flops=2 * n_act * tokens + attn_fwd_full / 2,
                    executed_flops=2 * n_act * tokens + attn_fwd_full)
    # decode: one token against an s-deep cache
    b, s = d["global_batch"], d["seq_len"]
    attn = 4 * L * b * s * Hq * Dh
    return dict(model_flops=2 * n_act * b + attn,
                executed_flops=2 * n_act * b + attn)


def _gnn_flops(arch: Arch, shape: Shape) -> dict:
    cfg = arch.model_cfg
    d = shape.dims
    n, e = d["n_nodes"], d["n_edges"]
    name = type(cfg).__name__
    h = cfg.d_hidden
    if name == "GCNConfig":
        f = d["d_feat"]
        fwd = 2 * n * f * h + 2 * n * h * d.get("n_classes", 16) + 4 * e * h
    elif name == "GINConfig":
        f = d["d_feat"]
        fwd = cfg.n_layers * (2 * n * h * h * 2 + 2 * e * h) + 2 * n * f * h
    elif name == "EGNNConfig":
        fwd = cfg.n_layers * (e * (2 * (2 * h + 1) * h + 2 * h * h * 2)
                              + n * (2 * 2 * h * h + 2 * h * h))
    else:  # MACE — Gaunt einsums dominate: E*C*9^3 (messages), 2*N*C*9^3
        c = cfg.d_hidden
        fwd = cfg.n_layers * (2 * e * c * 9 * 9 * 9 + 4 * n * c * 9 * 9 * 9
                              + 2 * e * (cfg.n_rbf * c + c * c)
                              + 9 * 2 * n * c * c * 3)
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + ~2x bwd
    return dict(model_flops=mult * fwd, executed_flops=(mult + 1) * fwd
                if shape.kind == "train" else fwd)   # +1 fwd for remat-ish


def _recsys_flops(arch: Arch, shape: Shape) -> dict:
    cfg = arch.model_cfg
    d = shape.dims
    b = d["batch"]
    t, h, e2 = cfg.seq_len, cfg.gru_dim, 2 * cfg.embed_dim
    gru = 2 * 3 * (e2 + h) * h * t * b * 2            # GRU + AUGRU
    att = 2 * t * b * ((h + e2) * 36 + 36)
    mlp_in = h + 2 * e2 + cfg.embed_dim
    mlp = 2 * b * (mlp_in * 200 + 200 * 80 + 80)
    fwd = gru + att + mlp
    if shape.kind == "train":
        return dict(model_flops=3 * fwd, executed_flops=3 * fwd)
    if shape.kind == "retrieval":
        nc = d["n_candidates"]
        ret = 2 * b * nc * cfg.embed_dim
        return dict(model_flops=fwd + ret, executed_flops=fwd + ret)
    return dict(model_flops=fwd, executed_flops=fwd)


def analytic_flops(arch: Arch, shape: Shape) -> dict:
    """Global (all-device) analytic FLOPs for one step of this cell."""
    if arch.family in ("lm-dense", "lm-moe"):
        return _lm_flops(arch, shape)
    if arch.family == "gnn":
        return _gnn_flops(arch, shape)
    return _recsys_flops(arch, shape)
