"""Graph500 BFS entry point (the paper's experiment driver).

  PYTHONPATH=src python -m repro.launch.bfs --scale 14 --edgefactor 16 \
      --mode hybrid --roots 16 [--validate] [--probe-impl pallas]

Modes: hybrid | hybrid_nosimd | topdown | bottomup_simd | bottomup_nosimd.
"""
from __future__ import annotations

import argparse
import json

from repro.graph.graph500 import run_graph500


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--mode", default="hybrid",
                    choices=["hybrid", "hybrid_nosimd", "topdown",
                             "bottomup_simd", "bottomup_nosimd"])
    ap.add_argument("--roots", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=14.0)
    ap.add_argument("--beta", type=float, default=24.0)
    ap.add_argument("--max-pos", type=int, default=8)
    ap.add_argument("--probe-impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    res = run_graph500(args.scale, args.edgefactor, mode=args.mode,
                       num_roots=args.roots, seed=args.seed,
                       validate=args.validate, alpha=args.alpha,
                       beta=args.beta, max_pos=args.max_pos,
                       probe_impl=args.probe_impl)
    print(json.dumps(res.summary(), indent=2))


if __name__ == "__main__":
    main()
