"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt

``--reduced`` runs the CPU-scale config (what the container can execute);
without it the full config is used — appropriate on a real TPU slice, where
``--model-parallel`` picks the mesh split. BFS training has no meaning; see
``repro.launch.bfs`` for the Graph500 entry point.
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_arch, list_archs
from repro.configs.reduced import reduce_arch
from repro.launch.mesh import host_device_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--shape", default=None,
                    help="train shape id (default: first train shape)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = reduce_arch(args.arch) if args.reduced else get_arch(args.arch)
    shape_id = args.shape or next(s.shape_id for s in arch.shapes
                                  if s.kind == "train")
    mesh = host_device_mesh(args.model_parallel)
    trainer = Trainer(arch, shape_id, mesh=mesh, cfg=TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, seed=args.seed))
    trainer.run()


if __name__ == "__main__":
    main()
