"""Gradient compression: int8 quantised reduction with error feedback.

Wire format: per-tensor scale (f32) + int8 payload -> 4x less all-reduce
traffic than f32, ~2x less than bf16. Error feedback keeps the residual
(g - dequant(quant(g))) locally and adds it to the next step's gradient, so
the compression bias telescopes away (Karimireddy et al., arXiv:1901.09847).

Used by the trainer when ``OptConfig.compress_grads`` is on; the dry-run
measures its collective-term effect in §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_state):
    """-> (quantised tree of (int8, scale), new error state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant(x)
        err = x - _dequant(q, scale)
        return (q, scale), err
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    etree = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, etree


def decompress_tree(qtree):
    return jax.tree.map(lambda q_s: _dequant(*q_s), qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2)


def psum_compressed(grads, error_state, axis_name):
    """int8 error-feedback psum inside shard_map: quantise, all-reduce the
    int8 payload (as int32 partial sums to avoid overflow), dequantise.

    Scales are all-reduced (max) so every member dequantises consistently.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0,
                             axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        err = x - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale).astype(g.dtype), err
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]))
