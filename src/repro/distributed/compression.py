"""Collective-payload compression: gradients (int8 + error feedback) and
packed frontier words (sparse index+payload pairs).

Two wire formats live here:

* **Gradients** — per-tensor scale (f32) + int8 payload -> 4x less
  all-reduce traffic than f32, ~2x less than bf16. Error feedback keeps
  the residual (g - dequant(quant(g))) locally and adds it to the next
  step's gradient, so the compression bias telescopes away (Karimireddy
  et al., arXiv:1901.09847). Used by the trainer when
  ``OptConfig.compress_grads`` is on.

* **Frontier words** — the 2-D MS-BFS exchange (``repro.core.dist2d``)
  ships per-device frontier-word slices every layer, and sparse frontiers
  are mostly zero words (a BFS spends most layers with a tiny fraction of
  vertices active). ``compress_words`` packs the nonzero words of a slice
  into (flat index, payload) pairs inside a fixed ``budget``-slot buffer —
  static shapes, so the codec jits inside ``shard_map`` — and
  ``decompress_words`` scatters them back. Pad slots carry
  ``(idx=0, payload=0)``: a zero payload is the OR identity, so
  decompression is exact whenever ``count <= budget`` (the engine falls
  back to the dense form otherwise — see ``sparse_budget`` /
  ``DENSE_THRESHOLD`` for the switch rule). With the sparse form chosen,
  bytes on the wire scale with the *frontier population*, not the graph.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "DENSE_THRESHOLD", "compress_tree", "compress_values", "compress_words",
    "decompress_tree", "decompress_values", "decompress_words",
    "init_error_state", "psum_compressed", "sparse_budget", "values_finite",
    "words_nnz", "wire_bytes",
]

# ---------------------------------------------------------------------------
# Packed frontier-word compression (the 2-D exchange wire format).
# ---------------------------------------------------------------------------

# sparse form wins while at most this fraction of words is nonzero: each
# sparse slot costs an int32 index + the word payload, so at itemsize 4
# break-even is 50% density — 25% leaves margin for the count header and
# keeps the switch conservative at 8-byte words too
DENSE_THRESHOLD = 0.25

_IDX_BYTES = 4      # int32 flat word index per sparse slot
_COUNT_BYTES = 4    # int32 nonzero-count header per sparse message


def sparse_budget(num_words: int, threshold: float = DENSE_THRESHOLD) -> int:
    """Sparse-buffer slot count for a ``num_words``-word slice: the codec
    carries at most ``floor(num_words * threshold)`` nonzero words (min 1).
    A slice whose nonzero count exceeds this ships dense — exactly the
    density switch the exchange applies per layer."""
    if num_words < 1:
        raise ValueError(f"need at least one word, got {num_words}")
    return max(1, int(num_words * threshold))


def words_nnz(words: jnp.ndarray) -> jnp.ndarray:
    """Nonzero-word count of a word slice (any shape) — int32 scalar."""
    return jnp.sum(words.reshape(-1) != 0, dtype=jnp.int32)


def compress_words(words: jnp.ndarray, budget: int):
    """Pack the nonzero words of ``words`` (any shape, flattened in row-
    major order) into a ``budget``-slot sparse buffer.

    Returns ``(idx int32[budget], payload word[budget], count int32)``:
    the first ``min(count, budget)`` slots hold the flat indices and
    values of the leading nonzero words in ascending index order; pad
    slots hold ``(0, 0)`` — a zero payload ORs harmlessly, so the buffer
    round-trips exactly iff ``count <= budget``. ``count`` is the TRUE
    nonzero total (it may exceed ``budget``): callers switch to the dense
    form when it does.
    """
    flat = words.reshape(-1)
    total = flat.shape[0]
    if budget < 1 or budget > total:
        raise ValueError(
            f"budget must be in [1, {total}], got {budget}")
    nz = flat != 0
    count = jnp.sum(nz, dtype=jnp.int32)
    # nonzero indices first, ascending; zeros pushed past every real slot
    pos = jnp.arange(total, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(nz, pos, total))
    idx = order[:budget].astype(jnp.int32)
    valid = jnp.arange(budget, dtype=jnp.int32) < count
    idx = jnp.where(valid, idx, 0)
    payload = jnp.where(valid, flat[idx], jnp.zeros((), flat.dtype))
    return idx, payload, count


def decompress_words(idx: jnp.ndarray, payload: jnp.ndarray, num_words: int,
                     ) -> jnp.ndarray:
    """Scatter a sparse buffer back into a flat ``num_words`` word array.

    Pad slots (idx 0, payload 0) cannot clobber slot 0's real word: real
    indices are unique and payloads unsigned, so a max-scatter IS the
    OR-merge of each slot with the zero background."""
    flat = jnp.zeros((num_words,), payload.dtype)
    return flat.at[idx].max(payload)


def wire_bytes(count, num_words: int, budget: int, itemsize: int):
    """Bytes a slice costs on the wire under the density switch: the
    sparse form (count header + index/payload pairs for the ``count``
    nonzero words) while ``count <= budget``, the dense form (every word)
    otherwise. ``count`` may be a traced scalar — the result then is too
    (the engine accumulates it per layer)."""
    sparse = _COUNT_BYTES + count * (_IDX_BYTES + itemsize)
    dense = num_words * itemsize
    if isinstance(count, jnp.ndarray):
        # int32 like every other engine counter (x64-independent)
        return jnp.where(count <= budget, sparse, dense).astype(jnp.int32)
    return sparse if count <= budget else dense


def values_finite(vals: jnp.ndarray) -> jnp.ndarray:
    """Finite-entry count of a float value slice (any shape) — int32
    scalar. The value-codec analog of ``words_nnz``: ``inf`` is the MIN
    identity, so finite entries are the only payload worth shipping."""
    return jnp.sum(jnp.isfinite(vals.reshape(-1)), dtype=jnp.int32)


def compress_values(vals: jnp.ndarray, budget: int):
    """Pack the FINITE entries of a float value slice (any shape,
    flattened row-major) into a ``budget``-slot sparse buffer.

    The float twin of ``compress_words`` for MIN-monoid exchanges
    (distributed SSSP): a lane value is "empty" when it is ``inf`` — the
    min identity — exactly as a zero word is empty under OR. Returns
    ``(idx int32[budget], payload[budget], count int32)`` with pad slots
    carrying ``(idx=0, payload=inf)``; an inf payload min-scatters
    harmlessly, so the buffer round-trips exactly iff ``count <= budget``.
    ``count`` is the TRUE finite total (may exceed ``budget``): callers
    switch to the dense form when it does.
    """
    flat = vals.reshape(-1)
    total = flat.shape[0]
    if budget < 1 or budget > total:
        raise ValueError(
            f"budget must be in [1, {total}], got {budget}")
    fin = jnp.isfinite(flat)
    count = jnp.sum(fin, dtype=jnp.int32)
    pos = jnp.arange(total, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(fin, pos, total))
    idx = order[:budget].astype(jnp.int32)
    valid = jnp.arange(budget, dtype=jnp.int32) < count
    idx = jnp.where(valid, idx, 0)
    payload = jnp.where(valid, flat[idx], jnp.full((), jnp.inf, flat.dtype))
    return idx, payload, count


def decompress_values(idx: jnp.ndarray, payload: jnp.ndarray,
                      num_values: int) -> jnp.ndarray:
    """Scatter a sparse value buffer back onto the all-``inf`` background.

    Pad slots (idx 0, payload inf) cannot clobber slot 0's real value:
    a min-scatter against ``inf`` IS the MIN-merge of each slot with the
    empty background."""
    flat = jnp.full((num_values,), jnp.inf, payload.dtype)
    return flat.at[idx].min(payload)


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_state):
    """-> (quantised tree of (int8, scale), new error state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant(x)
        err = x - _dequant(q, scale)
        return (q, scale), err
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    etree = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, etree


def decompress_tree(qtree):
    return jax.tree.map(lambda q_s: _dequant(*q_s), qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2)


def psum_compressed(grads, error_state, axis_name):
    """int8 error-feedback psum inside shard_map: quantise, all-reduce the
    int8 payload (as int32 partial sums to avoid overflow), dequantise.

    Scales are all-reduced (max) so every member dequantises consistently.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0,
                             axis_name)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        err = x - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale).astype(g.dtype), err
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]))
