"""Divisibility-aware logical-axis sharding resolver.

Params and inputs are annotated with *logical* axis names; the resolver maps
them to physical mesh axes with an ordered preference list, skipping any
candidate whose size does not divide the dimension or whose physical axes are
already taken by another dim of the same tensor. This is what lets one rule
set cover qwen1.5 (40 KV heads, not divisible by model=16 -> falls back) and
llama3 (8 KV heads) without per-arch PartitionSpecs.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Ordered candidates per logical axis. Each candidate is a tuple of physical
# axes used jointly (their sizes multiply).
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # FSDP: weight 'embed' dims shard over the data axes (pod+data jointly
    # when available — params/opt scale down with the full DP world size)
    "embed": (("pod", "data"), ("data",)),
    "mlp": (("model",),),
    "heads": (("model",),),
    "kv": (("model",),),
    "vocab": (("model",),),
    "experts": (("model",),),
    "expert_cap": (("pod", "data"), ("data",)),   # MoE buffer capacity dim
    # data-parallel batch over pod+data jointly, falling back to data
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),          # sequence parallelism (long contexts)
    "kv_seq": (("model",),),       # decode cache sequence dim
    "kv_heads": (("model",),),
    "nodes": (("pod", "data", "model"), ("data", "model")),
    "edges": (("pod", "data", "model"), ("data", "model")),
    "candidates": (("pod", "data", "model"), ("data", "model")),
}


def _axes_size(mesh, axes: tuple[str, ...]) -> int | None:
    sizes = dict(mesh.shape)   # works for Mesh and AbstractMesh
    total = 1
    for a in axes:
        if a not in sizes:
            return None
        total *= sizes[a]
    return total


def resolve_spec(shape: tuple[int, ...], logical: tuple[Any, ...],
                 mesh: Mesh, rules=None) -> P:
    """Map per-dim logical names to a PartitionSpec for ``shape``."""
    rules = rules or DEFAULT_RULES
    if logical is None:
        return P()
    assert len(logical) == len(shape), (logical, shape)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                size = _axes_size(mesh, cand)
                if size is None or size == 1:
                    continue
                if dim % size != 0:
                    continue
                if any(a in used for a in cand):
                    continue
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(shapes_tree, specs_tree, mesh: Mesh, rules=None):
    """NamedSharding pytree from a ShapeDtypeStruct tree + logical-spec tree.

    ``specs_tree`` mirrors ``shapes_tree`` with tuples of logical names as
    leaves (tuples are leaves, matched by structure).
    """
    flat_shapes, treedef = jax.tree.flatten(shapes_tree)
    flat_specs = treedef.flatten_up_to(specs_tree)
    assert len(flat_shapes) == len(flat_specs), (
        f"{len(flat_shapes)} arrays vs {len(flat_specs)} specs")
    shardings = [
        NamedSharding(mesh, resolve_spec(tuple(s.shape), spec, mesh, rules))
        for s, spec in zip(flat_shapes, flat_specs)
    ]
    return jax.tree.unflatten(treedef, shardings)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def ambient_axes_size(axes: tuple[str, ...] = ("model",)) -> int:
    """Product of the named ambient-mesh axis sizes (1 when no mesh)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return 1
    if mesh is None or not mesh.axis_names:
        return 1
    sizes = dict(mesh.shape)
    total = 1
    for a in axes:
        total *= sizes.get(a, 1)
    return total


def constrain(x, logical: tuple[Any, ...], rules=None):
    """Mesh-aware sharding constraint inside model code.

    Resolves logical axis names against the *ambient* mesh (set by
    ``with mesh:`` around jit/lower). No-op when tracing without a mesh
    (CPU smoke tests), so model code stays mesh-agnostic.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return x
    spec = resolve_spec(tuple(x.shape), logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)
