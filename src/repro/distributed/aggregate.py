"""Owner-aligned gather/scatter aggregation via shard_map.

The structural fix for collective-bound message passing under GSPMD
(EXPERIMENTS §Perf P2): instead of letting the SPMD partitioner schedule
the `H[senders]` gather and `segment_sum` scatter (measured ~73 GB
wire/layer/device for mace × ogb_products), do the exchange explicitly —
the same pattern as the distributed BFS bottom-up (DESIGN §3.4):

  forward : one all-gather of node features (payload = n·feat bytes)
            + one psum_scatter of the edge-owners' partial sums;
  backward: the transposes of the two collectives (psum_scatter,
            all-gather) — nothing else crosses the links.

Requires node/edge dims divisible by the mesh size (the input-spec builders
pad to multiples of 8192, divisible by both production meshes). Falls back
to the plain segment-sum path when no ambient mesh is set (CPU smoke tests
trace without a mesh) or divisibility fails.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat


def _ambient_axes():
    try:
        mesh = compat.get_abstract_mesh()
    except Exception:
        return None, 1
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return None, 1
    ndev = 1
    for s in dict(mesh.shape).values():
        ndev *= s
    return tuple(mesh.axis_names), ndev


def owner_gather_scatter(node_feats: jnp.ndarray, senders: jnp.ndarray,
                         receivers: jnp.ndarray, edge_data,
                         edge_fn: Callable, n_nodes: int):
    """A[v] = sum_{e: receivers[e]=v} edge_fn(node_feats[senders[e]],
    edge_data[e]).

    ``edge_data`` is a pytree of [E, ...] arrays (sharded on the edge dim by
    the caller); ``edge_fn(hj, edge_data)`` maps gathered sender features
    [E_loc, ...] + local edge data -> messages [E_loc, ...]. Returns the
    node-sharded aggregate with msgs' trailing shape.
    """
    axes, ndev = _ambient_axes()
    if (axes is None or n_nodes % ndev
            or senders.shape[0] % ndev):
        msgs = edge_fn(node_feats[senders], edge_data)
        return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)

    def body(h_loc, snd, rcv, ed):
        h_full = jax.lax.all_gather(h_loc, axes, tiled=True)   # [n, ...]
        msgs = edge_fn(h_full[snd], ed)                        # local edges
        a_part = jnp.zeros((n_nodes,) + msgs.shape[1:], msgs.dtype)
        a_part = a_part.at[rcv].add(msgs)
        return jax.lax.psum_scatter(a_part, axes, scatter_dimension=0,
                                    tiled=True)

    spec = P(axes)   # leading dim sharded over all mesh axes jointly
    ed_specs = jax.tree.map(lambda _: spec, edge_data)
    return compat.shard_map(body, in_specs=(spec, spec, spec, ed_specs),
                            out_specs=spec, check_vma=False)(
        node_feats, senders, receivers, edge_data)
