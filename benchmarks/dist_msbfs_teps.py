"""Distributed MS-BFS TEPS scaling curve: the sharded bit-lane engine.

Runs the pipelined packed engine over a 1-D partitioned graph
(``repro.core.dist_msbfs``) on a forced-host-device CPU mesh for
ndev ∈ {1, 2, 4} and R ∈ {64, 256}, against the single-host pipelined
engine as baseline. On one CPU the "devices" share the same cores, so the
curve measures the COST STRUCTURE of the distributed formulation (psum
counter merges + the per-layer allreduce-OR frontier exchange), not real
scaling — the acceptance axis is that every sharded point stays
validator-clean and bit-identical to serial BFS while the overhead stays
bounded; on a real mesh the same code path is the Graph500 root-batch
server.

  PYTHONPATH=src python benchmarks/dist_msbfs_teps.py --scale 12
  PYTHONPATH=src python benchmarks/dist_msbfs_teps.py --smoke --json out.json

XLA_FLAGS is set to force the needed host device count BEFORE jax loads;
an inherited XLA_FLAGS with the flag already present wins.
"""
from __future__ import annotations

import argparse
import json
import os


def _force_devices(ndev: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={ndev}".strip())


def run_curve(scale: int, edgefactor: int, ndevs, roots_curve, mode: str,
              seed: int, lanes: int | None, validate: bool) -> dict:
    """aggregate-TEPS per (ndev, R) point; ndev=0 keys the single-host
    pipelined baseline. Returns {label: teps}."""
    import numpy as np

    from repro.graph.generator import rmat_graph
    from repro.graph.graph500 import run_graph500

    g = rmat_graph(scale, edgefactor, seed)
    m_undirected = g.m // 2
    print(f"# dist MS-BFS TEPS — scale={scale} ef={edgefactor} mode={mode} "
          f"ndev={list(ndevs)} R={list(roots_curve)} "
          f"lanes={'auto' if not lanes else lanes}")
    print(f"  n={g.n:,} vertices, m={g.m:,} directed edges "
          f"({m_undirected:,} undirected)")

    points: dict[str, float] = {}
    for r in roots_curve:
        base = run_graph500(scale, edgefactor, mode=mode, num_roots=r,
                            seed=seed, graph=g, batched=True, lanes=lanes,
                            validate=validate)
        base_teps = base.aggregate_teps
        points[f"host_R{r}"] = base_teps
        print(f"  single-host R={r:4d}: "
              f"{base_teps / 1e6:8.2f} MTEPS (lanes={base.lanes})")
        for ndev in ndevs:
            res = run_graph500(scale, edgefactor, mode=mode, num_roots=r,
                               seed=seed, graph=g, batched=True,
                               lanes=lanes, ndev=ndev, validate=validate)
            teps = res.aggregate_teps
            points[f"ndev{ndev}_R{r}"] = teps
            rel = teps / max(base_teps, 1e-12)
            print(f"  sharded ndev={ndev} R={r:4d}: {teps / 1e6:8.2f} MTEPS "
                  f"({rel:5.2f}x single-host, lanes={res.lanes})")
        assert np.isfinite(points[f"host_R{r}"])
    return points


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--ndev", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--roots", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup_simd"))
    ap.add_argument("--lanes", type=int, default=0,
                    help="bit-lane pool; 0 = adaptive sizing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: scale 10, ndev {1,2}, R=64")
    ap.add_argument("--json", default=None,
                    help="write {label: teps} to this path")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.ndev, args.roots = 10, [1, 2], [64]
    _force_devices(max(args.ndev))

    points = run_curve(args.scale, args.edgefactor, args.ndev, args.roots,
                       args.mode, args.seed, args.lanes or None,
                       args.validate)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"  wrote {args.json}")


if __name__ == "__main__":
    main()
