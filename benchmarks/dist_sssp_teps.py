"""Distributed delta-stepping SSSP: TEPS-equivalents + bytes per step.

Runs the 2-D grid SSSP engine (``repro.core.dist_sssp``) over forced
host devices for a curve of grid shapes x wire formats, against the
single-host pipelined engine as baseline. On one CPU the grid devices
share cores, so the TEPS-equivalent column measures the COST STRUCTURE
of the sharded formulation (an expand + a MIN-fold exchange per step
instead of zero), not real scaling; the work numerator is the same fixed
proxy as ``sssp_bench`` (R traversals x m/2 undirected edges). The
second column is the one the MIN-monoid wire format exists for: **bytes
exchanged per engine step** — dense value exchanges ship
graph-proportional messages every step, compressed ones ship
frontier-proportional messages (a relaxation candidate is ``inf``
wherever no relaxation fired), and the headline ``xreduction`` point
(dense bytes / compressed bytes, higher is better) gates that property
in CI.

  PYTHONPATH=src python benchmarks/dist_sssp_teps.py --scale 12
  PYTHONPATH=src python benchmarks/dist_sssp_teps.py --smoke --json out.json

XLA_FLAGS is set to force the needed host device count BEFORE jax loads;
an inherited XLA_FLAGS with the flag already present wins.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _force_devices(ndev: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={ndev}".strip())


def run_curve(scale: int, edgefactor: int, grids, roots_curve, seed: int,
              lanes: int, validate: bool) -> dict:
    """TEPS-equivalent + per-step byte points per (grid, R, wire format).
    Returns a flat {label: value} dict (teps, bytes, xreduction)."""
    import numpy as np

    from repro.core.dist_sssp import (dist2d_sssp_engine_drain,
                                      dist2d_sssp_engine_enqueue,
                                      dist2d_sssp_engine_init,
                                      dist2d_sssp_engine_result, mesh2d,
                                      partition_weighted_graph_2d)
    from repro.graph.generator import rmat_weighted_graph, sample_roots
    from repro.traversal.sssp import default_delta, sssp_pipelined

    wg = rmat_weighted_graph(scale, edgefactor, seed)
    delta = float(default_delta(wg))
    print(f"# dist SSSP TEPS-equiv — scale={scale} ef={edgefactor} "
          f"grids={list(grids)} R={list(roots_curve)} lanes={lanes} "
          f"delta={delta:.4g}")
    print(f"  n={wg.n:,} vertices, m={wg.m:,} directed edges")

    points: dict[str, float] = {}
    for r in roots_curve:
        roots = sample_roots(wg, r, seed=seed)
        width = max(1, min(lanes, r))
        work = r * (wg.m // 2)               # fixed proxy, sssp_bench rule

        def host_sweep():
            return sssp_pipelined(wg, roots, delta=delta, lanes=width)
        base = host_sweep()                  # compile
        base.dist.block_until_ready()
        t0 = time.perf_counter()
        base = host_sweep()
        base.dist.block_until_ready()
        base_teps = work / (time.perf_counter() - t0)
        points[f"host_R{r}"] = base_teps
        print(f"  single-host      R={r:4d}: {base_teps / 1e6:8.2f} "
              f"MTEPS-equiv")
        for pr_, pc in grids:
            dwg2 = partition_weighted_graph_2d(wg, pr_, pc)
            mesh = mesh2d(pr_, pc)
            fmt_bytes = {}
            for compress, tag in ((False, "dense"), (True, "comp")):
                def sweep():
                    s = dist2d_sssp_engine_init(dwg2, mesh, capacity=r,
                                                lanes=width)
                    s = dist2d_sssp_engine_enqueue(s, roots)
                    return dist2d_sssp_engine_drain(
                        dwg2, s, mesh, delta, compress=compress)
                s = sweep()                  # compile + correctness run
                s.dist.block_until_ready()
                if validate:
                    res = dist2d_sssp_engine_result(dwg2, s)
                    np.testing.assert_array_equal(np.asarray(res.dist),
                                                  np.asarray(base.dist))
                t0 = time.perf_counter()
                s = sweep()
                s.dist.block_until_ready()
                dt = time.perf_counter() - t0
                steps = max(int(s.sweep_steps), 1)
                total_bytes = int(s.exch_bytes)
                bps = total_bytes / steps
                teps = work / dt
                fmt_bytes[tag] = total_bytes
                label = f"g{pr_}x{pc}_R{r}"
                points[f"{label}_{tag}"] = teps
                points[f"{label}_{tag}_bytes_per_step"] = bps
                rel = teps / max(base_teps, 1e-12)
                print(f"  grid {pr_}x{pc} {tag:5s} R={r:4d}: "
                      f"{teps / 1e6:8.2f} MTEPS-equiv ({rel:5.2f}x host), "
                      f"{bps / 1024:8.1f} KiB/step over {steps} steps")
            # the headline: exchange-volume reduction from compression
            red = fmt_bytes["dense"] / max(fmt_bytes["comp"], 1)
            points[f"g{pr_}x{pc}_R{r}_xreduction"] = red
            print(f"  grid {pr_}x{pc} exchange volume: {red:5.2f}x less "
                  f"compressed")
    return points


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--grids", type=str, nargs="+",
                    default=["1x2", "2x1", "2x2"],
                    help="grid shapes as PRxPC")
    ap.add_argument("--roots", type=int, nargs="+", default=[32, 64])
    ap.add_argument("--lanes", type=int, default=32,
                    help="dense tropical lane pool per sweep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: scale 10, grid 2x2, R=32, validated")
    ap.add_argument("--json", default=None,
                    help="write {label: value} to this path")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.grids, args.roots = 10, ["2x2"], [32]
        args.validate = True
    grids = [tuple(int(x) for x in s.split("x")) for s in args.grids]
    _force_devices(max(pr_ * pc for pr_, pc in grids))

    points = run_curve(args.scale, args.edgefactor, grids, args.roots,
                       args.seed, args.lanes, args.validate)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"  wrote {args.json}")


if __name__ == "__main__":
    main()
