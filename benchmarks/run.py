"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV for each benchmark, where
``us_per_call`` is the wall time of the benchmark's core measured operation
and ``derived`` the benchmark's headline derived quantity.

  PYTHONPATH=src python -m benchmarks.run            # fast defaults
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sweep
"""
from __future__ import annotations

import argparse
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def bench_table2(full: bool):
    from benchmarks.table2_switching import run
    rows, us = _timed(run, 14 if full else 11, 16)
    bu_layers = sum(1 for r in rows if r["approach"] == "bottom-up")
    return us, f"bu_layers={bu_layers}/{len(rows)}"


def bench_table3(full: bool):
    from benchmarks.table3_maxpos import run
    rows, us = _timed(run, 13 if full else 11, 16)
    big = max(rows, key=lambda r: r["found"])
    return us, f"retired@8={big['retired_frac'][8]:.3f}"


def bench_fig3(full: bool):
    from benchmarks.fig3_teps import run
    scales = (12, 13, 14) if full else (10, 11)
    efs = (16, 32, 64) if full else (16, 32)
    res, us = _timed(run, scales, efs, 16 if full else 4)
    sc = scales[-1]
    simd = res[(sc, efs[-1], "hybrid")]
    nosimd = res[(sc, efs[-1], "hybrid_nosimd")]
    return us, f"simd_vs_nosimd={simd / max(nosimd, 1):.3f}x"


def bench_table4(full: bool):
    from benchmarks.table4_counters import run
    rows, us = _timed(run, 13 if full else 11, 32 if full else 16)
    tot_no = sum(r["t_nosimd_ms"] for r in rows)
    tot_si = sum(r["t_simd_ms"] for r in rows)
    return us, f"bu_speedup={tot_no / max(tot_si, 1e-9):.2f}x"


def bench_roofline(full: bool):
    from benchmarks.roofline import load_records
    recs, us = _timed(load_records, "pod16x16")
    ok = [r for r in recs if r["status"] == "ok" and "roofline" in r]
    best = max(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    return us, (f"cells={len(ok)};best_frac="
                f"{best['roofline']['roofline_fraction']:.3f}"
                f"@{best.get('arch', 'bfs')}/{best.get('shape', '')}")


BENCHES = [
    ("table2_switching", bench_table2),
    ("table3_maxpos", bench_table3),
    ("fig3_teps", bench_fig3),
    ("table4_counters", bench_table4),
    ("roofline", bench_roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        us, derived = fn(args.full)
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
