"""CI benchmark gate: run the fast benches, emit BENCH_pr.json, compare.

Collects one higher-is-better throughput number per benchmark:

* every ``benchmarks/run.py`` fast-default bench as calls/sec
  (1e6 / us_per_call — the paper-table analogs have no TEPS axis);
* MS-BFS aggregate TEPS, serial loop and pipelined batched engine
  (scale 10, R=64);
* the analytics smoke (components / closeness / khop TEPS-equivalents on
  the lane engine, ``analytics_bench.bench_points`` at scale 10);
* the weighted-path smoke (delta-stepping SSSP / unit-weight anchor /
  weighted closeness, ``sssp_bench.bench_points`` at scale 10);
* the serving smoke (``serve_bench.bench_points`` at scale 10): a
  replayed mixed-workload trace through ``AnalyticsService`` — mix TEPS,
  answered-early fraction, and the khop layers saved by the streaming
  read-outs gate; p50/p99 sojourn layers are recorded as derived
  metadata;
* the distributed MS-BFS smoke (``dist_msbfs_teps.py --smoke``), run in a
  subprocess so the forced host-device count never leaks into the
  single-device timings;
* the 2-D grid smoke (``dist2d_teps.py --smoke``, same subprocess
  isolation): per-wire-format TEPS plus the exchange-volume reduction
  ratio from frontier compression;
* the distributed SSSP smoke (``dist_sssp_teps.py --smoke``, same
  isolation): the sharded delta-stepping engine's TEPS-equivalents per
  wire format plus ITS exchange-volume reduction ratio;
* the telemetry-overhead gate (``obs.overhead``): recorder-off TEPS over
  the raw drain's — proves ``recorder=None`` stays free (< 3% bound via
  its own per-bench ``tolerance``).

Gate: with ``--baseline BENCH_baseline.json``, exit 1 when any benchmark
regresses more than ``--tolerance`` (default 25%) below its baseline
value; a baseline entry carrying its own ``tolerance`` key gates at that
bound instead. New benchmarks absent from the baseline pass (and are
reported); refresh the checked-in baseline with ``--write-baseline`` on
a quiet machine when a PR legitimately shifts throughput.

  PYTHONPATH=src python benchmarks/ci_bench.py --out BENCH_pr.json \
      --baseline BENCH_baseline.json --tolerance 0.25
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

# allow `python benchmarks/ci_bench.py` (sys.path[0] = benchmarks/)
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)


def _bench_run_py() -> dict:
    from benchmarks.run import BENCHES
    out = {}
    for name, fn in BENCHES:
        try:
            us, derived = fn(False)
        except Exception as e:       # e.g. roofline needs dryrun artifacts
            print(f"skip run.{name}: {type(e).__name__}: {e}")
            continue
        out[f"run.{name}"] = dict(value=1e6 / max(us, 1e-9),
                                  unit="calls_per_sec", derived=derived)
    return out


def _bench_msbfs(scale: int = 10, roots: int = 64) -> dict:
    from repro.graph.generator import rmat_graph
    from repro.graph.graph500 import run_graph500
    g = rmat_graph(scale, 16, 0)
    out = {}
    for label, batched in (("serial", False), ("batched", True)):
        res = run_graph500(scale, 16, mode="hybrid", num_roots=roots,
                           seed=0, graph=g, batched=batched)
        out[f"msbfs.{label}_s{scale}_R{roots}"] = dict(
            value=res.aggregate_teps, unit="teps")
    return out


def _bench_analytics(scale: int = 10) -> dict:
    """Analytics smoke: components + closeness + khop TEPS-equivalents on
    the lane engine (``analytics_bench.bench_points``) — the new
    subsystem's regressions gate exactly like BFS TEPS."""
    from benchmarks.analytics_bench import bench_points
    return {f"analytics.{k}": dict(value=v, unit="teps_equiv")
            for k, v in bench_points(scale).items()}


def _bench_sssp(scale: int = 10) -> dict:
    """Weighted-path smoke: delta-stepping sweep + unit-weight anchor +
    weighted closeness TEPS-equivalents (``sssp_bench.bench_points``) —
    weighted regressions gate exactly like BFS TEPS."""
    from benchmarks.sssp_bench import bench_points
    return {f"sssp.{k}": dict(value=v, unit="teps_equiv")
            for k, v in bench_points(scale).items()}


def _bench_serve_smoke() -> dict:
    """Serving smoke (``serve_bench.bench_points`` at scale 10): one
    mixed bfs/khop/reach/closeness/sssp trace replayed through
    ``AnalyticsService`` with streaming read-outs on vs off. Gates the
    aggregate mix TEPS, the answered-early fraction, and the mean khop
    layers saved by streaming; the lower-is-better p50/p99 sojourn
    points ride along as ``derived`` metadata (recorded in the bench
    JSON, never compared — the dist benches' byte-counter precedent)."""
    from benchmarks.serve_bench import bench_points
    points = bench_points(10)
    sojourn = {k: v for k, v in points.items() if "sojourn" in k}
    out = {}
    for k, v in points.items():
        if "sojourn" in k:
            continue
        unit = ("teps" if "teps" in k
                else "ratio" if "frac" in k else "layers")
        out[f"serve.{k}"] = dict(value=v, unit=unit)
        if "teps" in k:
            out[f"serve.{k}"]["derived"] = sojourn
    return out


def _bench_dist_smoke() -> dict:
    here = os.path.dirname(os.path.abspath(__file__))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    try:
        subprocess.run(
            [sys.executable, os.path.join(here, "dist_msbfs_teps.py"),
             "--smoke", "--json", tmp],
            check=True, env=dict(os.environ), timeout=1800)
        with open(tmp) as f:
            points = json.load(f)
    finally:
        os.unlink(tmp)
    return {f"dist_msbfs.{k}": dict(value=v, unit="teps")
            for k, v in points.items()}


def _bench_dist2d_smoke() -> dict:
    """2-D grid smoke (``dist2d_teps.py --smoke``): TEPS per wire format
    plus the headline ``xreduction`` ratio (dense bytes / compressed
    bytes, higher is better). Raw ``bytes_per_layer`` points are
    lower-is-better and so stay out of the gate — the ratio carries the
    same signal in gateable form."""
    here = os.path.dirname(os.path.abspath(__file__))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    try:
        subprocess.run(
            [sys.executable, os.path.join(here, "dist2d_teps.py"),
             "--smoke", "--json", tmp],
            check=True, env=dict(os.environ), timeout=1800)
        with open(tmp) as f:
            points = json.load(f)
    finally:
        os.unlink(tmp)
    out = {}
    for k, v in points.items():
        if k.endswith("_bytes_per_layer"):
            continue
        unit = "ratio" if k.endswith("_xreduction") else "teps"
        out[f"dist2d.{k}"] = dict(value=v, unit=unit)
    return out


def _bench_dist_sssp_smoke() -> dict:
    """Distributed SSSP smoke (``dist_sssp_teps.py --smoke``):
    TEPS-equivalents per wire format plus the exchange-volume
    ``xreduction`` ratio. Raw ``bytes_per_step`` points are
    lower-is-better and stay out of the gate — the ratio carries the
    compression signal in gateable form."""
    here = os.path.dirname(os.path.abspath(__file__))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    try:
        subprocess.run(
            [sys.executable, os.path.join(here, "dist_sssp_teps.py"),
             "--smoke", "--json", tmp],
            check=True, env=dict(os.environ), timeout=1800)
        with open(tmp) as f:
            points = json.load(f)
    finally:
        os.unlink(tmp)
    out = {}
    for k, v in points.items():
        if k.endswith("_bytes_per_step"):
            continue
        unit = "ratio" if k.endswith("_xreduction") else "teps_equiv"
        out[f"sssp_dist.{k}"] = dict(value=v, unit=unit)
    return out


def _bench_obs_overhead(scale: int = 10, roots: int = 64,
                        reps: int = 3) -> dict:
    """The telemetry-overhead gate: TEPS of the recorder-OFF driver path
    (``msbfs_pipelined(recorder=None)``, which must compile to exactly
    the pre-obs fused drain) over TEPS of the raw engine drain called
    directly. A ratio below ~0.97 means the ``recorder=None`` branch is
    no longer free — the ISSUE's < 3% acceptance bound, gated with this
    bench's own tight per-bench ``tolerance``. The recorder-ON TEPS ride
    along as derived metadata (recording steps host-side per layer, so
    it is EXPECTED to be slower — that cost is opt-in, never gated)."""
    import jax
    import numpy as np

    from repro.core.msbfs import (msbfs_engine_drain, msbfs_engine_enqueue,
                                  msbfs_engine_init, msbfs_engine_result,
                                  msbfs_pipelined)
    from repro.graph.generator import rmat_graph
    from repro.obs import SweepRecorder

    g = rmat_graph(scale, 16, 0)
    rts = np.arange(roots, dtype=np.int32) % g.n
    lanes = 64

    def run_raw():
        s = msbfs_engine_init(g, capacity=roots, lanes=lanes)
        s = msbfs_engine_enqueue(s, rts)
        s = msbfs_engine_drain(g, s, "hybrid", 8.0, 8.0, 8, "xla")
        return msbfs_engine_result(g, s, derive_parents=False)

    def run_off():
        return msbfs_pipelined(g, rts, lanes=lanes, derive_parents=False)

    def teps_of(fn):
        res = fn()
        jax.block_until_ready(res.depth)       # warm compile out of timing
        edges = float(np.asarray(res.edges_traversed).sum()) / 2
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().depth)
            best = min(best, time.perf_counter() - t0)
        return edges / best

    teps_raw = teps_of(run_raw)
    teps_off = teps_of(run_off)
    res_on = msbfs_pipelined(g, rts, lanes=lanes, derive_parents=False,
                             recorder=SweepRecorder(engine="msbfs"))
    jax.block_until_ready(res_on.depth)
    t0 = time.perf_counter()
    jax.block_until_ready(
        msbfs_pipelined(g, rts, lanes=lanes, derive_parents=False,
                        recorder=SweepRecorder(engine="msbfs")).depth)
    wall_on = time.perf_counter() - t0
    edges = float(np.asarray(res_on.edges_traversed).sum()) / 2
    return {"obs.overhead": dict(
        value=teps_off / max(teps_raw, 1e-9), unit="ratio",
        tolerance=0.03,
        derived=dict(teps_recorder_off=round(teps_off),
                     teps_raw_drain=round(teps_raw),
                     teps_recorder_on=round(edges / max(wall_on, 1e-9))))}


def append_history(path: str, benches: dict) -> dict | None:
    """Append this run's ``{git_sha, benchmarks}`` entry to the JSONL
    trajectory file and return the PREVIOUS entry (None on first run).
    The sha comes from the environment (GITHUB_SHA in CI, GIT_SHA as a
    local override) — no wall-clock in the entry, so replaying the bench
    at the same sha appends an identical record."""
    sha = os.environ.get("GITHUB_SHA") or os.environ.get("GIT_SHA") \
        or "unknown"
    prev = None
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    prev = json.loads(line)
    entry = dict(git_sha=sha,
                 benchmarks={k: round(v["value"], 6)
                             for k, v in benches.items()})
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return prev


def print_trend(benches: dict, prev: dict | None) -> None:
    """Per-benchmark trend vs the previous history entry."""
    if prev is None:
        print("bench history: first entry, no trend yet")
        return
    print(f"bench trend vs {prev.get('git_sha', '?')[:12]}:")
    prev_b = prev.get("benchmarks", {})
    for name in sorted(benches):
        cur = benches[name]["value"]
        old = prev_b.get(name)
        if old is None:
            print(f"  {name:40s} {cur:12.4g}  (new)")
        elif old == 0:
            print(f"  {name:40s} {cur:12.4g}  (prev 0)")
        else:
            delta = cur / old - 1.0
            print(f"  {name:40s} {cur:12.4g}  {delta:+.1%}")


def compare(pr: dict, baseline: dict, tolerance: float) -> list[str]:
    """Regressions worse than the tolerance (fractional drop), as
    human-readable failure lines. A baseline entry may carry its own
    ``tolerance`` key (e.g. the tight ``obs.overhead`` gate) overriding
    the global one."""
    failures = []
    for name, base in baseline["benchmarks"].items():
        cur = pr["benchmarks"].get(name)
        if cur is None:
            failures.append(f"{name}: present in baseline but not in PR run")
            continue
        tol = float(base.get("tolerance", tolerance))
        floor = base["value"] * (1.0 - tol)
        if cur["value"] < floor:
            drop = 1.0 - cur["value"] / max(base["value"], 1e-12)
            failures.append(
                f"{name}: {cur['value']:.3g} {cur['unit']} is "
                f"{drop:.0%} below baseline {base['value']:.3g} "
                f"(tolerance {tol:.0%})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pr.json")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--write-baseline", action="store_true",
                    help="also write the result to the --baseline path")
    ap.add_argument("--skip-dist", action="store_true",
                    help="skip the subprocess dist smoke (debug aid)")
    ap.add_argument("--history", default=None, metavar="JSONL",
                    help="append this run's {git_sha, benchmarks} to the "
                         "JSONL trajectory file and print the trend vs "
                         "the previous entry")
    args = ap.parse_args()

    t0 = time.perf_counter()
    benches: dict = {}
    benches.update(_bench_run_py())
    benches.update(_bench_msbfs())
    benches.update(_bench_analytics())
    benches.update(_bench_sssp())
    benches.update(_bench_serve_smoke())
    benches.update(_bench_obs_overhead())
    if not args.skip_dist:
        benches.update(_bench_dist_smoke())
        benches.update(_bench_dist2d_smoke())
        benches.update(_bench_dist_sssp_smoke())
    pr = dict(tolerance=args.tolerance,
              wall_s=round(time.perf_counter() - t0, 2),
              benchmarks=benches)

    with open(args.out, "w") as f:
        json.dump(pr, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(benches)} benchmarks, "
          f"{pr['wall_s']}s)")
    for name in sorted(benches):
        b = benches[name]
        print(f"  {name:40s} {b['value']:12.4g} {b['unit']}")

    if args.history:
        prev = append_history(args.history, benches)
        print_trend(benches, prev)

    if args.write_baseline and args.baseline:
        with open(args.baseline, "w") as f:
            json.dump(pr, f, indent=2, sort_keys=True)
        print(f"wrote baseline {args.baseline}")
        return
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = compare(pr, baseline, args.tolerance)
        if failures:
            print("TEPS regression gate FAILED:")
            for line in failures:
                print(f"  {line}")
            sys.exit(1)
        print(f"regression gate passed vs {args.baseline} "
              f"(tolerance {args.tolerance:.0%})")


if __name__ == "__main__":
    main()
