"""Paper Table 3 analog: probe-depth statistics that justify MAX_POS = 8.

For each layer of a hybrid traversal, reconstructs the bottom-up entry state
and reports, for the vertices that find a parent this layer, how many probe
positions the vectorised bottom-up needed (fraction retired within
MAX_POS in {1, 2, 4, 8, 16}) plus the fallback residue.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bottomup import bottomup_probe_stats
from repro.core.hybrid import bfs
from repro.graph.generator import rmat_graph, sample_roots

MAX_POS_SWEEP = (1, 2, 4, 8, 16)


def run(scale: int = 12, edgefactor: int = 16, seed: int = 0):
    g = rmat_graph(scale, edgefactor, seed)
    root = int(sample_roots(g, 1, seed=seed + 1)[0])
    out = bfs(g, root, "hybrid")
    depth = np.asarray(out.depth)
    n_layers = int(out.num_layers)
    print(f"# Table 3 analog: SCALE={scale} edgefactor={edgefactor}")
    header = " ".join(f"ret@{mp:<3d}" for mp in MAX_POS_SWEEP)
    print(f"{'layer':>5s} {'unvisited':>10s} {'found':>9s} {header} residue@8")
    rows = []
    for layer in range(1, n_layers):
        visited = jnp.asarray((depth >= 0) & (depth < layer))
        frontier = jnp.asarray(depth == layer - 1)
        found = int((depth == layer).sum())
        if found == 0:
            continue
        fracs = []
        residue8 = 0
        for mp in MAX_POS_SWEEP:
            st = bottomup_probe_stats(g, frontier, visited, max_pos=mp)
            fracs.append(int(st["retired"]) / max(found, 1))
            if mp == 8:
                residue8 = int(st["residue"])
        print(f"{layer:5d} {int((depth < 0).sum() + (depth >= layer).sum()):10d} "
              f"{found:9d} " + " ".join(f"{f:7.3f}" for f in fracs)
              + f" {residue8:9d}")
        rows.append(dict(layer=layer, found=found,
                         retired_frac={mp: f for mp, f in
                                       zip(MAX_POS_SWEEP, fracs)},
                         residue8=residue8))
    return rows


if __name__ == "__main__":
    run()
