"""Inject the generated dry-run memory + roofline tables into EXPERIMENTS.md
(between the <!-- DRYRUN_MEMORY_TABLE --> / <!-- ROOFLINE_TABLES --> markers).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import device_gb, load_records, markdown_table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def memory_table() -> str:
    lines = ["| arch | shape | 1-pod GB/dev | 2-pod GB/dev | note |",
             "|---|---|---|---|---|"]
    by_key = {}
    for mesh in ("pod16x16", "pod2x16x16"):
        for r in load_records(mesh):
            if r["status"] != "ok" or "arch" not in r:
                continue
            by_key.setdefault((r["arch"], r["shape"]), {})[mesh] = device_gb(r)
    for (arch, shape), v in sorted(by_key.items()):
        g1 = v.get("pod16x16")
        g2 = v.get("pod2x16x16")
        worst = max(x for x in (g1, g2) if x is not None)
        note = ""
        if worst > 16:
            note = ("CPU f32-inflated; ~half native bf16"
                    if worst < 45 else "over budget — see §Perf")
        lines.append(f"| {arch} | {shape} | "
                     f"{g1:.1f} | {g2:.1f} | {note} |")
    return "\n".join(lines)


def bfs_table() -> str:
    lines = ["", "### Distributed BFS dry-run cells", "",
             "| cell | mesh | temp GB/dev | wire MB/layer/dev | dominant |",
             "|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(ROOT, "artifacts", "dryrun",
                                           "bfs-graph500__*.json"))):
        r = json.load(open(f))
        lines.append(
            f"| scale{r['scale']}_ef{r['edgefactor']} | {r['mesh']} | "
            f"{r['memory']['temp_bytes'] / 1e9:.2f} | "
            f"{r['collective']['per_layer_wire_bytes'] / 1e6:.1f} | "
            f"{r['roofline']['dominant']} |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- DRYRUN_MEMORY_TABLE -->",
                        memory_table(), 1)
    roof = (f"\n### Single pod (16×16 = 256 chips)\n\n"
            f"{markdown_table('pod16x16')}\n"
            f"\n### Two pods (2×16×16 = 512 chips)\n\n"
            f"{markdown_table('pod2x16x16')}\n{bfs_table()}\n")
    text = text.replace("<!-- ROOFLINE_TABLES -->", roof, 1)
    open(path, "w").write(text)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
