"""Paper Tables 4-7 analog: per-layer resource counters, non-SIMD vs SIMD
bottom-up.

PAPI hardware counters don't exist on a dry-run container; the analog
counters are the ones that determine TPU cost: active vector lanes (work),
probe lanes, bitmap-gather count, fallback activations, plus measured
per-layer wall time of the jitted step (CPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bottomup import (bottomup_nosimd_step, bottomup_probe_stats,
                                 bottomup_simd_step)
from repro.core.hybrid import bfs
from repro.graph.generator import rmat_graph, sample_roots


def run(scale: int = 12, edgefactor: int = 32, seed: int = 0,
        max_pos: int = 8):
    g = rmat_graph(scale, edgefactor, seed)
    root = int(sample_roots(g, 1, seed=seed + 1)[0])
    out = bfs(g, root, "hybrid")
    depth = np.asarray(out.depth)
    n_layers = int(out.num_layers)
    m = g.m

    simd = jax.jit(lambda f, v, p: bottomup_simd_step(g, f, v, p, max_pos))
    nosimd = jax.jit(lambda f, v, p: bottomup_nosimd_step(g, f, v, p))

    # warm-up (compile) outside the measured region
    f0 = jnp.asarray(depth == 0)
    v0 = jnp.asarray(depth == 0)
    p0 = jnp.full((g.n,), -1, jnp.int32)
    jax.block_until_ready(simd(f0, v0, p0))
    jax.block_until_ready(nosimd(f0, v0, p0))

    def _best_ms(fn, *args, reps=3):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    print(f"# Tables 4-7 analog: SCALE={scale} ef={edgefactor} "
          f"MAX_POS={max_pos}; per-layer bottom-up executed both ways")
    print(f"{'layer':>5s} {'NV':>9s} | {'noSIMD lanes':>12s} {'t(ms)':>8s} | "
          f"{'probe lanes':>11s} {'retired':>8s} {'residue':>8s} "
          f"{'t(ms)':>8s}")
    rows = []
    for layer in range(1, n_layers):
        visited = jnp.asarray((depth >= 0) & (depth < layer))
        frontier = jnp.asarray(depth == layer - 1)
        nv = int((~np.asarray(visited)).sum())
        par = jnp.full((g.n,), -1, jnp.int32)

        # non-SIMD: every unvisited vertex scans edges -> active lanes = m
        t_no = _best_ms(nosimd, frontier, visited, par)
        st = bottomup_probe_stats(g, frontier, visited, max_pos=max_pos)
        t_si = _best_ms(simd, frontier, visited, par)

        print(f"{layer:5d} {nv:9d} | {m:12d} {t_no:8.2f} | "
              f"{int(st['probe_lanes']):11d} {int(st['retired']):8d} "
              f"{int(st['residue']):8d} {t_si:8.2f}")
        rows.append(dict(layer=layer, nv=nv, nosimd_lanes=m, t_nosimd_ms=t_no,
                         probe_lanes=int(st["probe_lanes"]),
                         retired=int(st["retired"]),
                         residue=int(st["residue"]), t_simd_ms=t_si))
    return rows


if __name__ == "__main__":
    run()
