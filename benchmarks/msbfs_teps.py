"""MS-BFS aggregate TEPS: pipelined multi-root sweep vs the serial loop.

The Graph500 protocol answers a set of roots; the serial harness replays
one compiled executable per root, the batched harness streams ALL roots
through the pipelined bit-lane engine (``repro.core.msbfs``) in one
invocation — lanes refill from the pending-root queue mid-sweep, so
R > 64 pays extra layers, not batch barriers. The headline is aggregate
TEPS — total traversed edges over total wall time — i.e. throughput under
an R-query batch, the serving axis from ROADMAP.

Default is the scaling curve R ∈ {64, 128, 256} against the R=64 serial
baseline (the acceptance axis: pipelined R=256 must clear 3.5x the serial
baseline); ``--roots N`` switches to a single serial-vs-batched pair at N.

  PYTHONPATH=src python benchmarks/msbfs_teps.py --scale 14
  PYTHONPATH=src python benchmarks/msbfs_teps.py --scale 14 --roots 64

Wall-clock on the CPU container is not comparable to KNC GTEPS; the
*relative* claim validated here is batched >= serial throughput.
"""
from __future__ import annotations

import argparse

from repro.graph.generator import rmat_graph
from repro.graph.graph500 import run_graph500

CURVE_ROOTS = (64, 128, 256)


def _print_result(label, res):
    s = res.summary()
    print(f"  {label:14s}: aggregate {s['aggregate_teps'] / 1e6:10.2f} "
          f"MTEPS  (harmonic-mean per-root "
          f"{s['harmonic_mean_teps'] / 1e6:10.2f} MTEPS, "
          f"total time {sum(res.times):.3f}s, {s['nroots']} roots)")


def run(scale: int = 14, edgefactor: int = 16, num_roots: int = 64,
        mode: str = "hybrid", probe_impl: str = "xla", seed: int = 0,
        validate: bool = False, lanes: int = 64):
    g = rmat_graph(scale, edgefactor, seed)
    print(f"# MS-BFS aggregate TEPS — scale={scale} ef={edgefactor} "
          f"roots={num_roots} mode={mode} lanes={lanes}")
    print(f"  n={g.n:,} vertices, m={g.m:,} directed edges")

    results = {}
    for label, batched in (("serial", False), ("batched", True)):
        res = run_graph500(scale, edgefactor, mode=mode,
                           num_roots=num_roots, seed=seed, graph=g,
                           probe_impl=probe_impl, validate=validate,
                           batched=batched, lanes=lanes)
        results[label] = res
        _print_result(label, res)

    speedup = (results["batched"].aggregate_teps
               / max(results["serial"].aggregate_teps, 1e-12))
    print(f"  batched/serial aggregate-TEPS speedup: {speedup:.2f}x")
    return results


def run_curve(scale: int = 14, edgefactor: int = 16, mode: str = "hybrid",
              probe_impl: str = "xla", seed: int = 0,
              validate: bool = False, lanes: int = 64,
              roots_curve=CURVE_ROOTS):
    """Scaling curve: serial baseline at R=64, pipelined engine at each R.

    Every batched point is ONE engine invocation; the R=256 point must
    clear 3.5x the serial baseline (refill overlap keeps lanes busy, so
    aggregate TEPS should not degrade as R grows past the lane pool).
    """
    g = rmat_graph(scale, edgefactor, seed)
    print(f"# MS-BFS TEPS scaling curve — scale={scale} ef={edgefactor} "
          f"mode={mode} lanes={lanes} R={list(roots_curve)}")
    print(f"  n={g.n:,} vertices, m={g.m:,} directed edges")

    baseline = run_graph500(scale, edgefactor, mode=mode,
                            num_roots=roots_curve[0], seed=seed, graph=g,
                            probe_impl=probe_impl, validate=validate,
                            batched=False)
    _print_result(f"serial R={roots_curve[0]}", baseline)
    base_teps = max(baseline.aggregate_teps, 1e-12)

    curve = {"serial": baseline}
    for r in roots_curve:
        res = run_graph500(scale, edgefactor, mode=mode, num_roots=r,
                           seed=seed, graph=g, probe_impl=probe_impl,
                           validate=validate, batched=True, lanes=lanes)
        curve[r] = res
        _print_result(f"batched R={r}", res)
        print(f"    -> {res.aggregate_teps / base_teps:6.2f}x the "
              f"R={roots_curve[0]} serial baseline")
    return curve


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=None,
                    help="single-R mode; default runs the R=64/128/256 curve")
    ap.add_argument("--lanes", type=int, default=64,
                    help="bit-lane pool size of the pipelined engine")
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup_simd"))
    ap.add_argument("--probe-impl", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()
    if args.roots is None:
        run_curve(scale=args.scale, edgefactor=args.edgefactor,
                  mode=args.mode, probe_impl=args.probe_impl,
                  seed=args.seed, validate=args.validate, lanes=args.lanes)
    else:
        run(scale=args.scale, edgefactor=args.edgefactor,
            num_roots=args.roots, mode=args.mode,
            probe_impl=args.probe_impl, seed=args.seed,
            validate=args.validate, lanes=args.lanes)


if __name__ == "__main__":
    main()
