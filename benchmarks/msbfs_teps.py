"""MS-BFS aggregate TEPS: batched 64-root sweep vs the serial 64-root loop.

The Graph500 protocol answers 64 roots; the serial harness replays one
compiled executable per root, the batched harness packs all 64 roots into
uint32 bit-lanes and answers them in ONE traversal sweep
(``repro.core.msbfs``). The headline is aggregate TEPS — total traversed
edges over total wall time — i.e. throughput under a 64-query batch, the
serving axis from ROADMAP.

  PYTHONPATH=src python benchmarks/msbfs_teps.py --scale 14

Wall-clock on the CPU container is not comparable to KNC GTEPS; the
*relative* claim validated here is batched >= serial throughput.
"""
from __future__ import annotations

import argparse

from repro.graph.generator import rmat_graph
from repro.graph.graph500 import run_graph500


def run(scale: int = 14, edgefactor: int = 16, num_roots: int = 64,
        mode: str = "hybrid", probe_impl: str = "xla", seed: int = 0,
        validate: bool = False):
    g = rmat_graph(scale, edgefactor, seed)
    print(f"# MS-BFS aggregate TEPS — scale={scale} ef={edgefactor} "
          f"roots={num_roots} mode={mode}")
    print(f"  n={g.n:,} vertices, m={g.m:,} directed edges")

    results = {}
    for label, batched in (("serial", False), ("batched", True)):
        res = run_graph500(scale, edgefactor, mode=mode,
                           num_roots=num_roots, seed=seed, graph=g,
                           probe_impl=probe_impl, validate=validate,
                           batched=batched)
        results[label] = res
        s = res.summary()
        print(f"  {label:8s}: aggregate {s['aggregate_teps'] / 1e6:10.2f} "
              f"MTEPS  (harmonic-mean per-root "
              f"{s['harmonic_mean_teps'] / 1e6:10.2f} MTEPS, "
              f"total time {sum(res.times):.3f}s, "
              f"{s['nroots']} roots)")

    speedup = (results["batched"].aggregate_teps
               / max(results["serial"].aggregate_teps, 1e-12))
    print(f"  batched/serial aggregate-TEPS speedup: {speedup:.2f}x")
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=64)
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup_simd"))
    ap.add_argument("--probe-impl", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()
    run(scale=args.scale, edgefactor=args.edgefactor, num_roots=args.roots,
        mode=args.mode, probe_impl=args.probe_impl, seed=args.seed,
        validate=args.validate)


if __name__ == "__main__":
    main()
