"""Analytics workload throughput on the lane engine.

One TEPS-equivalent number per workload (higher is better), with the
compile excluded by a warmup run — the analytics analog of
``msbfs_teps.py``:

The work numerator is a fixed PROXY per workload — stable across runs by
construction, which is what the regression gate needs (actual traversal
work varies with lane/component collisions):

* ``components`` — label the whole graph; numerator = the graph's m/2
  undirected edges (the labelling floor), NOT per-lane traversal work,
  so its TEPS-equiv reads far below the raw-traversal points;
* ``closeness`` — sampled-source centrality; numerator = k * m/2
  (k traversals, most covering the giant component);
* ``khop`` — a k-hop query batch (S lanes, sliced at depth <= k after
  full traversals); numerator = S * m/2.

  PYTHONPATH=src python benchmarks/analytics_bench.py --scale 12
  PYTHONPATH=src python benchmarks/analytics_bench.py --smoke --json out.json

``--json`` writes {name: teps} points for the CI regression gate
(``ci_bench.py`` embeds these under ``analytics.*``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# allow `python benchmarks/analytics_bench.py` (sys.path[0] = benchmarks/)
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)


def _timed(fn):
    """(wall seconds, result) with one warmup call to absorb compiles."""
    fn()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def bench_points(scale: int, edgefactor: int = 16, seed: int = 0,
                 batch: int = 64, closeness_sources: int = 64,
                 khop_sources: int = 64, khop_k: int = 2,
                 ndev: int = 1) -> dict[str, float]:
    """TEPS-equivalent throughput per analytics workload at one scale."""
    from repro.analytics import (LaneEngine, closeness_centrality,
                                 connected_components, khop_neighborhood)
    from repro.graph.generator import rmat_graph, sample_roots
    g = rmat_graph(scale, edgefactor, seed)
    eng = LaneEngine(g, ndev=ndev, lanes=None)
    points = {}

    dt, _ = _timed(lambda: connected_components(eng, batch=batch))
    # labelling work: each component's edges once per covering lane; the
    # graph total (m/2 undirected edges fully labelled) is the floor
    points[f"components_s{scale}"] = (g.m // 2) / dt

    k = min(closeness_sources, g.n)
    dt, _ = _timed(
        lambda: closeness_centrality(eng, sources=k, seed=1, chunk=batch))
    # k sampled traversals, most covering the giant component
    points[f"closeness_s{scale}_k{k}"] = k * (g.m // 2) / dt

    roots = sample_roots(g, khop_sources, seed=2)
    dt, _ = _timed(lambda: khop_neighborhood(eng, roots, khop_k))
    points[f"khop_s{scale}_S{len(roots)}_k{khop_k}"] = (
        len(roots) * (g.m // 2) / dt)
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ndev", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI point: scale 10")
    ap.add_argument("--json", default=None, help="write {name: teps} here")
    args = ap.parse_args()

    scale = 10 if args.smoke else args.scale
    points = bench_points(scale, args.edgefactor, args.seed, ndev=args.ndev)
    for name, teps in points.items():
        print(f"{name:32s} {teps / 1e6:10.2f} MTEPS-equiv")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
