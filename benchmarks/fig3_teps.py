"""Paper Figure 3 analog: harmonic-mean TEPS across SCALE x edgefactor for
the SIMD hybrid (ours), the non-SIMD hybrid (paper's blue line) and the
pure top-down baseline.

Wall-clock on the CPU container is not comparable to KNC GTEPS; the
*relative* claims are whats validated: SIMD > non-SIMD, gap grows with
edgefactor, hybrid > top-down.
"""
from __future__ import annotations


from repro.graph.generator import rmat_graph
from repro.graph.graph500 import run_graph500

MODES = ("hybrid", "hybrid_nosimd", "topdown")


def run(scales=(10, 11, 12), edgefactors=(16, 32, 64), roots: int = 8,
        seed: int = 0):
    print("# Fig 3 analog: harmonic-mean TEPS (CPU wall-clock)")
    print(f"{'scale':>5s} {'ef':>3s} " + " ".join(f"{m:>16s}" for m in MODES))
    results = {}
    for ef in edgefactors:
        for sc in scales:
            g = rmat_graph(sc, ef, seed)
            vals = []
            for mode in MODES:
                res = run_graph500(sc, ef, mode=mode, num_roots=roots,
                                   seed=seed, graph=g)
                results[(sc, ef, mode)] = res.harmonic_mean_teps
                vals.append(res.harmonic_mean_teps)
            print(f"{sc:5d} {ef:3d} " + " ".join(f"{v:16,.0f}" for v in vals))
    return results


if __name__ == "__main__":
    run()
