"""2-D partitioned MS-BFS: TEPS + bytes-exchanged-per-layer.

Runs the 2-D grid engine (``repro.core.dist2d``) over forced host devices
for a curve of grid shapes x wire formats, against the single-host
pipelined engine as baseline. On one CPU the grid devices share cores, so
the TEPS column measures the COST STRUCTURE of the 2-D formulation (two
grid-axis exchanges per layer instead of one full allreduce), not real
scaling. The second column is the one the decomposition exists for:
**bytes exchanged per layer** — the dense wire format ships
graph-proportional messages every layer, the compressed format ships
frontier-proportional ones, and the headline ``xreduction`` point (dense
bytes / compressed bytes, higher is better) gates that property in CI.

  PYTHONPATH=src python benchmarks/dist2d_teps.py --scale 12
  PYTHONPATH=src python benchmarks/dist2d_teps.py --smoke --json out.json

XLA_FLAGS is set to force the needed host device count BEFORE jax loads;
an inherited XLA_FLAGS with the flag already present wins.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _force_devices(ndev: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={ndev}".strip())


def run_curve(scale: int, edgefactor: int, grids, roots_curve, mode: str,
              seed: int, lanes: int | None, validate: bool) -> dict:
    """TEPS + per-layer byte points per (grid, R, wire format). Returns a
    flat {label: value} dict (teps, bytes, and xreduction entries)."""
    import numpy as np

    from repro.core.dist2d import (dist2d_msbfs_engine_drain,
                                   dist2d_msbfs_engine_enqueue,
                                   dist2d_msbfs_engine_init,
                                   dist2d_msbfs_engine_result, mesh2d,
                                   partition_graph_2d)
    from repro.core.msbfs import msbfs_pipelined
    from repro.core.packed import adaptive_lane_pool
    from repro.graph.generator import rmat_graph
    from repro.graph.graph500 import sample_roots

    g = rmat_graph(scale, edgefactor, seed)
    print(f"# 2-D MS-BFS TEPS — scale={scale} ef={edgefactor} mode={mode} "
          f"grids={list(grids)} R={list(roots_curve)} "
          f"lanes={'auto' if not lanes else lanes}")
    print(f"  n={g.n:,} vertices, m={g.m:,} directed edges")

    points: dict[str, float] = {}
    for r in roots_curve:
        roots = sample_roots(g, r, seed=seed)
        width = lanes or adaptive_lane_pool(r, g.n, g.m)
        t0 = time.perf_counter()
        base = msbfs_pipelined(g, roots, mode, lanes=width)
        base.depth.block_until_ready()
        t0 = time.perf_counter()
        base = msbfs_pipelined(g, roots, mode, lanes=width)
        base.depth.block_until_ready()
        base_t = time.perf_counter() - t0
        base_teps = float(np.sum(np.asarray(
            base.edges_traversed, np.int64)) / 2) / base_t
        points[f"host_R{r}"] = base_teps
        print(f"  single-host      R={r:4d}: {base_teps / 1e6:8.2f} MTEPS")
        for pr_, pc in grids:
            dg = partition_graph_2d(g, pr_, pc)
            mesh = mesh2d(pr_, pc)
            fmt_bytes = {}
            for compress, tag in ((False, "dense"), (True, "comp")):
                def sweep():
                    s = dist2d_msbfs_engine_init(dg, mesh, capacity=r,
                                                 lanes=width)
                    s = dist2d_msbfs_engine_enqueue(s, roots)
                    return dist2d_msbfs_engine_drain(
                        dg, s, mesh, mode, compress=compress)
                s = sweep()                      # compile + correctness run
                s.frontier.block_until_ready()
                if validate:
                    res = dist2d_msbfs_engine_result(dg, s, mesh)
                    np.testing.assert_array_equal(np.asarray(res.depth),
                                                  np.asarray(base.depth))
                t0 = time.perf_counter()
                s = sweep()
                s.frontier.block_until_ready()
                dt = time.perf_counter() - t0
                layers = max(int(s.sweep_layers), 1)
                total_bytes = int(s.exch_bytes)
                bpl = total_bytes / layers
                teps = float(np.sum(np.asarray(
                    base.edges_traversed, np.int64)) / 2) / dt
                fmt_bytes[tag] = total_bytes
                label = f"g{pr_}x{pc}_R{r}"
                points[f"{label}_{tag}"] = teps
                points[f"{label}_{tag}_bytes_per_layer"] = bpl
                rel = teps / max(base_teps, 1e-12)
                print(f"  grid {pr_}x{pc} {tag:5s} R={r:4d}: "
                      f"{teps / 1e6:8.2f} MTEPS ({rel:5.2f}x host), "
                      f"{bpl / 1024:8.1f} KiB/layer over {layers} layers")
            # the headline: exchange-volume reduction from compression
            red = fmt_bytes["dense"] / max(fmt_bytes["comp"], 1)
            points[f"g{pr_}x{pc}_R{r}_xreduction"] = red
            print(f"  grid {pr_}x{pc} exchange volume: {red:5.2f}x less "
                  f"compressed")
    return points


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--grids", type=str, nargs="+",
                    default=["1x2", "2x1", "2x2"],
                    help="grid shapes as PRxPC")
    ap.add_argument("--roots", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--mode", default="hybrid",
                    choices=("hybrid", "topdown", "bottomup"))
    ap.add_argument("--lanes", type=int, default=0,
                    help="bit-lane pool; 0 = adaptive sizing")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: scale 10, grid 2x2, R=64, validated")
    ap.add_argument("--json", default=None,
                    help="write {label: value} to this path")
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.grids, args.roots = 10, ["2x2"], [64]
        args.validate = True
    grids = [tuple(int(x) for x in s.split("x")) for s in args.grids]
    _force_devices(max(pr_ * pc for pr_, pc in grids))

    points = run_curve(args.scale, args.edgefactor, grids, args.roots,
                       args.mode, args.seed, args.lanes or None,
                       args.validate)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"  wrote {args.json}")


if __name__ == "__main__":
    main()
