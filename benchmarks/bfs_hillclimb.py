"""§Perf hillclimb for the paper's own technique: measured Graph500 TEPS.

Baseline-to-optimized ladder (all MEASURED wall-clock on this machine,
harmonic-mean TEPS across roots):

  B0  topdown            pure top-down (no direction optimization)
  B1  bottomup_nosimd    pure Algorithm-2 bottom-up
  B2  hybrid_nosimd      hybrid with non-SIMD bottom-up (paper baseline)
  B3  hybrid             + vectorised probe, MAX_POS=8 (paper-faithful)
  O1  hybrid, no fallback-skip   (ablate the beyond-paper lax.cond)
  O2  MAX_POS sweep      {2, 4, 8, 16, 32}
  O3  alpha/beta sweep   switching thresholds

Writes artifacts/bfs_perf.json.
"""
from __future__ import annotations

import json
import os

from repro.graph.generator import rmat_graph
from repro.graph.graph500 import run_graph500

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts")


def run(scale: int = 14, edgefactor: int = 16, roots: int = 16, seed: int = 0):
    g = rmat_graph(scale, edgefactor, seed)
    out = {"scale": scale, "edgefactor": edgefactor, "roots": roots,
           "ladder": {}, "max_pos_sweep": {}, "alpha_beta_sweep": {},
           "fallback_ablation": {}}

    def teps(**kw):
        res = run_graph500(scale, edgefactor, num_roots=roots, seed=seed,
                           graph=g, **kw)
        return res.harmonic_mean_teps

    print(f"# BFS hillclimb: SCALE={scale} ef={edgefactor} roots={roots}")
    for tag, kw in [("B0_topdown", dict(mode="topdown")),
                    ("B1_bottomup_nosimd", dict(mode="bottomup_nosimd")),
                    ("B2_hybrid_nosimd", dict(mode="hybrid_nosimd")),
                    ("B3_hybrid_simd", dict(mode="hybrid"))]:
        v = teps(**kw)
        out["ladder"][tag] = v
        print(f"  {tag:22s} {v / 1e6:10.2f} MTEPS")

    v = teps(mode="hybrid", skip_empty_fallback=False)
    out["fallback_ablation"]["always_fallback"] = v
    out["fallback_ablation"]["with_skip"] = out["ladder"]["B3_hybrid_simd"]
    print(f"  {'O1_always_fallback':22s} {v / 1e6:10.2f} MTEPS")

    for mp in (2, 4, 8, 16, 32):
        v = teps(mode="hybrid", max_pos=mp)
        out["max_pos_sweep"][mp] = v
        print(f"  O2_max_pos={mp:<3d}         {v / 1e6:10.2f} MTEPS")

    for a, b in ((4.0, 24.0), (8.0, 24.0), (14.0, 24.0), (28.0, 24.0),
                 (14.0, 8.0), (14.0, 64.0)):
        v = teps(mode="hybrid", alpha=a, beta=b)
        out["alpha_beta_sweep"][f"a{a:g}_b{b:g}"] = v
        print(f"  O3_alpha={a:<4g} beta={b:<4g} {v / 1e6:10.2f} MTEPS")

    # O4: beyond-paper ELL top-down (bounded slabs + residue fallback)
    out["ell_topdown"] = {}
    for tag, kw in [("O4_ell_topdown", dict(mode="hybrid", td_impl="ell")),
                    ("O4_ell_td_alpha4", dict(mode="hybrid", td_impl="ell",
                                              alpha=4.0)),
                    ("O4_ell_pure_td", dict(mode="topdown", td_impl="ell"))]:
        v = teps(**kw)
        out["ell_topdown"][tag] = v
        print(f"  {tag:22s} {v / 1e6:10.2f} MTEPS")

    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"bfs_perf_s{scale}_ef{edgefactor}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    import sys
    scale = int(sys.argv[1]) if len(sys.argv) > 1 else 14
    ef = int(sys.argv[2]) if len(sys.argv) > 2 else 16
    run(scale, ef)
