"""Serving-path benchmark: replayed mixed-workload trace through
``repro.serving.AnalyticsService``.

One deterministic trace (``serving.trace.synthetic_trace`` — bursts of
bfs/khop/reach/closeness/sssp envelopes on the layer clock) is replayed
TWICE through identically-configured services: once with the mid-sweep
streaming read-outs on, once answer-at-flush. The run asserts, in-bench:

* **bit parity** — every khop words/counts and reach hops answer is
  identical between the two replays (the streamed depth-k band IS the
  flushed band);
* **early answers** — streamed khop requests resolve at least one layer
  earlier (mean sojourn gain >= 1) than their flush-time twins.

Reported points (higher is better, CI-gated via ``ci_bench.py`` under
``serve.*``):

* ``mix_teps`` — aggregate packed-engine TEPS over the streamed replay
  (early lane retirement returns capacity to the pool, so this also
  moves when streaming regresses);
* ``answered_early_frac`` — fraction of answered requests served from
  the mid-sweep read-out;
* ``early_gain_layers`` — mean khop sojourn saved by streaming.

p50/p99 sojourn layers for both replays are recorded alongside (the
``derived`` metadata of the CI point — lower-is-better numbers stay out
of the gate, like the exchange byte counters of the dist benches).

  PYTHONPATH=src python benchmarks/serve_bench.py --scale 12
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# allow `python benchmarks/serve_bench.py` (sys.path[0] = benchmarks/)
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)

SMOKE_MIX = "bfs:3,khop:3,reach:2,closeness:1,sssp:2"


def _replay(g, trace, *, streaming: bool, lanes: int, slots: int,
            sssp_slots: int, ndev: int):
    from repro.serving import AnalyticsService, ServiceConfig
    svc = AnalyticsService(g, ServiceConfig(
        lanes=lanes, slots=slots, sssp_slots=sssp_slots, ndev=ndev,
        streaming=streaming))
    svc.warmup(tropical=True)
    stats = svc.replay(trace)
    return svc, stats


def bench_points(scale: int, edgefactor: int = 16, seed: int = 0,
                 queries: int = 32, mix: str = SMOKE_MIX,
                 khop_k: int = 2, closeness_sources: int = 8,
                 lanes: int = 0, slots: int = 256, sssp_slots: int = 64,
                 burst: int = 4, every: int = 2,
                 ndev: int = 1) -> dict[str, float]:
    """Streamed-vs-flush replay of one mixed trace; see module doc."""
    import numpy as np
    from repro.graph.generator import rmat_weighted_graph
    from repro.serving.trace import synthetic_trace

    g = rmat_weighted_graph(scale, edgefactor, seed)

    def trace():
        # ids are fresh per build; the two replays match by index
        return synthetic_trace(
            g.n, queries, mix=mix, seed=seed, khop_k=khop_k,
            closeness_sources=closeness_sources, burst=burst, every=every)

    kw = dict(lanes=lanes, slots=slots, sssp_slots=sssp_slots, ndev=ndev)
    t_on, t_off = trace(), trace()
    svc_on, s_on = _replay(g, t_on, streaming=True, **kw)
    svc_off, s_off = _replay(g, t_off, streaming=False, **kw)

    gains = []
    for env_on, env_off in zip(t_on, t_off):
        r_on = svc_on.record(env_on.id)
        r_off = svc_off.record(env_off.id)
        assert r_on.kind == r_off.kind
        if r_on.kind == "khop":
            a, b = r_on.answer.result, r_off.answer.result
            assert np.array_equal(a.words, b.words), \
                "streamed khop band diverged from the flushed band"
            assert np.array_equal(a.counts, b.counts)
            gains.append(r_off.sojourn - r_on.sojourn)
        elif r_on.kind == "reach":
            a, b = r_on.answer.result, r_off.answer.result
            assert np.array_equal(a.hops, b.hops), \
                "streamed reach hops diverged from the flushed answer"
    gain = float(np.mean(gains)) if gains else 0.0
    assert gain >= 1.0, (
        f"streaming khop answers must land >= 1 layer before flush on "
        f"the smoke trace, measured mean gain {gain}")

    points = {
        f"mix_teps_s{scale}_q{queries}":
            s_on["aggregate_mteps"] * 1e6,
        f"answered_early_frac_s{scale}_q{queries}":
            s_on["answered_early_frac"],
        f"early_gain_layers_s{scale}_q{queries}": gain,
        # lower-is-better latency points: recorded, never CI-gated
        f"p50_sojourn_layers_s{scale}_q{queries}":
            s_on["sojourn_layers"]["p50"],
        f"p99_sojourn_layers_s{scale}_q{queries}":
            s_on["sojourn_layers"]["p99"],
        f"p50_sojourn_layers_flush_s{scale}_q{queries}":
            s_off["sojourn_layers"]["p50"],
        f"p99_sojourn_layers_flush_s{scale}_q{queries}":
            s_off["sojourn_layers"]["p99"],
    }
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--mix", default=SMOKE_MIX)
    ap.add_argument("--lanes", type=int, default=0)
    ap.add_argument("--slots", type=int, default=256)
    ap.add_argument("--ndev", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI point: scale 10, 32 queries")
    ap.add_argument("--json", default=None, help="write {name: value} here")
    args = ap.parse_args()

    scale = 10 if args.smoke else args.scale
    queries = 32 if args.smoke else args.queries
    points = bench_points(scale, args.edgefactor, args.seed,
                          queries=queries, mix=args.mix, lanes=args.lanes,
                          slots=args.slots, ndev=args.ndev)
    for name, v in points.items():
        if "teps" in name:
            print(f"{name:44s} {v / 1e6:10.2f} MTEPS")
        else:
            print(f"{name:44s} {v:10.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
