"""Paper Table 2 analog: hybrid BFS per-layer switching trace.

Prints the layer-by-layer (v_f, e_f, e_u, f, g, approach) table for one
Graph500 BFS, showing the TD -> BU -> TD switching points.
"""
from __future__ import annotations


from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT, bfs
from repro.graph.generator import rmat_graph, sample_roots


def run(scale: int = 12, edgefactor: int = 16, seed: int = 0):
    g = rmat_graph(scale, edgefactor, seed)
    root = int(sample_roots(g, 1, seed=seed + 1)[0])
    out = bfs(g, root, "hybrid")
    n_layers = int(out.num_layers)
    rows = []
    print(f"# Table 2 analog: SCALE={scale} edgefactor={edgefactor} "
          f"root={root}  (alpha={ALPHA_DEFAULT}, beta={BETA_DEFAULT})")
    print(f"{'layer':>5s} {'v_f':>9s} {'e_f':>11s} {'e_u':>12s} "
          f"{'f=e_u/a':>11s} {'g=n/b':>9s} approach")
    for i in range(n_layers):
        vf = int(out.trace_vf[i])
        ef = int(out.trace_ef[i])
        eu = int(out.trace_eu[i])
        f_thr = eu / ALPHA_DEFAULT
        g_thr = g.n / BETA_DEFAULT
        approach = "top-down" if int(out.trace_dir[i]) == 0 else "bottom-up"
        print(f"{i + 1:5d} {vf:9d} {ef:11d} {eu:12d} {f_thr:11.0f} "
              f"{g_thr:9.0f} {approach}")
        rows.append(dict(layer=i + 1, v_f=vf, e_f=ef, e_u=eu,
                         approach=approach))
    return rows


if __name__ == "__main__":
    run()
