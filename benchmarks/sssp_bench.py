"""Weighted-path (SSSP) throughput on the delta-stepping lane engine.

One TEPS-equivalent number per workload (higher is better), compile
excluded by a warmup run — the weighted analog of ``analytics_bench.py``.
The work numerator is a fixed PROXY per workload (R traversals covering
~the giant component's m/2 undirected edges each), stable across runs by
construction, which is what the regression gate needs:

* ``pipelined`` — R sources through one pipelined delta-stepping sweep
  (random uniform weights, default delta);
* ``unitweight`` — the same sweep over unit weights at delta=1, i.e. the
  boolean-anchor workload (bucket walk == BFS layers): its gap to the
  ``msbfs.batched`` point prices the dense-float-lane overhead;
* ``wcloseness`` — sampled weighted closeness (k sources through the
  chunked estimator).

  PYTHONPATH=src python benchmarks/sssp_bench.py --scale 12
  PYTHONPATH=src python benchmarks/sssp_bench.py --smoke --json out.json

``--json`` writes {name: teps} points for the CI regression gate
(``ci_bench.py`` embeds these under ``sssp.*``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# allow `python benchmarks/sssp_bench.py` (sys.path[0] = benchmarks/)
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)


def _timed(fn):
    """(wall seconds, result) with one warmup call to absorb compiles."""
    fn()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def bench_points(scale: int, edgefactor: int = 16, seed: int = 0,
                 sources: int = 32, lanes: int = 32,
                 closeness_sources: int = 32) -> dict[str, float]:
    """TEPS-equivalent throughput per weighted workload at one scale."""
    import numpy as np

    from repro.analytics import LaneEngine, weighted_closeness_centrality
    from repro.core.csr import from_weighted_edges
    from repro.graph.generator import rmat_weighted_graph, sample_roots
    from repro.traversal import sssp_pipelined

    wg = rmat_weighted_graph(scale, edgefactor, seed)
    roots = sample_roots(wg, sources, seed=1)
    points = {}

    dt, _ = _timed(lambda: sssp_pipelined(wg, roots, lanes=lanes))
    points[f"pipelined_s{scale}_R{len(roots)}"] = (
        len(roots) * (wg.m // 2) / dt)

    unit = from_weighted_edges(np.asarray(wg.src_idx),
                               np.asarray(wg.col_idx),
                               np.ones(wg.m), wg.n, symmetrize=False,
                               drop_self_loops=False)
    dt, _ = _timed(lambda: sssp_pipelined(unit, roots, delta=1.0,
                                          lanes=lanes))
    points[f"unitweight_s{scale}_R{len(roots)}"] = (
        len(roots) * (unit.m // 2) / dt)

    k = min(closeness_sources, wg.n)
    eng = LaneEngine(wg, lanes=lanes)
    dt, _ = _timed(lambda: weighted_closeness_centrality(
        eng, sources=k, seed=2, chunk=lanes))
    points[f"wcloseness_s{scale}_k{k}"] = k * (wg.m // 2) / dt
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sources", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI point: scale 10")
    ap.add_argument("--json", default=None, help="write {name: teps} here")
    args = ap.parse_args()

    scale = 10 if args.smoke else args.scale
    points = bench_points(scale, args.edgefactor, args.seed,
                          sources=args.sources, lanes=args.lanes)
    for name, teps in points.items():
        print(f"{name:32s} {teps / 1e6:10.2f} MTEPS-equiv")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(points, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
