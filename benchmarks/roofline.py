"""Aggregate artifacts/dryrun/*.json into the §Roofline table (markdown)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load_records(mesh: str | None = "pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def device_gb(r):
    m = r.get("memory") or {}
    vals = [m.get("argument_bytes") or 0, m.get("temp_bytes") or 0,
            m.get("output_bytes") or 0]
    return (sum(vals) - (m.get("alias_bytes") or 0)) / 1e9


def markdown_table(mesh="pod16x16"):
    lines = [
        "| arch | shape | kind | GB/dev | compute_s | memory_s | "
        "collective_s | dominant | roofline frac | model/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        if "arch" not in r:
            continue   # bfs-graph500 cells have their own table
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | "
                         f"— | — | — | — | skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | "
                         f"ERROR | | | | | | |")
            continue
        t = r["roofline"]
        ratio = r.get("model_to_hlo_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{device_gb(r):.1f} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['roofline_fraction']:.3f} | "
            f"{ratio:.2f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{device_gb(r):.1f} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {t['roofline_fraction']:.3f} | — |")
    return "\n".join(lines)


def run():
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n## Roofline table — mesh {mesh}\n")
        print(markdown_table(mesh))
    return True


if __name__ == "__main__":
    run()
