"""Batched LM serving: prefill + iterative decode with a donated KV cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs.reduced import reduce_arch
from repro.launch.serve import serve_lm

arch = reduce_arch("qwen3-moe-30b-a3b")
print(f"serving reduced {arch.arch_id} "
      f"({arch.model_cfg.param_count():,} params, MoE "
      f"{arch.model_cfg.moe.num_experts} experts top-"
      f"{arch.model_cfg.moe.top_k})")
serve_lm(arch, requests=4, prompt_len=32, new_tokens=16)
