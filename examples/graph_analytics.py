"""Graph analytics riding the MS-BFS lane engine — end to end.

  PYTHONPATH=src python examples/graph_analytics.py

Builds a Graph500 Kronecker graph, then answers three analytics workloads
through ONE shared ``LaneEngine`` (components, closeness, k-hop), plus
diameter bounds — every result computed by batching BFS traversals
through the packed bit-lane sweeps (mirrors examples/distributed_bfs.py
style: small scale, asserts at the end).
"""
import numpy as np

from repro.analytics import (ClosenessQuery, ComponentsQuery, DiameterQuery,
                             KHopQuery, LaneEngine, run_query)
from repro.graph.generator import rmat_graph, sample_roots

g = rmat_graph(10, 8, seed=0)
eng = LaneEngine(g, lanes=None)        # adaptive lane-pool sizing
print(f"n={g.n:,} m={g.m:,} (scale 10, edgefactor 8)")

comps = run_query(eng, ComponentsQuery(batch=64))
cid, csize = comps.largest
print(f"components: {comps.num_components} in {comps.sweeps} sweep(s); "
      f"largest = id {cid} with {csize:,} vertices "
      f"({100.0 * csize / g.n:.1f}%)")

clo = run_query(eng, ClosenessQuery())          # auto: exact at this scale
top = clo.top(3)
print(f"closeness ({clo.method}, {clo.num_sources} sources): top-3 = "
      + ", ".join(f"v{v}={c:.4f}" for v, c in top))

seeds = sample_roots(g, 4, seed=2)
hops = run_query(eng, KHopQuery(sources=tuple(int(s) for s in seeds), k=2))
print("2-hop neighbourhoods: " + ", ".join(
    f"|N_2({int(s)})|={int(c):,}" for s, c in zip(hops.sources, hops.counts)))

diam = run_query(eng, DiameterQuery(num_seeds=4, sweeps=3, seed=3))
print(f"diameter of component {diam.component}: "
      f"{diam.lower} <= D <= {diam.upper} "
      f"({'exact' if diam.exact else 'bracketed'} after {diam.sweeps} "
      f"sweeps)")

# the same queries served online: AnalyticsService streams khop answers
# mid-sweep (depth-k bands are final), bit-identical to run_query above
from repro.serving import AnalyticsService

with AnalyticsService(g, slots=64) as svc:
    rec = svc.submit(KHopQuery(sources=tuple(int(s) for s in seeds), k=2))
    served = svc.result(rec.request.id, timeout=120.0).result
print(f"served khop: streamed_early={rec.answered_early} "
      f"sojourn={rec.sojourn} layers")
assert np.array_equal(served.words, hops.words)
assert np.array_equal(served.counts, hops.counts)

# the invariants every run must satisfy
assert comps.sizes.sum() == g.n
assert csize == int(np.max(comps.sizes))
assert (clo.closeness >= 0).all() and clo.closeness.max() <= 1.0
assert (hops.counts >= 1).all()           # a seed always reaches itself
assert 0 <= diam.lower <= diam.upper
print("analytics OK")
