"""Weighted traversal quickstart: delta-stepping SSSP lanes + weighted
closeness on the semiring engine.

  PYTHONPATH=src python examples/weighted_sssp.py [--scale 10]

Walks the whole weighted stack:
  1. generate a Graph500 Kronecker graph WITH edge weights (same topology
     as the unweighted generator — weights ride alongside);
  2. answer a batch of SSSP sources in one pipelined delta-stepping sweep
     and cross-check one source against the NumPy Dijkstra oracle;
  3. show the boolean-semiring anchor: unit weights at delta=1 reproduce
     MS-BFS depths bit-for-bit;
  4. run the weighted analytics queries through the shared LaneEngine.
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.analytics import (LaneEngine, SSSPQuery, WeightedClosenessQuery,
                             run_query)
from repro.core.csr import from_weighted_edges
from repro.core.msbfs import msbfs_pipelined
from repro.graph.generator import rmat_weighted_graph, sample_roots
from repro.traversal import (default_delta, dijkstra_reference,
                             sssp_pipelined, to_numpy_weighted)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # 1. weighted Kronecker graph (weights uniform in (0, 1], symmetric)
    wg = rmat_weighted_graph(args.scale, args.edgefactor, args.seed)
    print(f"graph: n={wg.n} m={wg.m} "
          f"w in [{float(np.asarray(wg.weights).min()):.3f}, "
          f"{float(np.asarray(wg.weights).max()):.3f}] "
          f"default delta={default_delta(wg):.4f}")

    # 2. one pipelined sweep answers many sources (lanes < R -> the
    #    pending-source queue streams them through the pool)
    roots = sample_roots(wg, 8, seed=1)
    res = sssp_pipelined(wg, roots, lanes=4)
    for i, r in enumerate(roots[:3]):
        d = np.asarray(res.dist[:, i])
        fin = np.isfinite(d)
        print(f"source {int(r):6d}: reached {int(fin.sum())} vertices, "
              f"max dist {d[fin].max():.3f}, engine steps "
              f"{int(res.steps[i])}")
    ref = dijkstra_reference(*to_numpy_weighted(wg), int(roots[0]))
    ok = np.allclose(np.asarray(res.dist[:, 0])[np.isfinite(ref)],
                     ref[np.isfinite(ref)], atol=1e-4)
    print(f"lane 0 == Dijkstra oracle: {ok}")

    # 3. the boolean-semiring anchor: unit weights, delta=1 -> BFS depths
    unit = from_weighted_edges(np.asarray(wg.src_idx),
                               np.asarray(wg.col_idx), np.ones(wg.m),
                               wg.n, symmetrize=False,
                               drop_self_loops=False)
    sres = sssp_pipelined(unit, roots, delta=1.0, lanes=4)
    mres = msbfs_pipelined(unit.csr, jnp.asarray(roots, jnp.int32),
                           lanes=32)
    same = np.array_equal(np.asarray(sres.as_depth()),
                          np.asarray(mres.depth))
    print(f"unit-weight SSSP bit-identical to MS-BFS depths: {same}")

    # 4. weighted analytics through the shared engine facade
    eng = LaneEngine(wg)
    q = run_query(eng, SSSPQuery(sources=tuple(int(r) for r in roots[:4])))
    print(f"SSSPQuery: {q.dist.shape[1]} sources, delta={q.delta:.4f}")
    wc = run_query(eng, WeightedClosenessQuery())
    top = np.argmax(wc.closeness)
    print(f"WeightedClosenessQuery ({wc.method}, {wc.num_sources} "
          f"sources): top vertex {int(top)} "
          f"closeness {wc.closeness[top]:.4f}")


if __name__ == "__main__":
    main()
