"""Quickstart: the paper's pipeline in 30 lines.

  PYTHONPATH=src python examples/quickstart.py

1. generate a Graph500 Kronecker graph;
2. run the vectorised hybrid BFS (our reproduction of Paredes et al.);
3. validate the BFS tree against the Graph500 rules;
4. compare against the non-SIMD baseline;
5. answer a 64-root batch in ONE sweep with the bit-packed MS-BFS;
6. stream 128 roots through the 64-lane pipelined engine — finished
   lanes refill from the pending-root queue mid-sweep.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import to_numpy_adj
from repro.core.hybrid import bfs
from repro.core.msbfs import msbfs, msbfs_pipelined
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree

SCALE, EDGEFACTOR = 13, 16

print(f"generating Graph500 graph: SCALE={SCALE} edgefactor={EDGEFACTOR}")
g = rmat_graph(SCALE, EDGEFACTOR, seed=0)
print(f"  n={g.n:,} vertices, m={g.m:,} directed edges")

root = int(sample_roots(g, 1, seed=1)[0])
for mode in ("hybrid", "hybrid_nosimd", "topdown"):
    out = jax.block_until_ready(bfs(g, root, mode))     # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(bfs(g, root, mode))
    dt = time.perf_counter() - t0
    teps = int(out.edges_traversed) / 2 / dt
    dirs = "".join("TB"[d] for d in np.asarray(out.trace_dir)
                   [:int(out.num_layers)])
    print(f"  {mode:15s}: {dt * 1e3:7.2f} ms  {teps / 1e6:8.1f} MTEPS  "
          f"layers={dirs}")

rp, ci = to_numpy_adj(g)
stats = validate_bfs_tree(rp, ci, np.asarray(out.parent), root)
print(f"BFS tree valid: {stats}")

# --- batched MS-BFS: 64 roots, one bit-packed sweep --------------------
roots = jnp.asarray(sample_roots(g, 64, seed=2), dtype=jnp.int32)
bout = jax.block_until_ready(msbfs(g, roots, "hybrid"))     # compile
t0 = time.perf_counter()
bout = jax.block_until_ready(msbfs(g, roots, "hybrid"))
dt = time.perf_counter() - t0
edges = int(np.asarray(bout.edges_traversed).sum()) // 2
print(f"  msbfs x{len(roots):2d}: {dt * 1e3:7.2f} ms  "
      f"{edges / dt / 1e6:8.1f} MTEPS aggregate "
      f"(64 traversals, one sweep)")
r0 = int(roots[0])
stats = validate_bfs_tree(rp, ci, np.asarray(bout.parent[:, 0]), r0)
print(f"MS-BFS lane-0 tree valid: {stats}")

# --- pipelined engine: 128 roots streamed through 64 lanes -------------
roots = jnp.asarray(sample_roots(g, 128, seed=3), dtype=jnp.int32)
pout = jax.block_until_ready(msbfs_pipelined(g, roots, "hybrid"))  # compile
t0 = time.perf_counter()
pout = jax.block_until_ready(msbfs_pipelined(g, roots, "hybrid"))
dt = time.perf_counter() - t0
edges = int(np.asarray(pout.edges_traversed).sum()) // 2
print(f"  pipelined x{len(roots)}: {dt * 1e3:7.2f} ms  "
      f"{edges / dt / 1e6:8.1f} MTEPS aggregate "
      f"(64 lanes, queue-refilled mid-sweep)")
rl = int(roots[-1])
stats = validate_bfs_tree(rp, ci, np.asarray(pout.parent[:, -1]), rl)
print(f"pipelined last-root tree valid: {stats}")
