"""Online analytics serving through ``repro.serving.AnalyticsService``.

  PYTHONPATH=src python examples/serve_analytics.py

Builds a weighted Graph500 Kronecker graph and serves it three ways:

1. **async front door** — worker thread, ``submit``/``result``; a k-hop
   query streams its answer mid-sweep (depths already assigned are
   final), bit-identical to offline ``run_query``;
2. **admission control** — a per-tenant quota bounds in-flight work, so
   an over-quota submission comes back REJECTED (with the reason)
   instead of growing the queue;
3. **trace replay** — a deterministic mixed bfs/khop/reach/sssp arrival
   process on the layer clock, with per-type sojourn stats.
"""
import numpy as np

from repro.analytics import BFSQuery, KHopQuery, run_query
from repro.analytics.api import AnalyticsRequest
from repro.graph.generator import rmat_weighted_graph
from repro.serving import AnalyticsService, REJECTED, synthetic_trace

wg = rmat_weighted_graph(10, 8, seed=0)
print(f"n={wg.n:,} m={wg.m:,} (scale 10, edgefactor 8)")

# 1. async submit/result: the worker thread drives the engines ---------------
with AnalyticsService(wg, slots=64, sssp_slots=16) as svc:
    rec = svc.submit(KHopQuery(sources=(3, 17), k=2))
    ans = svc.result(rec.request.id, timeout=120.0)
print(f"khop: counts={ans.result.counts.tolist()} "
      f"streamed_early={rec.answered_early} sojourn={rec.sojourn} layers")
ref = run_query(wg, KHopQuery(sources=(3, 17), k=2))
assert np.array_equal(ans.result.words, ref.words)   # bit-identical
assert np.array_equal(ans.result.counts, ref.counts)

# 2. admission: quota bounds each tenant's in-flight requests ----------------
svc = AnalyticsService(wg, tenant_quota=1)
ok = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(0,)), tenant="t0"))
over = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(1,)), tenant="t0"))
print(f"quota: first={ok.status} second={over.status} ({over.reason})")
assert over.status == REJECTED
svc.run_until_idle()                       # DONE releases the quota
again = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(1,)),
                                    tenant="t0"))
assert again.status != REJECTED

# 3. replay a mixed arrival process on the layer clock -----------------------
trace = synthetic_trace(wg.n, 24, mix="bfs:3,khop:3,reach:2,sssp:2",
                        seed=1, burst=4, every=2, tenants=("t0", "t1"))
svc = AnalyticsService(wg, slots=64, sssp_slots=16)
stats = svc.replay(trace)
print(f"replay: {stats['done']}/{stats['requests']} answered in "
      f"{stats['layers']} layers, "
      f"{100 * stats['answered_early_frac']:.0f}% streamed early, "
      f"sojourn p50={stats['sojourn_layers']['p50']} "
      f"p99={stats['sojourn_layers']['p99']}")
for kind, row in sorted(stats["per_type"].items()):
    print(f"  {kind:6s} x{row['count']:<3d} "
          f"sojourn p50={row['sojourn_layers']['p50']}")

assert stats["done"] == stats["requests"] and stats["rejected"] == 0
assert stats["answered_early_frac"] > 0   # khop/reach streamed mid-sweep
print("serving OK")
