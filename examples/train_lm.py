"""End-to-end training driver: a small LM for a few hundred steps with
checkpointing and automatic resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ...]

Uses the reduced config of an assigned architecture (full configs target
the TPU mesh; this runs on the CPU container). Kill it mid-run and rerun —
it resumes from the last valid checkpoint.
"""
import argparse

from repro.configs.reduced import reduce_arch
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    arch = reduce_arch(args.arch)
    print(f"training {arch.arch_id} "
          f"({arch.model_cfg.param_count():,} params) for {args.steps} steps")
    trainer = Trainer(arch, "train_4k", cfg=TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
        log_every=20))
    log = trainer.run()
    print(f"final loss: {log[-1]['loss']:.4f} "
          f"(started at {log[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
