"""Multi-device hybrid BFS via shard_map (8 simulated devices).

  PYTHONPATH=src python examples/distributed_bfs.py

The same 1-D partitioned BFS that the multi-pod dry-run lowers on
(2, 16, 16); here executed for real on 8 host devices and checked against
the single-device result.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.dist_bfs import dist_bfs, partition_graph  # noqa: E402
from repro.core.hybrid import bfs  # noqa: E402
from repro.graph.generator import rmat_graph, sample_roots  # noqa: E402

g = rmat_graph(12, 16, seed=0)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
dg = partition_graph(g, 8)
root = int(sample_roots(g, 1, seed=1)[0])

res = dist_bfs(dg, root, mesh, "hybrid")
single = bfs(g, root, "hybrid")

match = bool((np.asarray(res.parent) == np.asarray(single.parent)).all()
             and (np.asarray(res.depth) == np.asarray(single.depth)).all())
print(f"n={g.n:,} m={g.m:,} root={root}")
print(f"distributed BFS over {mesh.devices.size} devices: "
      f"{int(res.num_layers)} layers; matches single-device: {match}")
assert match
