"""Capture a Perfetto trace + metrics scrape of a mixed-workload serve run.

  PYTHONPATH=src python examples/sweep_trace.py

Replays a deterministic mixed BFS/k-hop/SSSP workload through the
AnalyticsService with a ``Telemetry`` bundle attached, then exports
under the (gitignored) ``out/`` scratch dir:

* ``out/sweep_trace.json``  — Chrome trace-event JSON: request lifecycles
  (QUEUED → RUNNING spans, early-readout markers) plus one track per
  recorded engine sweep with per-layer TD/BU spans and frontier-density
  counters. Open it at https://ui.perfetto.dev ("Open trace file").
* ``out/sweep_metrics.txt`` — Prometheus text exposition of the service
  counters (requests by kind/status, sojourn histogram, engine layers,
  edges relaxed).
"""
import os

from repro.graph.generator import rmat_weighted_graph
from repro.obs import Telemetry, write_chrome_trace
from repro.serving import AnalyticsService, ServiceConfig, synthetic_trace

OUT_DIR = "out"
TRACE_OUT = os.path.join(OUT_DIR, "sweep_trace.json")
METRICS_OUT = os.path.join(OUT_DIR, "sweep_metrics.txt")
os.makedirs(OUT_DIR, exist_ok=True)

wg = rmat_weighted_graph(10, 16, seed=7)
tel = Telemetry()
svc = AnalyticsService(wg, ServiceConfig(lanes=64, slots=64, sssp_slots=16,
                                         telemetry=tel))
trace = synthetic_trace(wg.n, 24, mix="bfs:3,khop:2,reach:1,sssp:1", seed=3)
stats = svc.replay(trace)

write_chrome_trace(TRACE_OUT, svc.trace_events())
with open(METRICS_OUT, "w") as f:
    f.write(svc.metrics_text())

sweeps = [r.summary() for r in tel.sweeps]
print(f"n={wg.n:,}  requests={stats['requests']}  done={stats['done']}  "
      f"layers={stats['layers']}  "
      f"answered_early={stats['answered_early_frac']:.0%}")
for s in sweeps:
    print(f"  sweep {s['engine']:>6} ({s['kind']}): {s['layers']} layers, "
          f"{s['edges_relaxed']:,} edges relaxed")
print(f"wrote {TRACE_OUT} (open in https://ui.perfetto.dev) "
      f"and {METRICS_OUT}")
