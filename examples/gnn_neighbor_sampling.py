"""Neighbour-sampled GNN training — the minibatch_lg pipeline end to end.

  PYTHONPATH=src python examples/gnn_neighbor_sampling.py

The sampler is capped BFS frontier expansion (the paper's probe gather with
random positions); every step samples a fresh subgraph from a Graph500
graph and takes one GIN training step on it.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.generator import rmat_graph
from repro.graph.sampler import dedup_count, sampled_graph_batch
from repro.models.gnn.gin import GINConfig, gin_loss, init_gin
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state

STEPS, BATCH_NODES, FANOUT = 30, 64, (5, 3)

g = rmat_graph(12, 8, seed=0)
n_classes = 6
feats = jax.random.normal(jax.random.PRNGKey(0), (g.n, 16))
labels = jax.random.randint(jax.random.PRNGKey(1), (g.n,), 0, n_classes)

cfg = GINConfig(d_feat=16, d_hidden=32, n_layers=2, n_classes=n_classes,
                task="node")
params, _ = init_gin(jax.random.PRNGKey(2), cfg)
opt_cfg = OptConfig(lr=3e-3)
opt = init_opt_state(params, opt_cfg)


@jax.jit
def step(params, opt, gb):
    (loss, _), grads = jax.value_and_grad(
        lambda p: gin_loss(p, gb, cfg), has_aux=True)(params)
    params, opt = adamw_update(params, grads, opt, opt_cfg)
    return params, opt, loss


print(f"graph n={g.n:,} m={g.m:,}; sampling {BATCH_NODES} seeds x "
      f"fanout {FANOUT} per step")
for i in range(STEPS):
    key = jax.random.PRNGKey(100 + i)
    seeds = jax.random.choice(key, g.n, (BATCH_NODES,), replace=False)
    gb = sampled_graph_batch(key, g, seeds.astype(jnp.int32), feats, labels,
                             fanout=FANOUT, n_classes=n_classes)
    params, opt, loss = step(params, opt, gb)
    if i % 10 == 0 or i == STEPS - 1:
        uniq = int(dedup_count(jnp.concatenate([seeds.astype(jnp.int32)]),
                               g.n))
        print(f"step {i:3d} loss={float(loss):.4f} "
              f"subgraph_nodes={gb.n_nodes} unique_seeds={uniq}")
print("done")
