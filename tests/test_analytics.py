"""Analytics subsystem vs pure-NumPy references.

Every workload (components, closeness exact + sampled, k-hop,
reachability, diameter bounds) is cross-checked against a reference built
on ``repro.core.ref.bfs_reference`` over the property-suite graph zoo
(disconnected components, star, path, self-loops, duplicate edges,
isolated vertices). The typed query API dispatch, the serve_bfs
multi-workload loop, and an ndev=2 parity leg (forced multi-device mesh,
conftest subprocess pattern) ride the same cases.
"""
import numpy as np
import pytest
from conftest import run_in_subprocess

from repro.analytics import (ClosenessQuery, ComponentsQuery, DiameterQuery,
                             KHopQuery, LaneEngine, closeness_centrality,
                             connected_components, diameter_bounds,
                             khop_neighborhood, reachability, run_query)
from repro.analytics.closeness import closeness_from_depths
from repro.core.csr import from_edges, to_numpy_adj
from repro.core.ref import bfs_reference
from repro.graph.generator import rmat_graph


def path_graph(n):
    return from_edges(np.arange(n - 1), np.arange(1, n), n)


def star_graph(n):
    return from_edges(np.zeros(n - 1, np.int64), np.arange(1, n), n)


def zoo_graph():
    """Two components + isolated vertices + self-loop + duplicate edge."""
    src = np.concatenate([np.arange(5), np.full(5, 10), [3, 3, 12]])
    dst = np.concatenate([np.arange(1, 6), np.arange(11, 16), [3, 4, 13]])
    return from_edges(src, dst, 20)


def rmat_small():
    return rmat_graph(8, 4, seed=3)     # sparse -> several components


GRAPHS = [("path", path_graph(12)), ("star", star_graph(9)),
          ("zoo", zoo_graph()), ("rmat", rmat_small())]


def ref_depths_all(g):
    """int64[n, n] all-pairs hop distances via the serial reference."""
    rp, ci = to_numpy_adj(g)
    n = g.n
    d = np.empty((n, n), np.int64)
    for s in range(n):
        d[:, s] = bfs_reference(rp, ci, s)[1]
    return d


def ref_components(g):
    """Canonical min-vertex component labels via serial BFS."""
    rp, ci = to_numpy_adj(g)
    labels = np.full(g.n, -1, np.int64)
    for v in range(g.n):
        if labels[v] < 0:
            reached = bfs_reference(rp, ci, v)[1] >= 0
            labels[reached] = v
    return labels


def ref_closeness(g):
    """Wasserman–Faust closeness from all-pairs reference distances."""
    d = ref_depths_all(g)
    n = g.n
    reached = d >= 0
    r = reached.sum(axis=1)
    sum_d = np.where(reached, d, 0).sum(axis=1)
    out = np.zeros(n, np.float64)
    ok = (r > 1) & (sum_d > 0)
    out[ok] = (r[ok] - 1.0) ** 2 / (sum_d[ok] * (n - 1))
    return out


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
@pytest.mark.parametrize("batch", [4, 64])
def test_components_match_reference(name, g, batch):
    res = connected_components(g, batch=batch, lanes=8)
    np.testing.assert_array_equal(res.labels, ref_components(g),
                                  err_msg=f"{name} batch={batch}")
    ids, sizes = np.unique(res.labels, return_counts=True)
    assert res.num_components == ids.size
    np.testing.assert_array_equal(res.component_ids, ids)
    np.testing.assert_array_equal(res.sizes, sizes)
    assert res.sizes.sum() == g.n
    # the sweep count is the MS-BFS payoff: at most ceil(C / batch) sweeps
    # would be needed if every root hit a distinct component; in-batch
    # merges can spend roots on shared components, but every sweep still
    # retires >= 1 component
    assert -(-res.num_components // batch) <= res.sweeps
    assert res.sweeps <= res.num_components
    assert res.roots_used <= res.sweeps * batch


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
def test_closeness_exact_matches_reference(name, g):
    res = closeness_centrality(g, sources=None, chunk=16, lanes=8)
    assert res.method == "exact" and res.num_sources == g.n
    np.testing.assert_allclose(res.closeness, ref_closeness(g),
                               rtol=1e-12, err_msg=name)


def test_closeness_sampled_all_sources_equals_exact():
    """Sampling every vertex must reproduce the exact numbers exactly —
    the estimator's scale factor is constructed for this reduction."""
    g = zoo_graph()
    exact = closeness_centrality(g, sources=None, lanes=8)
    sampled = closeness_centrality(g, sources=g.n, seed=7, lanes=8)
    np.testing.assert_allclose(sampled.closeness, exact.closeness,
                               rtol=1e-12)


def test_closeness_sampled_estimates_converge():
    """On a connected graph, the sampled estimator tracks exact closeness
    (rank of the hub + bounded relative error at half coverage)."""
    g = star_graph(33)
    exact = closeness_centrality(g, sources=None, lanes=8)
    est = closeness_centrality(g, sources=16, seed=0, lanes=8)
    assert est.method == "sampled"
    assert np.argmax(est.closeness) == np.argmax(exact.closeness) == 0
    hub_err = abs(est.closeness[0] - exact.closeness[0]) / exact.closeness[0]
    assert hub_err < 0.5, hub_err


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
@pytest.mark.parametrize("k", [0, 1, 3])
def test_khop_equals_depth_filtered_bfs(name, g, k):
    rp, ci = to_numpy_adj(g)
    sources = np.asarray([0, g.n // 2, g.n - 1], np.int32)
    res = khop_neighborhood(g, sources, k, lanes=4)
    mask = res.member_mask()                      # unpacked lane words
    for i, s in enumerate(sources):
        dref = bfs_reference(rp, ci, int(s))[1]
        expect = (dref >= 0) & (dref <= k)
        np.testing.assert_array_equal(mask[:, i], expect,
                                      err_msg=f"{name} k={k} s={s}")
        np.testing.assert_array_equal(res.members(i), np.flatnonzero(expect))
        assert res.counts[i] == expect.sum()


def test_sampler_khop_node_sets_fast_path():
    """``graph.sampler.khop_node_sets`` (the GNN-sampler deliverable)
    returns exact depth-filtered neighbourhoods per seed."""
    from repro.graph.sampler import khop_node_sets
    g = rmat_small()
    rp, ci = to_numpy_adj(g)
    seeds = [0, g.n // 3, g.n - 1]
    sets, res = khop_node_sets(g, seeds, 2, lanes=4)
    assert len(sets) == len(seeds) and res.k == 2
    for i, s in enumerate(seeds):
        dref = bfs_reference(rp, ci, int(s))[1]
        expect = np.flatnonzero((dref >= 0) & (dref <= 2))
        np.testing.assert_array_equal(sets[i], expect)
        assert res.counts[i] == expect.size


def test_reachability_pairwise_hops():
    g = zoo_graph()
    rp, ci = to_numpy_adj(g)
    sources = np.asarray([0, 10, 18])
    targets = np.asarray([4, 15, 0, 18])
    hops = reachability(g, sources, targets, lanes=4)
    for i, s in enumerate(sources):
        dref = bfs_reference(rp, ci, int(s))[1]
        np.testing.assert_array_equal(hops[i], dref[targets])


@pytest.mark.parametrize("name,g", GRAPHS, ids=[n for n, _ in GRAPHS])
def test_diameter_bounds_bracket_true_diameter(name, g):
    d = ref_depths_all(g)
    res = diameter_bounds(g, num_seeds=4, sweeps=3, seed=0, lanes=4)
    # the true diameter of the witness component
    in_comp = ref_components(g) == res.component
    diam = int(d[np.ix_(in_comp, in_comp)].max())
    assert res.lower <= diam <= res.upper, (name, res.lower, diam, res.upper)
    assert (res.eccentricities >= 0).all()


def test_diameter_double_sweep_exact_on_path():
    """The double sweep is exact on trees: sweep 2 starts from a path
    endpoint, so the lower bound reaches the full diameter."""
    g = path_graph(14)
    res = diameter_bounds(g, num_seeds=2, sweeps=2, seed=1, lanes=2)
    assert res.lower == 13


def test_query_api_dispatch_and_shared_engine():
    g = zoo_graph()
    eng = LaneEngine(g, lanes=8)
    comps = run_query(eng, ComponentsQuery(batch=8))
    np.testing.assert_array_equal(comps.labels, ref_components(g))
    clo = run_query(eng, ClosenessQuery(sources=None))
    np.testing.assert_allclose(clo.closeness, ref_closeness(g), rtol=1e-12)
    hops = run_query(eng, KHopQuery(sources=(0, 10), k=2))
    assert hops.k == 2 and hops.counts.shape == (2,)
    diam = run_query(eng, DiameterQuery(num_seeds=2, sweeps=2))
    assert 0 <= diam.lower <= diam.upper
    with pytest.raises(TypeError):
        run_query(eng, object())
    with pytest.raises(ValueError):   # engine overrides on a built engine
        run_query(eng, ComponentsQuery(), lanes=4)


def test_engine_sweep_depth_only_contract():
    """Analytics sweeps skip the parent derivation (zero-width parent);
    depths are identical to the parents-on sweep."""
    g = rmat_small()
    eng = LaneEngine(g, lanes=8)
    res = eng.sweep([0, 5])
    assert res.parent.shape == (g.n, 0)
    full = eng.sweep([0, 5], derive_parents=True)
    assert full.parent.shape == (g.n, 2)
    np.testing.assert_array_equal(np.asarray(res.depth),
                                  np.asarray(full.depth))


def test_adaptive_lanes_flow_through_engine():
    from repro.core.packed import adaptive_lane_pool
    g = rmat_small()
    eng = LaneEngine(g, lanes=None)
    assert eng.lanes_for(100) == adaptive_lane_pool(100, g.n, g.m)
    eng_pinned = LaneEngine(g, lanes=32)
    assert eng_pinned.lanes_for(100) == 32


def test_serve_bfs_plain_bfs_requests():
    """``bfs_requests`` is the PR-2 compatibility surface: a plain root
    list served as all-bfs requests through the multi-workload loop."""
    from repro.graph.generator import sample_roots
    from repro.launch.serve_bfs import bfs_requests, serve
    g = rmat_graph(8, 8, seed=1)
    roots = sample_roots(g, 10, seed=2)
    requests = bfs_requests(roots)
    stats = serve(g, requests, lanes=8, burst=4, every=2, validate=True)
    assert stats["validated"] and stats["requests"] == 10
    assert set(stats["per_type"]) == {"bfs"}
    assert stats["per_type"]["bfs"]["count"] == 10


def test_serve_bfs_mixed_workloads():
    """The serving loop answers a mixed analytics workload through one
    engine sweep with per-type sojourn stats — and the khop/reach/
    closeness answers match the offline references."""
    from repro.launch.serve_bfs import make_requests, serve
    g = rmat_graph(8, 8, seed=0)
    rp, ci = to_numpy_adj(g)
    requests = make_requests(g, 12, mix="bfs:2,khop:2,reach:1,closeness:1",
                             seed=4, khop_k=2, closeness_sources=4)
    kinds = {r.qtype for r in requests}
    assert len(kinds) > 1, "mix must actually mix"
    stats = serve(g, requests, lanes=8, burst=4, every=2, validate=True)
    assert stats["validated"]
    assert set(stats["per_type"]) == kinds
    for kind, t in stats["per_type"].items():
        assert t["count"] >= 1
        assert t["sojourn_layers"]["max"] >= 1
    total = sum(t["count"] for t in stats["per_type"].values())
    assert total == len(requests) == stats["requests"]
    for req in requests:
        if req.qtype == "khop":
            dref = bfs_reference(rp, ci, int(req.roots[0]))[1]
            assert req.answer["size"] == ((dref >= 0) & (dref <= req.k)).sum()
        elif req.qtype == "reach":
            dref = bfs_reference(rp, ci, int(req.roots[0]))[1]
            assert req.answer["hops"] == dref[req.target]
        elif req.qtype == "closeness":
            d = np.stack([bfs_reference(rp, ci, int(s))[1]
                          for s in req.roots], axis=1)
            c = closeness_from_depths(d, g.n)
            assert req.answer["top_vertex"] == int(np.argmax(c))


DIST_CODE = """
import numpy as np
from repro.analytics import (LaneEngine, closeness_centrality,
                             connected_components, diameter_bounds,
                             khop_neighborhood)
from repro.core.csr import from_edges
from repro.graph.generator import rmat_graph

src = np.concatenate([np.arange(5), np.full(5, 10), [3, 3, 12]])
dst = np.concatenate([np.arange(1, 6), np.arange(11, 16), [3, 4, 13]])
graphs = [from_edges(src, dst, 20), rmat_graph(8, 4, seed=3)]
for g in graphs:
    host = LaneEngine(g, lanes=8)
    dist = LaneEngine(g, lanes=8, ndev=2)
    assert dist.ndev == 2 and dist.mesh.devices.size == 2
    a = connected_components(host, batch=8)
    b = connected_components(dist, batch=8)
    np.testing.assert_array_equal(a.labels, b.labels)
    assert a.num_components == b.num_components and a.sweeps == b.sweeps
    ca = closeness_centrality(host, sources=None, chunk=16)
    cb = closeness_centrality(dist, sources=None, chunk=16)
    np.testing.assert_allclose(ca.closeness, cb.closeness, rtol=0, atol=0)
    ka = khop_neighborhood(host, [0, g.n // 2], 2)
    kb = khop_neighborhood(dist, [0, g.n // 2], 2)
    np.testing.assert_array_equal(ka.words, kb.words)
    np.testing.assert_array_equal(ka.counts, kb.counts)
    da = diameter_bounds(host, num_seeds=3, sweeps=2, seed=0)
    db = diameter_bounds(dist, num_seeds=3, sweeps=2, seed=0)
    assert (da.lower, da.upper, da.component) == (db.lower, db.upper,
                                                  db.component)
print("ANALYTICS_DIST_OK")
"""


def test_analytics_ndev2_parity():
    """Every analytics workload on the ndev=2 sharded engine must equal
    the host engine bit-for-bit (the engines are bit-identical, so the
    analytics layered on them must be too)."""
    out = run_in_subprocess(DIST_CODE, devices=2)
    assert "ANALYTICS_DIST_OK" in out
