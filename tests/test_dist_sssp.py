"""Distributed delta-stepping SSSP: the cross-engine parity + property
test matrix.

The pinning story of the distributed-SSSP rung mirrors ``test_dist2d``:
distances, per-lane step counts, truncation flags, AND the bucket/phase
traces must be bit-identical across

  {host pipelined engine, 1-D dist engine, 2-D dist engine}
    x ndev {1, 2, 4} / grid {1x2, 2x1, 2x2}
    x wire format {dense, compressed}
    x LANE_WORD_BITS {32, 64}                  (u64 = x64 subprocess leg)

over the weighted graph zoo of ``test_sssp_properties.build_case``, plus
the unit-weight boolean anchor (distributed ``as_depth()`` == distributed
MS-BFS depths), streaming (mid-sweep enqueue), the MIN-monoid exchange
primitives with exact byte totals, the bytes-on-the-wire accounting
(path graph: compressed bytes track the active relaxation frontier,
dense bytes are population-blind), weighted-partition unit tests, and
identity guards that BOTH engines ride the one shared exchange layer.

Multi-device legs run in subprocesses with forced host devices (conftest
pattern); the u64 legs re-run the SAME code under LANE_WORD_BITS=64 +
JAX_ENABLE_X64=1 via ``run_in_subprocess(env_extra=...)``.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess

U64_ENV = {"LANE_WORD_BITS": "64", "JAX_ENABLE_X64": "1"}
# the u32 leg pins its env too: under the tier1-u64 CI job every
# subprocess inherits LANE_WORD_BITS=64, so "the default width" must be
# forced back explicitly for the W=32 assertion to mean anything
U32_ENV = {"LANE_WORD_BITS": "32", "JAX_ENABLE_X64": "0"}

FIELDS = ("sources", "dist", "steps", "truncated", "trace_bucket",
          "trace_phase")


# --------------------------------------------------------------------------
# the parity matrix
# --------------------------------------------------------------------------

MATRIX_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core import packed
from repro.core.dist_sssp import (default_delta_dist, dist2d_sssp,
                                  dist_sssp, host_mesh, mesh2d,
                                  partition_weighted_graph,
                                  partition_weighted_graph_2d)
from repro.traversal.sssp import default_delta, sssp_pipelined
from test_sssp_properties import build_case

FIELDS = ("sources", "dist", "steps", "truncated", "trace_bucket",
          "trace_phase")
GRIDS = ((1, 2), (2, 1), (2, 2))

for shape, wm, seed in (("random", "uniform", 3),
                        ("two_components", "with_zeros", 11)):
    wg, sources, delta = build_case(48, 140, seed=seed, shape=shape,
                                    weight_model=wm, dup_edges=False)
    lanes = max(1, len(sources) // 2)     # queue refill is exercised
    want = sssp_pipelined(wg, sources, delta=delta, lanes=lanes)
    for ndev in (1, 2, 4):
        dwg = partition_weighted_graph(wg, ndev)
        assert default_delta_dist(dwg) == default_delta(wg), (shape, ndev)
        mesh = host_mesh(ndev)
        for compress in (False, True):
            got = dist_sssp(dwg, sources, mesh, delta=delta, lanes=lanes,
                            compress=compress)
            for f in FIELDS:
                assert np.array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f))), (
                    "1d", shape, ndev, compress, f)
    for (pr, pc) in GRIDS:
        dwg2 = partition_weighted_graph_2d(wg, pr, pc)
        assert default_delta_dist(dwg2) == default_delta(wg), (shape, pr, pc)
        mesh = mesh2d(pr, pc)
        for compress in (False, True):
            got = dist2d_sssp(dwg2, sources, mesh, delta=delta,
                              lanes=lanes, compress=compress)
            for f in FIELDS:
                assert np.array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f))), (
                    "2d", shape, pr, pc, compress, f)
print("W=%d SSSP_MATRIX_OK" % packed.LANE_WORD_BITS)
"""


def test_dist_sssp_parity_matrix():
    out = run_in_subprocess(MATRIX_CODE, devices=4, timeout=900,
                            env_extra=U32_ENV)
    assert "W=32 SSSP_MATRIX_OK" in out


def test_dist_sssp_parity_matrix_u64():
    out = run_in_subprocess(MATRIX_CODE, devices=4, timeout=900,
                            env_extra=U64_ENV)
    assert "W=64 SSSP_MATRIX_OK" in out


# --------------------------------------------------------------------------
# the boolean anchor, distributed: unit weights == distributed MS-BFS
# --------------------------------------------------------------------------

ANCHOR_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist_msbfs import dist_msbfs, host_mesh, partition_graph
from repro.core.dist_sssp import (dist2d_sssp, dist_sssp, mesh2d,
                                  partition_weighted_graph,
                                  partition_weighted_graph_2d)
from test_sssp_properties import build_case

wg, sources, _ = build_case(48, 140, seed=5, shape="random",
                            weight_model="unit", dup_edges=False)
src = np.asarray(sources, np.int32)
depth = np.asarray(dist_msbfs(partition_graph(wg.csr, 2), src,
                              host_mesh(2)).depth)
d1 = dist_sssp(partition_weighted_graph(wg, 2), src, host_mesh(2),
               delta=1.0, lanes=max(1, len(src) // 2))
assert np.array_equal(np.asarray(d1.as_depth()), depth)
d2 = dist2d_sssp(partition_weighted_graph_2d(wg, 2, 2), src, mesh2d(2, 2),
                 delta=1.0, lanes=max(1, len(src) // 2), compress=True)
assert np.array_equal(np.asarray(d2.as_depth()), depth)
print("SSSP_ANCHOR_OK")
"""


def test_dist_sssp_unit_weight_anchor_matches_dist_msbfs():
    out = run_in_subprocess(ANCHOR_CODE, devices=4, timeout=600)
    assert "SSSP_ANCHOR_OK" in out


# --------------------------------------------------------------------------
# streaming: mid-sweep enqueue on the 2-D engine + byte-meter identity
# --------------------------------------------------------------------------

STREAM_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist_sssp import (dist2d_sssp_engine_drain,
                                  dist2d_sssp_engine_enqueue,
                                  dist2d_sssp_engine_idle,
                                  dist2d_sssp_engine_init,
                                  dist2d_sssp_engine_result,
                                  dist2d_sssp_engine_step, mesh2d,
                                  partition_weighted_graph_2d)
from repro.traversal.sssp import sssp_pipelined
from test_sssp_properties import build_case

FIELDS = ("sources", "dist", "steps", "truncated", "trace_bucket",
          "trace_phase")
wg, sources, delta = build_case(48, 140, seed=9, shape="random",
                                weight_model="uniform", dup_edges=False)
sources = np.asarray(sources, np.int32)
mesh = mesh2d(2, 2)
dwg2 = partition_weighted_graph_2d(wg, 2, 2)
s = dist2d_sssp_engine_init(dwg2, mesh, capacity=len(sources), lanes=2)
s = dist2d_sssp_engine_enqueue(s, sources[:2])
s = dist2d_sssp_engine_step(dwg2, s, mesh, delta, compress=True)
s = dist2d_sssp_engine_enqueue(s, sources[2:])
while not dist2d_sssp_engine_idle(s):
    s = dist2d_sssp_engine_step(dwg2, s, mesh, delta, compress=True)
res = dist2d_sssp_engine_result(dwg2, s)
want = sssp_pipelined(wg, sources, delta=delta, lanes=2)
for f in FIELDS:
    assert np.array_equal(np.asarray(getattr(res, f)),
                          np.asarray(getattr(want, f))), f
# the scalar meter is exactly the per-step log's total
assert int(s.exch_bytes) == int(np.asarray(s.exch_log).sum())
assert int(s.exch_bytes) > 0
print("SSSP_STREAM_OK")
"""


def test_dist2d_sssp_streaming_enqueue_and_byte_meter():
    out = run_in_subprocess(STREAM_CODE, devices=4, timeout=600)
    assert "SSSP_STREAM_OK" in out


# --------------------------------------------------------------------------
# bytes on the wire: dense is population-blind, compressed tracks the
# active relaxation frontier
# --------------------------------------------------------------------------

BYTES_CODE = """
import numpy as np
from repro.core.csr import from_weighted_edges
from repro.core.dist_sssp import (dist2d_sssp_engine_enqueue,
                                  dist2d_sssp_engine_idle,
                                  dist2d_sssp_engine_init,
                                  dist2d_sssp_engine_result,
                                  dist2d_sssp_engine_step, mesh2d,
                                  partition_weighted_graph_2d)

n = 32
src = np.arange(n - 1)
wg = from_weighted_edges(src, src + 1, np.ones(n - 1), n)
mesh = mesh2d(2, 2)
dwg2 = partition_weighted_graph_2d(wg, 2, 2)
logs = {}
for compress in (False, True):
    s = dist2d_sssp_engine_init(dwg2, mesh, capacity=1, lanes=1)
    s = dist2d_sssp_engine_enqueue(s, np.array([0], np.int32))
    while not dist2d_sssp_engine_idle(s):
        s = dist2d_sssp_engine_step(dwg2, s, mesh, 1.0, compress=compress)
    res = dist2d_sssp_engine_result(dwg2, s)
    assert np.array_equal(np.asarray(res.dist)[:, 0],
                          np.arange(n, dtype=np.float32)), compress
    logs[compress] = np.asarray(s.exch_log)
log_d, log_c = logs[False], logs[True]
live = log_d > 0
assert live.sum() >= n // 2      # a path is one long chain of steps
# dense value exchange ships every entry every step: population-blind
assert (log_d[live] == log_d[live][0]).all()
# the active frontier is ~1 vertex/step: compressed stays well below
assert (log_c[live] < log_d[live][0]).all()
assert log_c[live].max() * 2 < log_d[live][0]
print("SSSP_BYTES_OK live=%d dense=%d comp_max=%d"
      % (live.sum(), log_d[live][0], log_c[live].max()))
"""


def test_dist2d_sssp_compressed_bytes_track_frontier():
    out = run_in_subprocess(BYTES_CODE, devices=4, timeout=600)
    assert "SSSP_BYTES_OK" in out


# --------------------------------------------------------------------------
# MIN-monoid exchange primitives: exact byte totals
# --------------------------------------------------------------------------

EXCHANGE_VALUES_CODE = """
import numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import compat
from repro.core.dist_msbfs import host_mesh
from repro.core.exchange import (allreduce_min, exchange_reduce_min,
                                 gather_values)
from repro.distributed.compression import sparse_budget

mesh = host_mesh(2)
INF = np.float32(np.inf)

def run(vals, fn):
    return compat.shard_map(fn, mesh=mesh, in_specs=(P("data"),),
                            out_specs=(P("data"), P("data")),
                            check_vma=False)(vals)
# fn returns per-device (block[1, ...], bytes[1]) so both carry the
# device axis the out_specs name

# per-device [8, 2] float32 block: total=16 entries, itemsize 4
# dense bytes/group  = ndev * total * itemsize = 2 * 16 * 4 = 128
# sparse bytes/entry = count_header 4 + count * (idx 4 + payload 4)
sparse = np.full((2, 8, 2), INF, np.float32)
sparse[0, 3, 1] = 0.5
sparse[1, 6, 0] = 2.5
dense_pop = np.arange(32, dtype=np.float32).reshape(2, 8, 2)
mixed = np.full((2, 8, 2), INF, np.float32)
mixed[0, 3, 1] = 0.5
mixed[1] = 7.0                       # one dense member forces the group

assert sparse_budget(16) == 4

def fold(v):
    out = allreduce_min(v, ("data",))
    return out, jnp.zeros((1,), jnp.int32)

folded, _ = run(sparse, fold)
want = np.minimum(sparse[0], sparse[1])
assert np.array_equal(np.asarray(folded)[0], want)
assert np.array_equal(np.asarray(folded)[1], want)

for compress, pop, expect in ((False, sparse, 128),   # population-blind
                              (False, dense_pop, 128),
                              (True, sparse, 24),     # 4 + 1*8, x2 devs
                              (True, dense_pop, 128), # over budget: dense
                              (True, mixed, 128)):    # pmax group consensus
    def reduce_min(v, compress=compress):
        out, nbytes = exchange_reduce_min(v, "data", compress=compress)
        return out, nbytes.reshape(1)
    folded, nbytes = run(pop, reduce_min)
    want = np.minimum(pop[0], pop[1])
    assert np.array_equal(np.asarray(folded)[0], want), compress
    assert np.array_equal(np.asarray(folded)[1], want), compress
    assert int(np.asarray(nbytes)[0]) == expect, (compress, expect,
                                                  int(np.asarray(nbytes)[0]))

# gather keeps per-device order (the expand side of the 2-D exchange)
def gather(v):
    stacked, nbytes = gather_values(v, "data", compress=True)
    return stacked[None], nbytes.reshape(1)
stacked, nbytes = run(sparse, gather)
assert np.array_equal(np.asarray(stacked)[0][:, 0], sparse)
assert int(np.asarray(nbytes)[0]) == 24
print("SSSP_EXCHANGE_OK")
"""


def test_min_exchange_primitives_exact_bytes():
    out = run_in_subprocess(EXCHANGE_VALUES_CODE, devices=2, timeout=600)
    assert "SSSP_EXCHANGE_OK" in out


# --------------------------------------------------------------------------
# one shared exchange layer: both engines import THE SAME primitives
# --------------------------------------------------------------------------


def test_both_engines_ride_shared_exchange():
    from repro.core import dist2d, dist_msbfs, dist_sssp, exchange
    # the MS-BFS engines' OR surface is untouched by the SSSP growth
    assert dist_msbfs.allreduce_or is exchange.allreduce_or
    assert dist2d.exchange_reduce_or is exchange.exchange_reduce_or
    assert dist2d.exchange_expand is exchange.exchange_expand
    # and the SSSP engines ride the extracted MIN surface, not a copy
    assert dist_sssp.allreduce_min is exchange.allreduce_min
    assert dist_sssp.exchange_reduce_min is exchange.exchange_reduce_min
    assert dist_sssp.exchange_expand_values is exchange.exchange_expand_values


# --------------------------------------------------------------------------
# weighted partitions: slab cuts, inf pads, exact edge/weight accounting
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def wg_small():
    from repro.graph.generator import uniform_random_weighted_graph
    return uniform_random_weighted_graph(30, 90, seed=1)


def test_partition_weighted_1d_slabs(wg_small):
    from repro.core.dist_sssp import partition_weighted_graph
    wg = wg_small
    dwg = partition_weighted_graph(wg, 4)
    n_loc = dwg.n // 4
    assert dwg.n % 4 == 0 and dwg.n >= wg.n and dwg.n_orig == wg.n
    assert dwg.row_ptr.shape == (4, n_loc + 1)
    assert dwg.weights.shape == dwg.col_idx.shape == (4, dwg.m_loc)
    w = np.asarray(dwg.weights)
    fin = np.isfinite(w)
    rp = np.asarray(dwg.row_ptr)
    for d in range(4):
        k = int(rp[d, -1])
        # real edges first, inf pads after — nothing in between
        assert fin[d, :k].all() and not fin[d, k:].any()
    # slabs are contiguous cuts of the original weight array, in order
    assert int(fin.sum()) == wg.m
    flat = np.concatenate([w[d][fin[d]] for d in range(4)])
    assert np.array_equal(flat, np.asarray(wg.weights))


def test_partition_weighted_2d_blocks(wg_small):
    from repro.core.dist_sssp import partition_weighted_graph_2d
    wg = wg_small
    dwg2 = partition_weighted_graph_2d(wg, 2, 2)
    g2 = dwg2.g2
    w = np.asarray(dwg2.weights)
    assert w.shape == (4, g2.m_loc)
    assert dwg2.n == g2.n and dwg2.n_orig == wg.n
    fin = np.isfinite(w)
    rp = np.asarray(g2.row_ptr)
    for d in range(4):
        k = int(rp[d, -1])
        assert int(fin[d].sum()) == k
        assert fin[d, :k].all()
    # every edge lands in exactly one block; weights survive as a multiset
    assert int(fin.sum()) == wg.m
    assert np.array_equal(np.sort(w[fin]), np.sort(np.asarray(wg.weights)))


def test_partition_mesh_mismatch_and_bad_delta(wg_small):
    from repro.core.dist_sssp import (dist_sssp_engine_init,
                                      dist_sssp_engine_step, host_mesh,
                                      partition_weighted_graph)
    wg = wg_small
    with pytest.raises(ValueError, match="repartition"):
        dist_sssp_engine_init(partition_weighted_graph(wg, 2),
                              host_mesh(1), capacity=1)
    dwg = partition_weighted_graph(wg, 1)
    mesh = host_mesh(1)
    s = dist_sssp_engine_init(dwg, mesh, capacity=1, lanes=1)
    with pytest.raises(ValueError, match="delta"):
        dist_sssp_engine_step(dwg, s, mesh, 0.0)
    with pytest.raises(ValueError, match="delta"):
        dist_sssp_engine_step(dwg, s, mesh, (1.0, -2.0))


# --------------------------------------------------------------------------
# the LaneEngine facade dispatches weighted sweeps onto the partitions
# --------------------------------------------------------------------------

ENGINE_SSSP_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.analytics.engine import LaneEngine
from repro.traversal.sssp import sssp_pipelined
from test_sssp_properties import build_case

FIELDS = ("sources", "dist", "steps", "truncated", "trace_bucket",
          "trace_phase")
wg, sources, delta = build_case(48, 140, seed=13, shape="random",
                                weight_model="uniform", dup_edges=False)
sources = np.asarray(sources, np.int32)
eng1 = LaneEngine(wg, ndev=2)
eng2 = LaneEngine(wg, grid=(2, 2), compress=True)
lanes = eng1.sssp_lanes_for(len(sources))
want = sssp_pipelined(wg, sources, delta=delta, lanes=lanes)
for eng in (eng1, eng2):
    got = eng.sssp_sweep(sources, delta=delta)
    for f in FIELDS:
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(want, f))), (eng.grid, f)
    # the boolean workloads keep working on the same weighted engine
    assert np.asarray(eng.sweep(sources[:2]).depth).shape[0] == wg.n
print("ENGINE_SSSP_OK")
"""


def test_lane_engine_sssp_sweep_on_partitions():
    out = run_in_subprocess(ENGINE_SSSP_CODE, devices=4, timeout=600)
    assert "ENGINE_SSSP_OK" in out
