"""Per-architecture smoke tests: reduced config, one real train/serve step on
CPU, asserting output shapes and finite values — required for all 10 archs."""
import jax
import numpy as np
import pytest

from repro.configs.base import list_archs, make_step, param_builders
from repro.configs.reduced import reduce_arch
from repro.data.pipeline import make_batch
from repro.optim.adamw import init_opt_state

ARCHS = ["phi4-mini-3.8b", "qwen1.5-32b", "llama3-405b",
         "granite-moe-1b-a400m", "qwen3-moe-30b-a3b",
         "gin-tu", "gcn-cora", "mace", "egnn", "dien"]


def _finite(tree):
    return all(np.isfinite(np.asarray(jax.device_get(x))).all()
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_reduced_train_step(arch_id):
    arch = reduce_arch(arch_id)
    shape = next(s for s in arch.shapes if s.kind == "train")
    init_fn, _ = param_builders(arch, shape)
    params, _ = init_fn(jax.random.PRNGKey(0))
    opt = init_opt_state(params, arch.opt)
    step = jax.jit(make_step(arch, shape))
    batch = make_batch(arch, shape, 0)
    p2, opt2, metrics = step(params, opt, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert _finite(p2), "params contain NaN/inf after one step"
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape
    # a second step must further change the params (optimizer is live)
    batch2 = make_batch(arch, shape, 1)
    p3, _, m2 = step(p2, opt2, batch2)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch_id", ["phi4-mini-3.8b", "qwen3-moe-30b-a3b"])
def test_reduced_decode_step(arch_id):
    arch = reduce_arch(arch_id)
    shape = arch.shape("decode_32k")
    init_fn, _ = param_builders(arch, shape)
    params, _ = init_fn(jax.random.PRNGKey(0))
    from repro.configs.base import input_specs
    specs, _ = input_specs(arch, shape)
    batch = jax.tree.map(lambda s: jax.numpy.zeros(s.shape, s.dtype), specs)
    batch["cache_len"] = jax.numpy.int32(4)
    logits, cache = jax.jit(make_step(arch, shape))(params, batch)
    assert logits.shape == (shape.dims["global_batch"],
                            arch.model_cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_reduced_serve_and_retrieval():
    arch = reduce_arch("dien")
    for shape_id in ("serve_p99", "retrieval_cand"):
        shape = arch.shape(shape_id)
        init_fn, _ = param_builders(arch, shape)
        params, _ = init_fn(jax.random.PRNGKey(0))
        batch = make_batch(arch, shape, 0)
        out = jax.jit(make_step(arch, shape))(params, batch)
        assert np.isfinite(np.asarray(out).astype(np.float64)).all()


def test_registry_has_all_assigned():
    have = set(list_archs())
    assert set(ARCHS) <= have, have
