"""Table-driven unit test of the alpha/beta TD<->BU rule (paper Algorithm 3),
pinned independently of end-to-end runs: counters (e_f, v_f, e_u) ->
expected direction per layer, plus the per-lane vectorised form MS-BFS uses.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import (ALPHA_DEFAULT, BETA_DEFAULT, bfs,
                               switch_direction)
from repro.graph.generator import rmat_graph, sample_roots

N = 1024
ALPHA, BETA = 14.0, 24.0

# (currently_topdown, e_f, v_f, e_u) -> expected topdown after the rule.
# TD->BU iff e_f > e_u / alpha; BU->TD iff v_f < n / beta; else keep.
CASES = [
    # TD stays TD: frontier edges still small vs unexplored
    (True, 10, 4, 100_000, True),
    # TD -> BU: e_f crosses e_u / alpha (100_000 / 14 ~ 7142.9)
    (True, 7_143, 500, 100_000, False),
    # TD boundary: e_f == e_u / alpha exactly is NOT a switch (strict >)
    (True, 25, 10, 350, True),            # 350 / 14 == 25
    (True, 26, 10, 350, False),           # one past the boundary
    # BU stays BU: frontier still huge
    (False, 5_000, 900, 2_000, False),
    # BU -> TD: v_f drops below n / beta (1024 / 24 ~ 42.7)
    (False, 5_000, 42, 2_000, True),
    # BU boundary: v_f == ceil boundary region — 43 > 42.67 keeps BU
    (False, 5_000, 43, 2_000, False),
    # degenerate tail: empty frontier in BU flips TD (0 < n / beta)
    (False, 0, 0, 0, True),
    # TD with nothing unexplored: any e_f > 0 flips BU
    (True, 1, 1, 0, False),
]


@pytest.mark.parametrize("topdown,e_f,v_f,e_u,expected", CASES)
def test_switch_rule_table(topdown, e_f, v_f, e_u, expected):
    got = switch_direction(jnp.bool_(topdown), jnp.int32(e_f),
                           jnp.int32(v_f), jnp.int32(e_u), N, ALPHA, BETA)
    assert bool(got) == expected, (topdown, e_f, v_f, e_u)


def test_switch_rule_vectorised_lanes():
    """The MS-BFS controller applies the rule elementwise over lanes; the
    batched answer must equal the row-by-row scalar table."""
    td = jnp.asarray([c[0] for c in CASES])
    e_f = jnp.asarray([c[1] for c in CASES], jnp.int32)
    v_f = jnp.asarray([c[2] for c in CASES], jnp.int32)
    e_u = jnp.asarray([c[3] for c in CASES], jnp.int32)
    got = switch_direction(td, e_f, v_f, e_u, N, ALPHA, BETA)
    np.testing.assert_array_equal(np.asarray(got),
                                  [c[4] for c in CASES])


def test_switch_rule_defaults_match_module_constants():
    # alpha/beta defaults flow from the module constants (paper config)
    got = switch_direction(jnp.bool_(True), jnp.int32(1), jnp.int32(1),
                           jnp.int32(10 ** 6), N)
    assert bool(got) is True
    assert ALPHA_DEFAULT == 14.0 and BETA_DEFAULT == 24.0


def test_switch_rule_replays_end_to_end_trace():
    """Feeding the recorded per-layer counters of a real hybrid run back
    through the rule reproduces the recorded direction sequence —
    Algorithm 3 is exactly this recurrence."""
    g = rmat_graph(10, 16, seed=0)
    root = int(sample_roots(g, 1, seed=1)[0])
    out = bfs(g, root, "hybrid")
    nl = int(out.num_layers)
    dirs = np.asarray(out.trace_dir)[:nl]          # 0 TD, 1 BU
    e_f = np.asarray(out.trace_ef)[:nl]
    v_f = np.asarray(out.trace_vf)[:nl]
    e_u = np.asarray(out.trace_eu)[:nl]
    topdown = True                                 # layer-0 prior state
    for i in range(nl):
        topdown = bool(switch_direction(
            jnp.bool_(topdown), jnp.int32(e_f[i]), jnp.int32(v_f[i]),
            jnp.int32(e_u[i]), g.n, ALPHA_DEFAULT, BETA_DEFAULT))
        assert dirs[i] == (0 if topdown else 1), f"layer {i}"
