"""Unit tests for the loop-aware HLO roofline parser."""

from repro.launch.roofline import (_loop_multipliers, _split_computations,
                                   _type_bytes, parse_collectives,
                                   parse_hbm_bytes, roofline_terms)

HLO = """
HloModule test

%region_body.10 (arg.1: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg.1 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%arg.1), index=0
  %gte.1 = f32[128,256]{1,0} get-tuple-element(%arg.1), index=1
  %ag = f32[128,256]{1,0} all-reduce(%gte.1), replica_groups=[16,16]<=[256]
  %c1 = s32[] constant(1)
  %add = s32[] add(%gte.0, %c1)
  ROOT %tuple = (s32[], f32[128,256]{1,0}) tuple(%add, %ag)
}

%region_cond.20 (arg.2: (s32[], f32[128,256])) -> pred[] {
  %arg.2 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%arg.2), index=0
  %c32 = s32[] constant(32)
  ROOT %lt = pred[] compare(%gte.2, %c32), direction=LT
}

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag2 = f32[128,256]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256]
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[128,256]{1,0}) tuple(%c0, %ag2)
  %w = (s32[], f32[128,256]{1,0}) while(%t0), condition=%region_cond.20, body=%region_body.10
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_type_bytes():
    assert _type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _type_bytes("bf16[10]") == 20
    assert _type_bytes("(s32[], f32[8,8])") == 4 + 256


def test_split_and_multipliers():
    comps, entry = _split_computations(HLO)
    assert entry == "main.1"
    assert "region_body.10" in comps and "region_cond.20" in comps
    mult = _loop_multipliers(comps, entry)
    assert mult["main.1"] == 1.0
    assert mult["region_body.10"] == 32.0   # trip count from the condition


def test_collectives_weighted_by_trip_count():
    stats = parse_collectives(HLO, n_devices=256)
    r = 128 * 256 * 4
    # all-gather in entry: R*(k-1)/k with k=16; all-reduce in body x32 trips
    expect_ag = r * 15 / 16
    expect_ar = 32 * 2 * r * 15 / 16
    assert abs(stats.by_op["all-gather"]["wire_bytes"] - expect_ag) < 1
    assert abs(stats.by_op["all-reduce"]["wire_bytes"] - expect_ar) < 1


def test_hbm_parse_counts_loop_body():
    b = parse_hbm_bytes(HLO)
    # body all-reduce runs 32x: write result + read operand each iteration
    assert b >= 32 * 2 * 128 * 256 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert t["dominant"] == "memory"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["roofline_fraction"] - 0.5) < 1e-9


def test_analytic_flops_sane():
    from repro.configs.base import get_arch
    from repro.launch.flops import analytic_flops
    arch = get_arch("phi4-mini-3.8b")
    shape = arch.shape("train_4k")
    f = analytic_flops(arch, shape)
    n_act = arch.model_cfg.active_param_count()
    tokens = 256 * 4096
    assert f["model_flops"] > 6 * n_act * tokens * 0.99
    assert f["executed_flops"] > f["model_flops"]
    # decode flops are tiny vs train
    fd = analytic_flops(arch, arch.shape("decode_32k"))
    assert fd["model_flops"] < f["model_flops"] / 100
