"""Property-based delta-stepping suite: every tropical lane == Dijkstra.

Randomized weighted graphs — disconnected components, zero-weight edges,
duplicate/parallel edges, isolated sources, star/path shapes, adversarial
bucket widths — are swept with hypothesis (importorskip-guarded, the
``test_msbfs_properties`` pattern) through the pipelined SSSP engine with
a lane pool SMALLER than the source count, so every example exercises
queue refill mid-sweep.

Each lane must reproduce the binary-heap Dijkstra oracle
(``traversal.ref.dijkstra_reference``): identical reached sets, distances
equal to float32 accumulation tolerance. Unit-weight examples are
additionally pinned BIT-IDENTICAL to ``msbfs_pipelined`` depths — the
boolean-semiring anchor. A deterministic fallback case set always runs
and the hypothesis profile is derandomized (fixed seed, bounded examples)
so ``make test-properties`` stays reproducible in CI.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csr import from_weighted_edges
from repro.core.msbfs import msbfs_pipelined
from repro.traversal import (dijkstra_reference, sssp_pipelined,
                             to_numpy_weighted)

MAX_EXAMPLES = int(os.environ.get("MSBFS_PROP_EXAMPLES", "10"))

SHAPES = ("random", "star", "path", "two_components")
WEIGHT_MODELS = ("uniform", "unit", "with_zeros", "integer")


def build_case(n: int, m: int, seed: int, shape: str, weight_model: str,
               dup_edges: bool):
    """Build (weighted graph, sources, delta) for one property example.

    Sources are drawn from ALL vertices — isolated (degree-0) sources
    included. ``delta`` is drawn adversarially around the weight scale so
    all-light, all-heavy and mixed bucket splits are all exercised.
    """
    rng = np.random.default_rng(seed)
    if shape == "star":
        src = np.zeros(max(n - 1, 1), np.int64)
        dst = np.arange(1, max(n, 2), dtype=np.int64)
    elif shape == "path":
        ln = min(n, 40)
        src = np.arange(ln - 1, dtype=np.int64)
        dst = src + 1
    elif shape == "two_components":
        h = max(n // 2, 2)
        s1 = rng.integers(0, h, max(m // 2, 1))
        d1 = rng.integers(0, h, max(m // 2, 1))
        s2 = rng.integers(h, n, max(m // 2, 1)) if n > h else s1
        d2 = rng.integers(h, n, max(m // 2, 1)) if n > h else d1
        src = np.concatenate([s1, s2])
        dst = np.concatenate([d1, d2])
    else:  # random G(n, m) with repetition
        src = rng.integers(0, n, max(m, 1))
        dst = rng.integers(0, n, max(m, 1))
    if dup_edges and len(src):
        take = rng.integers(0, len(src), max(len(src) // 2, 1))
        src = np.concatenate([src, src[take]])
        dst = np.concatenate([dst, dst[take]])

    if weight_model == "unit":
        w = np.ones(len(src))
    elif weight_model == "with_zeros":
        w = rng.uniform(0.0, 1.0, len(src))
        w[rng.random(len(src)) < 0.3] = 0.0
    elif weight_model == "integer":
        w = rng.integers(0, 5, len(src)).astype(np.float64)
    else:
        w = rng.uniform(0.0, 1.0, len(src))

    wg = from_weighted_edges(src, dst, w, n, symmetrize=True,
                             drop_self_loops=True)
    num_src = min(n, int(rng.integers(2, 7)))
    sources = rng.choice(n, size=num_src, replace=False)
    # adversarial bucket widths: below/at/above the weight scale
    delta = float(rng.choice([0.05, 0.5, 1.0, 7.0]))
    return wg, sources, delta


def _check_case(n, m, seed, shape, weight_model, dup_edges):
    wg, sources, delta = build_case(n, m, seed, shape, weight_model,
                                    dup_edges)
    lanes = max(1, len(sources) // 2)        # queue refill is exercised
    res = sssp_pipelined(wg, sources, delta=delta, lanes=lanes)
    rp, ci, w = to_numpy_weighted(wg)
    for i, r in enumerate(sources):
        ref = dijkstra_reference(rp, ci, w, int(r))
        got = np.asarray(res.dist[:, i], np.float64)
        np.testing.assert_array_equal(
            np.isfinite(got), np.isfinite(ref),
            err_msg=f"lane {i} (root {r}) reached set, delta={delta}")
        fin = np.isfinite(ref)
        np.testing.assert_allclose(
            got[fin], ref[fin], atol=1e-4,
            err_msg=f"lane {i} (root {r}) distances, delta={delta}")
    if weight_model == "unit":
        # the boolean-semiring anchor on fuzzed shapes: distance == depth
        mres = msbfs_pipelined(wg.csr, jnp.asarray(sources, jnp.int32),
                               "hybrid", lanes=max(1, len(sources) // 2))
        np.testing.assert_array_equal(np.asarray(res.as_depth()),
                                      np.asarray(mres.depth))


def test_property_sssp_random_graphs():
    """Hypothesis sweep — skipped without hypothesis (the deterministic
    fallback below pins the same invariants). Derandomized: fixed seed,
    MSBFS_PROP_EXAMPLES bounds the example count (CI sets it)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=MAX_EXAMPLES, deadline=None, derandomize=True)
    @given(st.integers(4, 70), st.integers(1, 220), st.integers(0, 10 ** 6),
           st.sampled_from(SHAPES), st.sampled_from(WEIGHT_MODELS),
           st.booleans())
    def inner(n, m, seed, shape, weight_model, dup_edges):
        _check_case(n, m, seed, shape, weight_model, dup_edges)

    inner()


DETERMINISTIC_CASES = [
    # n, m, seed, shape, weight_model, dup_edges
    (40, 120, 0, "random", "uniform", False),
    (33, 50, 1, "random", "with_zeros", True),   # zero weights + dup edges
    (60, 10, 2, "random", "uniform", False),     # sparse -> isolated sources
    (25, 0, 3, "star", "integer", False),        # integer (tie-heavy) weights
    (44, 0, 4, "path", "uniform", True),         # deep chains of light edges
    (30, 0, 5, "path", "unit", False),           # unit weights == BFS anchor
    (48, 80, 6, "two_components", "uniform", False),
    (36, 90, 7, "random", "unit", True),         # unit anchor, messy graph
]


@pytest.mark.parametrize("n,m,seed,shape,weight_model,dup_edges",
                         DETERMINISTIC_CASES)
def test_deterministic_sssp_cases(n, m, seed, shape, weight_model,
                                  dup_edges):
    """Fixed fallback case set for the property above — always runs."""
    _check_case(n, m, seed, shape, weight_model, dup_edges)


def test_isolated_source_answers_immediately():
    """A degree-0 source's lane reaches exactly itself at distance 0."""
    wg = from_weighted_edges(np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]),
                             np.array([0.3, 0.1, 0.7, 0.2]), 6)
    res = sssp_pipelined(wg, [5, 0], lanes=1)
    d = np.asarray(res.dist[:, 0])
    assert d[5] == 0.0 and not np.isfinite(np.delete(d, 5)).any()
    rp, ci, w = to_numpy_weighted(wg)
    ref = dijkstra_reference(rp, ci, w, 0)
    np.testing.assert_allclose(np.asarray(res.dist[:5, 1]), ref[:5],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# distributed leg: the sharded engines fuzzed against the same oracle
# ---------------------------------------------------------------------------

DIST_PROP_CODE = """
import os
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist_sssp import (dist2d_sssp, dist_sssp, host_mesh,
                                  mesh2d, partition_weighted_graph,
                                  partition_weighted_graph_2d)
from repro.traversal import dijkstra_reference, to_numpy_weighted
from test_sssp_properties import build_case

MESH1 = host_mesh(2)
MESH2 = mesh2d(2, 1)


def check(n, m, seed, shape, weight_model, dup_edges):
    wg, sources, delta = build_case(n, m, seed, shape, weight_model,
                                    dup_edges)
    lanes = max(1, len(sources) // 2)
    compress = bool(seed % 2)                 # both wire formats fuzzed
    if seed % 3 == 0:                         # both partition shapes too
        res = dist2d_sssp(partition_weighted_graph_2d(wg, 2, 1), sources,
                          MESH2, delta=delta, lanes=lanes,
                          compress=compress)
    else:
        res = dist_sssp(partition_weighted_graph(wg, 2), sources, MESH1,
                        delta=delta, lanes=lanes, compress=compress)
    rp, ci, w = to_numpy_weighted(wg)
    for i, r in enumerate(sources):
        ref = dijkstra_reference(rp, ci, w, int(r))
        got = np.asarray(res.dist[:, i], np.float64)
        assert (np.isfinite(got) == np.isfinite(ref)).all(), (
            "reached set", seed, shape, weight_model, i)
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[fin], ref[fin], atol=1e-4)


# deterministic floor: zero weights, disconnected graphs, duplicate
# edges, adversarial deltas -- always runs, hypothesis or not
CASES = [
    (24, 60, 1, "random", "with_zeros", True),
    (48, 30, 2, "random", "uniform", False),
    (24, 0, 3, "star", "integer", False),
    (24, 0, 5, "path", "unit", False),
    (48, 80, 6, "two_components", "uniform", False),
]
for c in CASES:
    check(*c)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    print("DIST_PROP_OK hypothesis=0")
else:
    maxe = min(int(os.environ.get("MSBFS_PROP_EXAMPLES", "10")), 6)

    @settings(max_examples=maxe, deadline=None, derandomize=True)
    @given(st.sampled_from((24, 48)), st.integers(0, 160),
           st.integers(0, 10 ** 6),
           st.sampled_from(("random", "star", "path", "two_components")),
           st.sampled_from(("uniform", "unit", "with_zeros", "integer")),
           st.booleans())
    def inner(n, m, seed, shape, weight_model, dup_edges):
        check(n, m, seed, shape, weight_model, dup_edges)

    inner()
    print("DIST_PROP_OK hypothesis=1")
"""


def test_property_sssp_distributed():
    """The sharded delta-stepping engines (1-D and 2-D, dense and
    compressed wire) fuzzed against the Dijkstra oracle under 2 forced
    host devices — the distributed twin of the host property sweep."""
    from conftest import run_in_subprocess
    out = run_in_subprocess(DIST_PROP_CODE, devices=2, timeout=900)
    assert "DIST_PROP_OK" in out


# ---------------------------------------------------------------------------
# adaptive delta: the weight-histogram rule
# ---------------------------------------------------------------------------


def test_adaptive_delta_reduces_buckets_on_bimodal_rmat():
    """On an R-MAT graph with bimodal weights (many light edges + a heavy
    long-haul tier) the histogram rule widens delta past the gap: far
    fewer settle steps and buckets, bit-identical distances (any positive
    width is exact at fixpoint)."""
    from repro.graph.generator import rmat_edges
    from repro.traversal.sssp import adaptive_delta, default_delta

    src, dst, n = rmat_edges(6, 24, seed=2)
    rng = np.random.default_rng(2)
    m = len(src)
    w = np.where(rng.random(m) < 0.85,
                 rng.uniform(0.5, 1.0, m), rng.uniform(50.0, 55.0, m))
    wg = from_weighted_edges(src, dst, w, n)
    base = default_delta(wg)
    wide = adaptive_delta(wg)
    assert wide > 4 * base        # the rule found the light/heavy gap

    sources = [1, 2, 5, 9, 17, 33]
    r0 = sssp_pipelined(wg, sources, delta=base, lanes=3)
    r1 = sssp_pipelined(wg, sources, delta=wide, lanes=3)
    assert np.array_equal(np.asarray(r0.dist), np.asarray(r1.dist))
    assert not np.asarray(r1.truncated).any()

    def settle_steps(r):
        return int((np.asarray(r.trace_phase) == 1).sum())

    # measured on this seed: 24 -> 12 settle steps, max bucket 45 -> 7
    assert 2 * settle_steps(r1) <= settle_steps(r0)
    assert (np.asarray(r1.trace_bucket).max()
            < np.asarray(r0.trace_bucket).max())


def test_adaptive_delta_unimodal_falls_back_and_broadcasts():
    """Unimodal weights show no dominant gap: the rule returns
    ``default_delta`` unchanged; ``lanes=k`` broadcasts to a k-tuple."""
    from repro.traversal.sssp import adaptive_delta, default_delta

    wg, _, _ = build_case(40, 120, 0, "random", "uniform", False)
    base = default_delta(wg)
    assert adaptive_delta(wg) == base
    assert adaptive_delta(wg, lanes=4) == (base,) * 4


def test_per_lane_tuple_delta_matches_scalar_lanes():
    """A per-lane delta tuple runs each lane exactly as a scalar run
    with that width would: lane columns are independent (every bucket
    decision is columnwise), so the batched run is bit-equal per lane."""
    wg, sources, _ = build_case(40, 120, 8, "random", "uniform", False)
    sources = np.asarray(sources[:2], np.int32)
    widths = (0.25, 2.0)
    both = sssp_pipelined(wg, sources, delta=widths, lanes=2)
    for i, d in enumerate(widths):
        solo = sssp_pipelined(wg, sources[i:i + 1], delta=d, lanes=1)
        assert np.array_equal(np.asarray(both.dist[:, i]),
                              np.asarray(solo.dist[:, 0])), i
        assert int(both.steps[i]) == int(solo.steps[0]), i
        assert np.array_equal(np.asarray(both.trace_bucket[:, i]),
                              np.asarray(solo.trace_bucket[:, 0])), i
        assert np.array_equal(np.asarray(both.trace_phase[:, i]),
                              np.asarray(solo.trace_phase[:, 0])), i
