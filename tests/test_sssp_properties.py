"""Property-based delta-stepping suite: every tropical lane == Dijkstra.

Randomized weighted graphs — disconnected components, zero-weight edges,
duplicate/parallel edges, isolated sources, star/path shapes, adversarial
bucket widths — are swept with hypothesis (importorskip-guarded, the
``test_msbfs_properties`` pattern) through the pipelined SSSP engine with
a lane pool SMALLER than the source count, so every example exercises
queue refill mid-sweep.

Each lane must reproduce the binary-heap Dijkstra oracle
(``traversal.ref.dijkstra_reference``): identical reached sets, distances
equal to float32 accumulation tolerance. Unit-weight examples are
additionally pinned BIT-IDENTICAL to ``msbfs_pipelined`` depths — the
boolean-semiring anchor. A deterministic fallback case set always runs
and the hypothesis profile is derandomized (fixed seed, bounded examples)
so ``make test-properties`` stays reproducible in CI.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csr import from_weighted_edges
from repro.core.msbfs import msbfs_pipelined
from repro.traversal import (dijkstra_reference, sssp_pipelined,
                             to_numpy_weighted)

MAX_EXAMPLES = int(os.environ.get("MSBFS_PROP_EXAMPLES", "10"))

SHAPES = ("random", "star", "path", "two_components")
WEIGHT_MODELS = ("uniform", "unit", "with_zeros", "integer")


def build_case(n: int, m: int, seed: int, shape: str, weight_model: str,
               dup_edges: bool):
    """Build (weighted graph, sources, delta) for one property example.

    Sources are drawn from ALL vertices — isolated (degree-0) sources
    included. ``delta`` is drawn adversarially around the weight scale so
    all-light, all-heavy and mixed bucket splits are all exercised.
    """
    rng = np.random.default_rng(seed)
    if shape == "star":
        src = np.zeros(max(n - 1, 1), np.int64)
        dst = np.arange(1, max(n, 2), dtype=np.int64)
    elif shape == "path":
        ln = min(n, 40)
        src = np.arange(ln - 1, dtype=np.int64)
        dst = src + 1
    elif shape == "two_components":
        h = max(n // 2, 2)
        s1 = rng.integers(0, h, max(m // 2, 1))
        d1 = rng.integers(0, h, max(m // 2, 1))
        s2 = rng.integers(h, n, max(m // 2, 1)) if n > h else s1
        d2 = rng.integers(h, n, max(m // 2, 1)) if n > h else d1
        src = np.concatenate([s1, s2])
        dst = np.concatenate([d1, d2])
    else:  # random G(n, m) with repetition
        src = rng.integers(0, n, max(m, 1))
        dst = rng.integers(0, n, max(m, 1))
    if dup_edges and len(src):
        take = rng.integers(0, len(src), max(len(src) // 2, 1))
        src = np.concatenate([src, src[take]])
        dst = np.concatenate([dst, dst[take]])

    if weight_model == "unit":
        w = np.ones(len(src))
    elif weight_model == "with_zeros":
        w = rng.uniform(0.0, 1.0, len(src))
        w[rng.random(len(src)) < 0.3] = 0.0
    elif weight_model == "integer":
        w = rng.integers(0, 5, len(src)).astype(np.float64)
    else:
        w = rng.uniform(0.0, 1.0, len(src))

    wg = from_weighted_edges(src, dst, w, n, symmetrize=True,
                             drop_self_loops=True)
    num_src = min(n, int(rng.integers(2, 7)))
    sources = rng.choice(n, size=num_src, replace=False)
    # adversarial bucket widths: below/at/above the weight scale
    delta = float(rng.choice([0.05, 0.5, 1.0, 7.0]))
    return wg, sources, delta


def _check_case(n, m, seed, shape, weight_model, dup_edges):
    wg, sources, delta = build_case(n, m, seed, shape, weight_model,
                                    dup_edges)
    lanes = max(1, len(sources) // 2)        # queue refill is exercised
    res = sssp_pipelined(wg, sources, delta=delta, lanes=lanes)
    rp, ci, w = to_numpy_weighted(wg)
    for i, r in enumerate(sources):
        ref = dijkstra_reference(rp, ci, w, int(r))
        got = np.asarray(res.dist[:, i], np.float64)
        np.testing.assert_array_equal(
            np.isfinite(got), np.isfinite(ref),
            err_msg=f"lane {i} (root {r}) reached set, delta={delta}")
        fin = np.isfinite(ref)
        np.testing.assert_allclose(
            got[fin], ref[fin], atol=1e-4,
            err_msg=f"lane {i} (root {r}) distances, delta={delta}")
    if weight_model == "unit":
        # the boolean-semiring anchor on fuzzed shapes: distance == depth
        mres = msbfs_pipelined(wg.csr, jnp.asarray(sources, jnp.int32),
                               "hybrid", lanes=max(1, len(sources) // 2))
        np.testing.assert_array_equal(np.asarray(res.as_depth()),
                                      np.asarray(mres.depth))


def test_property_sssp_random_graphs():
    """Hypothesis sweep — skipped without hypothesis (the deterministic
    fallback below pins the same invariants). Derandomized: fixed seed,
    MSBFS_PROP_EXAMPLES bounds the example count (CI sets it)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=MAX_EXAMPLES, deadline=None, derandomize=True)
    @given(st.integers(4, 70), st.integers(1, 220), st.integers(0, 10 ** 6),
           st.sampled_from(SHAPES), st.sampled_from(WEIGHT_MODELS),
           st.booleans())
    def inner(n, m, seed, shape, weight_model, dup_edges):
        _check_case(n, m, seed, shape, weight_model, dup_edges)

    inner()


DETERMINISTIC_CASES = [
    # n, m, seed, shape, weight_model, dup_edges
    (40, 120, 0, "random", "uniform", False),
    (33, 50, 1, "random", "with_zeros", True),   # zero weights + dup edges
    (60, 10, 2, "random", "uniform", False),     # sparse -> isolated sources
    (25, 0, 3, "star", "integer", False),        # integer (tie-heavy) weights
    (44, 0, 4, "path", "uniform", True),         # deep chains of light edges
    (30, 0, 5, "path", "unit", False),           # unit weights == BFS anchor
    (48, 80, 6, "two_components", "uniform", False),
    (36, 90, 7, "random", "unit", True),         # unit anchor, messy graph
]


@pytest.mark.parametrize("n,m,seed,shape,weight_model,dup_edges",
                         DETERMINISTIC_CASES)
def test_deterministic_sssp_cases(n, m, seed, shape, weight_model,
                                  dup_edges):
    """Fixed fallback case set for the property above — always runs."""
    _check_case(n, m, seed, shape, weight_model, dup_edges)


def test_isolated_source_answers_immediately():
    """A degree-0 source's lane reaches exactly itself at distance 0."""
    wg = from_weighted_edges(np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]),
                             np.array([0.3, 0.1, 0.7, 0.2]), 6)
    res = sssp_pipelined(wg, [5, 0], lanes=1)
    d = np.asarray(res.dist[:, 0])
    assert d[5] == 0.0 and not np.isfinite(np.delete(d, 5)).any()
    rp, ci, w = to_numpy_weighted(wg)
    ref = dijkstra_reference(rp, ci, w, 0)
    np.testing.assert_allclose(np.asarray(res.dist[:5, 1]), ref[:5],
                               atol=1e-6)
