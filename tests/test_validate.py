"""Graph500 validator internals: the `_edges_exist` overflow guard.

The dense-key membership test encodes an edge as ``src * n + dst`` in
int64; for ``n > floor(sqrt(2**63 - 1))`` the multiplication wraps
SILENTLY and the validator would accept/reject edges at random on huge
synthetic id spaces (fuzzed inputs). `_edges_exist` now dispatches to an
overflow-safe per-row bisect above `_DENSE_KEY_N_MAX`; these tests pin the
threshold, the parity of both paths, and the dispatch itself.
"""
import numpy as np
import pytest

from repro.core.csr import to_numpy_adj
from repro.graph.generator import rmat_graph, uniform_random_graph
from repro.graph import validate as V


def _query_set(g, seed, k=200):
    """Mixed present/absent (u, v) queries + ground truth from adj sets."""
    rp, ci = to_numpy_adj(g)
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(g.n), np.diff(rp))
    present = rng.integers(0, len(ci), k // 2)
    u = np.concatenate([src[present], rng.integers(0, g.n, k // 2)])
    v = np.concatenate([ci[present], rng.integers(0, g.n, k // 2)])
    adj = {(int(a), int(b)) for a, b in zip(src, ci)}
    truth = np.array([(int(a), int(b)) in adj for a, b in zip(u, v)])
    return rp, ci, u.astype(np.int64), v.astype(np.int64), truth


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dense_key_and_bisect_agree(seed):
    g = uniform_random_graph(300, 1500, seed=seed)
    rp, ci, u, v, truth = _query_set(g, seed)
    dense = V._edges_exist_dense_key(rp, ci, u, v)
    bisect = V._edges_exist_bisect(rp, ci, u, v)
    np.testing.assert_array_equal(dense, truth)
    np.testing.assert_array_equal(bisect, truth)


def test_bisect_handles_empty_rows_and_graph():
    # rows: [1, 3], [], [0] -> trailing/interior empty rows + empty graph
    rp = np.array([0, 2, 2, 3])
    ci = np.array([1, 3, 0])
    u = np.array([0, 0, 1, 2, 2])
    v = np.array([1, 2, 0, 0, 3])
    np.testing.assert_array_equal(
        V._edges_exist_bisect(rp, ci, u, v), [True, False, False, True,
                                              False])
    rp0 = np.zeros(4, np.int64)
    np.testing.assert_array_equal(
        V._edges_exist_bisect(rp0, np.array([], np.int64), u[:2], v[:2]),
        [False, False])


def test_dispatch_threshold_is_maximal():
    """_DENSE_KEY_N_MAX is exactly the largest n whose max key n*n-1 fits
    int64 — one more and the dense key silently wraps."""
    t = V._DENSE_KEY_N_MAX
    assert t * t - 1 <= np.iinfo(np.int64).max          # python ints: exact
    assert (t + 1) * (t + 1) - 1 > np.iinfo(np.int64).max
    # demonstrate the silent wrap the guard prevents: the same product in
    # int64 comes out negative (and two DISTINCT edges can collide)
    with np.errstate(over="ignore"):
        wrapped = np.int64(t + 1) * np.int64(t + 1)
    assert wrapped != (t + 1) * (t + 1)


def test_dispatch_routes_huge_n_to_bisect(monkeypatch):
    """Above the threshold `_edges_exist` must use the bisect path; forced
    via a lowered threshold since a real >3e9-vertex CSR will not fit."""
    g = rmat_graph(8, 4, seed=3)
    rp, ci, u, v, truth = _query_set(g, 3)
    np.testing.assert_array_equal(V._edges_exist(rp, ci, u, v), truth)
    monkeypatch.setattr(V, "_DENSE_KEY_N_MAX", 4)
    np.testing.assert_array_equal(V._edges_exist(rp, ci, u, v), truth)
    # and the validator end-to-end still works through the bisect path
    from repro.core.ref import bfs_reference
    root = int(np.flatnonzero(np.diff(rp) > 0)[0])
    parent, _ = bfs_reference(rp, ci, root)
    V.validate_bfs_tree(rp, ci, parent, root)
