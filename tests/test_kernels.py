"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap
from repro.core.csr import ell_pad, to_numpy_adj
from repro.graph.generator import rmat_graph, uniform_random_graph
from repro.kernels import (bottom_up_probe_pallas, bottom_up_probe_ref,
                           ell_spmm_pallas, ell_spmm_ref, msbfs_probe_pallas,
                           msbfs_probe_ref, spmm_aggregate,
                           topdown_scan_pallas, topdown_scan_ref)


@pytest.mark.parametrize("scale,ef,seed", [(8, 4, 0), (9, 8, 1), (10, 16, 2),
                                           (7, 32, 3)])
@pytest.mark.parametrize("max_pos", [1, 8])
def test_bottom_up_probe_sweep(scale, ef, seed, max_pos):
    g = rmat_graph(scale, ef, seed=seed)
    n = g.n
    rng = np.random.default_rng(seed)
    vis = jnp.asarray(rng.random(n) < 0.4)
    fro = jnp.asarray(rng.random(n) < 0.25) & ~vis
    fw = bitmap.pack(fro)
    par = jnp.full((n,), -1, jnp.int32)
    f1, p1 = bottom_up_probe_pallas(g.row_ptr[:-1], g.deg, ~vis, par,
                                    g.col_idx, fw, max_pos=max_pos,
                                    interpret=True)
    f2, p2 = bottom_up_probe_ref(g.row_ptr[:-1], g.deg, ~vis, par,
                                 g.col_idx, fw, max_pos=max_pos)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("lane_words", [1, 2, 4])
@pytest.mark.parametrize("max_pos", [1, 3, 8])
def test_msbfs_probe_lane_word_sweep(lane_words, max_pos):
    """The probe's lane-word count W is a kernel grid parameter: parity
    with the oracle over randomized W (up to 128 roots) and MAX_POS —
    beyond the single-word case the per-plane retirement must not leak
    across planes."""
    g = rmat_graph(8, 4, seed=lane_words * 10 + max_pos)
    rng = np.random.default_rng(lane_words * 100 + max_pos)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, (g.n, lane_words),
                                   dtype=np.uint32))
    need = jnp.asarray(rng.integers(0, 2 ** 32, (g.n, lane_words),
                                    dtype=np.uint32))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=max_pos, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=max_pos)
    assert a1.shape == (g.n, lane_words)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("lane_words", [1, 3])
def test_msbfs_probe_local_block_full_frontier(lane_words):
    """Distributed shape: need covers a LOCAL row block, frontier the full
    vertex range, col_idx global ids (+ sentinel pads) — kernel == oracle.
    This is exactly what dist_msbfs feeds the probe under shard_map."""
    g = rmat_graph(8, 6, seed=lane_words)
    from repro.core.dist_bfs import partition_graph
    dg = partition_graph(g, 2)
    rng = np.random.default_rng(lane_words)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, (dg.n, lane_words),
                                   dtype=np.uint32))
    for d in range(2):
        row_ptr = dg.row_ptr[d]
        starts, deg = row_ptr[:-1], row_ptr[1:] - row_ptr[:-1]
        n_loc = dg.n // 2
        need = jnp.asarray(rng.integers(0, 2 ** 32, (n_loc, lane_words),
                                        dtype=np.uint32))
        a1 = msbfs_probe_pallas(starts, deg, need, dg.col_idx[d], fro,
                                max_pos=4, interpret=True)
        a2 = msbfs_probe_ref(starts, deg, need, dg.col_idx[d], fro,
                             max_pos=4)
        assert a1.shape == (n_loc, lane_words)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_msbfs_probe_flat_plane_compat():
    """uint32[n] single planes still round-trip (W=1 fast path)."""
    g = rmat_graph(7, 8, seed=9)
    rng = np.random.default_rng(9)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    need = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=4, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=4)
    assert a1.shape == (g.n,)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("n,m,seed", [(300, 1200, 0), (1024, 8000, 1),
                                      (77, 300, 2)])
def test_topdown_scan_sweep(n, m, seed):
    g = uniform_random_graph(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    vis = jnp.asarray(rng.random(g.n) < 0.4)
    fro = jnp.asarray(rng.random(g.n) < 0.25) & ~vis
    fw, vw = bitmap.pack(fro), bitmap.pack(vis)
    c1 = topdown_scan_pallas(g.src_idx, g.col_idx, fw, vw, g.n,
                             interpret=True)
    c2 = topdown_scan_ref(g.src_idx, g.col_idx, fw, vw, g.n)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("d", [16, 64, 130])
@pytest.mark.parametrize("k_max", [4, 16])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ell_spmm_sweep(d, k_max, dtype):
    g = uniform_random_graph(500, 3000, seed=d + k_max)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n, d), dtype)
    neigh, valid = ell_pad(g, k_max)
    y1 = ell_spmm_pallas(neigh, valid, x, interpret=True)
    y2 = ell_spmm_ref(neigh, valid, x)
    # kernel accumulates per tile — f32 reassociation vs the flat ref sum
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_spmm_aggregate_exact_vs_dense():
    g = uniform_random_graph(200, 2000, seed=5)
    rp, ci = to_numpy_adj(g)
    x = jax.random.normal(jax.random.PRNGKey(1), (g.n, 32))
    y = spmm_aggregate(g, x, k_max=8)
    xs = np.asarray(x)
    ref = np.zeros((g.n, 32), np.float32)
    for v in range(g.n):
        for u in ci[rp[v]:rp[v + 1]]:
            ref[v] += xs[u]
    np.testing.assert_allclose(np.asarray(y), ref, rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# u64 gather path: 64-bit lane words through the uint32 probe kernel
# --------------------------------------------------------------------------

def _require_x64():
    if not jax.config.jax_enable_x64:
        pytest.skip("u64 lane-word planes need jax x64 (JAX_ENABLE_X64=1 — "
                    "the tier1-u64 CI leg runs these without skips)")


def test_u64_split_merge_round_trip():
    """split_u64_words/merge_u64_words are exact inverses and OR commutes
    with the split — the identity the u64 gather path rests on."""
    _require_x64()
    from repro.kernels.common import merge_u64_words, split_u64_words
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 2 ** 64, (33, 3), dtype=np.uint64))
    b = jnp.asarray(rng.integers(0, 2 ** 64, (33, 3), dtype=np.uint64))
    assert split_u64_words(a).dtype == jnp.uint32
    assert split_u64_words(a).shape == (33, 6)
    np.testing.assert_array_equal(np.asarray(merge_u64_words(
        split_u64_words(a))), np.asarray(a))
    np.testing.assert_array_equal(
        np.asarray(merge_u64_words(split_u64_words(a) | split_u64_words(b))),
        np.asarray(a | b))


@pytest.mark.parametrize("lane_words", [1, 2, 3])
@pytest.mark.parametrize("max_pos", [1, 4, 8])
def test_msbfs_probe_u64_lane_word_sweep(lane_words, max_pos):
    """kernel == oracle at uint64[n, W] word planes (each plane gathered
    as hi/lo uint32 half-planes): up to 192 roots per probe call."""
    _require_x64()
    g = rmat_graph(8, 4, seed=lane_words * 7 + max_pos)
    rng = np.random.default_rng(lane_words * 70 + max_pos)
    fro = jnp.asarray(rng.integers(0, 2 ** 64, (g.n, lane_words),
                                   dtype=np.uint64))
    need = jnp.asarray(rng.integers(0, 2 ** 64, (g.n, lane_words),
                                    dtype=np.uint64))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=max_pos, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=max_pos)
    assert a1.dtype == jnp.uint64 and a1.shape == (g.n, lane_words)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_msbfs_probe_u64_matches_op_semantics():
    """The masked probe result (acc & need) at u64 equals the 32-bit probe
    run twice over the (lo, hi) word halves — the op-level contract the
    engines consume is word-width invariant."""
    _require_x64()
    from repro.kernels.common import merge_u64_words, split_u64_words
    g = rmat_graph(7, 6, seed=3)
    rng = np.random.default_rng(3)
    fro = jnp.asarray(rng.integers(0, 2 ** 64, (g.n, 2), dtype=np.uint64))
    need = jnp.asarray(rng.integers(0, 2 ** 64, (g.n, 2), dtype=np.uint64))
    wide = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                              max_pos=4, interpret=True) & need
    halves = msbfs_probe_pallas(
        g.row_ptr[:-1], g.deg, split_u64_words(need), g.col_idx,
        split_u64_words(fro), max_pos=4,
        interpret=True) & split_u64_words(need)
    np.testing.assert_array_equal(np.asarray(wide),
                                  np.asarray(merge_u64_words(halves)))
