"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap
from repro.core.csr import ell_pad, to_numpy_adj
from repro.graph.generator import rmat_graph, uniform_random_graph
from repro.kernels import (bottom_up_probe_pallas, bottom_up_probe_ref,
                           ell_spmm_pallas, ell_spmm_ref, msbfs_probe_pallas,
                           msbfs_probe_ref, spmm_aggregate,
                           topdown_scan_pallas, topdown_scan_ref)


@pytest.mark.parametrize("scale,ef,seed", [(8, 4, 0), (9, 8, 1), (10, 16, 2),
                                           (7, 32, 3)])
@pytest.mark.parametrize("max_pos", [1, 8])
def test_bottom_up_probe_sweep(scale, ef, seed, max_pos):
    g = rmat_graph(scale, ef, seed=seed)
    n = g.n
    rng = np.random.default_rng(seed)
    vis = jnp.asarray(rng.random(n) < 0.4)
    fro = jnp.asarray(rng.random(n) < 0.25) & ~vis
    fw = bitmap.pack(fro)
    par = jnp.full((n,), -1, jnp.int32)
    f1, p1 = bottom_up_probe_pallas(g.row_ptr[:-1], g.deg, ~vis, par,
                                    g.col_idx, fw, max_pos=max_pos,
                                    interpret=True)
    f2, p2 = bottom_up_probe_ref(g.row_ptr[:-1], g.deg, ~vis, par,
                                 g.col_idx, fw, max_pos=max_pos)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("lane_words", [1, 2, 4])
@pytest.mark.parametrize("max_pos", [1, 3, 8])
def test_msbfs_probe_lane_word_sweep(lane_words, max_pos):
    """The probe's lane-word count W is a kernel grid parameter: parity
    with the oracle over randomized W (up to 128 roots) and MAX_POS —
    beyond the single-word case the per-plane retirement must not leak
    across planes."""
    g = rmat_graph(8, 4, seed=lane_words * 10 + max_pos)
    rng = np.random.default_rng(lane_words * 100 + max_pos)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, (g.n, lane_words),
                                   dtype=np.uint32))
    need = jnp.asarray(rng.integers(0, 2 ** 32, (g.n, lane_words),
                                    dtype=np.uint32))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=max_pos, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=max_pos)
    assert a1.shape == (g.n, lane_words)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("lane_words", [1, 3])
def test_msbfs_probe_local_block_full_frontier(lane_words):
    """Distributed shape: need covers a LOCAL row block, frontier the full
    vertex range, col_idx global ids (+ sentinel pads) — kernel == oracle.
    This is exactly what dist_msbfs feeds the probe under shard_map."""
    g = rmat_graph(8, 6, seed=lane_words)
    from repro.core.dist_bfs import partition_graph
    dg = partition_graph(g, 2)
    rng = np.random.default_rng(lane_words)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, (dg.n, lane_words),
                                   dtype=np.uint32))
    for d in range(2):
        row_ptr = dg.row_ptr[d]
        starts, deg = row_ptr[:-1], row_ptr[1:] - row_ptr[:-1]
        n_loc = dg.n // 2
        need = jnp.asarray(rng.integers(0, 2 ** 32, (n_loc, lane_words),
                                        dtype=np.uint32))
        a1 = msbfs_probe_pallas(starts, deg, need, dg.col_idx[d], fro,
                                max_pos=4, interpret=True)
        a2 = msbfs_probe_ref(starts, deg, need, dg.col_idx[d], fro,
                             max_pos=4)
        assert a1.shape == (n_loc, lane_words)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_msbfs_probe_flat_plane_compat():
    """uint32[n] single planes still round-trip (W=1 fast path)."""
    g = rmat_graph(7, 8, seed=9)
    rng = np.random.default_rng(9)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    need = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=4, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=4)
    assert a1.shape == (g.n,)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("n,m,seed", [(300, 1200, 0), (1024, 8000, 1),
                                      (77, 300, 2)])
def test_topdown_scan_sweep(n, m, seed):
    g = uniform_random_graph(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    vis = jnp.asarray(rng.random(g.n) < 0.4)
    fro = jnp.asarray(rng.random(g.n) < 0.25) & ~vis
    fw, vw = bitmap.pack(fro), bitmap.pack(vis)
    c1 = topdown_scan_pallas(g.src_idx, g.col_idx, fw, vw, g.n,
                             interpret=True)
    c2 = topdown_scan_ref(g.src_idx, g.col_idx, fw, vw, g.n)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("d", [16, 64, 130])
@pytest.mark.parametrize("k_max", [4, 16])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ell_spmm_sweep(d, k_max, dtype):
    g = uniform_random_graph(500, 3000, seed=d + k_max)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n, d), dtype)
    neigh, valid = ell_pad(g, k_max)
    y1 = ell_spmm_pallas(neigh, valid, x, interpret=True)
    y2 = ell_spmm_ref(neigh, valid, x)
    # kernel accumulates per tile — f32 reassociation vs the flat ref sum
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_spmm_aggregate_exact_vs_dense():
    g = uniform_random_graph(200, 2000, seed=5)
    rp, ci = to_numpy_adj(g)
    x = jax.random.normal(jax.random.PRNGKey(1), (g.n, 32))
    y = spmm_aggregate(g, x, k_max=8)
    xs = np.asarray(x)
    ref = np.zeros((g.n, 32), np.float32)
    for v in range(g.n):
        for u in ci[rp[v]:rp[v + 1]]:
            ref[v] += xs[u]
    np.testing.assert_allclose(np.asarray(y), ref, rtol=3e-5, atol=3e-5)
