"""Live observability plane tests (``repro.obs.server`` + wire codec).

The ObservabilityServer is the ROADMAP's "real socket/HTTP transport"
rung, so the bar is parity: answers fetched over ``/v1/submit`` ->
``/v1/poll`` -> ``/v1/result`` must decode BIT-identical to in-process
``run_query``. Around that: the result wire codec round-trips every
typed result (uint64 frontier words, float inf distances, bools — raw
little-endian bytes, no decimal detour), ``/metrics`` scrapes valid
Prometheus text mid-run with monotone counters, ``/healthz`` flips
unhealthy the moment the worker stops, ``/readyz`` tracks the SLO
monitor, and the error paths answer the right codes (400/404/202/409).
"""
import dataclasses
import json
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.analytics import (BFSQuery, ClosenessQuery, ComponentsQuery,
                             DiameterQuery, KHopQuery, LaneEngine,
                             ReachQuery, SSSPQuery, run_query)
from repro.analytics.api import (AnalyticsAnswer, AnalyticsRequest,
                                 result_from_wire, result_to_wire)
from repro.graph.generator import rmat_weighted_graph
from repro.obs import ObservabilityServer, SLOConfig, Telemetry
from repro.serving import DONE, QUEUED, REJECTED, AnalyticsService


@pytest.fixture(scope="module")
def wg():
    """Weighted R-MAT graph: serves every query kind incl. sssp."""
    return rmat_weighted_graph(8, 8, seed=3)


@pytest.fixture(scope="module")
def offline(wg):
    return LaneEngine(wg)


# ---------------------------------------------------------------------------
# HTTP plumbing (stdlib client — the server must need nothing more)
# ---------------------------------------------------------------------------


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=60) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def _get_json(url):
    code, body = _get(url)
    return code, json.loads(body)


def _post_json(url, payload):
    req = urllib.request.Request(
        url, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _wait_done(base, request_id, timeout=120.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        code, body = _get_json(f"{base}/v1/poll/{request_id}")
        assert code == 200, body
        if body["status"] == DONE:
            return
        assert body["status"] != REJECTED, body
        time.sleep(0.05)
    raise TimeoutError(f"{request_id} never reached DONE")


def _counter_total(text, name):
    """Sum a counter over its label series in Prometheus text."""
    total = 0.0
    for line in text.splitlines():
        head, _, val = line.rpartition(" ")
        if head == name or head.startswith(name + "{"):
            total += float(val)
    return total


def _assert_results_equal(got, ref, *, check_meta=True):
    assert type(got) is type(ref)
    for f in dataclasses.fields(ref):
        a, b = getattr(got, f.name), getattr(ref, f.name)
        if f.name == "meta":
            if check_meta:
                assert a.as_dict() == b.as_dict()
            continue
        if isinstance(b, np.ndarray):
            assert isinstance(a, np.ndarray), f.name
            assert a.dtype == b.dtype, f.name
            assert a.shape == b.shape, f.name
            np.testing.assert_array_equal(a, b, err_msg=f.name)
        else:
            assert a == b, f.name


# ---------------------------------------------------------------------------
# result wire codec — every typed result round-trips bit-identical
# ---------------------------------------------------------------------------


def test_result_wire_codec_round_trips_every_kind(wg, offline):
    queries = [
        BFSQuery(sources=(0, 3, 5)),
        KHopQuery(sources=(1, 2), k=2),            # uint lane words
        ReachQuery(sources=(0, 1), targets=(2, 3)),
        ClosenessQuery(sources=(0, 1, 2, 3), chunk=4),
        SSSPQuery(sources=(0, 4)),                 # float dist incl. inf
        ComponentsQuery(batch=32),
        DiameterQuery(num_seeds=2, seed=0),
    ]
    for q in queries:
        ref = run_query(offline, q)
        # through real JSON text, exactly like the HTTP body
        wire = json.loads(json.dumps(result_to_wire(ref)))
        back = result_from_wire(wire)
        _assert_results_equal(back, ref)
    # inf distances must survive (raw bytes, not decimal text)
    sssp = run_query(offline, SSSPQuery(sources=(0,)))
    if np.isinf(sssp.dist).any():
        back = result_from_wire(json.loads(json.dumps(result_to_wire(sssp))))
        np.testing.assert_array_equal(back.dist, sssp.dist)
    with pytest.raises(TypeError, match="unknown result type"):
        result_to_wire(object())
    with pytest.raises(ValueError, match="unknown result type"):
        result_from_wire({"type": "NopeResult"})


# ---------------------------------------------------------------------------
# the live plane: wire parity + mid-run scrape + debug surfaces
# ---------------------------------------------------------------------------


def test_live_wire_round_trip_scrape_and_debug(wg, offline):
    tel = Telemetry()
    svc = AnalyticsService(wg, streaming=False, telemetry=tel,
                           slo=SLOConfig(p99_sojourn_layers=1e9))
    queries = dict(
        khop=KHopQuery(sources=(1, 2), k=2),
        reach=ReachQuery(sources=(0, 1), targets=(2, 3)),
        sssp=SSSPQuery(sources=(0, 4)),
    )
    with svc, ObservabilityServer(svc) as obs:
        base = obs.url
        code, before = _get(f"{base}/metrics")
        assert code == 200
        code, ready = _get_json(f"{base}/readyz")
        assert code == 200 and ready["ready"] and ready["alive"]
        assert ready["slo"]["healthy"]

        # submit through the front door, by wire envelope
        for name, q in queries.items():
            env = AnalyticsRequest(query=q, id=f"wire-{name}", tenant="t")
            code, body = _post_json(f"{base}/v1/submit", env.to_wire())
            assert code == 200, body
            assert body["id"] == env.id and body["status"] == QUEUED
        for name in queries:
            _wait_done(base, f"wire-{name}")

        # answers over the wire decode bit-identical to run_query
        for name, q in queries.items():
            code, wire = _get_json(f"{base}/v1/result/wire-{name}")
            assert code == 200, wire
            ans = AnalyticsAnswer.from_wire(wire)
            assert ans.id == f"wire-{name}"
            assert ans.meta is ans.result.meta
            _assert_results_equal(ans.result, run_query(offline, q),
                                  check_meta=False)

        # mid-run scrape: still valid Prometheus text, counters monotone
        code, after = _get(f"{base}/metrics")
        assert code == 200
        assert "# TYPE service_requests_total counter" in after
        assert "service_sojourn_layers" in after
        reqs_before = _counter_total(before, "service_requests_total")
        reqs_after = _counter_total(after, "service_requests_total")
        assert reqs_after >= reqs_before + len(queries)
        assert _counter_total(after, "http_requests_total") > 0
        # path labels stay normalized — no per-id series
        assert 'path="/v1/poll"' in after and "wire-khop" not in after

        # debug surfaces: request lifecycles + recorded sweeps
        code, views = _get_json(f"{base}/debug/requests")
        assert code == 200
        by_id = {v["id"]: v for v in views}
        for name in queries:
            assert by_id[f"wire-{name}"]["status"] == DONE
            assert by_id[f"wire-{name}"]["sojourn"] >= 1
        code, sweeps = _get_json(f"{base}/debug/sweeps")
        assert code == 200 and sweeps
        assert "records" not in sweeps[0]
        code, full = _get_json(f"{base}/debug/sweeps?full=1")
        assert code == 200
        assert full[0]["records"], "full=1 must inline the LayerRecords"
        assert {"layer", "mode", "active_lanes"} <= set(
            full[0]["records"][0])


def test_healthz_flips_unhealthy_after_stop(wg):
    svc = AnalyticsService(wg)
    svc.start()
    with ObservabilityServer(svc) as obs:
        code, h = _get_json(f"{obs.url}/healthz")
        assert code == 200 and h["alive"] and not h["stopping"]
        svc.stop()
        # the HTTP plane outlives the worker — that is the point of a
        # liveness probe: it must answer 503, not refuse the connection
        code, h = _get_json(f"{obs.url}/healthz")
        assert code == 503 and not h["alive"]
        code, h = _get_json(f"{obs.url}/readyz")
        assert code == 503 and not h["ready"]


def test_readyz_tracks_slo_breach(wg, offline):
    # every sojourn is >= 1 layer, so a 0.5-layer p99 target breaches on
    # the first answered request — deterministically
    svc = AnalyticsService(wg, slo=SLOConfig(p99_sojourn_layers=0.5))
    with svc, ObservabilityServer(svc) as obs:
        rec = svc.submit(KHopQuery(sources=(5,), k=1))
        svc.result(rec.request.id, timeout=120.0)
        code, h = _get_json(f"{obs.url}/healthz")
        assert code == 200, "liveness is not readiness"
        code, h = _get_json(f"{obs.url}/readyz")
        assert code == 503 and h["alive"] and not h["ready"]
        slo = h["slo"]
        assert not slo["healthy"]
        assert not slo["healthy_per_target"]["p99_sojourn_layers"]
        assert slo["observed"]["p99_sojourn_layers"] >= 1
        code, text = _get(f"{obs.url}/metrics")
        assert 'slo_breaches_total{slo="p99_sojourn_layers"} 1' in text
        assert "slo_healthy 0" in text


def test_error_paths(wg):
    # worker NOT started: submissions stay QUEUED, so the pending (202)
    # and rejected (409) result paths are deterministic
    svc = AnalyticsService(wg, max_pending=1)
    with ObservabilityServer(svc) as obs:
        base = obs.url
        code, body = _get_json(f"{base}/nope")
        assert code == 404 and "no route" in body["error"]
        code, body = _get_json(f"{base}/v1/poll/ghost")
        assert code == 404
        code, body = _get_json(f"{base}/v1/result/ghost")
        assert code == 404
        code, body = _post_json(f"{base}/v1/submit",
                                {"kind": "nope", "query": {}})
        assert code == 400 and "unknown query tag" in body["error"]

        env = AnalyticsRequest(query=KHopQuery(sources=(0,), k=1), id="q1")
        code, body = _post_json(f"{base}/v1/submit", env.to_wire())
        assert code == 200 and body["status"] == QUEUED
        code, body = _get_json(f"{base}/v1/result/q1")
        assert code == 202 and body["status"] == QUEUED

        # duplicate id is a client error, not a server crash
        code, body = _post_json(f"{base}/v1/submit", env.to_wire())
        assert code == 400 and "duplicate" in body["error"]

        # queue full: admission rejects, the result route says 409
        env2 = AnalyticsRequest(query=KHopQuery(sources=(1,), k=1), id="q2")
        code, body = _post_json(f"{base}/v1/submit", env2.to_wire())
        assert code == 200 and body["status"] == REJECTED
        assert body["reason"]
        code, body = _get_json(f"{base}/v1/result/q2")
        assert code == 409 and body["status"] == REJECTED
