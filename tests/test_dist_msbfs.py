"""Cross-engine equivalence for the sharded MS-BFS engine.

``dist_msbfs`` at ndev ∈ {1, 2, 4} must equal the single-host pipelined
engine AND serial BFS per lane — depths, parents, num_layers, edge counts
and the per-root TD/BU traces — on the property-suite graph shapes
(random / star / path / disconnected components, with self-loops and
duplicate edges), every lane validator-clean. Multi-device runs execute
in a subprocess with forced host devices (conftest pattern); the
adaptive-pool sizing unit tests run in-process.
"""
import pytest
from conftest import run_in_subprocess

from repro.core import packed
from repro.core.packed import adaptive_lane_pool

CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
import sys
sys.path.insert(0, {testdir!r})
from test_msbfs_properties import build_case
from repro.core.dist_msbfs import partition_graph, dist_msbfs
from repro.core.msbfs import msbfs_pipelined
from repro.core.ref import bfs_reference
from repro.core.csr import to_numpy_adj
from repro.graph.validate import validate_bfs_tree

CASES = [  # n, m, seed, shape, self_loops, dup_edges
    (40, 120, 0, "random", False, False),
    (33, 50, 1, "random", True, True),
    (25, 0, 3, "star", True, False),
    (64, 0, 4, "path", False, True),
    (48, 80, 6, "two_components", False, False),
]
devs = jax.devices()
for n, m, seed, shape, self_loops, dup_edges in CASES:
    g, roots = build_case(n, m, seed, shape, self_loops, dup_edges)
    rp, ci = to_numpy_adj(g)
    roots_j = jnp.asarray(roots, jnp.int32)
    lanes = max(1, len(roots) // 2)   # lanes < R -> queue refill exercised
    host = msbfs_pipelined(g, roots_j, "hybrid", lanes=lanes)
    for ndev in (1, 2, 4):
        dg = partition_graph(g, ndev)
        mesh = Mesh(np.asarray(devs[:ndev]), ("data",))
        out = dist_msbfs(dg, roots_j, mesh, "hybrid", lanes=lanes)
        tag = (shape, seed, ndev)
        for f in ("parent", "depth", "num_layers", "edges_traversed",
                  "trace_dir", "trace_vf", "trace_ef", "trace_eu"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out, f)), np.asarray(getattr(host, f)),
                err_msg=f"{{f}} {{tag}}")
        for i, r in enumerate(roots):
            pref, dref = bfs_reference(rp, ci, int(r))
            np.testing.assert_array_equal(np.asarray(out.depth[:, i]),
                                          dref, err_msg=f"depth {{tag}}")
            np.testing.assert_array_equal(np.asarray(out.parent[:, i]),
                                          pref, err_msg=f"parent {{tag}}")
            validate_bfs_tree(rp, ci, np.asarray(out.parent[:, i]), int(r))
print("DIST_MSBFS_OK")
"""


def test_dist_msbfs_matches_host_engine_and_serial():
    import os
    testdir = os.path.dirname(os.path.abspath(__file__))
    out = run_in_subprocess(CODE.format(testdir=testdir), devices=4)
    assert "DIST_MSBFS_OK" in out


MODES_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.dist_msbfs import partition_graph, dist_msbfs
from repro.core.msbfs import msbfs_pipelined
from repro.graph.generator import rmat_graph, sample_roots

g = rmat_graph(8, 8, seed=2)
roots = jnp.asarray(sample_roots(g, 6, seed=3), jnp.int32)
dg = partition_graph(g, 4)
mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
for mode in ("topdown", "bottomup"):
    out = dist_msbfs(dg, roots, mesh, mode, lanes=4)
    host = msbfs_pipelined(g, roots, mode, lanes=4)
    np.testing.assert_array_equal(np.asarray(out.depth),
                                  np.asarray(host.depth), err_msg=mode)
    np.testing.assert_array_equal(np.asarray(out.parent),
                                  np.asarray(host.parent), err_msg=mode)
# pallas probe through the sharded bottom-up path
out = dist_msbfs(dg, roots, mesh, "hybrid", probe_impl="pallas", lanes=4)
host = msbfs_pipelined(g, roots, "hybrid", probe_impl="pallas", lanes=4)
np.testing.assert_array_equal(np.asarray(out.depth), np.asarray(host.depth))
np.testing.assert_array_equal(np.asarray(out.parent),
                              np.asarray(host.parent))
print("DIST_MODES_OK")
"""


def test_dist_msbfs_forced_modes_and_pallas_probe():
    # at LANE_WORD_BITS=64 the pallas leg takes the u64 gather path —
    # no skip: the tier1-u64 CI leg runs this file end to end
    out = run_in_subprocess(MODES_CODE, devices=4)
    assert "DIST_MODES_OK" in out


STREAM_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.dist_msbfs import (
    partition_graph, dist_msbfs_engine_init, dist_msbfs_engine_enqueue,
    dist_msbfs_engine_step, dist_msbfs_engine_idle,
    dist_msbfs_engine_result)
from repro.core.ref import bfs_reference
from repro.core.csr import to_numpy_adj
from repro.graph.generator import rmat_graph, sample_roots

g = rmat_graph(8, 8, seed=5)
rp, ci = to_numpy_adj(g)
roots = sample_roots(g, 8, seed=6)
dg = partition_graph(g, 2)
mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
state = dist_msbfs_engine_init(dg, mesh, capacity=8, lanes=2)
state = dist_msbfs_engine_enqueue(state, roots[:4])
fed, steps = 4, 0
while fed < 8 or not dist_msbfs_engine_idle(state):
    state = dist_msbfs_engine_step(dg, state, mesh, "hybrid")
    steps += 1
    if steps == 3 and fed < 8:                 # mid-sweep arrivals
        state = dist_msbfs_engine_enqueue(state, roots[4:])
        fed = 8
    assert steps < 500
out = dist_msbfs_engine_result(dg, state, mesh)
for i, r in enumerate(roots):
    pref, dref = bfs_reference(rp, ci, int(r))
    np.testing.assert_array_equal(np.asarray(out.depth[:, i]), dref)
    np.testing.assert_array_equal(np.asarray(out.parent[:, i]), pref)
print("DIST_STREAM_OK")
"""


def test_dist_msbfs_streaming_enqueue_mid_sweep():
    out = run_in_subprocess(STREAM_CODE, devices=4)
    assert "DIST_STREAM_OK" in out


def test_adaptive_lane_pool_rules():
    # full-word granularity, bounded below by one word (the word width
    # follows LANE_WORD_BITS — the u64 CI leg runs these at 64)
    word = packed.LANE_WORD_BITS
    assert adaptive_lane_pool(1, 1000, 4000) == word
    assert adaptive_lane_pool(40, 1000, 100) == 64
    # never (usefully) wider than pending, monotone in pending
    sparse = [adaptive_lane_pool(p, 10_000, 20_000) for p in (8, 64, 500)]
    assert sparse == sorted(sparse)
    assert sparse[-1] == 256                       # sparse earns max_lanes
    # dense graphs cap at the 64-lane default tier
    assert adaptive_lane_pool(500, 10_000, 20 * 10_000) == 64
    # mid-density tier
    assert adaptive_lane_pool(500, 10_000, 8 * 10_000) == 128
    # state budget caps huge graphs regardless of pending
    big = adaptive_lane_pool(10_000, 200_000_000, 16 * 200_000_000,
                             state_budget_bytes=64 << 20)
    assert big == word
    with pytest.raises(ValueError):
        adaptive_lane_pool(4, 0, 0)


def test_adaptive_lane_pool_flows_through_harness():
    """lanes=0/None surfaces: graph500 batched + serve_bfs pick the pool."""
    from repro.graph.generator import rmat_graph
    from repro.graph.graph500 import run_graph500
    g = rmat_graph(8, 8, seed=0)
    res = run_graph500(8, 8, num_roots=16, graph=g, batched=True,
                       lanes=None, warmup=False)
    assert res.lanes == adaptive_lane_pool(16, g.n, g.m)
    assert res.summary()["lanes"] == res.lanes


DIST_BFS_DEPTH_CODE = """
import numpy as np, jax
from repro.core.dist_bfs import partition_graph, dist_bfs
from repro.core.ref import bfs_reference
from repro.core.csr import to_numpy_adj
from repro.graph.generator import rmat_graph, sample_roots

g = rmat_graph(8, 8, seed=1)
rp, ci = to_numpy_adj(g)
dg = partition_graph(g, 4)
mesh = jax.make_mesh((4,), ("data",))
r = int(sample_roots(g, 1, seed=2)[0])
res = dist_bfs(dg, r, mesh, "hybrid")
pref, dref = bfs_reference(rp, ci, r)
np.testing.assert_array_equal(np.asarray(res.parent), pref)
np.testing.assert_array_equal(np.asarray(res.depth), dref)
unreached = np.asarray(res.parent) < 0
assert (np.asarray(res.depth)[unreached] == -1).all()   # MSBFS sentinel
print("DIST_BFS_DEPTH_OK")
"""


def test_dist_bfs_returns_depth_with_msbfs_sentinel():
    out = run_in_subprocess(DIST_BFS_DEPTH_CODE, devices=4)
    assert "DIST_BFS_DEPTH_OK" in out
