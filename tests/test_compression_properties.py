"""Property suite for the frontier-word compression codec
(``repro.distributed.compression``): the sparse (index, payload) wire
format must round-trip EXACTLY whenever the nonzero count fits the slot
budget — the 2-D exchange's correctness rests on it (a lossy codec would
silently drop frontier bits and corrupt traversals, not crash).

Hypothesis sweeps arbitrary word arrays (importorskip-guarded, the PR-1
pattern), with a deterministic fallback case set that always runs:
empty/all-zero slices, all-ones (maximum density), counts exactly AT the
budget boundary, single-word slices, and both word widths. The adversarial
density direction — count OVER budget — must be detected via the returned
count (callers then ship dense), never mis-decoded silently.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (DENSE_THRESHOLD, compress_words,
                                           decompress_words, sparse_budget,
                                           wire_bytes, words_nnz)

MAX_EXAMPLES = int(os.environ.get("MSBFS_PROP_EXAMPLES", "10"))


def _round_trip_case(flat: np.ndarray, budget: int):
    """Check one (array, budget) case against every codec invariant."""
    total = flat.size
    words = jnp.asarray(flat)
    idx, payload, count = compress_words(words, budget)
    nnz = int((flat != 0).sum())
    assert int(count) == nnz == int(words_nnz(words))
    assert idx.shape == payload.shape == (budget,)
    if nnz <= budget:
        # exact round-trip: the wire format loses nothing
        out = decompress_words(idx, payload, total)
        np.testing.assert_array_equal(np.asarray(out), flat)
        # sparse slots beyond count are OR-identity pads
        assert (np.asarray(idx)[nnz:] == 0).all()
        assert (np.asarray(payload)[nnz:] == 0).all()
        # indices ascending -> deterministic wire format
        assert (np.diff(np.asarray(idx)[:nnz]) > 0).all()
    else:
        # over budget: the codec REPORTS it (count > budget) so callers
        # ship dense; the truncated buffer still decodes to a subset
        assert int(count) > budget
        out = np.asarray(decompress_words(idx, payload, total))
        nz = out != 0
        np.testing.assert_array_equal(out[nz], flat[nz])
    # byte accounting follows the same switch
    itemsize = flat.dtype.itemsize
    b = int(wire_bytes(count, total, budget, itemsize))
    if nnz <= budget:
        assert b == 4 + nnz * (4 + itemsize)
    else:
        assert b == total * itemsize


def test_property_compression_round_trip():
    """Hypothesis sweep over arbitrary uint32 word arrays and budgets —
    skipped without hypothesis (deterministic fallbacks below pin the
    same invariants). Derandomized + bounded, as in the MS-BFS suite."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=MAX_EXAMPLES, deadline=None, derandomize=True)
    @given(st.integers(1, 200), st.integers(0, 10 ** 6),
           st.floats(0.0, 1.0), st.integers(0, 3))
    def inner(total, seed, density, budget_sel):
        rng = np.random.default_rng(seed)
        flat = np.where(rng.random(total) < density,
                        rng.integers(1, 2 ** 32, total, dtype=np.uint64),
                        0).astype(np.uint32)
        budgets = sorted({1, max(1, total // 4), max(1, total // 2), total})
        _round_trip_case(flat, budgets[min(budget_sel, len(budgets) - 1)])

    inner()


DETERMINISTIC_CASES = [
    # (total, nnz, budget) — nnz nonzero words scattered deterministically
    (16, 0, 4),      # empty slice: count 0, all-pad buffer
    (16, 16, 4),     # all-ones: maximum density, must report over-budget
    (16, 4, 4),      # count EXACTLY at the budget boundary (sparse side)
    (16, 5, 4),      # one past the boundary (dense side)
    (1, 1, 1),       # single-word slice
    (1, 0, 1),
    (128, 32, 32),   # at the DENSE_THRESHOLD=0.25 budget exactly
    (200, 1, 50),    # lone nonzero word
]


@pytest.mark.parametrize("total,nnz,budget", DETERMINISTIC_CASES)
def test_deterministic_round_trip_cases(total, nnz, budget):
    rng = np.random.default_rng(total * 1000 + nnz)
    flat = np.zeros(total, np.uint32)
    pos = rng.choice(total, nnz, replace=False)
    flat[pos] = rng.integers(1, 2 ** 32, nnz, dtype=np.uint64).astype(
        np.uint32)
    _round_trip_case(flat, budget)


def test_round_trip_multi_dim_and_u64():
    """The codec flattens row-major and preserves dtype — including the
    uint64 lane words of the LANE_WORD_BITS=64 configuration (payload
    dtype follows the input; under default x64-off jnp the payloads are
    uint32, so craft the case with uint32 to stay width-agnostic)."""
    rng = np.random.default_rng(7)
    arr = np.where(rng.random((8, 3)) < 0.2,
                   rng.integers(1, 2 ** 32, (8, 3), dtype=np.uint64),
                   0).astype(np.uint32)
    budget = sparse_budget(24)
    idx, payload, count = compress_words(jnp.asarray(arr), budget)
    out = np.asarray(decompress_words(idx, payload, 24)).reshape(8, 3)
    if int(count) <= budget:
        np.testing.assert_array_equal(out, arr)


def test_sparse_budget_rule():
    assert sparse_budget(16) == 4
    assert sparse_budget(1) == 1            # never zero slots
    assert sparse_budget(100, 0.5) == 50
    assert sparse_budget(3) == 1
    assert DENSE_THRESHOLD == 0.25
    with pytest.raises(ValueError):
        sparse_budget(0)


def test_compress_words_budget_validation():
    with pytest.raises(ValueError):
        compress_words(jnp.zeros((4,), jnp.uint32), 0)
    with pytest.raises(ValueError):
        compress_words(jnp.zeros((4,), jnp.uint32), 5)


def test_wire_bytes_traced_matches_python():
    """Traced and host paths of wire_bytes agree on both switch sides."""
    for count, budget in ((3, 4), (5, 4)):
        host = wire_bytes(count, 16, budget, 4)
        traced = int(wire_bytes(jnp.int32(count), 16, budget, 4))
        assert host == traced
