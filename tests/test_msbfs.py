"""Bit-packed MS-BFS parity: 64 packed roots == 64 serial ``bfs`` runs.

Parents use the same deterministic min-id rule as the serial steps, so the
comparison is exact array equality on parent AND depth, plus Graph500
validator equivalence. Ring/star fixtures exercise lanes that terminate at
different layers; the lane-word sweep covers R below/at/above one word.
"""
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import msbfs as ms
from repro.core import packed
from repro.core.csr import from_edges, to_numpy_adj
from repro.core.hybrid import bfs
from repro.core.msbfs import (msbfs, msbfs_engine_enqueue, msbfs_engine_idle,
                              msbfs_engine_init, msbfs_engine_result,
                              msbfs_engine_step, msbfs_pipelined, pack_lanes,
                              segment_or, unpack_lanes)
from repro.core.ref import bfs_reference
from repro.graph.generator import rmat_graph, sample_roots
from repro.graph.validate import validate_bfs_tree
from repro.kernels import msbfs_probe_pallas, msbfs_probe_ref


@pytest.fixture(scope="module")
def g_rmat():
    return rmat_graph(10, 16, seed=0)


def ring_graph(n):
    v = np.arange(n)
    return from_edges(v, (v + 1) % n, n)


def star_graph(n):
    leaves = np.arange(1, n)
    return from_edges(np.zeros(n - 1, np.int64), leaves, n)


def _assert_lanes_match_serial(g, roots, out, mode="hybrid"):
    rp, ci = to_numpy_adj(g)
    for r_i, root in enumerate(roots):
        pref, dref = bfs_reference(rp, ci, int(root))
        np.testing.assert_array_equal(np.asarray(out.depth[:, r_i]), dref,
                                      err_msg=f"lane {r_i} depth")
        np.testing.assert_array_equal(np.asarray(out.parent[:, r_i]), pref,
                                      err_msg=f"lane {r_i} parent")
        validate_bfs_tree(rp, ci, np.asarray(out.parent[:, r_i]), int(root))


@pytest.mark.parametrize("mode", ["hybrid", "topdown", "bottomup"])
def test_msbfs_matches_serial_rmat(g_rmat, mode):
    """Full 64-lane batch on R-MAT == 64 serial runs, all controller modes."""
    roots = sample_roots(g_rmat, 64, seed=1)
    out = msbfs(g_rmat, jnp.asarray(roots), mode)
    _assert_lanes_match_serial(g_rmat, roots, out, mode)


@pytest.mark.parametrize("num_roots", [1, 5, 32, 33])
def test_msbfs_lane_word_sweep(g_rmat, num_roots):
    """R below / at / above one 32-bit lane word."""
    roots = sample_roots(g_rmat, num_roots, seed=2)
    out = msbfs(g_rmat, jnp.asarray(roots), "hybrid")
    assert out.parent.shape == (g_rmat.n, num_roots)
    _assert_lanes_match_serial(g_rmat, roots, out)


def test_msbfs_lanes_terminate_at_different_layers():
    """Star (eccentricity 1-2) and ring (eccentricity n/2) lanes packed in
    one batch: per-lane num_layers must match the serial loop count even
    though the sweep keeps running for the deepest lane."""
    n = 48
    ring = ring_graph(n)
    roots = np.array([0, 1, n // 2, n - 1])
    out = msbfs(ring, jnp.asarray(roots), "hybrid")
    _assert_lanes_match_serial(ring, roots, out)
    for r_i, root in enumerate(roots):
        s = bfs(ring, int(root), "hybrid")
        assert int(out.num_layers[r_i]) == int(s.num_layers)
        assert int(out.edges_traversed[r_i]) == int(s.edges_traversed)

    star = star_graph(n)
    roots = np.array([0, 1, 2, n - 1])     # center lane ends 2 layers early
    out = msbfs(star, jnp.asarray(roots), "hybrid")
    _assert_lanes_match_serial(star, roots, out)
    layers = [int(x) for x in out.num_layers]
    assert layers[0] < layers[1], "center lane must terminate first"
    # idle lanes show -1 in the trace once their frontier empties
    dirs = np.asarray(out.trace_dir)
    assert (dirs[layers[0]:layers[1], 0] == -1).all()
    assert (dirs[:layers[1] - 1, 1] != -1).all()


def test_msbfs_per_lane_trace_matches_serial(g_rmat):
    """Per-lane switching replays the serial alpha/beta decisions: the
    lane's TD/BU trace equals the serial trace for the same root."""
    roots = sample_roots(g_rmat, 8, seed=3)
    out = msbfs(g_rmat, jnp.asarray(roots), "hybrid")
    for r_i, root in enumerate(roots):
        s = bfs(g_rmat, int(root), "hybrid")
        nl = int(s.num_layers)
        np.testing.assert_array_equal(
            np.asarray(out.trace_dir[:nl, r_i]),
            np.asarray(s.trace_dir[:nl]), err_msg=f"lane {r_i} trace_dir")
        np.testing.assert_array_equal(np.asarray(out.trace_vf[:nl, r_i]),
                                      np.asarray(s.trace_vf[:nl]))
        np.testing.assert_array_equal(np.asarray(out.trace_ef[:nl, r_i]),
                                      np.asarray(s.trace_ef[:nl]))
        np.testing.assert_array_equal(np.asarray(out.trace_eu[:nl, r_i]),
                                      np.asarray(s.trace_eu[:nl]))


def test_msbfs_pallas_probe_end_to_end(g_rmat):
    # runs at either LANE_WORD_BITS: 64-bit words take the kernel's u64
    # gather path (hi/lo uint32 half-planes) — the tier1-u64 CI leg
    # exercises this test with zero skips
    roots = sample_roots(g_rmat, 40, seed=4)
    out = msbfs(g_rmat, jnp.asarray(roots), "hybrid", 14.0, 24.0, 8,
                "pallas")
    _assert_lanes_match_serial(g_rmat, roots, out)


@contextmanager
def lane_word_bits(bits):
    """Run packed-word code under a different ``packed.LANE_WORD_BITS`` —
    the single knob of the ROADMAP uint64-lane rung. The packed helpers
    read the constant (and derive the word dtype) at call time, so the
    swap is a plain module-global override; 64-bit words additionally
    need jax x64 (without it jnp silently downcasts uint64 to uint32)."""
    old = packed.LANE_WORD_BITS
    packed.LANE_WORD_BITS = bits
    try:
        if bits == 64:
            with jax.experimental.enable_x64():
                yield
        else:
            yield
    finally:
        packed.LANE_WORD_BITS = old


def test_word_dtype_x64_guard_names_fix():
    """64-bit lane words without jax x64 must fail loudly (a silent
    uint64->uint32 downcast drops lanes 32-63), and the error must NAME
    the fix — the exact config call to run."""
    old = packed.LANE_WORD_BITS
    packed.LANE_WORD_BITS = 64
    try:
        with jax.experimental.disable_x64():
            with pytest.raises(RuntimeError) as exc:
                packed.word_dtype()
    finally:
        packed.LANE_WORD_BITS = old
    msg = str(exc.value)
    assert 'jax.config.update("jax_enable_x64", True)' in msg
    assert "JAX_ENABLE_X64" in msg


@pytest.mark.parametrize("bits", [32, 64])
def test_pack_unpack_roundtrip(bits):
    with lane_word_bits(bits):
        rng = np.random.default_rng(0)
        for r in (1, bits - 1, bits, bits + 1, 2 * bits):
            mask = jnp.asarray(rng.random((17, r)) < 0.5)
            words = pack_lanes(mask)
            assert words.dtype == packed.word_dtype()
            assert words.shape == (17, packed.num_lane_words(r))
            np.testing.assert_array_equal(np.asarray(unpack_lanes(words, r)),
                                          np.asarray(mask))


@pytest.mark.parametrize("bits", [32, 64])
def test_pack_lanes_top_bit(bits):
    """Lane ``bits - 1`` must land in the word's MSB — the first bit a
    32-bit-assuming shift would lose at 64-bit words."""
    with lane_word_bits(bits):
        mask = jnp.zeros((3, bits), jnp.bool_).at[1, bits - 1].set(True)
        words = pack_lanes(mask)
        assert words.shape == (3, 1)
        expect = np.zeros((3, 1), np.uint64)
        expect[1, 0] = np.uint64(1) << np.uint64(bits - 1)
        np.testing.assert_array_equal(np.asarray(words).astype(np.uint64),
                                      expect)


@pytest.mark.parametrize("bits", [32, 64])
def test_segment_or_with_empty_and_trailing_rows(bits):
    """Empty rows (including trailing ones, whose row start == m) OR to 0
    and must not corrupt their neighbours' segments — at either lane-word
    width (the 64-bit values exercise bits a uint32 pipeline would
    truncate)."""
    with lane_word_bits(bits):
        dt = np.uint64 if bits == 64 else np.uint32
        hi = 1 << (bits - 1)
        # rows: [a, b], [], [c], [] -> row_ptr [0, 2, 2, 3, 3]
        row_ptr = jnp.asarray([0, 2, 2, 3, 3], jnp.int32)
        vals = jnp.asarray(np.asarray([[1], [4 + hi], [8]], dt))
        out = np.asarray(segment_or(vals, row_ptr))
        np.testing.assert_array_equal(
            out, np.asarray([[5 + hi], [0], [8], [0]], dt))


@pytest.mark.parametrize("bits", [32, 64])
def test_depth_slice_words_roundtrip(bits):
    """depth_slice_words repacks depth bands into the engines' bit layout
    for any word width (the k-hop read-out surface)."""
    with lane_word_bits(bits):
        rng = np.random.default_rng(1)
        r = bits + 3                       # spill into a second word
        depth = jnp.asarray(rng.integers(-1, 5, size=(29, r)), jnp.int32)
        words = packed.depth_slice_words(depth, 2)
        assert words.dtype == packed.word_dtype()
        assert words.shape == (29, packed.num_lane_words(r))
        band = (np.asarray(depth) >= 0) & (np.asarray(depth) <= 2)
        np.testing.assert_array_equal(np.asarray(unpack_lanes(words, r)),
                                      band)
        layer1 = packed.depth_slice_words(depth, 1, min_depth=1)
        np.testing.assert_array_equal(
            np.asarray(unpack_lanes(layer1, r)), np.asarray(depth) == 1)


@pytest.mark.parametrize("scale,ef,seed", [(8, 4, 0), (9, 8, 1), (7, 32, 2)])
@pytest.mark.parametrize("max_pos", [1, 8])
def test_msbfs_probe_kernel_vs_ref(scale, ef, seed, max_pos):
    g = rmat_graph(scale, ef, seed=seed)
    rng = np.random.default_rng(seed)
    fro = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    need = jnp.asarray(rng.integers(0, 2 ** 32, g.n, dtype=np.uint32))
    a1 = msbfs_probe_pallas(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                            max_pos=max_pos, interpret=True)
    a2 = msbfs_probe_ref(g.row_ptr[:-1], g.deg, need, g.col_idx, fro,
                         max_pos=max_pos)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_msbfs_rejects_bad_batches(g_rmat):
    with pytest.raises(ValueError, match="at most"):
        msbfs(g_rmat, jnp.zeros((65,), jnp.int32))
    with pytest.raises(ValueError, match="mode"):
        msbfs(g_rmat, jnp.zeros((2,), jnp.int32), "sideways")
    with pytest.raises(ValueError, match="mode"):
        msbfs_pipelined(g_rmat, jnp.zeros((2,), jnp.int32), "sideways")
    with pytest.raises(ValueError, match="at least one root"):
        msbfs_pipelined(g_rmat, jnp.zeros((0,), jnp.int32))


# --------------------------- pipelined engine ---------------------------


@pytest.mark.parametrize("num_roots,lanes", [(96, 64), (20, 8), (7, 32)])
def test_pipelined_matches_serial_beyond_lane_pool(g_rmat, num_roots, lanes):
    """R above / below the lane pool: refilled lanes replay serial runs."""
    roots = sample_roots(g_rmat, num_roots, seed=11)
    out = msbfs_pipelined(g_rmat, jnp.asarray(roots), "hybrid", lanes=lanes)
    assert out.parent.shape == (g_rmat.n, num_roots)
    _assert_lanes_match_serial(g_rmat, roots, out)


def test_pipelined_equals_single_batch_sweep(g_rmat):
    """Same roots through both engines: bit-for-bit identical results,
    including per-root traces (lane refill must not perturb a root's
    switching decisions)."""
    roots = jnp.asarray(sample_roots(g_rmat, 40, seed=12))
    a = msbfs(g_rmat, roots, "hybrid")
    b = msbfs_pipelined(g_rmat, roots, "hybrid", lanes=16)
    for name in MSBFSResult_fields():
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def MSBFSResult_fields():
    return ("parent", "depth", "num_layers", "edges_traversed", "trace_dir",
            "trace_vf", "trace_ef", "trace_eu")


@pytest.mark.parametrize("mode", ["topdown", "bottomup"])
def test_pipelined_forced_modes(g_rmat, mode):
    roots = sample_roots(g_rmat, 70, seed=13)
    out = msbfs_pipelined(g_rmat, jnp.asarray(roots), mode, lanes=64)
    _assert_lanes_match_serial(g_rmat, roots, out, mode)


def test_pipelined_pallas_probe(g_rmat):
    """R > MAX_LANES through the W-parametric Pallas probe kernel (at
    64-bit lane words this is the u64 gather path, W half-plane pairs)."""
    roots = sample_roots(g_rmat, 72, seed=14)
    out = msbfs_pipelined(g_rmat, jnp.asarray(roots), "hybrid",
                          probe_impl="pallas", lanes=64)
    _assert_lanes_match_serial(g_rmat, roots, out)


def test_pipelined_sweep_is_shorter_than_batch_sum():
    """The refill pipeline's whole point: mixing deep (ring) and shallow
    (star) roots, total engine layers must beat the barriered word-batch
    schedule (each batch waits for its deepest lane)."""
    n = 96
    v = np.arange(n)
    ring_edges = (v, (v + 1) % n)
    star_src = np.full(n - 2, n, np.int64)
    g = from_edges(np.concatenate([ring_edges[0], star_src]),
                   np.concatenate([ring_edges[1],
                                   np.arange(1, n - 1) + n]),
                   2 * n)
    # 2 lanes, 4 roots: lane pool must process [deep, shallow, shallow,
    # shallow]; pipelining lets the shallow lane chew through queue while
    # the ring lane is still going
    roots = jnp.asarray([0, n, n + 1, n + 2], jnp.int32)
    state = msbfs_engine_init(g, capacity=4, lanes=2)
    state = msbfs_engine_enqueue(state, roots)
    layers = 0
    while not msbfs_engine_idle(state):
        state = msbfs_engine_step(g, state, "hybrid")
        layers += 1
    deep = int(bfs(g, 0, "hybrid").num_layers)
    sh = [int(bfs(g, int(r), "hybrid").num_layers) for r in roots[1:]]
    # barriered word-batches of 2: (deep | sh0) then (sh1 | sh2)
    barriered = max(deep, sh[0]) + max(sh[1], sh[2])
    assert layers < barriered, (layers, barriered)
    # refill keeps lane 2 busy back-to-back while lane 1 walks the ring:
    # total layers = the longer of the two lane schedules, no bubbles
    assert layers == max(deep, sum(sh)), (layers, deep, sh)


def test_streaming_enqueue_mid_sweep(g_rmat):
    """Roots enqueued WHILE the sweep runs land in idle lanes and finish
    validator-clean — the serve_bfs serving loop in miniature."""
    roots = sample_roots(g_rmat, 24, seed=15)
    state = msbfs_engine_init(g_rmat, capacity=24, lanes=8)
    state = msbfs_engine_enqueue(state, roots[:8])
    fed, steps = 8, 0
    while fed < 24 or not msbfs_engine_idle(state):
        state = msbfs_engine_step(g_rmat, state, "hybrid")
        steps += 1
        if steps % 2 == 0 and fed < 24:
            state = msbfs_engine_enqueue(state, roots[fed:fed + 4])
            fed += 4
    out = msbfs_engine_result(g_rmat, state)
    _assert_lanes_match_serial(g_rmat, roots, out)
    assert (np.asarray(state.out_layers[:24]) > 0).all()


def test_engines_agree_on_multi_component_traces():
    """A lane that finishes early (small component) must leave its unused
    trace rows at init values in BOTH engines — the single-batch sweep
    keeps looping for deeper lanes, but dead lanes record nothing."""
    # path 0-..-5, star at 10-15, plus an unreached blob 20-23
    src = np.concatenate([np.arange(5), np.full(5, 10), np.arange(20, 23)])
    dst = np.concatenate([np.arange(1, 6), np.arange(11, 16),
                          np.arange(21, 24)])
    g = from_edges(src, dst, 24)
    roots = jnp.asarray([0, 10], jnp.int32)
    a = msbfs(g, roots, "hybrid")
    b = msbfs_pipelined(g, roots, "hybrid", lanes=2)
    for name in MSBFSResult_fields():
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)
    # star lane (num_layers 2-3) leaves later rows untouched
    nl = int(a.num_layers[1])
    assert (np.asarray(a.trace_eu)[nl:, 1] == 0).all()
    assert (np.asarray(a.trace_dir)[nl:, 1] == -1).all()


def test_engines_agree_at_max_trace_cap():
    """Component diameter >= MAX_TRACE: both engines cap num_layers at
    MAX_TRACE (the serial loop bound) with identical truncated depths."""
    n = ms.MAX_TRACE + 10
    v = np.arange(n - 1)
    g = from_edges(v, v + 1, n)          # path graph, diameter n-1 > cap
    roots = jnp.asarray([0], jnp.int32)
    a = msbfs(g, roots, "topdown")
    b = msbfs_pipelined(g, roots, "topdown", lanes=1)
    s = bfs(g, 0, "topdown")
    assert int(a.num_layers[0]) == int(b.num_layers[0]) \
        == int(s.num_layers) == ms.MAX_TRACE
    np.testing.assert_array_equal(np.asarray(a.depth[:, 0]),
                                  np.asarray(s.depth))
    np.testing.assert_array_equal(np.asarray(b.depth[:, 0]),
                                  np.asarray(s.depth))


def test_engine_result_on_fresh_engine_is_empty(g_rmat):
    state = msbfs_engine_init(g_rmat, capacity=4, lanes=2)
    out = msbfs_engine_result(g_rmat, state)
    assert out.parent.shape == (g_rmat.n, 0)
    assert out.depth.shape == (g_rmat.n, 0)
    assert out.num_layers.shape == (0,)


def test_engine_queue_overflow_and_init_guards(g_rmat):
    state = msbfs_engine_init(g_rmat, capacity=4, lanes=2)
    state = msbfs_engine_enqueue(state, jnp.zeros((4,), jnp.int32))
    with pytest.raises(ValueError, match="overflow"):
        msbfs_engine_enqueue(state, jnp.zeros((1,), jnp.int32))
    with pytest.raises(ValueError, match="capacity"):
        msbfs_engine_init(g_rmat, capacity=0)
    with pytest.raises(ValueError, match="lanes"):
        msbfs_engine_init(g_rmat, capacity=4, lanes=0)
