"""Semiring traversal subsystem: abstraction, kernel parity, SSSP anchors.

Three layers of pinning:

* the ``Semiring`` step primitives against hand oracles and against the
  PACKED engine's own formulations (boolean semiring == unpacked
  top-down step — the generic path must reproduce the bit engines);
* the ``semiring_relax`` Pallas kernel against its pure-jnp ref across a
  lane-count/MAX_POS/shape sweep (including the distributed local-block
  shape);
* the delta-stepping engine against Dijkstra, and — the hard anchor —
  unit-weight SSSP bit-identical (depths, reached sets) to
  ``msbfs_pipelined`` on the same roots.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (ClosenessQuery, LaneEngine, SSSPQuery,
                             WeightedClosenessQuery, run_query,
                             sssp_distances, weighted_closeness_centrality)
from repro.core.csr import from_weighted_edges
from repro.core.msbfs import msbfs_pipelined
from repro.core.packed import pack_lanes, topdown_packed_step, unpack_lanes
from repro.graph.generator import (rmat_graph, rmat_weighted_graph,
                                   sample_roots,
                                   uniform_random_weighted_graph)
from repro.kernels import semiring_relax_pallas, semiring_relax_ref
from repro.traversal import (BOOLEAN, PLUS_TIMES, TROPICAL, default_delta,
                             dijkstra_reference, segment_reduce,
                             semiring_spmv, sssp_engine_drain,
                             sssp_engine_enqueue, sssp_engine_idle,
                             sssp_engine_init, sssp_engine_result,
                             sssp_engine_step, sssp_pipelined,
                             to_numpy_weighted, tropical_relax)


@pytest.fixture(scope="module")
def wg_rmat():
    return rmat_weighted_graph(8, 8, seed=0)


def _assert_dist_matches_dijkstra(wg, roots, dist, atol=1e-4):
    rp, ci, w = to_numpy_weighted(wg)
    for i, r in enumerate(np.asarray(roots)):
        ref = dijkstra_reference(rp, ci, w, int(r))
        got = np.asarray(dist[:, i], np.float64)
        np.testing.assert_array_equal(np.isfinite(got), np.isfinite(ref),
                                      err_msg=f"lane {i} reached set")
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[fin], ref[fin], atol=atol,
                                   err_msg=f"lane {i} distances (root {r})")


# ---------------------------------------------------------------------------
# Semiring primitives
# ---------------------------------------------------------------------------


def test_segment_reduce_tropical_hand_case():
    """Rows [a,b], [], [c], [] — min per row, inf for empty rows
    (including trailing ones whose start == m)."""
    row_ptr = jnp.asarray([0, 2, 2, 3, 3], jnp.int32)
    vals = jnp.asarray([[3.0], [1.5], [7.0]], jnp.float32)
    out = np.asarray(segment_reduce(vals, row_ptr, TROPICAL))
    np.testing.assert_array_equal(
        out, np.asarray([[1.5], [np.inf], [7.0], [np.inf]], np.float32))


def test_segment_reduce_plus_times_hand_case():
    row_ptr = jnp.asarray([0, 2, 2, 3], jnp.int32)
    vals = jnp.asarray([[3.0], [1.5], [7.0]], jnp.float32)
    out = np.asarray(segment_reduce(vals, row_ptr, PLUS_TIMES))
    np.testing.assert_array_equal(
        out, np.asarray([[4.5], [0.0], [7.0]], np.float32))


def test_boolean_spmv_matches_packed_topdown_step():
    """The boolean-semiring SpMV IS the packed top-down expansion: dense
    0/1 lanes through the generic path == unpacked engine words."""
    g = rmat_graph(7, 6, seed=3)
    rng = np.random.default_rng(3)
    lanes = 5
    fro = rng.random((g.n, lanes)) < 0.2
    dense = semiring_spmv(g, jnp.asarray(fro, jnp.uint8), None, BOOLEAN)

    words = pack_lanes(jnp.asarray(fro))
    sel = pack_lanes(jnp.ones((lanes,), jnp.bool_))
    packed_new = topdown_packed_step(g, words, jnp.zeros_like(words), sel)
    np.testing.assert_array_equal(
        np.asarray(dense, bool),
        np.asarray(unpack_lanes(packed_new, lanes)))


def test_plus_times_spmv_matches_dense_matmul():
    wg = uniform_random_weighted_graph(60, 240, seed=4)
    rng = np.random.default_rng(4)
    x = rng.random((wg.n, 3)).astype(np.float32)
    out = semiring_spmv(wg.csr, jnp.asarray(x), wg.weights, PLUS_TIMES)
    # dense weighted adjacency oracle: A[v, u] = sum of parallel weights
    a = np.zeros((wg.n, wg.n), np.float64)
    rp, ci, w = to_numpy_weighted(wg)
    for v in range(wg.n):
        for e in range(rp[v], rp[v + 1]):
            a[v, ci[e]] += w[e]
    np.testing.assert_allclose(np.asarray(out), a @ x, rtol=1e-5, atol=1e-5)


def test_tropical_relax_pallas_equals_xla():
    """Full relax contract (probe + deep-row fallback) agrees between the
    edge-parallel scan and the kernel path, at a max_pos small enough
    that the fallback must fire."""
    wg = uniform_random_weighted_graph(90, 500, seed=5)
    rng = np.random.default_rng(5)
    vals = rng.uniform(0, 4, (wg.n, 4)).astype(np.float32)
    vals[rng.random((wg.n, 4)) < 0.4] = np.inf
    v = jnp.asarray(vals)
    assert int(np.asarray(wg.deg).max()) > 2   # fallback genuinely fires
    a_xla = tropical_relax(wg.csr, wg.weights, v, max_pos=2, impl="xla")
    a_pal = tropical_relax(wg.csr, wg.weights, v, max_pos=2, impl="pallas")
    np.testing.assert_allclose(np.asarray(a_xla), np.asarray(a_pal),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# semiring_relax kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lanes", [1, 3, 8])
@pytest.mark.parametrize("max_pos", [1, 4, 8])
def test_semiring_relax_kernel_lane_sweep(lanes, max_pos):
    """Kernel vs oracle over the lane-grid dimension and MAX_POS, with
    inf-masked sources (the delta-stepping phase encoding)."""
    wg = uniform_random_weighted_graph(300, 1500, seed=lanes * 10 + max_pos)
    rng = np.random.default_rng(lanes * 100 + max_pos)
    vals = rng.uniform(0, 8, (wg.n, lanes)).astype(np.float32)
    vals[rng.random((wg.n, lanes)) < 0.35] = np.inf
    v = jnp.asarray(vals)
    a1 = semiring_relax_pallas(wg.row_ptr[:-1], wg.deg, wg.col_idx,
                               wg.weights, v, max_pos=max_pos,
                               interpret=True)
    a2 = semiring_relax_ref(wg.row_ptr[:-1], wg.deg, wg.col_idx,
                            wg.weights, v, max_pos=max_pos)
    assert a1.shape == (wg.n, lanes)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_semiring_relax_local_block_full_values():
    """Distributed shape: rows cover a LOCAL block, values the full
    vertex range, col_idx global ids — kernel == oracle (what a future
    sharded SSSP feeds the kernel under shard_map)."""
    g = rmat_graph(8, 6, seed=7)
    from repro.core.dist_bfs import partition_graph
    dg = partition_graph(g, 2)
    rng = np.random.default_rng(7)
    vals = jnp.asarray(rng.uniform(0, 5, (dg.n, 3)).astype(np.float32))
    for d in range(2):
        row_ptr = dg.row_ptr[d]
        starts, deg = row_ptr[:-1], row_ptr[1:] - row_ptr[:-1]
        w = jnp.asarray(
            rng.uniform(0, 1, dg.col_idx[d].shape[0]).astype(np.float32))
        a1 = semiring_relax_pallas(starts, deg, dg.col_idx[d], w, vals,
                                   max_pos=4, interpret=True)
        a2 = semiring_relax_ref(starts, deg, dg.col_idx[d], w, vals,
                                max_pos=4)
        assert a1.shape == (dg.n // 2, 3)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_semiring_relax_flat_plane_compat():
    """float32[n] single planes round-trip (L=1 fast path)."""
    wg = uniform_random_weighted_graph(120, 500, seed=9)
    rng = np.random.default_rng(9)
    v = jnp.asarray(rng.uniform(0, 3, wg.n).astype(np.float32))
    a1 = semiring_relax_pallas(wg.row_ptr[:-1], wg.deg, wg.col_idx,
                               wg.weights, v, max_pos=4, interpret=True)
    a2 = semiring_relax_ref(wg.row_ptr[:-1], wg.deg, wg.col_idx,
                            wg.weights, v, max_pos=4)
    assert a1.shape == (wg.n,)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


# ---------------------------------------------------------------------------
# Weighted CSR construction
# ---------------------------------------------------------------------------


def test_weighted_csr_symmetric_weights():
    """Symmetrization carries the SAME weight both ways."""
    wg = from_weighted_edges(np.asarray([0, 1]), np.asarray([1, 2]),
                             np.asarray([0.5, 2.0]), 3)
    rp, ci, w = to_numpy_weighted(wg)
    lut = {(u, v): wt for u, v, wt in
           zip(np.asarray(wg.src_idx), ci, w)}
    assert lut[(0, 1)] == lut[(1, 0)] == 0.5
    assert lut[(1, 2)] == lut[(2, 1)] == 2.0


def test_weighted_csr_dedup_keeps_min_weight():
    wg = from_weighted_edges(np.asarray([0, 0, 0]), np.asarray([1, 1, 1]),
                             np.asarray([3.0, 1.0, 2.0]), 2, dedup=True)
    assert wg.m == 2      # one edge each way
    np.testing.assert_array_equal(np.asarray(wg.weights), [1.0, 1.0])


def test_weighted_csr_rejects_negative_and_nan_weights():
    with pytest.raises(ValueError, match="invalid edge weight"):
        from_weighted_edges(np.asarray([0]), np.asarray([1]),
                            np.asarray([-0.5]), 2)
    # NaN fails both orderings — a min() < 0 guard would let it through
    with pytest.raises(ValueError, match="invalid edge weight"):
        from_weighted_edges(np.asarray([0]), np.asarray([1]),
                            np.asarray([np.nan]), 2)
    # +inf passes a sign check but would make default_delta inf
    with pytest.raises(ValueError, match="invalid edge weight"):
        from_weighted_edges(np.asarray([0]), np.asarray([1]),
                            np.asarray([np.inf]), 2)


def test_engine_caps_pinned_bit_pool_for_dense_lanes(wg_rmat):
    """A pinned 256-bit-lane pool must NOT become 256 dense float lanes."""
    from repro.traversal.sssp import DEFAULT_LANES
    eng = LaneEngine(wg_rmat, lanes=256)
    assert eng.sssp_lanes_for(300) == DEFAULT_LANES
    assert eng.sssp_lanes_for(4) == 4
    narrow = LaneEngine(wg_rmat, lanes=8)
    assert narrow.sssp_lanes_for(300) == 8


def test_rmat_weighted_topology_matches_unweighted():
    """Same (scale, seed) -> the weighted graph's CSR is bit-identical to
    ``rmat_graph``'s (weights ride alongside, never perturb topology)."""
    g = rmat_graph(7, 4, seed=2)
    wg = rmat_weighted_graph(7, 4, seed=2)
    np.testing.assert_array_equal(np.asarray(g.row_ptr),
                                  np.asarray(wg.row_ptr))
    np.testing.assert_array_equal(np.asarray(g.col_idx),
                                  np.asarray(wg.col_idx))
    assert wg.weights.shape == (wg.m,)
    assert float(np.asarray(wg.weights).min()) >= 0.0


# ---------------------------------------------------------------------------
# Delta-stepping engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relax_impl", ["xla", "pallas"])
def test_sssp_matches_dijkstra(wg_rmat, relax_impl):
    roots = sample_roots(wg_rmat, 8, seed=1)
    res = sssp_pipelined(wg_rmat, roots, lanes=4, relax_impl=relax_impl)
    _assert_dist_matches_dijkstra(wg_rmat, roots, res.dist)


@pytest.mark.parametrize("delta", [0.02, 0.3, 50.0])
def test_sssp_delta_sweep(wg_rmat, delta):
    """Any positive bucket width gives the same shortest paths — tiny
    deltas make every edge heavy (Dijkstra-like bucket walk), huge ones
    make every edge light (Bellman-Ford iteration)."""
    roots = sample_roots(wg_rmat, 4, seed=2)
    res = sssp_pipelined(wg_rmat, roots, delta=delta, lanes=2)
    _assert_dist_matches_dijkstra(wg_rmat, roots, res.dist)


def test_sssp_unit_weight_bit_identical_to_msbfs(wg_rmat):
    """THE boolean-semiring anchor: unit-weight SSSP == MS-BFS, depths
    and reached sets bit-for-bit, pipelining (lanes < R) included."""
    rp, ci, _ = to_numpy_weighted(wg_rmat)
    unit = from_weighted_edges(
        np.asarray(wg_rmat.src_idx), ci, np.ones(wg_rmat.m), wg_rmat.n,
        symmetrize=False, drop_self_loops=False)
    roots = sample_roots(unit, 12, seed=3)
    res = sssp_pipelined(unit, roots, delta=1.0, lanes=4)
    mres = msbfs_pipelined(unit.csr, jnp.asarray(roots, jnp.int32),
                           "hybrid", lanes=32)
    np.testing.assert_array_equal(np.asarray(res.as_depth()),
                                  np.asarray(mres.depth))
    np.testing.assert_array_equal(np.asarray(res.reached()),
                                  np.asarray(mres.depth) >= 0)


def test_sssp_streaming_enqueue_mid_sweep(wg_rmat):
    """The pipelined pattern: sources enqueued while lanes are mid-flight
    land in idle lanes and answer identically to a one-shot drain."""
    roots = sample_roots(wg_rmat, 6, seed=4)
    delta = default_delta(wg_rmat)
    state = sssp_engine_init(wg_rmat, capacity=len(roots), lanes=2)
    state = sssp_engine_enqueue(state, roots[:3])
    for _ in range(3):                       # mid-sweep by construction
        state = sssp_engine_step(wg_rmat, state, delta)
    assert not sssp_engine_idle(state)
    state = sssp_engine_enqueue(state, roots[3:])
    state = sssp_engine_drain(wg_rmat, state, delta)
    assert sssp_engine_idle(state)
    out = sssp_engine_result(state)
    one_shot = sssp_pipelined(wg_rmat, roots, delta=delta, lanes=2)
    np.testing.assert_array_equal(np.asarray(out.dist),
                                  np.asarray(one_shot.dist))
    _assert_dist_matches_dijkstra(wg_rmat, roots, out.dist)


def test_sssp_zero_weight_edges():
    """Zero-weight edges collapse distances within the light fixpoint."""
    # path 0-1-2-3 with a zero-weight shortcut 0-2
    wg = from_weighted_edges(np.asarray([0, 1, 2, 0]),
                             np.asarray([1, 2, 3, 2]),
                             np.asarray([1.0, 1.0, 1.0, 0.0]), 5)
    res = sssp_pipelined(wg, [0], delta=0.5)
    got = np.asarray(res.dist[:, 0])
    np.testing.assert_allclose(got[:4], [0.0, 1.0, 0.0, 1.0], atol=1e-6)
    assert not np.isfinite(got[4])           # isolated vertex unreached


def test_sssp_rejects_bad_delta(wg_rmat):
    with pytest.raises(ValueError, match="delta"):
        sssp_engine_step(wg_rmat, sssp_engine_init(wg_rmat, 1), 0.0)


def test_sssp_step_cap_marks_truncated_lanes():
    """A lane flushed by the max_steps safety net must carry the
    ``truncated`` marker — its distances are partial relaxations, and
    without the bit they would be indistinguishable from an answer."""
    wg = uniform_random_weighted_graph(60, 240, seed=10)
    roots = [0, 1]
    capped = sssp_pipelined(wg, roots, delta=0.5, max_steps=2)
    assert bool(np.asarray(capped.truncated).all())
    np.testing.assert_array_equal(np.asarray(capped.steps), [2, 2])
    full = sssp_pipelined(wg, roots, delta=0.5)
    assert not bool(np.asarray(full.truncated).any())
    _assert_dist_matches_dijkstra(wg, roots, full.dist)


# ---------------------------------------------------------------------------
# Analytics + query dispatch
# ---------------------------------------------------------------------------


def test_sssp_query_dispatch(wg_rmat):
    eng = LaneEngine(wg_rmat)
    roots = tuple(int(r) for r in sample_roots(wg_rmat, 3, seed=5))
    res = run_query(eng, SSSPQuery(sources=roots))
    _assert_dist_matches_dijkstra(wg_rmat, np.asarray(roots), res.dist)
    assert res.delta == pytest.approx(default_delta(wg_rmat))
    d = res.distances_to([0, 1])
    assert d.shape == (3, 2)


def test_weighted_closeness_unit_weights_equals_hop_closeness():
    """With unit weights the weighted estimator must reproduce the
    boolean closeness exactly — same formula, same distances."""
    wg = uniform_random_weighted_graph(80, 300, seed=6)
    rp, ci, _ = to_numpy_weighted(wg)
    unit = from_weighted_edges(np.asarray(wg.src_idx), ci,
                               np.ones(wg.m), wg.n, symmetrize=False,
                               drop_self_loops=False)
    eng = LaneEngine(unit)
    cw = weighted_closeness_centrality(eng, sources=None, delta=1.0)
    cb = run_query(eng, ClosenessQuery(sources=None))
    np.testing.assert_allclose(cw.closeness, cb.closeness, rtol=1e-9)
    assert cw.meta["weighted"] is True


def test_weighted_closeness_sampled_full_coverage_reduces_to_exact():
    wg = uniform_random_weighted_graph(40, 160, seed=7)
    eng = LaneEngine(wg)
    exact = weighted_closeness_centrality(eng, sources=None)
    full = weighted_closeness_centrality(eng, sources=40)
    assert full.method == "exact"
    np.testing.assert_allclose(full.closeness, exact.closeness, rtol=1e-9)


def test_weighted_query_on_unweighted_engine_raises(wg_rmat):
    eng = LaneEngine(wg_rmat.csr)
    with pytest.raises(TypeError, match="WeightedCSRGraph"):
        run_query(eng, SSSPQuery(sources=(0,)))
    with pytest.raises(TypeError, match="WeightedCSRGraph"):
        sssp_distances(eng, [0])


def test_weighted_sweep_on_dist_engine_matches_host(wg_rmat):
    eng = LaneEngine(wg_rmat, mesh=None, ndev=1)
    assert eng.weighted
    # a mesh-backed engine used to refuse weighted sweeps; the sharded
    # delta-stepping engine now serves them bit-identically (the full
    # multi-device matrix lives in tests/test_dist_sssp.py — this pins
    # the dispatch itself on the in-process single-device mesh)
    from repro.core.dist_msbfs import host_mesh
    deng = LaneEngine(wg_rmat, mesh=host_mesh(1))
    assert deng.dwg is not None
    want = eng.sssp_sweep([0, 3, 7])
    got = deng.sssp_sweep([0, 3, 7])
    for f in ("sources", "dist", "steps", "truncated"):
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(want, f))), f


# ---------------------------------------------------------------------------
# Serving loop: sssp-tagged requests in the mixed-workload loop
# ---------------------------------------------------------------------------


def test_serve_mixed_with_sssp():
    from repro.launch.serve_bfs import Request, serve
    wg = rmat_weighted_graph(8, 8, seed=0)
    roots = sample_roots(wg, 6, seed=8)
    requests = [
        Request("bfs", np.asarray([roots[0]], np.int32)),
        Request("sssp", np.asarray([roots[1]], np.int32)),
        Request("khop", np.asarray([roots[2]], np.int32), k=2),
        Request("sssp", np.asarray([roots[3]], np.int32)),
        Request("reach", np.asarray([roots[4]], np.int32),
                target=int(roots[5])),
    ]
    stats = serve(wg, requests, lanes=8, burst=2, every=2, validate=True)
    assert stats["requests"] == 5
    assert stats["per_type"]["sssp"]["count"] == 2
    assert stats["sssp_steps"] > 0 and stats["delta"] > 0
    # each sssp answer counts exactly the Dijkstra-reachable set
    rp, ci, w = to_numpy_weighted(wg)
    for req in requests:
        if req.qtype == "sssp":
            ref = dijkstra_reference(rp, ci, w, int(req.roots[0]))
            assert req.answer["reached"] == int(np.isfinite(ref).sum())
            assert req.answer["max_dist"] == pytest.approx(
                float(ref[np.isfinite(ref)].max()), abs=1e-4)


def test_serve_sssp_only_mix():
    """An all-sssp workload runs without the packed engine existing."""
    from repro.launch.serve_bfs import Request, serve
    wg = rmat_weighted_graph(7, 6, seed=1)
    roots = sample_roots(wg, 3, seed=9)
    requests = [Request("sssp", np.asarray([r], np.int32)) for r in roots]
    stats = serve(wg, requests, lanes=4, burst=1, every=1)
    assert stats["per_type"]["sssp"]["count"] == 3
    assert stats["aggregate_mteps"] == 0.0   # no packed-engine edges


def test_serve_sssp_on_unweighted_graph_raises():
    from repro.launch.serve_bfs import Request, serve
    g = rmat_graph(7, 6, seed=1)
    req = [Request("sssp", np.asarray([0], np.int32))]
    with pytest.raises(ValueError, match="WeightedCSRGraph"):
        serve(g, req, lanes=4, burst=1, every=1)
