# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device. Multi-device tests (dist BFS, elastic) spawn
# subprocesses that set --xla_force_host_platform_device_count themselves.
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600,
                      env_extra: dict | None = None):
    """Run python code in a fresh process with N fake host devices.

    ``env_extra`` adds/overrides environment variables — the cross-width
    parity tests use it to run the same code under LANE_WORD_BITS=64 +
    JAX_ENABLE_X64=1 (both must be set BEFORE the first jax import, hence
    a fresh process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if env_extra:
        env.update({k: str(v) for k, v in env_extra.items()})
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
