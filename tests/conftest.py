# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device. Multi-device tests (dist BFS, elastic) spawn
# subprocesses that set --xla_force_host_platform_device_count themselves.
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
