"""Property-based MS-BFS suite: every packed lane is validator-clean.

Randomized graphs — disconnected components, self-loops, duplicate edges,
isolated roots, star/path/complete shapes — are swept with hypothesis
(importorskip-guarded, PR 1 pattern) over BOTH engines:

  * the single-batch ``msbfs`` sweep (R <= 64), and
  * the pipelined engine with a lane pool SMALLER than the root count, so
    every example exercises queue refill mid-sweep.

Each lane must (a) pass the Graph500 spec-4 validator
(``graph.validate.validate_bfs_tree``) and (b) reproduce serial depths —
``bfs_reference`` for every lane, the jitted ``bfs()`` for a spot lane.
A deterministic fallback case set always runs (hypothesis or not) and the
hypothesis profile is derandomized (fixed seed) with bounded examples so
``make test-properties`` is reproducible in CI.

Shapes keep component diameters well under MAX_TRACE (64): the serial
controller caps layers there, and a >64-diameter component would make the
capped tree fail rule 5 by construction — a property of the cap, not a
lane-masking bug.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csr import from_edges, to_numpy_adj
from repro.core.hybrid import bfs
from repro.core.msbfs import msbfs, msbfs_pipelined
from repro.core.ref import bfs_reference
from repro.graph.validate import validate_bfs_tree

MAX_EXAMPLES = int(os.environ.get("MSBFS_PROP_EXAMPLES", "10"))

SHAPES = ("random", "star", "path", "complete", "two_components")


def build_case(n: int, m: int, seed: int, shape: str, self_loops: bool,
               dup_edges: bool):
    """Build (graph, roots) for one property example.

    Roots are drawn from ALL vertices — isolated (degree-0) roots included,
    unlike the Graph500 harness's degree>0 sampling.
    """
    rng = np.random.default_rng(seed)
    if shape == "star":
        src = np.zeros(n - 1, np.int64)
        dst = np.arange(1, n, dtype=np.int64)
    elif shape == "path":
        ln = min(n, 48)  # diameter < MAX_TRACE; leftovers stay isolated
        src = np.arange(ln - 1, dtype=np.int64)
        dst = src + 1
    elif shape == "complete":
        k = min(n, 14)
        src, dst = np.triu_indices(k, k=1)
    elif shape == "two_components":
        h = max(n // 2, 2)
        s1 = rng.integers(0, h, max(m // 2, 1))
        d1 = rng.integers(0, h, max(m // 2, 1))
        s2 = rng.integers(h, n, max(m // 2, 1)) if n > h else s1
        d2 = rng.integers(h, n, max(m // 2, 1)) if n > h else d1
        src = np.concatenate([s1, s2])
        dst = np.concatenate([d1, d2])
    else:  # random G(n, m) with repetition
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if self_loops:
        loops = rng.integers(0, n, 3)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    if dup_edges and len(src):
        take = rng.integers(0, len(src), max(len(src) // 2, 1))
        src = np.concatenate([src, src[take]])
        dst = np.concatenate([dst, dst[take]])
    g = from_edges(src, dst, n, symmetrize=True,
                   drop_self_loops=not self_loops, dedup=False)
    num_roots = min(n, int(rng.integers(2, 9)))
    roots = rng.choice(n, size=num_roots, replace=False)
    return g, roots


def _check_lanes(g, roots, out, mode="hybrid"):
    """Every lane: validator-clean tree + exact serial depth/parent."""
    rp, ci = to_numpy_adj(g)
    for i, r in enumerate(roots):
        pref, dref = bfs_reference(rp, ci, int(r))
        np.testing.assert_array_equal(np.asarray(out.depth[:, i]), dref,
                                      err_msg=f"lane {i} depth (root {r})")
        np.testing.assert_array_equal(np.asarray(out.parent[:, i]), pref,
                                      err_msg=f"lane {i} parent (root {r})")
        validate_bfs_tree(rp, ci, np.asarray(out.parent[:, i]), int(r))
    # spot-check one lane against the jitted serial controller too
    s = bfs(g, int(roots[0]), mode if mode != "bottomup" else "bottomup_simd")
    np.testing.assert_array_equal(np.asarray(out.depth[:, 0]),
                                  np.asarray(s.depth))


def _check_case(n, m, seed, shape, self_loops, dup_edges):
    g, roots = build_case(n, m, seed, shape, self_loops, dup_edges)
    roots_j = jnp.asarray(roots, jnp.int32)
    # single-batch sweep
    out = msbfs(g, roots_j, "hybrid")
    _check_lanes(g, roots, out)
    # pipelined engine with lanes < R -> queue refill is exercised
    lanes = max(1, len(roots) // 2)
    pout = msbfs_pipelined(g, roots_j, "hybrid", lanes=lanes)
    _check_lanes(g, roots, pout)
    # both engines agree bit-for-bit on results
    np.testing.assert_array_equal(np.asarray(out.depth),
                                  np.asarray(pout.depth))
    np.testing.assert_array_equal(np.asarray(out.parent),
                                  np.asarray(pout.parent))
    np.testing.assert_array_equal(np.asarray(out.num_layers),
                                  np.asarray(pout.num_layers))
    np.testing.assert_array_equal(np.asarray(out.edges_traversed),
                                  np.asarray(pout.edges_traversed))


def test_property_msbfs_random_graphs():
    """Hypothesis sweep — skipped without hypothesis (the deterministic
    fallback below pins the same invariants). Derandomized: fixed seed,
    MSBFS_PROP_EXAMPLES bounds the example count (CI sets it)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=MAX_EXAMPLES, deadline=None, derandomize=True)
    @given(st.integers(4, 90), st.integers(1, 300), st.integers(0, 10 ** 6),
           st.sampled_from(SHAPES), st.booleans(), st.booleans())
    def inner(n, m, seed, shape, self_loops, dup_edges):
        _check_case(n, m, seed, shape, self_loops, dup_edges)

    inner()


DETERMINISTIC_CASES = [
    # n, m, seed, shape, self_loops, dup_edges
    (40, 120, 0, "random", False, False),
    (33, 50, 1, "random", True, True),      # self-loops + duplicate edges
    (60, 10, 2, "random", False, False),    # sparse -> isolated roots likely
    (25, 0, 3, "star", True, False),
    (64, 0, 4, "path", False, True),        # deep lanes + isolated leftovers
    (30, 0, 5, "complete", True, True),
    (48, 80, 6, "two_components", False, False),  # disconnected components
]


@pytest.mark.parametrize("n,m,seed,shape,self_loops,dup_edges",
                         DETERMINISTIC_CASES)
def test_deterministic_property_cases(n, m, seed, shape, self_loops,
                                      dup_edges):
    """Fixed fallback case set for the property above — always runs."""
    _check_case(n, m, seed, shape, self_loops, dup_edges)


def test_isolated_root_is_validator_clean():
    """A degree-0 root's lane reaches exactly itself and validates."""
    # vertex 5 isolated: edges only among 0..4
    g = from_edges(np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]), 6)
    roots = np.array([5, 0])
    out = msbfs_pipelined(g, jnp.asarray(roots, jnp.int32), "hybrid",
                          lanes=1)
    rp, ci = to_numpy_adj(g)
    _check_lanes(g, roots, out)
    assert int(out.num_layers[0]) == 1
    assert int(out.edges_traversed[0]) == 0
    d = np.asarray(out.depth[:, 0])
    assert d[5] == 0 and (np.delete(d, 5) == -1).all()


@pytest.mark.parametrize("mode", ["topdown", "bottomup"])
def test_property_modes_deterministic(mode):
    """Forced-direction engines stay validator-clean on the fuzz shapes."""
    g, roots = build_case(36, 90, 7, "random", True, True)
    out = msbfs_pipelined(g, jnp.asarray(roots, jnp.int32), mode,
                          lanes=max(1, len(roots) // 2))
    _check_lanes(g, roots, out, mode)
